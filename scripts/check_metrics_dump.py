#!/usr/bin/env python
"""CI gate: the Prometheus export round-trips and covers the registry.

Drives a real store end to end with an enabled ObsPlane, dumps both
export formats, then asserts:

1. the Prometheus text parses with `repro.obs.parse_prometheus`
   (summary-style quantile lines, counter samples, the enabled marker);
2. every histogram site in `obs.HISTOGRAM_SITES` appears in the text —
   a site dropped from the export is invisible to a scraper even if the
   store still records it;
3. the JSON dump loads and carries the same histogram sites plus the
   counters block;
4. `ISTORE_METRICS_DUMP` names the same registry (the atexit hook path
   is exercised by running a child interpreter with the env var set).

Usage: PYTHONPATH=src python scripts/check_metrics_dump.py
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile

_HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.join(_HERE, "..")
sys.path.insert(0, os.path.join(ROOT, "src"))

import numpy as np                                        # noqa: E402

from repro.core import Clock, InfiniStore, StoreConfig    # noqa: E402
from repro.obs import (HISTOGRAM_SITES, METRIC_SITES,     # noqa: E402
                       ObsPlane, parse_prometheus)

_CHILD = """
import os, sys
import numpy as np
sys.path.insert(0, {src!r})
from repro.core import Clock, InfiniStore, StoreConfig
st = InfiniStore(StoreConfig(), clock=Clock())   # auto-plane via env
st.put("k", np.arange(2048, dtype=np.uint8))
assert st.get("k") is not None
st.close()
"""


def _drive(plane: ObsPlane) -> InfiniStore:
    st = InfiniStore(StoreConfig(obs=plane), clock=Clock())
    rng = np.random.default_rng(3)
    for i in range(6):
        st.put(f"k{i}", rng.bytes(32_000))
    assert st.flush_writeback(timeout=600.0)
    for fid in list(st.sms.slabs):               # force the COS path
        st.inject_failure(fid)
    for i in range(6):
        assert st.get(f"k{i}") is not None
    return st


def main() -> None:
    plane = ObsPlane(name="ci")
    st = _drive(plane)
    with tempfile.TemporaryDirectory(prefix="metrics-dump-") as td:
        prom_path = os.path.join(td, "metrics.prom")
        json_path = os.path.join(td, "metrics.json")
        st.dump_metrics(prom_path)
        st.dump_metrics(json_path)
        text = open(prom_path).read()
        parsed = parse_prometheus(text)
        for site in sorted(HISTOGRAM_SITES):
            name = "istore_" + site.replace(".", "_").replace("-", "_")
            assert name in text, f"site {site!r} missing from export"
            assert name in parsed and f"{name}_count" in parsed, \
                f"site {site!r} not parseable back out"
        assert parsed["istore_obs_enabled"] == {"": 1.0}
        jdump = json.load(open(json_path))
        assert set(jdump["histograms"]) == set(HISTOGRAM_SITES)
        assert jdump["counters"], "stats counters missing from JSON dump"
        assert set(jdump["sites"]) == set(METRIC_SITES)
        st.close()

        # the env-var atexit hook: a child interpreter with the dump
        # path set must leave a parseable file behind on clean exit
        env_path = os.path.join(td, "atexit.prom")
        env = dict(os.environ, ISTORE_METRICS_DUMP=env_path,
                   PYTHONPATH=os.path.join(ROOT, "src"))
        subprocess.run([sys.executable, "-c",
                        _CHILD.format(src=os.path.join(ROOT, "src"))],
                       check=True, env=env, cwd=ROOT)
        assert os.path.exists(env_path), "atexit dump never written"
        parsed_env = parse_prometheus(open(env_path).read())
        assert parsed_env["istore_obs_enabled"] == {"": 1.0}
    print(f"metrics dump gate: {len(HISTOGRAM_SITES)} histogram sites "
          f"exported, {len(parsed)} parsed samples, atexit hook OK")


if __name__ == "__main__":
    main()
