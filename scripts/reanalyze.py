"""Re-run the HLO analyzer over saved experiments/hlo/*.txt without
recompiling, refreshing the analysis fields of experiments/dryrun.jsonl
in place. Lets the roofline methodology iterate cheaply.

Usage: PYTHONPATH=src python scripts/reanalyze.py [dryrun.jsonl]
"""
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.analysis.hlo import analyze_hlo, bf16_upcast_f32_bytes  # noqa: E402

HLO_DIR = Path("experiments/hlo")


def main() -> None:
    path = Path(sys.argv[1] if len(sys.argv) > 1
                else "experiments/dryrun.jsonl")
    recs = [json.loads(l) for l in path.read_text().splitlines() if l]
    n = 0
    for rec in recs:
        if not rec.get("ok"):
            continue
        hlo = HLO_DIR / (f"{rec['arch']}_{rec['shape']}_{rec['mesh']}"
                         f"{rec.get('tag', '')}.txt")
        if not hlo.exists():
            continue
        txt = hlo.read_text()
        multi = rec["mesh"].count("x") == 2
        a = analyze_hlo(txt, pod_stride=256 if multi else 10**9)
        rec["analysis"] = a.summary()
        rec["collectives_by_op"] = {}
        for c in a.collectives:
            key = f"{c.opcode}{'_dcn' if c.dcn else ''}"
            d = rec["collectives_by_op"].setdefault(
                key, {"count": 0.0, "result_bytes": 0.0, "ring_bytes": 0.0})
            d["count"] += c.count
            d["result_bytes"] += c.result_bytes
            d["ring_bytes"] += c.ring_bytes
        upcast = bf16_upcast_f32_bytes(txt)
        rec["memory"]["f32_upcast_bytes"] = upcast
        rec["memory"]["tpu_corrected_bytes"] = max(
            rec["memory"]["total_bytes"] - upcast,
            rec["memory"]["argument_bytes"] + rec["memory"]["output_bytes"]
            - rec["memory"]["alias_bytes"])
        n += 1
    path.write_text("\n".join(json.dumps(r) for r in recs) + "\n")
    print(f"re-analyzed {n}/{len(recs)} records in {path}")


if __name__ == "__main__":
    main()
