#!/usr/bin/env bash
# One reproducible tier-1 gate: dev deps (best effort — the hypothesis
# fallback shim keeps tests runnable offline), the tier-1 pytest command
# from ROADMAP.md, and an EC-path benchmark sanity run.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install -q -r requirements-dev.txt 2>/dev/null \
    || echo "ci.sh: pip install failed (offline?); using preinstalled deps"

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
# istore-lint first: the static concurrency/invariant gate is seconds,
# so a lock-order cycle or unwaived finding fails fast before the
# multi-minute test suite runs. Zero new findings required.
python -m repro.devtools.lint src/repro
python -m pytest -x -q
python benchmarks/ec_path.py --smoke
# async PUT path exercised end-to-end (1 MB point, sync-vs-async ack)
python benchmarks/put_latency.py --smoke
# pipelined GET path end-to-end (warm/aged/degraded + prefetch scan)
python benchmarks/get_latency.py --smoke
# spill-journal overhead + kill/restart replay (crash-consistent writeback)
python benchmarks/spill_overhead.py --smoke
# sharded scale-out, thread AND process mode: fails if 4-shard thread
# aggregate PUT-ack throughput regresses below 1 shard, if either
# crash-one-shard replay (thread-mode simulated kill, process-mode REAL
# worker SIGKILL) loses an acked write, or on the CPU-aware
# process-vs-thread gate — multi-core: top process point >= 1.3x the
# same-count thread number and >= the 4-shard thread number;
# single-core: the IPC hop must keep >= 30% of same-count thread
# throughput at the process curve's best point (non-collapse, since
# one core can't parallelize) and the curve must not decay over the
# counts the box can run in parallel
# (writes BENCH_shard_smoke.json)
python benchmarks/shard_scaleout.py --smoke
# observability export gate: drives a store with an enabled ObsPlane,
# asserts the Prometheus dump parses and contains every HISTOGRAM_SITES
# name, the JSON dump mirrors the full registry, and the
# ISTORE_METRICS_DUMP atexit hook leaves a parseable file behind
python scripts/check_metrics_dump.py
# deterministic chaos soak: seeded fault schedule (COS errors/throttle,
# slab kill, torn journal tail, 2PC leader death) + full restart must
# lose zero acked writes, strand zero in-doubt tickets, and reproduce
# the identical fault log twice; idle fault plane <= 2% PUT-ack overhead.
# Also runs the network-chaos gate over the TCP transport: seeded
# net.drop/delay/dup on the PUT stream plus a net.partition that eats a
# 2PC commit frame — zero acked loss, zero stranded tickets, zero
# stale-epoch acks, and the byte-identical net fault log twice.
# Also gates the observability plane: a disabled (attached) ObsPlane
# must cost <= 2% PUT-ack overhead, and a REAL worker SIGKILL must
# leave recoverable flight-recorder forensics behind
python benchmarks/fault_soak.py --smoke
