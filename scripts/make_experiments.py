"""Render the §Dry-run and §Roofline tables of EXPERIMENTS.md from
experiments/dryrun.jsonl (between AUTOGEN markers; the rest of the file
is hand-written).

Usage: PYTHONPATH=src:. python scripts/make_experiments.py
"""
import json
import sys
from pathlib import Path

sys.path.insert(0, "src")
sys.path.insert(0, ".")

from benchmarks.roofline import load_records, terms  # noqa: E402
from repro.configs import get_config, shapes_for  # noqa: E402

OUT = Path("EXPERIMENTS.md")
MARK_DRY = ("<!-- AUTOGEN:DRYRUN -->", "<!-- /AUTOGEN:DRYRUN -->")
MARK_ROOF = ("<!-- AUTOGEN:ROOFLINE -->", "<!-- /AUTOGEN:ROOFLINE -->")


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def dryrun_table(recs):
    lines = [
        "| arch | shape | mesh | GiB/dev (CPU-measured) | GiB/dev "
        "(TPU-corrected) | fits 16G | lower+compile (s) | params |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        m = r["memory"]
        fits = "yes" if m["tpu_corrected_bytes"] <= 16e9 else "NO"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{fmt_bytes(m['total_bytes'])} | "
            f"{fmt_bytes(m['tpu_corrected_bytes'])} | {fits} | "
            f"{r['lower_s'] + r['compile_s']:.1f} | "
            f"{r['param_count'] / 1e9:.2f}B |")
    # skips
    lines.append("")
    lines.append("Skipped cells (DESIGN.md §5): "
                 + "; ".join(
                     f"`{a}`×`long_500k` (pure full attention)"
                     for a in sorted(
                         n for n in
                         ("qwen1.5-0.5b", "qwen3-1.7b", "qwen3-14b",
                          "qwen1.5-110b", "internvl2-1b",
                          "qwen2-moe-a2.7b", "granite-moe-1b-a400m",
                          "musicgen-large"))))
    return "\n".join(lines)


def collective_mix(r):
    parts = []
    for k, v in sorted(r.get("collectives_by_op", {}).items(),
                       key=lambda kv: -kv[1]["ring_bytes"])[:2]:
        parts.append(f"{k}:{v['ring_bytes'] / 1e9:.1f}GB")
    return " ".join(parts) if parts else "-"


NOTES = {
    "compute": "raise MXU occupancy: larger microbatch / fused kernels",
    "memory": "cut HBM traffic: flash-attn custom-vjp, fused norms, "
              "bf16 end-to-end",
    "collective": "reshard / overlap: change EP axis, reduce microbatch "
                  "all-gathers, overlap grads with backward",
}


def roofline_table(recs):
    lines = [
        "| arch | shape | mesh | compute (s) | memory (s) | collective "
        "(s) | dominant | MODEL_FLOPS | useful-flops ratio | roofline "
        "fraction | top collectives |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        t = terms(r)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{t['compute_s']:.2e} | {t['memory_s']:.2e} | "
            f"{t['collective_s']:.2e} | **{t['dominant']}** | "
            f"{t['model_flops']:.2e} | {t['useful_flops_ratio']:.2f} | "
            f"{t['roofline_fraction']:.3f} | {collective_mix(r)} |")
    return "\n".join(lines)


def splice(text, markers, payload):
    a, b = markers
    i, j = text.index(a) + len(a), text.index(b)
    return text[:i] + "\n" + payload + "\n" + text[j:]


def main():
    recs = load_records()
    if not recs:
        raise SystemExit("no dry-run records")
    text = OUT.read_text()
    text = splice(text, MARK_DRY, dryrun_table(recs))
    text = splice(text, MARK_ROOF, roofline_table(recs))
    OUT.write_text(text)
    print(f"EXPERIMENTS.md updated with {len(recs)} cells")


if __name__ == "__main__":
    main()
