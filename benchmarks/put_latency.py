"""PUT ack-latency benchmark: sync vs async COS writeback (§5.3.2).

Measures the PUT acknowledgement latency of `InfiniStore.put` at
1 / 10 / 100 MB with COS persistence ON the ack path
(`async_writeback=False`, the seed behaviour) vs OFF it (the writeback
queue drains in the background), plus GET latency with the grouped
per-function gather and the invoke amortization it buys.

COS latency is modelled S3-like (per-op base + bandwidth, wall-clock
sleep) so the comparison captures what the paper's persistent-buffer
path actually removes from the critical path: the slowest layer.
Numbers use a logical clock for the store and wall time for latency.

Full runs write ``BENCH_put_async.json`` at the repo root so later PRs
have a perf trajectory; ``--smoke`` runs write
``BENCH_put_async_smoke.json`` so CI never clobbers it.

Usage: PYTHONPATH=src python benchmarks/put_latency.py [--smoke] [--out P]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

if __package__ in (None, ""):                      # direct-script invocation
    _HERE = os.path.dirname(os.path.abspath(__file__))
    sys.path.insert(0, os.path.join(_HERE, ".."))
    sys.path.insert(0, os.path.join(_HERE, "..", "src"))

import numpy as np

from repro.core import Clock, InfiniStore, StoreConfig
from repro.core.ec import ECConfig
from repro.core.gc_window import GCConfig

from benchmarks.common import lat_summary

MB = 1024 * 1024
ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")

# S3-like COS PUT model: ~15 ms per op + ~100 MB/s single-stream
# (typical per-connection S3 throughput; the client daemon writes
# chunks from one stream)
COS_PUT_BASE_S = 0.015
COS_PUT_PER_BYTE_S = 1.0 / (100 * MB)


def make_store(*, async_writeback: bool) -> InfiniStore:
    cfg = StoreConfig(
        ec=ECConfig(k=10, p=2),
        function_capacity=512 * MB,
        fragment_bytes=64 * MB,
        gc=GCConfig(gc_interval=1e12),
        num_recovery_functions=4,
        async_writeback=async_writeback,
        writeback_depth=4096,
    )
    st = InfiniStore(cfg, clock=Clock())
    st.cos.put_delay_base_s = COS_PUT_BASE_S
    st.cos.put_delay_per_byte_s = COS_PUT_PER_BYTE_S
    return st


def bench_point(size: int, repeats: int) -> dict:
    rng = np.random.default_rng(size)
    mb = size / MB
    out = {"object_mb": mb}
    for mode in ("sync", "async"):
        st = make_store(async_writeback=(mode == "async"))
        acks, get_lats = [], []
        for r in range(repeats):
            data = rng.bytes(size)
            t0 = time.perf_counter()
            st.put(f"obj{r}", data)               # ack latency
            acks.append(time.perf_counter() - t0)
        if mode == "async":
            # the win must not come from dropped durability: every chunk
            # still reaches COS, just off the critical path
            assert st.flush_writeback(timeout=600.0)
            assert st.writeback.stats.failures == 0
        inv0 = st.stats.gather_invokes
        for r in range(repeats):
            t0 = time.perf_counter()
            got = st.get(f"obj{r}")
            get_lats.append(time.perf_counter() - t0)
            assert len(got) == size
        out[f"{mode}_put_ack_ms"] = round(min(acks) * 1e3, 2)
        out[f"{mode}_get_ms"] = round(min(get_lats) * 1e3, 2)
        out[f"{mode}_put_ack_us"] = lat_summary(a * 1e6 for a in acks)
        out[f"{mode}_get_us"] = lat_summary(g * 1e6 for g in get_lats)
        if mode == "async":
            out["get_gather_invokes_per_op"] = round(
                (st.stats.gather_invokes - inv0) / repeats, 2)
            out["writeback_persisted"] = st.writeback.stats.persisted
        st.close()
    out["put_ack_speedup"] = round(
        out["sync_put_ack_ms"] / out["async_put_ack_ms"], 2)
    return out


def run_bench(smoke: bool) -> dict:
    if smoke:
        points = [bench_point(1 * MB, repeats=2)]
    else:
        points = [bench_point(1 * MB, repeats=3),
                  bench_point(10 * MB, repeats=2),
                  bench_point(100 * MB, repeats=2)]
    return {"bench": "put_latency", "smoke": smoke,
            "ec": {"k": 10, "p": 2},
            "cos_model": {"put_base_s": COS_PUT_BASE_S,
                          "put_MBps": round(1.0 / COS_PUT_PER_BYTE_S / MB)},
            "points": points}


def _default_out(smoke: bool) -> str:
    name = "BENCH_put_async_smoke.json" if smoke else "BENCH_put_async.json"
    return os.path.join(ROOT, name)


def _write(result: dict, path: str) -> None:
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")


def run() -> list:
    """benchmarks.run entry point (smoke sizes, CSV rows)."""
    result = run_bench(smoke=True)
    _write(result, _default_out(smoke=True))
    rows = []
    for pt in result["points"]:
        tag = f"{pt['object_mb']:g}MB"
        rows.append(f"put_ack_async_{tag},{pt['async_put_ack_ms'] * 1e3:.2f},"
                    f"ms*1e-3 speedup={pt['put_ack_speedup']}x vs sync")
        rows.append(f"get_grouped_{tag},{pt['async_get_ms'] * 1e3:.2f},"
                    f"ms*1e-3 invokes/op="
                    f"{pt['get_gather_invokes_per_op']}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="1 MB point only (CI sanity); writes "
                         "BENCH_put_async_smoke.json unless --out is given")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    result = run_bench(args.smoke)
    out = args.out or _default_out(args.smoke)
    _write(result, out)
    for pt in result["points"]:
        print(f"{pt['object_mb']:>6g} MB | put ack "
              f"{pt['sync_put_ack_ms']:>9.2f} -> "
              f"{pt['async_put_ack_ms']:>8.2f} ms "
              f"({pt['put_ack_speedup']}x) | get "
              f"{pt['async_get_ms']:>8.2f} ms | "
              f"gather invokes/op {pt['get_gather_invokes_per_op']}")
    print(f"wrote {os.path.relpath(out)}")


if __name__ == "__main__":
    main()
