"""Paper Figs. 9 & 15/16: the function count must track the working set,
and throughput must scale with offered load."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import MB, bench_store, replay, row
from repro.data.traces import azure_blob_trace, ibm_registry_trace


def run() -> list:
    out = []
    # Fig 9: function count vs WSS over the IBM-like trace
    events = ibm_registry_trace(num_objects=150, num_requests=900,
                                duration=2400.0, scale_bytes=0.002, seed=3)
    st, clock = bench_store(elastic=True, gc_interval=60.0, M=2, N=2,
                            capacity=1 * MB)
    t0 = time.perf_counter()
    r = replay(st, clock, events, seed=3)
    us = (time.perf_counter() - t0) * 1e6 / len(events)
    series = np.array(r.func_count_series)
    # windowed WSS proxy: distinct keys in trailing window
    wss = []
    win = 120
    keys = [e.key for e in events]
    for i in range(len(events)):
        wss.append(len(set(keys[max(0, i - win):i + 1])))
    corr = float(np.corrcoef(series, np.array(wss))[0, 1])
    out.append(row("fig9_elastic_function_count", us,
                   f"min={series.min()} max={series.max()} "
                   f"ratio={series.max() / max(series.min(), 1):.1f} "
                   f"corr_wss={corr:.2f}"))

    # Fig 15-like: azure burst replay — store absorbs RPS bursts by scaling
    ev_az = azure_blob_trace(num_objects=80, num_requests=700,
                             duration=600.0, scale_bytes=0.002, seed=4)
    st2, clock2 = bench_store(elastic=True, gc_interval=30.0, M=2, N=2,
                              capacity=1 * MB)
    t0 = time.perf_counter()
    r2 = replay(st2, clock2, ev_az, seed=4)
    us2 = (time.perf_counter() - t0) * 1e6 / len(ev_az)
    s2 = np.array(r2.func_count_series)
    out.append(row("fig15_azure_burst_scaling", us2,
                   f"funcs_min={s2.min()} funcs_max={s2.max()} "
                   f"hit={r2.hit_ratio:.3f}"))
    return out
