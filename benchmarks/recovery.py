"""Paper Figs. 18-21: parallel recovery — recovery time/throughput vs the
number of recovery functions, and GET latency impact during recovery."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import MB, row
from repro.core import Clock, InfiniStore, StoreConfig
from repro.core.ec import ECConfig
from repro.core.gc_window import GCConfig


def build_loaded_store(num_recovery: int, objects: int = 60,
                       obj_bytes: int = 60_000):
    cfg = StoreConfig(ec=ECConfig(k=4, p=2),
                      function_capacity=64 * MB,
                      gc=GCConfig(gc_interval=1e12),
                      num_recovery_functions=num_recovery)
    st = InfiniStore(cfg, clock=Clock())
    rng = np.random.default_rng(0)
    payloads = {}
    for i in range(objects):
        payloads[f"o{i}"] = rng.bytes(obj_bytes)
        st.put(f"o{i}", payloads[f"o{i}"])
    return st, payloads


def run() -> list:
    out = []
    # Fig 19/20: recovery time & throughput vs recovery-group size
    for R in (1, 4, 8):
        st, payloads = build_loaded_store(R)
        fid = st.chunk_map["o0|1/f0#0"]
        lost_bytes = sum(len(v) for v in st.sms.get(fid).storage.values())
        st.inject_failure(fid)
        t0 = time.perf_counter()
        assert st.get("o0") == payloads["o0"]     # triggers recovery
        wall = time.perf_counter() - t0
        thpt = st.recovery.stats.bytes_recovered / max(wall, 1e-9) / MB
        out.append(row(f"fig19_recovery_R{R}", wall * 1e6,
                       f"lost={lost_bytes / 1024:.0f}KB "
                       f"recovered={st.recovery.stats.bytes_recovered / 1024:.0f}KB "
                       f"thpt={thpt:.0f}MB/s "
                       f"parallel={st.recovery.stats.parallel_recoveries}"))
    # Fig 21: GET latency around a reclamation event
    st, payloads = build_loaded_store(4)
    lat_before, lat_after = [], []
    for i in range(20):
        t0 = time.perf_counter()
        st.get(f"o{i % 10}")
        lat_before.append((time.perf_counter() - t0) * 1e6)
    fid = st.chunk_map["o3|1/f0#1"]
    st.inject_failure(fid)
    for i in range(20):
        t0 = time.perf_counter()
        got = st.get(f"o{i % 10}")
        lat_after.append((time.perf_counter() - t0) * 1e6)
        assert got == payloads[f"o{i % 10}"]
    out.append(row("fig21_get_latency_during_recovery",
                   float(np.mean(lat_after)),
                   f"before_p50={np.percentile(lat_before, 50):.0f}us "
                   f"after_p50={np.percentile(lat_after, 50):.0f}us "
                   f"no_interruption=True"))
    return out
