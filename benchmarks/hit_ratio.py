"""Paper Table 2: SMS-level read hit ratio, InfiniStore vs baselines.

Baselines:
  * IS      — full InfiniStore (sliding window, compaction, demand cache)
  * IC-like — static pool, periodic provider reclamation, no window/
              compaction (InfiniCache-shaped)
  * COS-only — no memory tier (hit ratio 0 by construction; sanity floor)
"""
from __future__ import annotations

import time

from benchmarks.common import bench_store, replay, row
from repro.data.traces import ibm_registry_trace


def run(num_requests: int = 800) -> list:
    """All variants replayed under 3% provider reclamation: recovery +
    the sliding window keep InfiniStore's memory-level hit ratio high;
    disabling recovery (SNR, Fig. 22) turns reclamations into misses."""
    events = ibm_registry_trace(num_objects=120,
                                num_requests=num_requests,
                                duration=1200.0, scale_bytes=0.002, seed=7)
    out = []
    results = {}
    for name, kw in [
        ("IS", dict(elastic=True, recovery=True)),
        ("IS_no_recovery", dict(elastic=True, recovery=False)),
        ("static_no_window", dict(elastic=False, recovery=True)),
    ]:
        t0 = time.perf_counter()
        st, clock = bench_store(gc_interval=60.0, M=3, N=3, **kw)
        r = replay(st, clock, events, seed=1, fail_rate=0.03)
        us = (time.perf_counter() - t0) * 1e6 / max(r.gets + r.puts, 1)
        results[name] = r
        out.append(row(f"table2_hit_ratio_{name}", us,
                       f"hit={r.hit_ratio:.3f} funcs_final="
                       f"{r.func_count_series[-1]}"))
    holds = (results["IS"].hit_ratio
             >= results["IS_no_recovery"].hit_ratio)
    out.append(row("table2_recovery_preserves_hits", 0.0,
                   f"IS>=IS_no_recovery holds={holds}"))
    return out
