"""Paper Figs. 12-14: YCSB-style stress test — zipfian keys, two
read/update mixes, several object sizes; reports p50/p90 latency and
throughput against the real store."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import MB, bench_store, row


def ycsb(st, clock, *, num_keys: int, object_bytes: int, ops: int,
         read_frac: float, zipf_a: float = 1.3, seed: int = 0):
    rng = np.random.default_rng(seed)
    payloads = {}
    for i in range(num_keys):
        payloads[f"u{i}"] = rng.bytes(object_bytes)
        st.put(f"u{i}", payloads[f"u{i}"])
    ranks = rng.zipf(zipf_a, size=ops * 2)
    ranks = ranks[ranks <= num_keys][:ops] - 1
    get_lat, put_lat = [], []
    t_start = time.perf_counter()
    for i, r in enumerate(ranks):
        key = f"u{r}"
        clock.advance(0.05)
        if rng.random() < read_frac:
            t0 = time.perf_counter()
            got = st.get(key)
            get_lat.append((time.perf_counter() - t0) * 1e6)
            assert got == payloads[key]
        else:
            data = rng.bytes(object_bytes)
            t0 = time.perf_counter()
            st.put(key, data)
            put_lat.append((time.perf_counter() - t0) * 1e6)
            payloads[key] = data
        if i % 50 == 0:
            st.gc_tick()
    wall = time.perf_counter() - t_start
    return {
        "rps": ops / wall,
        "mbps": ops * object_bytes / wall / MB,
        "get_p50": float(np.percentile(get_lat, 50)) if get_lat else 0.0,
        "get_p90": float(np.percentile(get_lat, 90)) if get_lat else 0.0,
        "put_p90": float(np.percentile(put_lat, 90)) if put_lat else 0.0,
    }


def run(ops: int = 300) -> list:
    out = []
    for size_name, nbytes in [("64KB", 64 * 1024), ("256KB", 256 * 1024),
                              ("1MB", 1 * MB)]:
        for mix_name, read_frac in [("95:5", 0.95), ("100:0", 1.0)]:
            st, clock = bench_store(elastic=True, gc_interval=600.0,
                                    capacity=8 * MB)
            t0 = time.perf_counter()
            r = ycsb(st, clock, num_keys=24, object_bytes=nbytes,
                     ops=ops, read_frac=read_frac, seed=5)
            us = (time.perf_counter() - t0) * 1e6 / ops
            out.append(row(f"fig14_ycsb_{size_name}_{mix_name}", us,
                           f"rps={r['rps']:.0f} thpt={r['mbps']:.1f}MB/s "
                           f"get_p50={r['get_p50']:.0f}us "
                           f"get_p90={r['get_p90']:.0f}us "
                           f"put_p90={r['put_p90']:.0f}us"))
    return out
