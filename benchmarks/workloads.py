"""Paper Fig. 1: workload characterization of the two traces."""
from __future__ import annotations

import time

from benchmarks.common import row
from repro.data.traces import (azure_blob_trace, ibm_registry_trace,
                               trace_stats)


def run() -> list:
    out = []
    t0 = time.perf_counter()
    ibm = ibm_registry_trace(num_objects=300, num_requests=3000,
                             duration=3600.0, seed=0)
    az = azure_blob_trace(num_objects=200, num_requests=3000,
                          duration=1800.0, seed=0)
    us = (time.perf_counter() - t0) * 1e6 / 6000
    si, sa = trace_stats(ibm), trace_stats(az)
    out.append(row("fig1_ibm_trace", us,
                   f"reuse_p80={si['reuse_p80']:.0f}s "
                   f"cov_gt1={si['frac_cov_gt1']:.2f} "
                   f"large={si['frac_large']:.2f}"))
    out.append(row("fig1_azure_trace", us,
                   f"reuse_p50={sa['reuse_p50']:.1f}s "
                   f"cov_gt1={sa['frac_cov_gt1']:.2f} "
                   f"large={sa['frac_large']:.2f}"))
    return out
