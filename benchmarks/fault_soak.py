"""Fault-plane soak: disabled-plane overhead + seeded chaos schedule.

Two gates the deterministic fault-injection plane (`repro.core.faults`)
must pass with numbers, both asserted (CI fails on violation):

1. **Disabled-plane ack cost** — every instrumented site guards with a
   single `faults is not None` check, and an ATTACHED-but-idle plan adds
   only a dict probe per op. PUT-ack latency with an armed-idle plan
   must be <= 2% over `faults=None`. Interleaved min-of-N floors (the
   spill_overhead.py methodology) so both modes sample the same machine
   load windows.
2. **Chaos soak** — the acceptance schedule over a 2-shard
   `ShardedStore`: transient COS errors + throttling on the read path,
   one slab kill mid-store, one torn journal tail at the crash, and one
   leader death between the 2PC rounds; then a full restart. Gates:
   every acked write is readable after the restart, the interrupted
   cross-shard batch converges to fully-committed (its decision was
   durable), no ticket stays in doubt / no key stays PENDING, and the
   SAME SEED reproduces the byte-identical fault log twice.
3. **Network chaos** — the same discipline over the TCP transport
   (`ProcessShardedStore(transport="tcp")`): seeded `net.drop` /
   `net.delay` / `net.dup` on the PUT stream, then a `net.partition`
   that eats one shard's 2PC commit frame mid-batch; the heartbeat
   detector declares the shard DOWN, reconnects at a new epoch, and
   the in-doubt sweep rolls the ticket forward. Gates: zero acked-write
   loss, zero stranded tickets, ZERO stale-epoch acks (the worker's
   fencing counter), at least one duplicate frame deduped, the shard
   back at a higher epoch, and the byte-identical fault log twice.

Writes ``BENCH_faults.json`` at the repo root (the chaos gates are
identical in --smoke; smoke only shrinks the overhead sampling).

Usage: PYTHONPATH=src python benchmarks/fault_soak.py [--smoke] [--out P]
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
import tempfile
import time

if __package__ in (None, ""):                      # direct-script invocation
    _HERE = os.path.dirname(os.path.abspath(__file__))
    sys.path.insert(0, os.path.join(_HERE, ".."))
    sys.path.insert(0, os.path.join(_HERE, "..", "src"))

import numpy as np

from repro.core import (Clock, FaultPlan, FaultPoint, HeartbeatConfig,
                        InfiniStore, InjectedCrash, ProcessShardedStore,
                        ShardedStore, ShardWorkerDied, StoreConfig)
from repro.core.ec import ECConfig
from repro.core.gc_window import GCConfig
from repro.obs import ObsPlane

from benchmarks.common import lat_summary

MB = 1024 * 1024
ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
CHAOS_SEED = 77


def _cfg(*, faults=None, spill_dir=None, **kw) -> StoreConfig:
    kw.setdefault("ec", ECConfig(k=4, p=2))
    kw.setdefault("function_capacity", 16 * MB)
    kw.setdefault("fragment_bytes", 1 * MB)
    kw.setdefault("gc", GCConfig(gc_interval=1e12))
    kw.setdefault("num_recovery_functions", 4)
    return StoreConfig(faults=faults, spill_dir=spill_dir, **kw)


# ---------------------------------------------------------------------------
# gate 1: disabled / idle fault-plane ack overhead
# ---------------------------------------------------------------------------

def bench_overhead(size: int, repeats: int, max_repeats: int = 0) -> dict:
    """PUT-ack latency, faults=None vs an attached plan with no point
    at any hot site. Interleaved, min-of-N, adaptive tail — identical
    methodology to spill_overhead.bench_ack. Asserts the <= 2% gate."""
    rng = np.random.default_rng(size)
    idle_plan = FaultPlan(seed=0).add(
        FaultPoint(site="never.fired", hits=(1,)))
    stores = {
        "off": InfiniStore(_cfg(faults=None), clock=Clock()),
        "armed_idle": InfiniStore(_cfg(faults=idle_plan), clock=Clock()),
    }
    acks = {m: [] for m in stores}
    for st in stores.values():
        st.writeback.pause()                  # measure the ack path only
    max_repeats = max_repeats or 4 * repeats
    since_new_min = 0
    for r in range(max_repeats):
        data = rng.bytes(size)
        improved = False
        for mode, st in stores.items():
            t0 = time.perf_counter()
            st.put(f"obj{r}", data)
            dt = time.perf_counter() - t0
            if not acks[mode] or dt < min(acks[mode]):
                improved = True
            acks[mode].append(dt)
        since_new_min = 0 if improved else since_new_min + 1
        if r + 1 >= repeats and since_new_min >= 8:
            break
    for st in stores.values():
        st.writeback.resume()
        assert st.flush_writeback(timeout=600.0)
        st.close()
    assert idle_plan.fired() == 0             # the plan really was idle
    off_ms = min(acks["off"]) * 1e3
    armed_ms = min(acks["armed_idle"]) * 1e3
    overhead_pct = (armed_ms - off_ms) / off_ms * 100.0
    out = {"object_mb": size / MB,
           "repeats": len(acks["off"]),
           "off_put_ack_ms": round(off_ms, 3),
           "armed_idle_put_ack_ms": round(armed_ms, 3),
           "off_put_ack_us": lat_summary(a * 1e6 for a in acks["off"]),
           "armed_idle_put_ack_us": lat_summary(
               a * 1e6 for a in acks["armed_idle"]),
           "overhead_pct": round(overhead_pct, 2),
           "gate_overhead_max_pct": 2.0}
    assert overhead_pct <= 2.0, \
        f"disabled fault plane costs {overhead_pct:.2f}% PUT-ack (> 2%)"
    return out


# ---------------------------------------------------------------------------
# gate 1b: disabled observability-plane ack overhead
# ---------------------------------------------------------------------------

def bench_obs_overhead(size: int, repeats: int,
                       max_repeats: int = 0) -> dict:
    """PUT-ack latency, obs=None vs an ATTACHED-but-disabled ObsPlane.
    Every instrumented site guards with one `obs is not None` check and
    a disabled plane early-returns before touching buckets or rings, so
    the delta must stay <= 2% — same methodology as bench_overhead."""
    rng = np.random.default_rng(size)
    plane = ObsPlane(enabled=False, name="bench-disabled")
    stores = {
        "off": InfiniStore(_cfg(faults=None), clock=Clock()),
        "attached_disabled": InfiniStore(_cfg(faults=None, obs=plane),
                                         clock=Clock()),
    }
    acks = {m: [] for m in stores}
    for st in stores.values():
        st.writeback.pause()                  # measure the ack path only
    max_repeats = max_repeats or 4 * repeats
    since_new_min = 0
    for r in range(max_repeats):
        data = rng.bytes(size)
        improved = False
        for mode, st in stores.items():
            t0 = time.perf_counter()
            st.put(f"obj{r}", data)
            dt = time.perf_counter() - t0
            if not acks[mode] or dt < min(acks[mode]):
                improved = True
            acks[mode].append(dt)
        since_new_min = 0 if improved else since_new_min + 1
        if r + 1 >= repeats and since_new_min >= 8:
            break
    for st in stores.values():
        st.writeback.resume()
        assert st.flush_writeback(timeout=600.0)
        st.close()
    snap = plane.snapshot()
    recorded = sum(h["count"] for h in snap["histograms"].values())
    assert recorded == 0, "disabled plane recorded samples"
    assert not snap["spans"] and not snap["events"]
    off_ms = min(acks["off"]) * 1e3
    dis_ms = min(acks["attached_disabled"]) * 1e3
    overhead_pct = (dis_ms - off_ms) / off_ms * 100.0
    out = {"object_mb": size / MB,
           "repeats": len(acks["off"]),
           "off_put_ack_ms": round(off_ms, 3),
           "attached_disabled_put_ack_ms": round(dis_ms, 3),
           "off_put_ack_us": lat_summary(a * 1e6 for a in acks["off"]),
           "attached_disabled_put_ack_us": lat_summary(
               a * 1e6 for a in acks["attached_disabled"]),
           "overhead_pct": round(overhead_pct, 2),
           "gate_overhead_max_pct": 2.0}
    assert overhead_pct <= 2.0, \
        f"disabled obs plane costs {overhead_pct:.2f}% PUT-ack (> 2%)"
    return out


# ---------------------------------------------------------------------------
# gate 1c: flight recorder survives a real SIGKILL
# ---------------------------------------------------------------------------

def flight_recorder_soak(workdir: str) -> dict:
    """SIGKILL one worker process mid-run, restart it, and require the
    dead incarnation's flight file (mmap page-cache writes) to come
    back as forensics on the parent plane — events AND mirrored spans,
    tagged with the dead worker's epoch."""
    plane = ObsPlane(name="flight-soak")
    cfg = _cfg(faults=None, spill_dir=os.path.join(workdir, "spill"),
               obs=plane)
    st = ProcessShardedStore(cfg, num_shards=2, clock=Clock(),
                             cos_root=os.path.join(workdir, "cos"),
                             seed=7)
    rng = np.random.default_rng(7)
    try:
        acked = {f"f{i}": rng.bytes(8_000) for i in range(8)}
        for k, v in acked.items():
            assert st.put(k, v) == 1
        assert st.flush_writeback(timeout=600.0)
        st.simulate_crash(shard=0)            # REAL SIGKILL
        st.restart_shard(0)                   # reads forensics first
        snap = st.snapshot_metrics()
        forensics = [f for f in snap["forensics"]
                     if f["source"] == "shard-0"]
        assert forensics, "no forensics recovered after SIGKILL"
        records = forensics[0]["records"]
        kinds = {r.get("kind") for r in records}
        assert "store.open" in kinds, kinds
        assert "span" in kinds, kinds         # mirrored spans survived
        epochs = {r.get("epoch") for r in records if "epoch" in r}
        assert epochs, "records lost their epoch tags"
        # the restarted worker replayed its journal: no acked-write loss
        got = st.get_many(list(acked))
        lost = [k for k, v in acked.items() if got[k] != v]
        assert not lost, f"acked writes lost across SIGKILL: {lost[:8]}"
    finally:
        st.close()
    return {"forensic_records": len(records),
            "forensic_kinds": sorted(k for k in kinds if k),
            "dead_epochs": sorted(epochs),
            "acked_writes": len(acked),
            "lost_acked_writes": 0}


# ---------------------------------------------------------------------------
# gate 2: seeded chaos schedule over a 2-shard store
# ---------------------------------------------------------------------------

def _chaos_plan(seed: int) -> FaultPlan:
    """The acceptance schedule. Only sites fired from the (serial)
    client call sequence are scheduled, so the fault LOG ORDER is a
    deterministic function of the seed — the reproducibility artifact."""
    return FaultPlan(seed=seed, points=(
        # transient COS errors + throttling on the degraded read path
        FaultPoint(site="cos.get", action="transient", prob=0.10,
                   times=8),
        FaultPoint(site="cos.get", action="throttle", prob=0.05,
                   times=3, latency_s=0.001),
        # one slab killed mid-store (function reclaimed under a PUT)
        FaultPoint(site="sms.store", action="reclaim", hits=(40,),
                   times=1),
        # one leader death between the 2PC rounds (decision durable)
        FaultPoint(site="shard.leader_death", action="crash", hits=(2,),
                   times=1),
        # one torn journal tail at the SIGKILL
        FaultPoint(site="spill.torn_close", action="torn", hits=(1,),
                   times=1),
    ))


def _cross_shard_batch(st, tag, rng, n_per_shard=2) -> dict:
    per = {sid: 0 for sid in range(st.num_shards)}
    out, i = {}, 0
    while any(c < n_per_shard for c in per.values()):
        k = f"{tag}{i}"
        i += 1
        sid = st.router.shard_of(k)
        if per[sid] < n_per_shard:
            per[sid] += 1
            out[k] = rng.bytes(12_000)
    return out


def chaos_soak(seed: int, workdir: str, n_keys: int) -> dict:
    """One run of the seeded schedule. Returns the fault log + gates."""
    spill = os.path.join(workdir, "spill")
    cosr = os.path.join(workdir, "cos")
    plan = _chaos_plan(seed)
    cfg = _cfg(faults=plan, spill_dir=spill,
               pipelined_get=False, enable_recovery=False)
    st = ShardedStore(cfg, num_shards=2, clock=Clock(), cos_root=cosr,
                      seed=seed)
    rng = np.random.default_rng(seed)
    acked = {}
    t0 = time.perf_counter()
    for i in range(n_keys):                   # rides through the slab kill
        k = f"s{i}"
        acked[k] = rng.bytes(15_000)
        assert st.put(k, acked[k]) == 1
    # cross-shard batch 1 commits clean; batch 2 loses its leader
    # between the rounds — the durable decision means it MUST converge
    # to committed even though the client never got an ack
    b1 = _cross_shard_batch(st, "x", rng)
    assert all(v == 1 for v in st.put_many(b1).values())
    acked.update(b1)
    b2 = {k: rng.bytes(12_000) for k in b1}
    leader_died = False
    try:
        st.put_many(b2)
    except InjectedCrash:
        leader_died = True
    assert leader_died, "schedule must kill the leader between rounds"
    indoubt_before = len(st.indoubt_tickets())
    # persist + degrade the read path: killing MORE slabs than EC can
    # mask (p=2) forces COS chunk reads, which draw the scheduled
    # transient/throttle errors through the unified RetryPolicy
    assert st.flush_writeback(timeout=600.0)
    for s in st.shards:
        for fid in sorted(s.sms.slabs)[:3]:
            s.inject_failure(fid)
    for k, v in acked.items():
        assert st.get(k) == v, f"acked write {k} lost pre-crash"
    # full crash (tears one journal tail), then rebuild + resolve
    st.simulate_crash()
    st2 = ShardedStore(_cfg(spill_dir=spill), num_shards=2,
                       clock=Clock(), cos_root=cosr, seed=seed)
    # the restart resolver rolls the interrupted batch FORWARD (its
    # decision was durable): those keys must now read the b2 payloads
    expected = dict(acked)
    expected.update(b2)
    lost = [k for k, v in expected.items() if st2.get(k) != v]
    rolled_forward = all(st2.get(k) == v for k, v in b2.items())
    stranded = st2.indoubt_tickets()
    flushed = st2.flush_writeback(timeout=600.0)
    st2.close()
    elapsed = time.perf_counter() - t0
    snap = plan.snapshot()
    fired_by_site = {}
    for site, _, _ in snap["log"]:
        fired_by_site[site] = fired_by_site.get(site, 0) + 1
    result = {
        "seed": seed,
        "acked_writes": len(acked),
        "faults_fired": snap["fired"],
        "fired_by_site": fired_by_site,
        "indoubt_at_crash": indoubt_before,
        "lost_acked_writes": len(lost),
        "interrupted_batch_rolled_forward": bool(rolled_forward),
        "stranded_indoubt_after_restart": len(stranded),
        "flushed_after_restart": bool(flushed),
        "elapsed_s": round(elapsed, 2),
        "log": snap["log"],
    }
    assert not lost, f"acked writes lost: {lost[:8]}"
    assert rolled_forward, "in-doubt batch not rolled forward"
    assert not stranded, f"tickets stranded in doubt: {stranded}"
    assert flushed
    assert indoubt_before > 0                 # the leader kill was real
    assert fired_by_site.get("sms.store", 0) == 1
    assert fired_by_site.get("spill.torn_close", 0) == 1
    assert fired_by_site.get("cos.get", 0) >= 2
    return result


# ---------------------------------------------------------------------------
# gate 3: network chaos over the TCP transport
# ---------------------------------------------------------------------------

#: hot detector for the soak: the box is single-core, so sub-second
#: death + fast reconnect keeps the partition round bounded
_NET_HB = HeartbeatConfig(interval_s=0.05, suspect_after_s=0.15,
                          dead_after_s=0.4, connect_timeout_s=5.0,
                          rpc_deadline_s=1.5, reconnect_max_attempts=60,
                          reconnect_backoff_base_s=0.05,
                          reconnect_backoff_cap_s=0.2, partition_s=1.2)


def _net_chaos_plan(seed: int) -> FaultPlan:
    """Seeded network schedule. Every point carries a `match` filter,
    so the nondeterministic heartbeat stream consumes no hit indices —
    the log stays a pure function of the serial client call sequence."""
    return FaultPlan(seed=seed, points=(
        # one PUT frame silently lost (fails by rpc deadline; the retry
        # lands at version 1 because the worker never saw it)
        FaultPoint(site="net.drop", action="drop", hits=(2,),
                   match="op:put:"),
        # periodic injected latency on the PUT stream
        FaultPoint(site="net.delay", action="delay", every=5,
                   latency_s=0.01, match="op:put:"),
        # one duplicated PUT frame (worker rid-dedupe must drop it)
        FaultPoint(site="net.dup", action="dup", hits=(4,),
                   match="op:put:"),
        # partition eats shard 0's SECOND 2PC commit frame (the first
        # cross-shard batch commits clean) and blackholes the link
        FaultPoint(site="net.partition", action="partition", hits=(2,),
                   match="op:commit2pc:s0"),
    ))


def _poll(pred, timeout, what):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return
        time.sleep(0.05)
    raise AssertionError(f"net chaos: timed out waiting for {what}")


def net_chaos_soak(seed: int, workdir: str, n_keys: int) -> dict:
    """One run of the seeded network schedule over TCP loopback."""
    plan = _net_chaos_plan(seed)
    cfg = _cfg(faults=plan, spill_dir=os.path.join(workdir, "spill"),
               pipelined_get=False, enable_recovery=False)
    st = ProcessShardedStore(cfg, num_shards=2, clock=Clock(),
                             cos_root=os.path.join(workdir, "cos"),
                             seed=seed, transport="tcp",
                             heartbeat=_NET_HB)
    rng = np.random.default_rng(seed)
    acked = {}
    t0 = time.perf_counter()
    net_drops = 0
    try:
        # phase A: serial PUT stream through drop/delay/dup
        for i in range(n_keys):
            k = f"n{i}"
            v = rng.bytes(12_000)
            try:
                st.put(k, v)
            except ShardWorkerDied:
                net_drops += 1       # frame lost: worker never saw it
                assert st.put(k, v) == 1
            acked[k] = v
        # phase B: clean cross-shard batch (commit round 1 untouched)
        b1 = _cross_shard_batch(st, "nx", rng)
        assert all(v == 1 for v in st.put_many(b1).values())
        acked.update(b1)
        # phase C: the partition eats shard 0's commit frame mid-batch
        b2 = {k: rng.bytes(12_000) for k in b1}
        partitioned = False
        try:
            st.put_many(b2)
        except Exception:                                 # noqa: BLE001
            partitioned = True
        assert partitioned, "schedule must strand the 2PC batch"
        assert ("net.partition", 2, "partition") in plan.log
        # phase D: reconnect at a new epoch, sweep rolls the ticket
        # forward — acked writes intact, nothing stranded, no stale acks
        _poll(lambda: st.shard_transport_health()[0]["state"]
              == "CONNECTED"
              and st.shard_transport_health()[0]["epoch"] >= 2,
              timeout=30.0, what="shard 0 reconnect")

        def settled():
            if st.indoubt_tickets():
                st.resolve_indoubt()
                return False
            got = st.get_many(list(b2))
            return all(got[k] == v for k, v in b2.items())
        _poll(settled, timeout=30.0, what="ticket roll-forward")
        expected = dict(acked)
        expected.update(b2)
        lost = [k for k, v in expected.items() if st.get(k) != v]
        stranded = st.indoubt_tickets()
        xstats = [s.transport_stats() for s in st.shards]
        health = st.shard_transport_health()
        flushed = st.flush_writeback(timeout=600.0)
    finally:
        st.close()
    elapsed = time.perf_counter() - t0
    snap = plan.snapshot()
    fired_by_site = {}
    for site, _, _ in snap["log"]:
        fired_by_site[site] = fired_by_site.get(site, 0) + 1
    stale_acks = sum(x["stale_acks_suppressed"] for x in xstats)
    dups_dropped = sum(x["dup_frames_dropped"] for x in xstats)
    result = {
        "seed": seed,
        "acked_writes": len(acked),
        "net_drops_retried": net_drops,
        "faults_fired": snap["fired"],
        "fired_by_site": fired_by_site,
        "lost_acked_writes": len(lost),
        "stranded_indoubt": len(stranded),
        "stale_epoch_acks": stale_acks,
        "dup_frames_dropped": dups_dropped,
        "shard0_epoch": health[0]["epoch"],
        "flushed": bool(flushed),
        "elapsed_s": round(elapsed, 2),
        "log": snap["log"],
    }
    assert not lost, f"acked writes lost to network chaos: {lost[:8]}"
    assert not stranded, f"tickets stranded: {stranded}"
    assert stale_acks == 0, f"stale-epoch acks delivered: {stale_acks}"
    assert dups_dropped >= 1, "net.dup never exercised rid dedupe"
    assert net_drops >= 1, "net.drop never cost an RPC"
    assert health[0]["epoch"] >= 2, "partition never forced a new epoch"
    assert fired_by_site.get("net.partition", 0) == 1
    assert flushed
    return result


def run_bench(smoke: bool) -> dict:
    # Lock-order witness rides the whole soak: every lock created by
    # the stores below is validated against the static hierarchy, and
    # a single observed inversion fails the gate. Parent-side only —
    # forked shard workers inherit a dormant copy they never assert.
    from repro.core import locks as _locks
    from repro.devtools.witness import LockWitness
    witness = LockWitness.with_static_order()
    _locks.install_witness(witness)
    overhead = bench_overhead(256 * 1024, repeats=16 if smoke else 48)
    obs_overhead = bench_obs_overhead(256 * 1024,
                                      repeats=16 if smoke else 48)
    flight_dir = tempfile.mkdtemp(prefix="flight-soak-")
    try:
        flight = flight_recorder_soak(flight_dir)
    finally:
        shutil.rmtree(flight_dir, ignore_errors=True)
    runs = []
    for tag in ("a", "b"):                    # same seed, twice
        workdir = tempfile.mkdtemp(prefix=f"fault-soak-{tag}-")
        try:
            runs.append(chaos_soak(CHAOS_SEED, workdir,
                                   n_keys=20 if smoke else 60))
        finally:
            shutil.rmtree(workdir, ignore_errors=True)
    reproducible = runs[0]["log"] == runs[1]["log"]
    assert reproducible, "same seed produced different fault sequences"
    net_runs = []
    for tag in ("a", "b"):                    # same seed, twice
        workdir = tempfile.mkdtemp(prefix=f"net-chaos-{tag}-")
        try:
            net_runs.append(net_chaos_soak(CHAOS_SEED, workdir,
                                           n_keys=12 if smoke else 24))
        finally:
            shutil.rmtree(workdir, ignore_errors=True)
    net_reproducible = net_runs[0]["log"] == net_runs[1]["log"]
    assert net_reproducible, \
        "same seed produced different network fault sequences"
    for r in runs + net_runs:
        r["log"] = [list(e) for e in r["log"]]
    witness.assert_clean()           # zero lock-order inversions
    _locks.install_witness(None)
    return {"bench": "fault_soak", "smoke": smoke,
            "lock_witness": witness.snapshot(),
            "overhead": overhead,
            "obs_overhead": obs_overhead,
            "flight_recorder": flight,
            "chaos": {"seed": CHAOS_SEED,
                      "reproducible_log": reproducible,
                      "runs": runs},
            "net_chaos": {"seed": CHAOS_SEED,
                          "reproducible_log": net_reproducible,
                          "runs": net_runs}}


def _write(result: dict, path: str) -> None:
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")


def run() -> list:
    """benchmarks.run entry point (smoke sizes, CSV rows)."""
    result = run_bench(smoke=True)
    _write(result, os.path.join(ROOT, "BENCH_faults.json"))
    ov = result["overhead"]
    oo = result["obs_overhead"]
    fl = result["flight_recorder"]
    r0 = result["chaos"]["runs"][0]
    n0 = result["net_chaos"]["runs"][0]
    return [f"fault_plane_idle_overhead,{ov['overhead_pct']},"
            f"% of {ov['off_put_ack_ms']}ms PUT ack",
            f"obs_plane_disabled_overhead,{oo['overhead_pct']},"
            f"% of {oo['off_put_ack_ms']}ms PUT ack",
            f"flight_recorder_sigkill,{fl['forensic_records']},"
            f"records recovered lost={fl['lost_acked_writes']}",
            f"chaos_soak,{r0['faults_fired']},"
            f"faults lost={r0['lost_acked_writes']} "
            f"stranded={r0['stranded_indoubt_after_restart']}",
            f"net_chaos_soak,{n0['faults_fired']},"
            f"faults lost={n0['lost_acked_writes']} "
            f"stranded={n0['stranded_indoubt']} "
            f"stale_acks={n0['stale_epoch_acks']}"]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="smaller overhead sampling; chaos gates identical")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    result = run_bench(args.smoke)
    out = args.out or os.path.join(ROOT, "BENCH_faults.json")
    _write(result, out)
    ov = result["overhead"]
    print(f"idle fault plane | put ack {ov['off_put_ack_ms']} ms -> "
          f"{ov['armed_idle_put_ack_ms']} ms "
          f"({ov['overhead_pct']:+.2f}%, gate <= 2%)")
    oo = result["obs_overhead"]
    print(f"disabled obs plane | put ack {oo['off_put_ack_ms']} ms -> "
          f"{oo['attached_disabled_put_ack_ms']} ms "
          f"({oo['overhead_pct']:+.2f}%, gate <= 2%)")
    fl = result["flight_recorder"]
    print(f"flight recorder | SIGKILL -> {fl['forensic_records']} "
          f"forensic records {fl['forensic_kinds']} | epochs "
          f"{fl['dead_epochs']} | lost {fl['lost_acked_writes']}")
    for i, r in enumerate(result["chaos"]["runs"]):
        print(f"chaos run {i} | {r['faults_fired']} faults "
              f"{r['fired_by_site']} | acked {r['acked_writes']} "
              f"lost {r['lost_acked_writes']} | in-doubt at crash "
              f"{r['indoubt_at_crash']} -> stranded "
              f"{r['stranded_indoubt_after_restart']} | "
              f"{r['elapsed_s']}s")
    print(f"log reproducible across same-seed runs: "
          f"{result['chaos']['reproducible_log']}")
    for i, r in enumerate(result["net_chaos"]["runs"]):
        print(f"net chaos run {i} | {r['faults_fired']} faults "
              f"{r['fired_by_site']} | acked {r['acked_writes']} "
              f"lost {r['lost_acked_writes']} | drops retried "
              f"{r['net_drops_retried']} dups dropped "
              f"{r['dup_frames_dropped']} stale acks "
              f"{r['stale_epoch_acks']} | shard0 epoch "
              f"{r['shard0_epoch']} | {r['elapsed_s']}s")
    print(f"net log reproducible across same-seed runs: "
          f"{result['net_chaos']['reproducible_log']}")
    print(f"wrote {os.path.relpath(out)}")


if __name__ == "__main__":
    main()
