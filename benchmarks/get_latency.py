"""GET latency benchmark: serial vs pipelined read path (§5.3.3).

Three scenarios per object size, each measured with the legacy serial
path (`StoreConfig(pipelined_get=False)`: gather-everything barrier,
one-chunk-at-a-time COS fallback, inline compaction migration) and the
pipelined path (grouped SMS sweep, bounded-concurrency COS fan-out,
ready-order decode, gc_tick migration):

- **warm**: every chunk SMS-resident in an ACTIVE bucket (pure in-memory
  gather + decode; the two paths should be near parity).
- **aged**: chunks SMS-resident but their bucket aged to DEGRADED — the
  serial path migrates every hit chunk inline (COS reads ON the read
  path); the pipelined path defers the round to gc_tick.
- **degraded**: every slab reclaimed (recovery off), so all chunks come
  from COS — the serial consistency loop vs the parallel fan-out.

Plus a sequential-scan pass (ordered `.../sN` keys over a degraded
store) with the prefetcher on vs off, reporting warm-chunk hit/waste
accounting.

COS GET latency is modelled S3-like (first-byte base + per-connection
bandwidth, wall-clock sleeps outside the COS lock) so overlap is
physically possible; the store runs on a logical clock.

Full runs write ``BENCH_get.json`` at the repo root; ``--smoke`` writes
``BENCH_get_smoke.json`` so CI never clobbers the trajectory.

Usage: PYTHONPATH=src python benchmarks/get_latency.py [--smoke] [--out P]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

if __package__ in (None, ""):                      # direct-script invocation
    _HERE = os.path.dirname(os.path.abspath(__file__))
    sys.path.insert(0, os.path.join(_HERE, ".."))
    sys.path.insert(0, os.path.join(_HERE, "..", "src"))

import numpy as np

from repro.core import Clock, InfiniStore, StoreConfig
from repro.core.ec import ECConfig
from repro.core.gc_window import GCConfig

from benchmarks.common import lat_summary

MB = 1024 * 1024
ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")

# S3-like COS GET model: ~10 ms first-byte + ~90 MB/s per connection
COS_GET_BASE_S = 0.010
COS_GET_PER_BYTE_S = 1.0 / (90 * MB)


def make_store(*, pipelined: bool, prefetch: bool = True,
               io_workers: int = 8) -> InfiniStore:
    cfg = StoreConfig(
        ec=ECConfig(k=10, p=2),
        function_capacity=512 * MB,
        fragment_bytes=64 * MB,
        gc=GCConfig(gc_interval=30.0, active_intervals=2,
                    degraded_intervals=12),
        num_recovery_functions=4,
        enable_recovery=False,       # reclaimed slabs = pure COS fallback
        pipelined_get=pipelined,
        prefetch=prefetch,
        get_io_workers=io_workers,
        writeback_depth=4096,
    )
    st = InfiniStore(cfg, clock=Clock())
    st.cos.get_delay_base_s = COS_GET_BASE_S
    st.cos.get_delay_per_byte_s = COS_GET_PER_BYTE_S
    return st


def _put_objects(st: InfiniStore, size: int, count: int, seed: int) -> dict:
    rng = np.random.default_rng(seed)
    objs = {f"obj{i}": rng.bytes(size) for i in range(count)}
    for k, v in objs.items():
        st.put(k, v)
    assert st.flush_writeback(timeout=600.0)
    return objs


def _age_to_degraded(st: InfiniStore) -> None:
    """Seal the data-holding FGs, open a fresh one, age the sealed bucket
    to DEGRADED (open FGs carry over and stay ACTIVE)."""
    for fg_id in list(st.placement.open_fg_ids):
        st.placement.seal_fg(fg_id)
    st.put("opener", b"x" * 1024)
    assert st.flush_writeback(timeout=600.0)
    for _ in range(3):
        st.clock.advance(30.0)
        st.gc_tick()


def _timed_gets(st: InfiniStore, objs: dict) -> list:
    lats = []
    for k, v in objs.items():
        t0 = time.perf_counter()
        got = st.get(k)
        lats.append(time.perf_counter() - t0)
        assert got == v
    return lats


def bench_point(size: int, repeats: int) -> dict:
    out = {"object_mb": size / MB}
    for mode in ("serial", "pipelined"):
        pipelined = mode == "pipelined"
        # warm: ACTIVE-bucket SMS hits
        st = make_store(pipelined=pipelined)
        objs = _put_objects(st, size, repeats, seed=size)
        # warm reads are sub-ms at 1 MB, so min over enough rounds that
        # cross-thread wakeup jitter doesn't dominate the number
        rounds = 3 if size >= 100 * MB else 12
        lats = []
        for _ in range(rounds):
            lats += _timed_gets(st, objs)
        out[f"{mode}_warm_ms"] = round(min(lats) * 1e3, 2)
        out[f"{mode}_warm_us"] = lat_summary(v * 1e6 for v in lats)
        st.close()
        # aged: DEGRADED-bucket SMS hits (serial pays inline migration)
        st = make_store(pipelined=pipelined)
        objs = _put_objects(st, size, repeats, seed=size + 1)
        _age_to_degraded(st)
        lats = _timed_gets(st, objs)
        out[f"{mode}_aged_ms"] = round(min(lats) * 1e3, 2)
        out[f"{mode}_aged_us"] = lat_summary(v * 1e6 for v in lats)
        st.close()
        # degraded: slabs reclaimed, every chunk demand-read from COS
        st = make_store(pipelined=pipelined)
        objs = _put_objects(st, size, repeats, seed=size + 2)
        for fid in list(st.sms.slabs):
            st.inject_failure(fid)
        lats = _timed_gets(st, objs)
        out[f"{mode}_degraded_ms"] = round(min(lats) * 1e3, 2)
        out[f"{mode}_degraded_us"] = lat_summary(v * 1e6 for v in lats)
        if pipelined:
            out["cos_fallback_reads"] = st.stats.cos_fallback_reads
            out["decode_batches"] = st.stats.decode_batches
        st.close()
    for scen in ("warm", "aged", "degraded"):
        out[f"{scen}_speedup"] = round(
            out[f"serial_{scen}_ms"] / max(out[f"pipelined_{scen}_ms"], 1e-9),
            2)
    return out


def bench_scan(size: int, count: int) -> dict:
    """Ordered degraded scan (checkpoint-restore shape): prefetch off vs
    on, both on the pipelined path. The executor gets headroom beyond
    one object's demand fan-out (16 workers vs k=10 chunks) so warm
    fetches for the next objects can run during the inter-GET gaps."""
    out = {"object_mb": size / MB, "objects": count}
    for tag, prefetch in (("noprefetch", False), ("prefetch", True)):
        st = make_store(pipelined=True, prefetch=prefetch, io_workers=16)
        rng = np.random.default_rng(77)
        objs = {f"scan/s{i}": rng.bytes(size) for i in range(count)}
        st.put_many(objs)
        assert st.flush_writeback(timeout=600.0)
        for fid in list(st.sms.slabs):
            st.inject_failure(fid)
        t0 = time.perf_counter()
        for k, v in objs.items():        # one GET at a time, in order
            assert st.get(k) == v
        out[f"scan_{tag}_ms"] = round((time.perf_counter() - t0) * 1e3, 2)
        if prefetch:
            out["prefetch_hits"] = st.stats.prefetch_hits
            out["prefetch_wasted"] = st.stats.prefetch_wasted
            out["prefetch"] = st.prefetcher.snapshot()
        st.close()
    out["scan_speedup"] = round(
        out["scan_noprefetch_ms"] / max(out["scan_prefetch_ms"], 1e-9), 2)
    return out


def run_bench(smoke: bool) -> dict:
    if smoke:
        points = [bench_point(1 * MB, repeats=2)]
        scan = bench_scan(1 * MB, count=6)
    else:
        points = [bench_point(1 * MB, repeats=3),
                  bench_point(10 * MB, repeats=2),
                  bench_point(100 * MB, repeats=2)]
        scan = bench_scan(2 * MB, count=8)
    return {"bench": "get_latency", "smoke": smoke,
            "ec": {"k": 10, "p": 2},
            "cos_model": {"get_base_s": COS_GET_BASE_S,
                          "get_MBps": round(1.0 / COS_GET_PER_BYTE_S / MB)},
            "points": points, "scan": scan}


def _default_out(smoke: bool) -> str:
    name = "BENCH_get_smoke.json" if smoke else "BENCH_get.json"
    return os.path.join(ROOT, name)


def _write(result: dict, path: str) -> None:
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")


def run() -> list:
    """benchmarks.run entry point (smoke sizes, CSV rows)."""
    result = run_bench(smoke=True)
    _write(result, _default_out(smoke=True))
    rows = []
    for pt in result["points"]:
        tag = f"{pt['object_mb']:g}MB"
        rows.append(
            f"get_degraded_pipe_{tag},{pt['pipelined_degraded_ms'] * 1e3:.2f},"
            f"ms*1e-3 speedup={pt['degraded_speedup']}x vs serial")
        rows.append(
            f"get_aged_pipe_{tag},{pt['pipelined_aged_ms'] * 1e3:.2f},"
            f"ms*1e-3 speedup={pt['aged_speedup']}x vs serial")
    sc = result["scan"]
    rows.append(f"get_scan_prefetch,{sc['scan_prefetch_ms'] * 1e3:.2f},"
                f"ms*1e-3 speedup={sc['scan_speedup']}x "
                f"hits={sc['prefetch_hits']}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="1 MB point only (CI sanity); writes "
                         "BENCH_get_smoke.json unless --out is given")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    result = run_bench(args.smoke)
    out = args.out or _default_out(args.smoke)
    _write(result, out)
    for pt in result["points"]:
        print(f"{pt['object_mb']:>6g} MB | warm "
              f"{pt['serial_warm_ms']:>8.2f} -> {pt['pipelined_warm_ms']:>8.2f} ms "
              f"({pt['warm_speedup']}x) | aged "
              f"{pt['serial_aged_ms']:>8.2f} -> {pt['pipelined_aged_ms']:>8.2f} ms "
              f"({pt['aged_speedup']}x) | degraded "
              f"{pt['serial_degraded_ms']:>9.2f} -> "
              f"{pt['pipelined_degraded_ms']:>8.2f} ms "
              f"({pt['degraded_speedup']}x)")
    sc = result["scan"]
    print(f"scan {sc['objects']}x{sc['object_mb']:g} MB | "
          f"{sc['scan_noprefetch_ms']:.2f} -> {sc['scan_prefetch_ms']:.2f} ms "
          f"({sc['scan_speedup']}x) | prefetch hits {sc['prefetch_hits']} "
          f"wasted {sc['prefetch_wasted']}")
    print(f"wrote {os.path.relpath(out)}")


if __name__ == "__main__":
    main()
