"""§Roofline: derive the three roofline terms per (arch × shape × mesh)
from the dry-run records (experiments/dryrun.jsonl).

  compute    = FLOPs_per_chip / peak_FLOP/s
  memory     = bytes_per_chip / HBM_bw
  collective = ici_ring_bytes / ici_bw + dcn_ring_bytes / dcn_bw

(The post-SPMD HLO is the per-device program, so the analyzer's numbers
are already per-chip; multiplying by chips and dividing back per the
assignment formula is an identity.) MODEL_FLOPS uses 6·N·D for train,
2·N·D for prefill, 2·N_active·B for decode (attention-read flops added
for decode cells).
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional

from repro.configs import SHAPES_BY_NAME, get_config
from repro.launch.mesh import HW

DRYRUN = Path("experiments/dryrun.jsonl")


def active_params(cfg) -> int:
    """Activated parameter count (MoE: shared + top_k/E of routed)."""
    from repro.models import build_model
    total = build_model(cfg).param_count()
    if cfg.moe is None:
        return total
    m = cfg.moe
    routed_per_layer = m.num_experts * 3 * cfg.d_model * m.d_expert
    routed = cfg.num_layers * routed_per_layer
    active_routed = routed * m.top_k / m.num_experts
    return int(total - routed + active_routed)


def model_flops(cfg, shape) -> float:
    n_act = active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_act * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_act * tokens
    # decode: one token per sequence + attention reads over the cache
    flops = 2.0 * n_act * shape.global_batch
    if cfg.full_attention:
        attn = (4.0 * cfg.num_heads * cfg.head_dim * shape.seq_len
                * cfg.num_layers * shape.global_batch)
        flops += attn
    return flops


def load_records(path: Path = DRYRUN, tag: str = "") -> List[dict]:
    recs = []
    seen = {}
    if not path.exists():
        return recs
    for line in path.read_text().splitlines():
        try:
            r = json.loads(line)
        except json.JSONDecodeError:
            continue
        if r.get("ok") and r.get("tag", "") == tag:
            seen[(r["arch"], r["shape"], r["mesh"])] = r   # last wins
    return list(seen.values())


def terms(rec: dict) -> Dict[str, float]:
    a = rec["analysis"]
    compute = a["flops"] / HW["peak_flops_bf16"]
    memory = a["bytes_accessed"] / HW["hbm_bw"]
    collective = (a["ici_ring_bytes"] / HW["ici_bw"]
                  + a["dcn_ring_bytes"] / HW["dcn_bw"])
    dominant = max(("compute", compute), ("memory", memory),
                   ("collective", collective), key=lambda kv: kv[1])[0]
    cfg = get_config(rec["arch"])
    shape = SHAPES_BY_NAME[rec["shape"]]
    mf = model_flops(cfg, shape)
    hlo_total = a["flops"] * rec["chips"]
    useful = mf / hlo_total if hlo_total else 0.0
    bound = max(compute, memory, collective)
    mfu = (mf / rec["chips"] / HW["peak_flops_bf16"]) / bound if bound else 0.0
    return {"compute_s": compute, "memory_s": memory,
            "collective_s": collective, "dominant": dominant,
            "model_flops": mf, "useful_flops_ratio": useful,
            "roofline_fraction": mfu}


def table(recs: List[dict]) -> List[str]:
    lines = ["arch,shape,mesh,compute_s,memory_s,collective_s,dominant,"
             "useful_ratio,roofline_frac,mem_GiB,mem_GiB_tpu"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        t = terms(r)
        m = r["memory"]
        lines.append(
            f"{r['arch']},{r['shape']},{r['mesh']},"
            f"{t['compute_s']:.3e},{t['memory_s']:.3e},"
            f"{t['collective_s']:.3e},{t['dominant']},"
            f"{t['useful_flops_ratio']:.3f},{t['roofline_fraction']:.3f},"
            f"{m['total_bytes'] / 2**30:.1f},"
            f"{m['tpu_corrected_bytes'] / 2**30:.1f}")
    return lines


def run() -> list:
    recs = load_records()
    if not recs:
        return ["roofline,0.00,NO dryrun.jsonl found — run "
                "`python -m repro.launch.dryrun --all` first"]
    out = []
    doms = {}
    for r in recs:
        t = terms(r)
        doms[t["dominant"]] = doms.get(t["dominant"], 0) + 1
    out.append(f"roofline_cells,{len(recs)},dominant_terms={doms}")
    # worst roofline fraction (hillclimb candidate #1)
    worst = min(recs, key=lambda r: terms(r)["roofline_fraction"])
    tw = terms(worst)
    out.append(f"roofline_worst_cell,0.00,{worst['arch']}/{worst['shape']}"
               f"/{worst['mesh']} frac={tw['roofline_fraction']:.3f} "
               f"dom={tw['dominant']}")
    most_coll = max(recs, key=lambda r: terms(r)["collective_s"]
                    / max(max(terms(r)["compute_s"],
                              terms(r)["memory_s"]), 1e-12))
    tc = terms(most_coll)
    out.append(f"roofline_most_collective,0.00,{most_coll['arch']}/"
               f"{most_coll['shape']}/{most_coll['mesh']} "
               f"coll={tc['collective_s']:.2e}s")
    return out


if __name__ == "__main__":
    for line in table(load_records()):
        print(line)
