"""Paper Figs. 16/17: scale-out microbenchmark — offered load increases
stepwise; InfiniStore must scale function count and sustain throughput
(the static-capacity baseline saturates)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import MB, bench_store, row


def run() -> list:
    out = []
    st, clock = bench_store(elastic=True, gc_interval=120.0,
                            capacity=1 * MB)
    rng = np.random.default_rng(0)
    obj = 128 * 1024
    tput = []
    funcs = []
    for phase, nops in enumerate((20, 60, 120)):     # load x1, x3, x6
        t0 = time.perf_counter()
        for i in range(nops):
            st.put(f"p{phase}_{i}", rng.bytes(obj))
            clock.advance(0.2)
            if i % 20 == 0:
                st.gc_tick()
        wall = time.perf_counter() - t0
        tput.append(nops * obj / wall / MB)
        funcs.append(st.num_functions())
    out.append(row("fig16_scaleout_throughput", 0.0,
                   f"phases_MBps={[f'{t:.0f}' for t in tput]} "
                   f"functions={funcs} "
                   f"scaled={funcs[-1] > funcs[0]}"))
    # static baseline: fixed pool saturates (placement rejects -> COS path)
    st2, clock2 = bench_store(elastic=False, capacity=1 * MB)
    st2.placement.scale_out()                        # one fixed FG
    orig = st2.placement.scale_out
    st2.placement.autoscale = "linear"
    out.append(row("fig16_static_baseline", 0.0,
                   f"fixed_pool_functions={st2.num_functions()}"))
    return out
