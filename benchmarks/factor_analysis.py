"""Paper Figs. 22/23: factor analysis — which design options pay.

Configurations (paper §6.5):
  SNR  — static pool, no parallel recovery
  SR   — static pool, recovery on
  IS_NC— InfiniStore without demand-cache functions
  IS   — full InfiniStore
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import MB, bench_store, replay, row
from repro.data.traces import ibm_registry_trace


def run(num_requests: int = 600) -> list:
    events = ibm_registry_trace(num_objects=100, num_requests=num_requests,
                                duration=1800.0, scale_bytes=0.002, seed=9)
    out = []
    variants = {
        "SNR": dict(elastic=False, recovery=False),
        "SR": dict(elastic=False, recovery=True),
        "IS_NC": dict(elastic=True, recovery=True, demand_cache=False),
        "IS": dict(elastic=True, recovery=True),
    }
    results = {}
    for name, kw in variants.items():
        st, clock = bench_store(capacity=1 * MB, gc_interval=120.0,
                                M=3, N=3, **kw)
        if not kw.get("demand_cache", True):
            st._demand_cache = lambda ckey, data: None   # disable caching
        t0 = time.perf_counter()
        r = replay(st, clock, events, seed=9, fail_rate=0.02)
        us = (time.perf_counter() - t0) * 1e6 / len(events)
        results[name] = r
        out.append(row(f"fig22_23_{name}", us,
                       f"cost=${r.dollars['total']:.6f} "
                       f"hit={r.hit_ratio:.3f} "
                       f"get_p90={r.p('get_lat_us', 90):.0f}us"))
    # headline comparisons from the paper
    is_r, nc = results["IS"], results["IS_NC"]
    out.append(row("fig22_23_summary", 0.0,
                   f"IS_hit>{'=' if is_r.hit_ratio >= nc.hit_ratio else '<'}"
                   f"NC={is_r.hit_ratio >= nc.hit_ratio} "
                   f"IS_cost=${is_r.dollars['total']:.6f} "
                   f"SR_cost=${results['SR'].dollars['total']:.6f}"))
    return out
