"""End-to-end erasure-coding path benchmark (paper §5.2–§5.3).

Measures encode / decode / degraded-read throughput (MB/s) of the RS
codec at 1 / 10 / 100 MB object sizes, comparing the seed's per-fragment
path (framed concat + exp/log matmul + fresh Gauss-Jordan inversion per
degraded fragment) against the batched data path (`encode_many` /
`decode_many`: one stacked table-matmul per batch + LRU-cached decode
matrices). Full runs write ``BENCH_ec.json`` at the repo root so later
PRs have a perf trajectory; ``--smoke`` runs write
``BENCH_ec_smoke.json`` so CI never clobbers it.

Usage: PYTHONPATH=src python benchmarks/ec_path.py [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

if __package__ in (None, ""):                      # direct-script invocation
    _HERE = os.path.dirname(os.path.abspath(__file__))
    sys.path.insert(0, os.path.join(_HERE, ".."))
    sys.path.insert(0, os.path.join(_HERE, "..", "src"))

import numpy as np

from repro.core.ec import _HEADER, ECConfig, RSCodec
from repro.kernels.rs_gf256.ref import gf_inv_matrix_np, gf_matmul_np

MB = 1024 * 1024
ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")


# ---------------------------------------------------------------------------
# per-fragment baseline: the seed implementation, kept verbatim-in-spirit
# ---------------------------------------------------------------------------

def _encode_baseline(codec: RSCodec, frag: bytes) -> list:
    """Seed encode: `framed` bytes concat, exp/log matmul, row tobytes."""
    k, p = codec.cfg.k, codec.cfg.p
    framed = _HEADER.pack(len(frag)) + frag
    clen = -(-len(framed) // k)
    buf = np.zeros((k, clen), np.uint8)
    flat = np.frombuffer(framed, np.uint8)
    buf.reshape(-1)[:len(flat)] = flat
    parity = gf_matmul_np(codec._parity, buf)
    return [buf[i].tobytes() for i in range(k)] + \
           [parity[i].tobytes() for i in range(p)]


def _decode_baseline(codec: RSCodec, chunks: dict) -> bytes:
    """Seed decode: fresh O(k^3) inversion + exp/log matmul per fragment."""
    k = codec.cfg.k
    idx = sorted(chunks)[:k]
    if idx == list(range(k)):
        data_rows = np.stack(
            [np.frombuffer(chunks[i], np.uint8) for i in idx])
    else:
        sub = codec._gen[idx]
        surv = np.stack([np.frombuffer(chunks[i], np.uint8) for i in idx])
        data_rows = gf_matmul_np(gf_inv_matrix_np(sub), surv)
    framed = data_rows.reshape(-1).tobytes()
    (orig_len,) = _HEADER.unpack(framed[:_HEADER.size])
    return framed[_HEADER.size:_HEADER.size + orig_len]


def _best(fn, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def bench_point(size: int, frag_bytes: int, *, k: int = 10, p: int = 2,
                repeats: int = 2) -> dict:
    rng = np.random.default_rng(size)
    payload = rng.bytes(size)
    fragments = [payload[i:i + frag_bytes]
                 for i in range(0, size, frag_bytes)]
    codec = RSCodec(ECConfig(k=k, p=p))
    mb = size / MB

    # ---- encode ----
    t_enc_base = _best(
        lambda: [_encode_baseline(codec, f) for f in fragments], repeats)
    t_enc_batch = _best(lambda: codec.encode_many(fragments), repeats)
    chunk_lists = codec.encode_many(fragments)
    assert [c for c in chunk_lists] == \
        [_encode_baseline(codec, f) for f in fragments], "encode mismatch"

    # ---- degraded read: two data chunks lost per fragment ----
    lost = (1, min(3, k - 1))
    cmaps = [{i: ch[i] for i in range(k + p) if i not in lost}
             for ch in chunk_lists]
    t_dec_base = _best(
        lambda: [_decode_baseline(codec, cm) for cm in cmaps], repeats)
    t_dec_batch = _best(lambda: codec.decode_many(cmaps), repeats)
    assert b"".join(codec.decode_many(cmaps)) == payload, "decode mismatch"

    # ---- healthy read (all data rows survive — no matmul either way) ----
    healthy = [{i: ch[i] for i in range(k)} for ch in chunk_lists]
    t_dec_healthy = _best(lambda: codec.decode_many(healthy), repeats)

    info = codec.cache_info()
    return {
        "object_mb": mb, "fragments": len(fragments), "k": k, "p": p,
        "encode_base_MBps": round(mb / t_enc_base, 1),
        "encode_batched_MBps": round(mb / t_enc_batch, 1),
        "encode_speedup": round(t_enc_base / t_enc_batch, 2),
        "degraded_base_MBps": round(mb / t_dec_base, 1),
        "degraded_batched_MBps": round(mb / t_dec_batch, 1),
        "degraded_speedup": round(t_dec_base / t_dec_batch, 2),
        "healthy_MBps": round(mb / t_dec_healthy, 1),
        "decode_inversions": info["inversions"],
        "decode_cache_hits": info["hits"],
    }


def run_bench(smoke: bool) -> dict:
    if smoke:
        points = [bench_point(1 * MB, 128 * 1024, repeats=2)]
    else:
        points = [bench_point(1 * MB, 128 * 1024, repeats=3),
                  bench_point(10 * MB, 1 * MB, repeats=2),
                  bench_point(100 * MB, 10 * MB, repeats=1)]
    return {"bench": "ec_path", "smoke": smoke,
            "ec": {"k": 10, "p": 2}, "points": points}


def _default_out(smoke: bool) -> str:
    # smoke results go to a scratch file so CI never clobbers the
    # committed full-run perf trajectory in BENCH_ec.json
    name = "BENCH_ec_smoke.json" if smoke else "BENCH_ec.json"
    return os.path.join(ROOT, name)


def run() -> list:
    """benchmarks.run entry point (smoke sizes, CSV rows)."""
    result = run_bench(smoke=True)
    _write(result, _default_out(smoke=True))
    rows = []
    for pt in result["points"]:
        tag = f"{pt['object_mb']:g}MB"
        rows.append(f"ec_encode_batched_{tag},"
                    f"{pt['encode_batched_MBps']:.2f},"
                    f"MB/s speedup={pt['encode_speedup']}x")
        rows.append(f"ec_degraded_batched_{tag},"
                    f"{pt['degraded_batched_MBps']:.2f},"
                    f"MB/s speedup={pt['degraded_speedup']}x "
                    f"inversions={pt['decode_inversions']}")
    return rows


def _write(result: dict, path: str) -> None:
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="1 MB point only (CI sanity); writes "
                         "BENCH_ec_smoke.json unless --out is given")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    result = run_bench(args.smoke)
    out = args.out or _default_out(args.smoke)
    _write(result, out)
    for pt in result["points"]:
        print(f"{pt['object_mb']:>6g} MB | "
              f"encode {pt['encode_base_MBps']:>8.1f} -> "
              f"{pt['encode_batched_MBps']:>8.1f} MB/s "
              f"({pt['encode_speedup']}x) | "
              f"degraded {pt['degraded_base_MBps']:>7.1f} -> "
              f"{pt['degraded_batched_MBps']:>7.1f} MB/s "
              f"({pt['degraded_speedup']}x) | "
              f"inversions={pt['decode_inversions']}")
    print(f"wrote {os.path.relpath(out)}")


if __name__ == "__main__":
    main()
