"""Shared benchmark helpers: store factories, trace replay, timing."""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional

import numpy as np

from repro.core import Clock, InfiniStore, StoreConfig
from repro.core.ec import ECConfig
from repro.core.gc_window import GCConfig
from repro.obs import LatencyHistogram, quantile_us, summarize
from repro.data.traces import TraceEvent

MB = 1024 * 1024


def lat_summary(samples_us: Iterable[float]) -> Dict[str, float]:
    """Quantile summary through the SAME log-spaced histogram the store
    exports (`repro.obs.metrics`): every BENCH json reports p50/p99/p999
    with identical bucketing, so bench numbers and live `dump_metrics`
    output are directly comparable."""
    samples_us = list(samples_us)
    h = LatencyHistogram()
    for v in samples_us:
        h.record(v)
    out = summarize(h.snapshot())
    if samples_us:
        out["min_us"] = round(min(samples_us), 3)
        out["mean_us"] = round(sum(samples_us) / len(samples_us), 3)
        out["max_us"] = round(max(samples_us), 3)
    return out


def bench_store(*, elastic: bool = True, recovery: bool = True,
                demand_cache: bool = True, gc_interval: float = 60.0,
                M: int = 2, N: int = 2, capacity: int = 2 * MB,
                visibility_lag: float = 0.0) -> tuple:
    """Paper-shaped store scaled to CPU-bench sizes (EC 4+2, MB slabs)."""
    clock = Clock()
    cfg = StoreConfig(
        ec=ECConfig(k=4, p=2),
        function_capacity=capacity,
        fragment_bytes=1 * MB,
        gc=GCConfig(gc_interval=gc_interval if elastic else 1e12,
                    active_intervals=M, degraded_intervals=N,
                    active_warmup=gc_interval / 10,
                    degraded_warmup=gc_interval / 2),
        num_recovery_functions=4,
        enable_recovery=recovery,
        cos_visibility_lag=visibility_lag,
    )
    store = InfiniStore(cfg, clock=clock)
    if not demand_cache:
        store._demand_cache = lambda ckey, data: None
    if not elastic:
        store.window.mark = lambda key: None     # no compaction (IC-like)
    return store, clock


@dataclass
class ReplayResult:
    gets: int = 0
    puts: int = 0
    get_lat_us: List[float] = field(default_factory=list)
    put_lat_us: List[float] = field(default_factory=list)
    func_count_series: List[int] = field(default_factory=list)
    alive_series: List[int] = field(default_factory=list)
    hit_ratio: float = 0.0
    dollars: Dict[str, float] = field(default_factory=dict)
    overhead: float = 0.0

    def p(self, series: str, q: float) -> float:
        """Percentile (q in 0..100) through the shared histogram."""
        data = getattr(self, series)
        if not data:
            return 0.0
        h = LatencyHistogram()
        for v in data:
            h.record(v)
        return quantile_us(h.snapshot(), q / 100.0)

    def lat_summaries(self) -> Dict[str, Dict[str, float]]:
        return {"get": lat_summary(self.get_lat_us),
                "put": lat_summary(self.put_lat_us)}


def replay(store: InfiniStore, clock: Clock, events: List[TraceEvent],
           *, payload_cache: Optional[dict] = None,
           fail_rate: float = 0.0, seed: int = 0,
           scale_bytes: float = 1.0) -> ReplayResult:
    """Replay a trace against the store, driving the logical clock."""
    rng = np.random.default_rng(seed)
    res = ReplayResult()
    payloads = payload_cache if payload_cache is not None else {}
    t_prev = 0.0
    for ev in events:
        dt = max(ev.t - t_prev, 0.0)
        if dt > 0:
            clock.advance(dt)
            store.gc_tick()
        t_prev = ev.t
        size = max(16, int(ev.size * scale_bytes))
        if fail_rate and rng.random() < fail_rate and store.sms.slabs:
            fids = sorted(store.sms.slabs)
            store.inject_failure(fids[rng.integers(len(fids))])
        if ev.op == "put" or ev.key not in payloads:
            data = rng.bytes(min(size, 4 * MB))
            t0 = time.perf_counter()
            store.put(ev.key, data)
            res.put_lat_us.append((time.perf_counter() - t0) * 1e6)
            payloads[ev.key] = data
            res.puts += 1
        else:
            t0 = time.perf_counter()
            got = store.get(ev.key)
            res.get_lat_us.append((time.perf_counter() - t0) * 1e6)
            assert got == payloads[ev.key], f"corrupt read {ev.key}"
            res.gets += 1
        res.func_count_series.append(store.num_functions())
        res.alive_series.append(store.sms.alive_count())
    res.hit_ratio = store.stats.hit_ratio
    res.dollars = store.ledger.dollars()
    res.overhead = store.ledger.pay_per_access_overhead()
    return res


def row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.2f},{derived}"
