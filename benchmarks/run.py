"""Benchmark orchestrator: one module per paper table/figure.

Usage: PYTHONPATH=src python -m benchmarks.run [--only NAME]
Prints ``name,us_per_call,derived`` CSV (plus a roofline summary read
from the dry-run records, see benchmarks/roofline.py).
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


MODULES = [
    ("workloads", "Fig 1 workload characterization"),
    ("hit_ratio", "Table 2 SMS hit ratios"),
    ("elasticity", "Figs 9/15 elasticity"),
    ("cost_timeline", "Figs 10/11 cost + pay-per-access"),
    ("ycsb", "Figs 12-14 YCSB latency/throughput"),
    ("scaleout", "Figs 16/17 scale-out"),
    ("recovery", "Figs 18-21 parallel recovery"),
    ("factor_analysis", "Figs 22/23 factor analysis"),
    ("ec_path", "EC encode/decode throughput (writes BENCH_ec.json)"),
    ("put_latency", "sync vs async PUT ack latency "
                    "(writes BENCH_put_async.json)"),
    ("get_latency", "serial vs pipelined GET latency "
                    "(writes BENCH_get.json)"),
    ("shard_scaleout", "sharded multi-daemon PUT/GET scale-out "
                       "(writes BENCH_shard_smoke.json)"),
    ("fault_soak", "deterministic chaos soak + idle fault-plane "
                   "overhead (writes BENCH_faults.json)"),
    ("kernels", "kernel microbenchmarks"),
    ("roofline", "§Roofline summary (reads experiments/dryrun.jsonl)"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failures = 0
    for mod_name, desc in MODULES:
        if args.only and args.only != mod_name:
            continue
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{mod_name}",
                             fromlist=["run"])
            for line in mod.run():
                print(line, flush=True)
            print(f"# {mod_name} ({desc}) done in {time.time()-t0:.1f}s",
                  flush=True)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"# {mod_name} FAILED:", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
