"""Sharded scale-out benchmark: thread daemons vs worker processes.

Four questions the keyspace-partitioned stores must answer with
numbers:

1. **PUT-ack throughput vs shard count** — sustained acked MB/s from 8
   concurrent client threads under an S3-like COS latency model
   (bounded writeback depth, so the steady state is the real pipeline:
   client -> shard daemon -> journal -> slab ack -> background COS
   drain). Acceptance: aggregate PUT-ack throughput scales >= 2.5x
   from 1 -> 4 shards on the uniform-key workload. The smoke gate
   fails CI outright if 4 shards regress below 1 shard.
2. **Threads vs processes** — the same uniform curve through
   `ProcessShardedStore` (one worker process per shard, shared-memory
   data plane). Per-point CPU utilization (parent + workers, sampled
   from /proc) shows where the GIL was the binding constraint. Gates
   are CPU-aware: on a multi-core box the process curve at the top
   shard count must beat the same-count thread number by >= 1.3x and
   the 4-shard thread number outright; on a single core (where extra
   processes cannot add CPU) the gate is non-collapse — the IPC hop
   must not halve throughput, and the curve must not decay with shard
   count.
3. **Skew sensitivity** — every key routed to ONE hot shard (the
   adversarial case for hash partitioning): extra shards cannot help,
   so the skewed curve shows the honest lower bound.
4. **Crash-one-shard replay, in BOTH modes** — with writebacks held
   pending, one shard dies mid-stream (thread mode: simulated daemon
   kill; process mode: a real SIGKILL of the worker). Survivors must
   keep serving, and a timed `restart_shard` must replay the dead
   shard's journal with ZERO acked-write loss.

Full runs write ``BENCH_shard.json`` at the repo root; ``--smoke`` runs
write ``BENCH_shard_smoke.json`` so CI never clobbers it.

Usage: PYTHONPATH=src python benchmarks/shard_scaleout.py
           [--smoke] [--mode {thread,process,both}] [--out P]
"""
from __future__ import annotations

import argparse
import json
import os
import resource
import shutil
import sys
import tempfile
import threading
import time

if __package__ in (None, ""):                      # direct-script invocation
    _HERE = os.path.dirname(os.path.abspath(__file__))
    sys.path.insert(0, os.path.join(_HERE, ".."))
    sys.path.insert(0, os.path.join(_HERE, "..", "src"))

import numpy as np

from repro.core import (Clock, ProcessShardedStore, ShardedStore,
                        StoreConfig)
from repro.core.ec import ECConfig
from repro.core.gc_window import GCConfig

MB = 1024 * 1024
ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")

# S3-like COS model (same family as put_latency/spill_overhead): the
# background writers pay it, so sustained ack throughput reflects the
# whole pipeline, not just the daemon CPU path
COS_PUT_BASE_S = 0.002
COS_PUT_PER_BYTE_S = 1.0 / (100 * MB)
COS_LATENCY = {"put_delay_base_s": COS_PUT_BASE_S,
               "put_delay_per_byte_s": COS_PUT_PER_BYTE_S}

CLIENTS = 8                       # concurrent client threads
CPUS = os.cpu_count() or 1


def make_sharded(num_shards: int, spill_root: str, *,
                 depth: int = 16, mode: str = "thread"):
    cfg = StoreConfig(
        ec=ECConfig(k=4, p=2),
        function_capacity=512 * MB,
        fragment_bytes=4 * MB,
        gc=GCConfig(gc_interval=1e12),
        num_recovery_functions=4,
        writeback_depth=depth,                 # backpressure: sustained
        spill_dir=spill_root,                  # journaled ack path
    )
    if mode == "process":
        return ProcessShardedStore(cfg, num_shards=num_shards,
                                   clock=Clock(),
                                   cos_latency=COS_LATENCY)
    st = ShardedStore(cfg, num_shards=num_shards, clock=Clock())
    st.cos.put_delay_base_s = COS_PUT_BASE_S
    st.cos.put_delay_per_byte_s = COS_PUT_PER_BYTE_S
    return st


# -- CPU accounting ---------------------------------------------------------

_CLK_TCK = os.sysconf("SC_CLK_TCK") if hasattr(os, "sysconf") else 100


def _pid_cpu_s(pid: int) -> float:
    """utime+stime of one live process from /proc (Linux; 0 elsewhere).

    RUSAGE_CHILDREN only covers *waited-for* children, so live shard
    workers must be sampled directly."""
    try:
        with open(f"/proc/{pid}/stat") as f:
            stat = f.read()
        fields = stat.rsplit(")", 1)[1].split()
        return (int(fields[11]) + int(fields[12])) / _CLK_TCK
    except (OSError, IndexError, ValueError):
        return 0.0


def _cpu_seconds(st) -> float:
    r = resource.getrusage(resource.RUSAGE_SELF)
    total = r.ru_utime + r.ru_stime
    pids = getattr(st, "worker_pids", None)
    if pids is not None:
        total += sum(_pid_cpu_s(p) for p in pids())
    return total


def _skewed_key(st, t: int, i: int) -> str:
    """Rejection-sample a key that routes to shard 0 (the hot shard)."""
    n = 0
    while True:
        key = f"hot/{t}/{i}/{n}"
        if st.router.shard_of(key) == 0:
            return key
        n += 1


def _run_clients(fn) -> float:
    """Run `fn(t)` on CLIENTS threads behind a start barrier; return
    the wall seconds from barrier release to the last join."""
    barrier = threading.Barrier(CLIENTS + 1)
    errors: list = []

    def wrap(t):
        barrier.wait()
        try:
            fn(t)
        except BaseException as e:             # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=wrap, args=(t,))
               for t in range(CLIENTS)]
    for th in threads:
        th.start()
    barrier.wait()
    t0 = time.perf_counter()
    for th in threads:
        th.join()
    if errors:
        raise errors[0]
    return time.perf_counter() - t0


def bench_workload(num_shards: int, *, skewed: bool, per_thread: int,
                   size: int, mode: str = "thread") -> dict:
    """One shard-count point: sustained PUT-ack throughput (with CPU
    utilization over the PUT phase), then (after a full writeback
    flush) warm batched-GET throughput on the same keys, plus the
    shard-balance histogram."""
    root = tempfile.mkdtemp(prefix=f"shard-bench-{mode}-{num_shards}-")
    st = make_sharded(num_shards, root, mode=mode)
    rng = np.random.default_rng(num_shards)
    payloads = [rng.bytes(size) for _ in range(4)]
    if skewed:
        keys = [[_skewed_key(st, t, i) for i in range(per_thread)]
                for t in range(CLIENTS)]
    else:
        keys = [[f"u/{t}/{i}" for i in range(per_thread)]
                for t in range(CLIENTS)]

    def put_client(t):
        futs = [st.put_async(k, payloads[i % 4])
                for i, k in enumerate(keys[t])]
        for f in futs:
            assert f.result() == 1

    cpu0 = _cpu_seconds(st)
    put_s = _run_clients(put_client)
    cpu_put = _cpu_seconds(st) - cpu0
    total = CLIENTS * per_thread * size
    assert st.flush_writeback(timeout=600.0)

    def get_client(t):
        mine = keys[t]
        for i in range(0, len(mine), 8):
            got = st.get_many(mine[i:i + 8])
            assert all(v is not None for v in got.values())

    get_s = _run_clients(get_client)
    balance = st.shard_balance()
    stats = st.stats
    out = {"shards": num_shards,
           "mode": mode,
           "workload": "skewed" if skewed else "uniform",
           "clients": CLIENTS,
           "objects": CLIENTS * per_thread,
           "object_mb": size / MB,
           "total_mb": round(total / MB, 1),
           "put_ack_MBps": round(total / MB / put_s, 1),
           "put_acks_per_s": round(CLIENTS * per_thread / put_s, 1),
           "put_cpu_cores_busy": round(cpu_put / put_s, 2),
           "get_MBps": round(total / MB / get_s, 1),
           "balance": balance,
           "gather_invokes": stats.gather_invokes,
           "commit_tickets": stats.commit_tickets}
    st.close()
    shutil.rmtree(root, ignore_errors=True)
    return out


def bench_crash_replay(num_shards: int = 4, *, objects: int = 48,
                       size: int = 512 * 1024,
                       mode: str = "thread") -> dict:
    """Kill one shard with every write acked-but-unpersisted (a REAL
    SIGKILL of the worker in process mode), check the survivors keep
    serving mid-outage, then time the journal replay and verify zero
    acked loss."""
    root = tempfile.mkdtemp(prefix=f"shard-crash-{mode}-")
    st = make_sharded(num_shards, root, depth=4096, mode=mode)
    st.pause_writeback()                      # hold everything pending
    rng = np.random.default_rng(7)
    vals = {f"c{i}": rng.bytes(size) for i in range(objects)}
    for k, v in vals.items():
        assert st.put(k, v) == 1
    victim = 0
    dead = [k for k in vals if st.router.shard_of(k) == victim]
    st.simulate_crash(shard=victim)
    # mid-outage: every surviving shard's keyspace still serves
    survivors_ok = all(st.get(k) == vals[k] for k in vals
                       if st.router.shard_of(k) != victim)
    t0 = time.perf_counter()
    st.restart_shard(victim)
    replay_s = time.perf_counter() - t0
    lost = sum(1 for k, v in vals.items() if st.get(k) != v)
    replayed = st.shards[victim].stats.spill_replayed_writes
    st.resume_writeback()
    persisted = st.flush_writeback(timeout=600.0)
    out = {"shards": num_shards,
           "mode": mode,
           "acked_objects": objects,
           "object_kb": size // 1024,
           "victim_shard": victim,
           "victim_objects": len(dead),
           "survivors_served_during_outage": bool(survivors_ok),
           "replay_ms": round(replay_s * 1e3, 2),
           "replayed_writes": replayed,
           "lost_after_restart": lost,
           "all_cos_persistent": bool(persisted)}
    st.close()
    shutil.rmtree(root, ignore_errors=True)
    return out


def run_bench(smoke: bool, mode: str = "both") -> dict:
    if smoke:
        shard_counts, per_thread, size = (1, 4), 6, 512 * 1024
        skew_counts = (4,)
        crash_kw = dict(objects=16, size=256 * 1024)
    else:
        shard_counts, per_thread, size = (1, 2, 4, 8), 16, 1 * MB
        skew_counts = shard_counts
        crash_kw = {}
    do_thread = mode in ("thread", "both")
    do_process = mode in ("process", "both")
    uniform, process, skewed = [], [], []
    crash = crash_process = None
    if do_thread:
        uniform = [bench_workload(s, skewed=False,
                                  per_thread=per_thread, size=size)
                   for s in shard_counts]
        skewed = [bench_workload(s, skewed=True, per_thread=per_thread,
                                 size=size) for s in skew_counts]
        crash = bench_crash_replay(**crash_kw)
    if do_process:
        process = [bench_workload(s, skewed=False,
                                  per_thread=per_thread, size=size,
                                  mode="process")
                   for s in shard_counts]
        crash_process = bench_crash_replay(mode="process", **crash_kw)
    by_shards = {pt["shards"]: pt for pt in uniform}
    scale_4x = None
    if 1 in by_shards and 4 in by_shards:
        scale_4x = round(by_shards[4]["put_ack_MBps"]
                         / by_shards[1]["put_ack_MBps"], 2)
    proc_vs_thread = proc_vs_thread_best = None
    if uniform and process:
        top = shard_counts[-1]
        tpt = {pt["shards"]: pt for pt in process}
        if top in by_shards and top in tpt:
            proc_vs_thread = round(tpt[top]["put_ack_MBps"]
                                   / by_shards[top]["put_ack_MBps"], 2)
        # the process curve's sweet spot vs the SAME-count thread
        # number: on an oversubscribed single-CPU box the top count
        # measures scheduler thrash, not the IPC hop, so the
        # single-core gate reads this ratio instead
        best = max(process, key=lambda pt: pt["put_ack_MBps"])
        if best["shards"] in by_shards:
            proc_vs_thread_best = round(
                best["put_ack_MBps"]
                / by_shards[best["shards"]]["put_ack_MBps"], 2)
    return {"bench": "shard_scaleout", "smoke": smoke, "cpus": CPUS,
            "ec": {"k": 4, "p": 2},
            "cos_model": {"put_base_s": COS_PUT_BASE_S,
                          "put_MBps": round(1.0 / COS_PUT_PER_BYTE_S / MB)},
            "put_ack_scale_1_to_4": scale_4x,
            "process_vs_thread_at_max": proc_vs_thread,
            "process_vs_thread_best": proc_vs_thread_best,
            "uniform": uniform, "process": process, "skewed": skewed,
            "crash": crash, "crash_process": crash_process}


def check_gates(result: dict) -> list:
    """CI gates, CPU-aware. Always: 4-shard thread PUT-ack must not
    regress below 1 shard; either crash scenario must lose nothing
    while the survivors kept serving; the process curve must not decay
    with shard count (>10%) over the counts the box can actually run
    in parallel. Multi-core (>=4 CPUs) only: the top process point
    must beat the same-count thread point by >= 1.3x AND the 4-shard
    thread number outright — on a single core extra processes cannot
    add CPU, so there the gate is non-collapse: at the process curve's
    best point the IPC hop must keep >= 30% of the same-count
    thread-mode number — measured hop cost on one core is ~0.4-0.6x
    and noisy, so this catches a broken data plane (every payload
    falling back to inline pickle, a serialized lock), not the
    inherent hop."""
    problems = []
    scale = result.get("put_ack_scale_1_to_4")
    if scale is not None and scale < 1.0:
        problems.append(
            f"4-shard PUT-ack throughput regressed below 1 shard "
            f"({scale}x)")
    for tag in ("crash", "crash_process"):
        crash = result.get(tag)
        if crash is None:
            continue
        if crash["lost_after_restart"] != 0:
            problems.append(f"{tag}: replay lost "
                            f"{crash['lost_after_restart']} acked writes")
        if not crash["survivors_served_during_outage"]:
            problems.append(
                f"{tag}: surviving shards failed reads during the outage")
    cpus = result.get("cpus", 1)
    process = result.get("process") or []
    parallel = [pt for pt in process if pt["shards"] <= max(cpus, 4)]
    for a, b in zip(parallel, parallel[1:]):
        if b["put_ack_MBps"] < 0.9 * a["put_ack_MBps"]:
            problems.append(
                f"process PUT-ack decays {a['shards']}->{b['shards']} "
                f"shards ({a['put_ack_MBps']} -> {b['put_ack_MBps']} MB/s)")
    ratio = result.get("process_vs_thread_at_max")
    if ratio is not None:
        if cpus >= 4:
            if ratio < 1.3:
                problems.append(
                    f"process mode only {ratio}x thread mode at the top "
                    f"shard count on {cpus} CPUs (need >= 1.3x)")
            thread4 = {pt["shards"]: pt["put_ack_MBps"]
                       for pt in result.get("uniform", [])}.get(4)
            top_proc = result["process"][-1]["put_ack_MBps"]
            if thread4 is not None and top_proc < thread4:
                problems.append(
                    f"top process point ({top_proc} MB/s) below the "
                    f"4-shard thread number ({thread4} MB/s)")
        else:
            best = result.get("process_vs_thread_best")
            if best is not None and best < 0.3:
                problems.append(
                    f"process-mode IPC hop collapsed throughput to "
                    f"{best}x thread mode at the process curve's best "
                    f"point on a single CPU (need >= 0.3x)")
    return problems


def _default_out(smoke: bool) -> str:
    name = "BENCH_shard_smoke.json" if smoke else "BENCH_shard.json"
    return os.path.join(ROOT, name)


def _write(result: dict, path: str) -> None:
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")


def _all_points(result: dict) -> list:
    return (result.get("uniform") or []) + (result.get("process") or []) \
        + (result.get("skewed") or [])


def run() -> list:
    """benchmarks.run entry point (smoke sizes, CSV rows)."""
    result = run_bench(smoke=True)
    _write(result, _default_out(smoke=True))
    rows = []
    for pt in _all_points(result):
        rows.append(f"put_ack_{pt['mode']}_{pt['workload']}_"
                    f"{pt['shards']}shard,{pt['put_ack_MBps']},"
                    f"MB/s get={pt['get_MBps']}MB/s "
                    f"cpu={pt['put_cpu_cores_busy']}")
    for tag in ("crash", "crash_process"):
        crash = result.get(tag)
        if crash is not None:
            rows.append(f"shard_{tag}_replay,{crash['replay_ms']},"
                        f"ms lost={crash['lost_after_restart']}")
    for p in check_gates(result):
        rows.append(f"# GATE FAILED: {p}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="1 and 4 shards only, small objects (CI gate); "
                         "writes BENCH_shard_smoke.json unless --out")
    ap.add_argument("--mode", choices=("thread", "process", "both"),
                    default="both",
                    help="which front-end(s) to measure")
    ap.add_argument("--process", dest="mode", action="store_const",
                    const="process",
                    help="shorthand for --mode process")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    result = run_bench(args.smoke, mode=args.mode)
    out = args.out or _default_out(args.smoke)
    _write(result, out)
    for pt in _all_points(result):
        print(f"{pt['shards']:>2} shards | {pt['mode']:>7} | "
              f"{pt['workload']:>7} | "
              f"put ack {pt['put_ack_MBps']:>7.1f} MB/s "
              f"({pt['put_acks_per_s']:>6.1f} acks/s, "
              f"{pt['put_cpu_cores_busy']:>4.2f} cores) | "
              f"get {pt['get_MBps']:>7.1f} MB/s | balance {pt['balance']}")
    for tag in ("crash", "crash_process"):
        crash = result.get(tag)
        if crash is None:
            continue
        print(f"{tag}: shard {crash['victim_shard']} "
              f"({crash['victim_objects']}/{crash['acked_objects']} objects)"
              f" | survivors served: {crash['survivors_served_during_outage']}"
              f" | replay {crash['replay_ms']:.1f} ms"
              f" | lost {crash['lost_after_restart']}"
              f" | COS-persistent {crash['all_cos_persistent']}")
    if result["put_ack_scale_1_to_4"] is not None:
        print(f"PUT-ack scaling 1 -> 4 shards: "
              f"{result['put_ack_scale_1_to_4']}x (uniform threads)")
    if result["process_vs_thread_at_max"] is not None:
        print(f"process vs thread at the top shard count: "
              f"{result['process_vs_thread_at_max']}x on {CPUS} CPUs "
              f"(best-point ratio {result['process_vs_thread_best']}x)")
    problems = check_gates(result)
    print(f"wrote {os.path.relpath(out)}")
    if problems:
        for p in problems:
            print(f"GATE FAILED: {p}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
