"""Sharded multi-daemon scale-out benchmark (`repro.core.shard`).

Three questions the keyspace-partitioned `ShardedStore` must answer
with numbers:

1. **PUT-ack throughput vs shard count** — sustained acked MB/s from 8
   concurrent client threads under an S3-like COS latency model
   (bounded writeback depth, so the steady state is the real pipeline:
   client -> shard daemon -> journal -> slab ack -> background COS
   drain). Acceptance: aggregate PUT-ack throughput scales >= 2.5x
   from 1 -> 4 shards on the uniform-key workload. The smoke gate
   fails CI outright if 4 shards regress below 1 shard.
2. **Skew sensitivity** — the same workload with every key routed to
   ONE hot shard (the adversarial case for hash partitioning): extra
   shards cannot help, so the skewed curve shows the honest lower
   bound and the uniform/skew gap isolates what partitioning buys.
3. **Crash-one-shard replay** — with writebacks held pending, one
   shard's daemon is killed mid-stream; the surviving shards must keep
   serving their keyspaces, and a timed `restart_shard` must replay
   the dead shard's journal with ZERO acked-write loss.

GET throughput (warm, slab-resident reads through the scatter/join
fan-out) is reported per shard count as well.

Full runs write ``BENCH_shard.json`` at the repo root; ``--smoke`` runs
write ``BENCH_shard_smoke.json`` so CI never clobbers it.

Usage: PYTHONPATH=src python benchmarks/shard_scaleout.py [--smoke] [--out P]
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
import tempfile
import threading
import time

if __package__ in (None, ""):                      # direct-script invocation
    _HERE = os.path.dirname(os.path.abspath(__file__))
    sys.path.insert(0, os.path.join(_HERE, ".."))
    sys.path.insert(0, os.path.join(_HERE, "..", "src"))

import numpy as np

from repro.core import Clock, ShardedStore, StoreConfig
from repro.core.ec import ECConfig
from repro.core.gc_window import GCConfig

MB = 1024 * 1024
ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")

# S3-like COS model (same family as put_latency/spill_overhead): the
# background writers pay it, so sustained ack throughput reflects the
# whole pipeline, not just the daemon CPU path
COS_PUT_BASE_S = 0.002
COS_PUT_PER_BYTE_S = 1.0 / (100 * MB)

CLIENTS = 8                       # concurrent client threads


def make_sharded(num_shards: int, spill_root: str, *,
                 depth: int = 16) -> ShardedStore:
    cfg = StoreConfig(
        ec=ECConfig(k=4, p=2),
        function_capacity=512 * MB,
        fragment_bytes=4 * MB,
        gc=GCConfig(gc_interval=1e12),
        num_recovery_functions=4,
        writeback_depth=depth,                 # backpressure: sustained
        spill_dir=spill_root,                  # journaled ack path
    )
    st = ShardedStore(cfg, num_shards=num_shards, clock=Clock())
    st.cos.put_delay_base_s = COS_PUT_BASE_S
    st.cos.put_delay_per_byte_s = COS_PUT_PER_BYTE_S
    return st


def _skewed_key(st: ShardedStore, t: int, i: int) -> str:
    """Rejection-sample a key that routes to shard 0 (the hot shard)."""
    n = 0
    while True:
        key = f"hot/{t}/{i}/{n}"
        if st.router.shard_of(key) == 0:
            return key
        n += 1


def _run_clients(fn) -> float:
    """Run `fn(t)` on CLIENTS threads behind a start barrier; return
    the wall seconds from barrier release to the last join."""
    barrier = threading.Barrier(CLIENTS + 1)
    errors: list = []

    def wrap(t):
        barrier.wait()
        try:
            fn(t)
        except BaseException as e:             # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=wrap, args=(t,))
               for t in range(CLIENTS)]
    for th in threads:
        th.start()
    barrier.wait()
    t0 = time.perf_counter()
    for th in threads:
        th.join()
    if errors:
        raise errors[0]
    return time.perf_counter() - t0


def bench_workload(num_shards: int, *, skewed: bool, per_thread: int,
                   size: int) -> dict:
    """One shard-count point: sustained PUT-ack throughput, then (after
    a full writeback flush) warm batched-GET throughput on the same
    keys, plus the shard-balance histogram."""
    root = tempfile.mkdtemp(prefix=f"shard-bench-{num_shards}-")
    st = make_sharded(num_shards, root)
    rng = np.random.default_rng(num_shards)
    payloads = [rng.bytes(size) for _ in range(4)]
    if skewed:
        keys = [[_skewed_key(st, t, i) for i in range(per_thread)]
                for t in range(CLIENTS)]
    else:
        keys = [[f"u/{t}/{i}" for i in range(per_thread)]
                for t in range(CLIENTS)]

    def put_client(t):
        futs = [st.put_async(k, payloads[i % 4])
                for i, k in enumerate(keys[t])]
        for f in futs:
            assert f.result() == 1

    put_s = _run_clients(put_client)
    total = CLIENTS * per_thread * size
    assert st.flush_writeback(timeout=600.0)

    def get_client(t):
        mine = keys[t]
        for i in range(0, len(mine), 8):
            got = st.get_many(mine[i:i + 8])
            assert all(v is not None for v in got.values())

    get_s = _run_clients(get_client)
    balance = st.shard_balance()
    stats = st.stats
    out = {"shards": num_shards,
           "workload": "skewed" if skewed else "uniform",
           "clients": CLIENTS,
           "objects": CLIENTS * per_thread,
           "object_mb": size / MB,
           "total_mb": round(total / MB, 1),
           "put_ack_MBps": round(total / MB / put_s, 1),
           "put_acks_per_s": round(CLIENTS * per_thread / put_s, 1),
           "get_MBps": round(total / MB / get_s, 1),
           "balance": balance,
           "gather_invokes": stats.gather_invokes,
           "commit_tickets": stats.commit_tickets}
    st.close()
    shutil.rmtree(root, ignore_errors=True)
    return out


def bench_crash_replay(num_shards: int = 4, *, objects: int = 48,
                       size: int = 512 * 1024) -> dict:
    """Kill one shard with every write acked-but-unpersisted, check the
    survivors keep serving mid-outage, then time the journal replay and
    verify zero acked loss."""
    root = tempfile.mkdtemp(prefix="shard-crash-")
    st = make_sharded(num_shards, root, depth=4096)
    st.pause_writeback()                      # hold everything pending
    rng = np.random.default_rng(7)
    vals = {f"c{i}": rng.bytes(size) for i in range(objects)}
    for k, v in vals.items():
        assert st.put(k, v) == 1
    victim = 0
    dead = [k for k in vals if st.router.shard_of(k) == victim]
    st.simulate_crash(shard=victim)
    # mid-outage: every surviving shard's keyspace still serves
    survivors_ok = all(st.get(k) == vals[k] for k in vals
                       if st.router.shard_of(k) != victim)
    t0 = time.perf_counter()
    st.restart_shard(victim)
    replay_s = time.perf_counter() - t0
    lost = sum(1 for k, v in vals.items() if st.get(k) != v)
    replayed = st.shards[victim].stats.spill_replayed_writes
    st.resume_writeback()
    persisted = st.flush_writeback(timeout=600.0)
    out = {"shards": num_shards,
           "acked_objects": objects,
           "object_kb": size // 1024,
           "victim_shard": victim,
           "victim_objects": len(dead),
           "survivors_served_during_outage": bool(survivors_ok),
           "replay_ms": round(replay_s * 1e3, 2),
           "replayed_writes": replayed,
           "lost_after_restart": lost,
           "all_cos_persistent": bool(persisted)}
    st.close()
    shutil.rmtree(root, ignore_errors=True)
    return out


def run_bench(smoke: bool) -> dict:
    if smoke:
        shard_counts, per_thread, size = (1, 4), 6, 512 * 1024
        skew_counts = (4,)
        crash = bench_crash_replay(objects=16, size=256 * 1024)
    else:
        shard_counts, per_thread, size = (1, 2, 4, 8), 16, 1 * MB
        skew_counts = shard_counts
        crash = bench_crash_replay()
    uniform = [bench_workload(s, skewed=False, per_thread=per_thread,
                              size=size) for s in shard_counts]
    skewed = [bench_workload(s, skewed=True, per_thread=per_thread,
                             size=size) for s in skew_counts]
    by_shards = {pt["shards"]: pt for pt in uniform}
    scale_4x = None
    if 1 in by_shards and 4 in by_shards:
        scale_4x = round(by_shards[4]["put_ack_MBps"]
                         / by_shards[1]["put_ack_MBps"], 2)
    return {"bench": "shard_scaleout", "smoke": smoke,
            "ec": {"k": 4, "p": 2},
            "cos_model": {"put_base_s": COS_PUT_BASE_S,
                          "put_MBps": round(1.0 / COS_PUT_PER_BYTE_S / MB)},
            "put_ack_scale_1_to_4": scale_4x,
            "uniform": uniform, "skewed": skewed, "crash": crash}


def check_gates(result: dict) -> list:
    """CI gates: 4-shard uniform PUT-ack throughput must not regress
    below 1 shard (smoke + full), and the crash scenario must lose
    nothing while the survivors kept serving."""
    problems = []
    scale = result["put_ack_scale_1_to_4"]
    if scale is not None and scale < 1.0:
        problems.append(
            f"4-shard PUT-ack throughput regressed below 1 shard "
            f"({scale}x)")
    crash = result["crash"]
    if crash["lost_after_restart"] != 0:
        problems.append(
            f"crash replay lost {crash['lost_after_restart']} acked writes")
    if not crash["survivors_served_during_outage"]:
        problems.append("surviving shards failed reads during the outage")
    return problems


def _default_out(smoke: bool) -> str:
    name = "BENCH_shard_smoke.json" if smoke else "BENCH_shard.json"
    return os.path.join(ROOT, name)


def _write(result: dict, path: str) -> None:
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")


def run() -> list:
    """benchmarks.run entry point (smoke sizes, CSV rows)."""
    result = run_bench(smoke=True)
    _write(result, _default_out(smoke=True))
    rows = []
    for pt in result["uniform"] + result["skewed"]:
        rows.append(f"put_ack_{pt['workload']}_{pt['shards']}shard,"
                    f"{pt['put_ack_MBps']},MB/s get={pt['get_MBps']}MB/s")
    crash = result["crash"]
    rows.append(f"shard_crash_replay,{crash['replay_ms']},"
                f"ms lost={crash['lost_after_restart']}")
    for p in check_gates(result):
        rows.append(f"# GATE FAILED: {p}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="1 and 4 shards only, small objects (CI gate); "
                         "writes BENCH_shard_smoke.json unless --out")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    result = run_bench(args.smoke)
    out = args.out or _default_out(args.smoke)
    _write(result, out)
    for pt in result["uniform"] + result["skewed"]:
        print(f"{pt['shards']:>2} shards | {pt['workload']:>7} | "
              f"put ack {pt['put_ack_MBps']:>7.1f} MB/s "
              f"({pt['put_acks_per_s']:>6.1f} acks/s) | "
              f"get {pt['get_MBps']:>7.1f} MB/s | balance {pt['balance']}")
    crash = result["crash"]
    print(f"crash shard {crash['victim_shard']} "
          f"({crash['victim_objects']}/{crash['acked_objects']} objects) | "
          f"survivors served: {crash['survivors_served_during_outage']} | "
          f"replay {crash['replay_ms']:.1f} ms | "
          f"lost {crash['lost_after_restart']} | "
          f"COS-persistent {crash['all_cos_persistent']}")
    if result["put_ack_scale_1_to_4"] is not None:
        print(f"PUT-ack scaling 1 -> 4 shards: "
              f"{result['put_ack_scale_1_to_4']}x (uniform)")
    problems = check_gates(result)
    print(f"wrote {os.path.relpath(out)}")
    if problems:
        for p in problems:
            print(f"GATE FAILED: {p}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
