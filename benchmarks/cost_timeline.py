"""Paper Figs. 10/11: hourly cost breakdown, pay-per-access overhead, and
comparison against statically-provisioned ElastiCache-style baselines."""
from __future__ import annotations

import time

from benchmarks.common import MB, bench_store, replay, row
from repro.core.costmodel import (ELASTICACHE_M6G_LARGE_HOURLY,
                                  ELASTICACHE_R6G_2XLARGE_HOURLY,
                                  elasticache_cost)
from repro.data.traces import ibm_registry_trace


def run() -> list:
    out = []
    hours = 2.0
    events = ibm_registry_trace(num_objects=120, num_requests=1000,
                                duration=hours * 3600.0,
                                scale_bytes=0.002, seed=11)
    st, clock = bench_store(elastic=True, gc_interval=300.0, M=3, N=4,
                            capacity=1 * MB)
    t0 = time.perf_counter()
    r = replay(st, clock, events, seed=11, fail_rate=0.01)
    us = (time.perf_counter() - t0) * 1e6 / len(events)
    d = r.dollars
    out.append(row("fig10_cost_breakdown", us,
                   f"request=${d['request']:.6f} warmup=${d['warmup']:.6f} "
                   f"recovery=${d['recovery']:.6f} cos=${d['cos']:.6f}"))
    out.append(row("fig10_pay_per_access_overhead", 0.0,
                   f"overhead={r.overhead * 100:.2f}% (paper: 26.00%)"))
    # Fig 11: static baselines (scaled: 1 instance-hour equivalents)
    ec_storage = elasticache_cost(ELASTICACHE_R6G_2XLARGE_HOURLY, 1, hours)
    ec_cache = elasticache_cost(ELASTICACHE_M6G_LARGE_HOURLY, 1, hours)
    ratio_s = ec_storage / max(d["total"], 1e-9)
    ratio_c = ec_cache / max(d["total"], 1e-9)
    out.append(row("fig11_vs_static_baselines", 0.0,
                   f"IS=${d['total']:.6f} ECstorage=${ec_storage:.3f} "
                   f"({ratio_s:.0f}x) ECcache=${ec_cache:.3f} "
                   f"({ratio_c:.0f}x)"))
    return out
