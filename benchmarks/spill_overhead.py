"""Spill-journal overhead benchmark: crash-consistent writeback (§5.3.2).

Two questions the durable spill journal must answer with numbers:

1. **Ack cost** — how much PUT-ack latency does journaling every
   enqueued write (before the ack) add over the memory-only pending map
   (`spill_dir=None`)?  The acceptance bar is <= 25% at 1 MB.  COS is
   modelled S3-like (same model as put_latency.py) so the ack paths
   being compared are the real persistent-buffer ack paths.
2. **Replay cost** — how long does a daemon restart take to replay the
   journal back into the queue, as a function of acked-but-unpersisted
   bytes at the crash?  Measured by killing the daemon mid-flight
   (`simulate_crash`) and timing the rebuild, then verifying every
   acked key is readable and flushes to COS.

Full runs write ``BENCH_spill.json`` at the repo root; ``--smoke`` runs
write ``BENCH_spill_smoke.json`` so CI never clobbers it.

Usage: PYTHONPATH=src python benchmarks/spill_overhead.py [--smoke] [--out P]
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
import tempfile
import time

if __package__ in (None, ""):                      # direct-script invocation
    _HERE = os.path.dirname(os.path.abspath(__file__))
    sys.path.insert(0, os.path.join(_HERE, ".."))
    sys.path.insert(0, os.path.join(_HERE, "..", "src"))

import numpy as np

from repro.core import Clock, InfiniStore, StoreConfig
from repro.core.ec import ECConfig
from repro.core.gc_window import GCConfig

MB = 1024 * 1024
ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")

# same S3-like COS PUT model as put_latency.py (~15 ms base + 100 MB/s)
COS_PUT_BASE_S = 0.015
COS_PUT_PER_BYTE_S = 1.0 / (100 * MB)


def make_store(*, spill_dir, cos_model: bool = True) -> InfiniStore:
    cfg = StoreConfig(
        ec=ECConfig(k=10, p=2),
        function_capacity=512 * MB,
        fragment_bytes=64 * MB,
        gc=GCConfig(gc_interval=1e12),
        num_recovery_functions=4,
        writeback_depth=4096,
        spill_dir=spill_dir,
    )
    st = InfiniStore(cfg, clock=Clock())
    if cos_model:
        st.cos.put_delay_base_s = COS_PUT_BASE_S
        st.cos.put_delay_per_byte_s = COS_PUT_PER_BYTE_S
    return st


def bench_ack(size: int, repeats: int, max_repeats: int = 0) -> dict:
    """Journaled vs memory-only PUT ack latency (async writeback both).
    The two modes' PUTs are INTERLEAVED so both sample the same machine
    load windows, the floors are min-of-N (the systematic cost, with
    noisy-neighbor spikes excluded), and sampling continues past
    `repeats` until both floors stabilize (no new min for 8 straight
    pairs) or `max_repeats` is hit — shared CI boxes need the adaptive
    tail to find a quiet window. The background COS writers are paused
    during the measured PUTs so both modes see an identical quiesced
    store; they are resumed and fully flushed afterwards to verify the
    durability half."""
    rng = np.random.default_rng(size)
    out = {"object_mb": size / MB}
    tmp = tempfile.mkdtemp(prefix="spill-bench-")
    # no COS latency model here: the writer is paused during the
    # measured acks (COS never runs on them), and the post-measurement
    # verification flush shouldn't dominate the benchmark's runtime
    stores = {"memory": make_store(spill_dir=None, cos_model=False),
              "journal": make_store(spill_dir=tmp, cos_model=False)}
    acks = {"memory": [], "journal": []}
    for st in stores.values():
        st.writeback.pause()
    max_repeats = max_repeats or 3 * repeats
    since_new_min = 0
    for r in range(max_repeats):
        data = rng.bytes(size)
        improved = False
        for mode, st in stores.items():
            t0 = time.perf_counter()
            st.put(f"obj{r}", data)               # ack latency
            dt = time.perf_counter() - t0
            if not acks[mode] or dt < min(acks[mode]):
                improved = True
            acks[mode].append(dt)
        since_new_min = 0 if improved else since_new_min + 1
        if r + 1 >= repeats and since_new_min >= 8:
            break
    out["repeats"] = len(acks["memory"])
    out["journal_appends"] = stores["journal"].spill.stats.appends
    out["journal_mb"] = round(
        stores["journal"].spill.stats.appended_bytes / MB, 2)
    for mode, st in stores.items():
        st.writeback.resume()
        # the journal must not cost durability either: every write still
        # reaches COS in the background
        assert st.flush_writeback(timeout=600.0)
        assert st.writeback.stats.failures == 0
        st.close()
        out[f"{mode}_put_ack_ms"] = round(min(acks[mode]) * 1e3, 2)
    shutil.rmtree(tmp, ignore_errors=True)
    out["overhead_pct"] = round(
        (out["journal_put_ack_ms"] - out["memory_put_ack_ms"])
        / out["memory_put_ack_ms"] * 100.0, 1)
    return out


def bench_replay(pending_mb: int, object_mb: int = 1) -> dict:
    """Kill the daemon with `pending_mb` acked-but-unpersisted MB and
    time the restart replay; verify zero loss end-to-end."""
    tmp = tempfile.mkdtemp(prefix="spill-bench-")
    rng = np.random.default_rng(pending_mb)
    try:
        st = make_store(spill_dir=tmp, cos_model=False)
        st.writeback.pause()                      # hold everything pending
        n = max(1, pending_mb // object_mb)
        objs = {f"k{i}": rng.bytes(object_mb * MB) for i in range(n)}
        for k, v in objs.items():
            st.put(k, v)
        pending_bytes = st.spill.pending_bytes
        st.simulate_crash()
        t0 = time.perf_counter()
        st2 = make_store(spill_dir=tmp, cos_model=False)
        replay_s = time.perf_counter() - t0
        lost = sum(1 for k, v in objs.items() if st2.get(k) != v)
        assert st2.flush_writeback(timeout=600.0)
        persisted = all(st2.get(k) == v for k, v in objs.items())
        out = {"pending_mb": round(pending_bytes / MB, 2),
               "objects": n,
               "replay_ms": round(replay_s * 1e3, 2),
               "replayed_writes": st2.stats.spill_replayed_writes,
               "replayed_metas": st2.stats.spill_replayed_metas,
               "replay_MBps": round(pending_bytes / MB / replay_s, 1),
               "lost_after_restart": lost,
               "all_cos_persistent": bool(persisted)}
        st2.close()
        return out
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def run_bench(smoke: bool) -> dict:
    if smoke:
        ack = [bench_ack(1 * MB, repeats=16)]
        replay = [bench_replay(4)]
    else:
        ack = [bench_ack(1 * MB, repeats=24),
               bench_ack(10 * MB, repeats=6)]
        replay = [bench_replay(8), bench_replay(32), bench_replay(128)]
    return {"bench": "spill_overhead", "smoke": smoke,
            "ec": {"k": 10, "p": 2},
            "cos_model": {"put_base_s": COS_PUT_BASE_S,
                          "put_MBps": round(1.0 / COS_PUT_PER_BYTE_S / MB)},
            "ack": ack, "replay": replay}


def _default_out(smoke: bool) -> str:
    name = "BENCH_spill_smoke.json" if smoke else "BENCH_spill.json"
    return os.path.join(ROOT, name)


def _write(result: dict, path: str) -> None:
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")


def run() -> list:
    """benchmarks.run entry point (smoke sizes, CSV rows)."""
    result = run_bench(smoke=True)
    _write(result, _default_out(smoke=True))
    rows = []
    for pt in result["ack"]:
        tag = f"{pt['object_mb']:g}MB"
        rows.append(f"put_ack_journal_{tag},{pt['journal_put_ack_ms']},"
                    f"ms overhead={pt['overhead_pct']}% vs memory-only")
    for pt in result["replay"]:
        rows.append(f"spill_replay_{pt['pending_mb']:g}MB,"
                    f"{pt['replay_ms']},ms lost={pt['lost_after_restart']}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="1 MB ack + 4 MB replay only (CI sanity); writes "
                         "BENCH_spill_smoke.json unless --out is given")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    result = run_bench(args.smoke)
    out = args.out or _default_out(args.smoke)
    _write(result, out)
    for pt in result["ack"]:
        print(f"{pt['object_mb']:>6g} MB | put ack memory "
              f"{pt['memory_put_ack_ms']:>8.2f} ms -> journal "
              f"{pt['journal_put_ack_ms']:>8.2f} ms "
              f"({pt['overhead_pct']:+.1f}%)")
    for pt in result["replay"]:
        print(f"{pt['pending_mb']:>6g} MB pending | replay "
              f"{pt['replay_ms']:>8.2f} ms "
              f"({pt['replay_MBps']} MB/s) | lost "
              f"{pt['lost_after_restart']} | COS-persistent "
              f"{pt['all_cos_persistent']}")
    print(f"wrote {os.path.relpath(out)}")


if __name__ == "__main__":
    main()
