"""Kernel microbenchmarks: wall-time of the Pallas kernels (interpret
mode on CPU — correctness-path timing, NOT TPU performance) vs the
XLA/numpy references, plus work-per-call accounting."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row


def _time(fn, *args, n=3):
    fn(*args)            # compile/warm
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out) if hasattr(out, "block_until_ready") else None
    return (time.perf_counter() - t0) / n * 1e6


def run() -> list:
    out = []
    # RS encode: 1 MB payload through GF(256) matmul — exp/log numpy vs
    # product-table numpy vs the two Pallas kernels (ladder vs bit-sliced)
    from repro.kernels.rs_gf256.ref import (cauchy_parity_matrix,
                                            gf_matmul_np, gf_matmul_table)
    from repro.kernels.rs_gf256.kernel import (gf256_matmul_bitsliced,
                                               gf256_matmul_pallas_ladder)
    rng = np.random.default_rng(0)
    k, p, L = 10, 2, 104_858   # ~1MB/10 per chunk
    G = cauchy_parity_matrix(k, p)
    X = rng.integers(0, 256, (k, L)).astype(np.uint8)
    us_np = _time(lambda: gf_matmul_np(G, X))
    us_tab = _time(lambda: gf_matmul_table(G, X))
    Xj = jnp.asarray(X)
    us_ld = _time(lambda: np.asarray(
        gf256_matmul_pallas_ladder(G, Xj, interpret=True)))
    us_bs = _time(lambda: np.asarray(
        gf256_matmul_bitsliced(G, Xj, interpret=True)))
    out.append(row("kernel_rs_encode_numpy", us_np,
                   f"bytes={k * L} parity={p} exp/log path"))
    out.append(row("kernel_rs_encode_numpy_table", us_tab,
                   "full 256x256 product table (codec hot path)"))
    out.append(row("kernel_rs_encode_pallas_ladder", us_ld,
                   "xtime ladder, byte/lane (CPU interpret)"))
    out.append(row("kernel_rs_encode_pallas_bitsliced", us_bs,
                   "bit-planes, 4 bytes/lane (CPU interpret, TPU target)"))
    # paged attention vs gather fallback
    from repro.kernels.paged_attention.kernel import \
        paged_decode_attention_pallas
    from repro.kernels.paged_attention.ref import paged_decode_attention_ref
    B, P, ps, K, G_, hd = 4, 16, 32, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (B, K * G_, hd))
    kp = jax.random.normal(ks[1], (B, P, ps, K, hd))
    vp = jax.random.normal(ks[2], (B, P, ps, K, hd))
    tbl = jnp.tile(jnp.arange(P, dtype=jnp.int32)[None], (B, 1))
    lens = jnp.full((B,), P * ps, jnp.int32)
    ref_fn = jax.jit(paged_decode_attention_ref)
    us_ref = _time(lambda: ref_fn(q, kp, vp, tbl, lens))
    us_pal = _time(lambda: paged_decode_attention_pallas(
        q, kp, vp, tbl, lens, interpret=True))
    cache_bytes = 2 * B * P * ps * K * hd * 4
    out.append(row("kernel_paged_attn_xla_gather", us_ref,
                   f"cache={cache_bytes // 1024}KB gather-copies=1"))
    out.append(row("kernel_paged_attn_pallas_interpret", us_pal,
                   "zero-copy page walk (TPU target)"))
    return out
