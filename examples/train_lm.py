"""End-to-end training driver: train a reduced LM for a few hundred steps
with InfiniStore-backed checkpointing, then SIMULATE a node failure
(mass slab reclamation) and restart — the loss curve must continue
exactly where it left off.

    PYTHONPATH=src python examples/train_lm.py [--arch qwen1.5-0.5b]
    [--steps 200]
"""
import argparse
import dataclasses

from repro.checkpoint import Checkpointer
from repro.configs import ShapeConfig, get_config, reduced
from repro.launch.train import make_store_for_checkpoints, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    cfg = dataclasses.replace(
        reduced(get_config(args.arch), layers=4, d_model=128, d_ff=256),
        dtype="float32")
    shape = ShapeConfig("example", seq_len=args.seq_len,
                        global_batch=args.batch, kind="train")
    store = make_store_for_checkpoints()
    ckpt = Checkpointer(store)

    half = args.steps // 2
    print(f"phase 1: training {half} steps "
          f"({cfg.name}, {args.batch}x{args.seq_len})")
    r1 = train(cfg, shape, steps=half, seed=0, checkpointer=ckpt,
               checkpoint_every=max(half // 4, 1))
    print(f"  loss {r1.losses[0]:.3f} -> {r1.final_loss:.3f} "
          f"in {r1.wall_s:.1f}s")

    # simulate a host failure: reclaim every slab holding checkpoint chunks
    for fid in list(store.sms.slabs):
        store.inject_failure(fid)
    print(f"simulated node failure: reclaimed all "
          f"{len(store.sms.slabs)} slabs")

    print(f"phase 2: restart + resume to {args.steps} steps")
    r2 = train(cfg, shape, steps=args.steps, seed=0, checkpointer=ckpt,
               checkpoint_every=max(half // 4, 1), resume=True)
    print(f"  restored from step {r2.restored_from}; "
          f"loss -> {r2.final_loss:.3f} in {r2.wall_s:.1f}s")
    print(f"  recoveries: {store.recovery.stats.local_recoveries} local, "
          f"{store.recovery.stats.parallel_recoveries} parallel")
    assert r2.restored_from == half
    assert r2.final_loss < r1.losses[0], "loss should keep improving"
    print("restart-after-failure ok")


if __name__ == "__main__":
    main()
