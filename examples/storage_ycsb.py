"""YCSB-style stress example against the storage engine (paper §6.2).

    PYTHONPATH=src python examples/storage_ycsb.py [--ops 500]
"""
import argparse

from benchmarks.common import MB, bench_store
from benchmarks.ycsb import ycsb


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ops", type=int, default=500)
    ap.add_argument("--object-kb", type=int, default=256)
    ap.add_argument("--read-frac", type=float, default=0.95)
    args = ap.parse_args()
    st, clock = bench_store(elastic=True, gc_interval=600.0,
                            capacity=8 * MB)
    r = ycsb(st, clock, num_keys=24, object_bytes=args.object_kb * 1024,
             ops=args.ops, read_frac=args.read_frac, seed=0)
    print(f"{args.ops} ops, {args.object_kb}KB objects, "
          f"{args.read_frac:.0%} reads:")
    print(f"  throughput {r['rps']:.0f} req/s ({r['mbps']:.0f} MB/s)")
    print(f"  GET p50={r['get_p50']:.0f}us p90={r['get_p90']:.0f}us; "
          f"PUT p90={r['put_p90']:.0f}us")
    print(f"  functions: {st.num_functions()}, "
          f"hit ratio {st.stats.hit_ratio:.3f}")
    print(f"  cost: {st.ledger.dollars()}")


if __name__ == "__main__":
    main()
