"""The async futures-based store API in 50 lines.

    PYTHONPATH=src python examples/async_put_get.py

Covers: non-blocking `put_async`/`get_async` with `StoreFuture`s,
request pipelining, the background COS writeback queue + `flush`
barrier, durability before persistence completes, and zero-copy
device/array payloads via `get_array`.
"""
import numpy as np

from repro.core import Clock, InfiniStore, StoreConfig
from repro.core.ec import ECConfig
from repro.core.gc_window import GCConfig

MB = 1024 * 1024


def main() -> None:
    store = InfiniStore(StoreConfig(
        ec=ECConfig(k=4, p=2),
        function_capacity=8 * MB,
        gc=GCConfig(gc_interval=10.0),
    ), clock=Clock())
    rng = np.random.default_rng(0)

    # 1. pipeline a burst of non-blocking PUTs: each acks once its
    # chunks sit in function memory + the persistent buffer — COS
    # persistence drains in the background
    futs = {f"obj/{i}": store.put_async(f"obj/{i}", rng.bytes(200_000))
            for i in range(8)}
    versions = {k: f.result() for k, f in futs.items()}
    print(f"8 PUTs acked (versions {sorted(set(versions.values()))}); "
          f"writeback queue depth: {store.writeback.depth}")

    # 2. reads are correct immediately — even if the provider reclaims
    # an instance before the writeback queue has persisted anything
    store.inject_failure(next(iter(store.sms.slabs)))
    got = store.get_async("obj/3").result()
    assert got is not None and len(got) == 200_000
    print("read-after-ack survived an instance failure pre-persistence")

    # 3. flush() is the durability barrier (checkpoint-style)
    store.flush_writeback(timeout=30.0)
    print(f"flushed: {store.writeback.stats.persisted} writes in COS, "
          f"persistent buffer holds {store.pb.size_bytes} bytes")

    # 4. array payloads skip the bytes round-trip entirely
    weights = np.arange(50_000, dtype=np.float32)
    store.put("weights", weights)                  # uint8 views end-to-end
    back = store.get_array("weights").view(np.float32)
    np.testing.assert_array_equal(back, weights)
    print(f"device-path roundtrip ok "
          f"(array payload puts: {store.stats.array_payload_puts})")

    # 5. close() flushes the queue and releases the store's threads
    store.close()


if __name__ == "__main__":
    main()
