"""Serving example: batched generation over the SMS-paged KV cache, with
the full page lifecycle — hot pages tracked, finished sequences aged out
by the GC window, and an evicted sequence resumed from COS.

    PYTHONPATH=src python examples/serve_kv.py
"""
import dataclasses

import numpy as np

from repro.configs import get_config, reduced
from repro.core.clock import Clock
from repro.serving import ServeConfig, ServeEngine


def main() -> None:
    cfg = dataclasses.replace(reduced(get_config("qwen3-1.7b")),
                              dtype="float32")
    clock = Clock()
    eng = ServeEngine(cfg, ServeConfig(batch_slots=4, max_len=96,
                                       page_size=8, gc_interval=30.0),
                      clock=clock)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (4, 16)).astype(np.int32)

    out = eng.generate(prompts, max_new_tokens=12)
    print("generated token ids:\n", out)
    print("kv pages:", eng.kv.stats)
    print(f"serve: {eng.stats.tokens_generated} tokens, "
          f"prefill {eng.stats.prefill_seconds:.2f}s, "
          f"decode {eng.stats.decode_seconds:.2f}s")

    # sequences finished -> pages cool -> the GC window releases them
    for _ in range(8):
        clock.advance(30.0)
        eng.kv.gc_tick()
    print("after idle aging:", eng.kv.stats)
    assert eng.kv.stats.pages_evicted_to_cos > 0

    # a follow-up turn on seq0: on-demand migration restores its pages
    restored = eng.resume("seq0", slot=0)
    print(f"resumed seq0: {restored} pages restored from COS")
    assert restored > 0


if __name__ == "__main__":
    main()
