"""Quickstart: the InfiniStore public API in 60 lines.

    PYTHONPATH=src python examples/quickstart.py

Covers: versioned PUT/GET, erasure coding, the sliding GC window,
provider reclamation + parallel recovery, and pay-per-access accounting.
"""
import numpy as np

from repro.core import Clock, InfiniStore, StoreConfig
from repro.core.ec import ECConfig
from repro.core.gc_window import GCConfig

MB = 1024 * 1024


def main() -> None:
    clock = Clock()
    store = InfiniStore(
        StoreConfig(
            ec=ECConfig(k=4, p=2),                 # RS(4+2) erasure coding
            function_capacity=8 * MB,              # slab ("function") size
            gc=GCConfig(gc_interval=10.0,          # GC every 10s
                        active_intervals=2,        # M
                        degraded_intervals=2),     # N  (H = 40s)
        ),
        clock=clock,
    )
    rng = np.random.default_rng(0)

    # 1. versioned writes
    payload_v1 = rng.bytes(500_000)
    payload_v2 = rng.bytes(300_000)
    assert store.put("model/embedding", payload_v1) == 1
    assert store.put("model/embedding", payload_v2) == 2
    assert store.get("model/embedding") == payload_v2
    print(f"PUT/GET ok; {store.num_functions()} functions provisioned "
          f"(chunks spread one-per-function)")

    # 2. provider reclaims an instance -> detected + recovered on access
    victim = store.chunk_map["model/embedding|2/f0#0"]
    store.inject_failure(victim)
    assert store.get("model/embedding") == payload_v2
    print(f"survived reclamation of function {victim}: "
          f"{store.recovery.stats.local_recoveries} local / "
          f"{store.recovery.stats.parallel_recoveries} parallel recoveries")

    # 3. the sliding window ages cold data out of memory...
    for _ in range(5):
        clock.advance(10.0)
        store.gc_tick()
    print(f"after 50s idle: {store.sms.alive_count()} live instances "
          f"(cold data released to COS)")

    # ...but everything stays durable
    assert store.get("model/embedding") == payload_v2
    print("cold read via COS on-demand migration ok")

    # 4. pay-per-access accounting
    dollars = store.ledger.dollars()
    print("cost breakdown:",
          {k: f"${v:.6f}" for k, v in dollars.items()})
    print(f"durability overhead vs ideal pay-per-access: "
          f"{store.ledger.pay_per_access_overhead() * 100:.2f}% "
          f"(paper: 26.00%)")


if __name__ == "__main__":
    main()
