"""Quickstart: the InfiniStore public API in ~100 lines.

    PYTHONPATH=src python examples/quickstart.py

Covers: versioned PUT/GET, erasure coding, the sliding GC window,
provider reclamation + parallel recovery, pay-per-access accounting —
and the sharded multi-daemon variant (`ShardedStore`): keyspace
partitioning, all-or-nothing cross-shard batches, and one-shard
crash/restart with zero acked loss.
"""
import tempfile

import numpy as np

from repro.core import Clock, InfiniStore, ShardedStore, StoreConfig
from repro.core.ec import ECConfig
from repro.core.gc_window import GCConfig

MB = 1024 * 1024


def main() -> None:
    clock = Clock()
    store = InfiniStore(
        StoreConfig(
            ec=ECConfig(k=4, p=2),                 # RS(4+2) erasure coding
            function_capacity=8 * MB,              # slab ("function") size
            gc=GCConfig(gc_interval=10.0,          # GC every 10s
                        active_intervals=2,        # M
                        degraded_intervals=2),     # N  (H = 40s)
        ),
        clock=clock,
    )
    rng = np.random.default_rng(0)

    # 1. versioned writes
    payload_v1 = rng.bytes(500_000)
    payload_v2 = rng.bytes(300_000)
    assert store.put("model/embedding", payload_v1) == 1
    assert store.put("model/embedding", payload_v2) == 2
    assert store.get("model/embedding") == payload_v2
    print(f"PUT/GET ok; {store.num_functions()} functions provisioned "
          f"(chunks spread one-per-function)")

    # 2. provider reclaims an instance -> detected + recovered on access
    victim = store.chunk_map["model/embedding|2/f0#0"]
    store.inject_failure(victim)
    assert store.get("model/embedding") == payload_v2
    print(f"survived reclamation of function {victim}: "
          f"{store.recovery.stats.local_recoveries} local / "
          f"{store.recovery.stats.parallel_recoveries} parallel recoveries")

    # 3. the sliding window ages cold data out of memory...
    for _ in range(5):
        clock.advance(10.0)
        store.gc_tick()
    print(f"after 50s idle: {store.sms.alive_count()} live instances "
          f"(cold data released to COS)")

    # ...but everything stays durable
    assert store.get("model/embedding") == payload_v2
    print("cold read via COS on-demand migration ok")

    # 4. pay-per-access accounting
    dollars = store.ledger.dollars()
    print("cost breakdown:",
          {k: f"${v:.6f}" for k, v in dollars.items()})
    print(f"durability overhead vs ideal pay-per-access: "
          f"{store.ledger.pay_per_access_overhead() * 100:.2f}% "
          f"(paper: 26.00%)")


def sharded() -> None:
    """The multi-daemon variant: same StoreFrontend surface, N shards."""
    spill_root = tempfile.mkdtemp(prefix="quickstart-shards-")
    store = ShardedStore(
        StoreConfig(
            ec=ECConfig(k=4, p=2),
            function_capacity=8 * MB,
            gc=GCConfig(gc_interval=1e9),
            spill_dir=spill_root,              # per-shard journals live
        ),                                     # under shard-<i>/
        num_shards=4,
        clock=Clock(),
    )
    rng = np.random.default_rng(1)

    # 1. the router partitions the keyspace; each shard's own daemon
    #    serves its slice — same API, N client daemons
    vals = {f"user/{i}": rng.bytes(100_000) for i in range(16)}
    for key, val in vals.items():
        assert store.put(key, val) == 1
    print(f"16 keys over 4 shards, balance={store.shard_balance()}")

    # 2. a cross-shard batch commits all-or-nothing via the leader-
    #    sequenced two-round protocol: if any shard fails to prepare,
    #    no key of the batch ever becomes visible anywhere
    batch = {f"batch/{i}": rng.bytes(50_000) for i in range(8)}
    assert all(v == 1 for v in store.put_many(batch).values())
    got = store.get_many(list(batch))
    assert all(got[k] == batch[k] for k in batch)
    print(f"cross-shard put_many ok "
          f"(commit tickets issued: {store.tickets_issued()})")

    # 3. one shard crashes mid-flight -> survivors keep serving ->
    #    restart replays its journal with zero acked loss
    store.pause_writeback()                    # hold writes pre-COS
    more = {f"late/{i}": rng.bytes(80_000) for i in range(8)}
    for key, val in more.items():
        store.put(key, val)
    store.simulate_crash(shard=2)
    store.restart_shard(2)
    assert all(store.get(k) == v for k, v in {**vals, **more}.items())
    store.resume_writeback()
    assert store.flush_writeback(timeout=60.0)
    print("crashed shard 2 mid-stream, restarted: zero acked loss")
    print("aggregate stats: puts={s.puts} gets={s.gets} "
          "hit_ratio={s.hit_ratio:.2f}".format(s=store.stats))
    store.close()
    import shutil
    shutil.rmtree(spill_root, ignore_errors=True)


if __name__ == "__main__":
    main()
    print("\n--- sharded multi-daemon variant ---")
    sharded()
