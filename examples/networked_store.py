"""Networked ProcessShardedStore: TCP transport, heartbeats, epochs.

    PYTHONPATH=src python examples/networked_store.py

`ProcessShardedStore(transport="tcp")` swaps the shared-memory rings
for a socket control/data plane: each shard worker serves a framed RPC
protocol on a loopback port (length-prefixed header + out-of-band
payload section), and the parent keeps one connection per shard alive
with a heartbeat failure detector. Same `StoreFrontend` surface, same
2PC batch semantics — what changes is what the link can do to you:

  frames can be lost        per-RPC deadlines fail fast with
                            `ShardWorkerDied` instead of hanging
  the peer can go silent    heartbeats walk CONNECTED -> SUSPECT ->
                            DOWN on `HeartbeatConfig` timers; DOWN
                            fails every in-flight RPC and starts a
                            backoff reconnect loop
  the link can heal         each (re)connection carries a fresh
                            monotonically-increasing EPOCH; a zombie
                            worker from a prior incarnation cannot ack
                            into the new one (stale acks are counted
                            and suppressed, never delivered)

Durability is unchanged: acked writes live in the worker's spill
journal, so a worker lost mid-stream replays on restart, and the
inherited 2PC sweep (`resolve_indoubt`) settles any cross-shard batch
a partition stranded in doubt.

`HeartbeatConfig` defaults are lazy (0.5s pings, DOWN after 10s) to
stay quiet on loaded boxes; this demo runs a hot detector so the
failure story fits in seconds.

The demo also attaches an `ObsPlane` (`StoreConfig(obs=...)`): every
op is traced ACROSS the TCP frames into the worker processes, latency
histograms merge back into one `snapshot_metrics()` view, and the
SIGKILL'd worker's last spans come back as flight-recorder forensics.
See `docs/observability.md` for the site registry, the span taxonomy,
and the Prometheus export format.
"""
import os
import shutil
import signal
import tempfile
import time

import numpy as np

from repro.core import (Clock, HeartbeatConfig, ProcessShardedStore,
                        ShardWorkerDied, StoreConfig)
from repro.core.ec import ECConfig
from repro.core.gc_window import GCConfig
from repro.obs import ObsPlane

MB = 1024 * 1024

HOT = HeartbeatConfig(interval_s=0.05, suspect_after_s=0.2,
                      dead_after_s=0.6, connect_timeout_s=2.0,
                      rpc_deadline_s=5.0, reconnect_max_attempts=60,
                      reconnect_backoff_base_s=0.05,
                      reconnect_backoff_cap_s=0.2)


def _wait(pred, timeout=15.0, what=""):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return
        time.sleep(0.05)
    raise TimeoutError(f"timed out waiting for {what}")


def main() -> None:
    spill_root = tempfile.mkdtemp(prefix="networked-store-")
    store = ProcessShardedStore(
        StoreConfig(
            ec=ECConfig(k=4, p=2),
            function_capacity=8 * MB,
            gc=GCConfig(gc_interval=1e9),
            spill_dir=spill_root,
            obs=ObsPlane(name="demo"),     # tracing + histograms + flight
        ),
        num_shards=2,
        clock=Clock(),
        transport="tcp",                   # sockets instead of shm rings
        heartbeat=HOT,
    )
    rng = np.random.default_rng(0)

    # 1. the surface is identical — these puts are framed RPCs over
    #    loopback TCP, payload bytes in the frame's payload section
    vals = {f"user/{i}": rng.bytes(100_000) for i in range(8)}
    for key, val in vals.items():
        assert store.put(key, val) == 1
    health = store.shard_transport_health()
    print("shard links:", [(h["state"], f"epoch {h['epoch']}",
                            h["addr"]) for h in health])

    # 2. cross-shard batches still run 2PC, now with prepare/commit
    #    frames crossing sockets; epoch tags keep the rounds fenced
    batch = {f"batch/{i}": rng.bytes(50_000) for i in range(8)}
    assert all(v == 1 for v in store.put_many(batch).values())
    print("cross-shard put_many over TCP ok")

    # 3. a silent peer (SIGSTOP — the process is alive, the link is
    #    dead): the detector walks to DOWN, in-flight calls fail fast,
    #    and the health surface says so
    victim_pid = store.worker_pids()[0]
    os.kill(victim_pid, signal.SIGSTOP)
    _wait(lambda: store.shard_transport_health()[0]["state"]
          in ("DOWN", "RECONNECTING"),
          what="failure detection")
    print(f"worker 0 went silent -> detector state "
          f"{store.shard_transport_health()[0]['state']}")
    try:
        store.put(next(k for k in vals
                       if store.router.shard_of(k) == 0), b"x" * 1024)
    except ShardWorkerDied as e:
        print(f"RPC against a DOWN shard fails fast: shard={e.shard_id} "
              f"epoch={e.epoch} op={e.op!r}")

    # 4. the link heals on its own: SIGCONT the worker and the
    #    reconnect loop re-handshakes at a HIGHER epoch — anything the
    #    old incarnation still had buffered is fenced out
    os.kill(victim_pid, signal.SIGCONT)
    _wait(lambda: store.shard_transport_health()[0]["state"]
          == "CONNECTED"
          and store.shard_transport_health()[0]["epoch"] >= 2,
          what="reconnect")
    h0 = store.shard_transport_health()[0]
    print(f"link healed: state {h0['state']}, epoch {h0['epoch']}, "
          f"reconnects {h0['reconnects']}")
    assert all(store.get(k) == v for k, v in vals.items())
    assert all(store.get(k) == v for k, v in batch.items())
    assert store.indoubt_tickets() == []
    print("zero acked writes lost across the outage")

    # 5. real crashes work like the shm transport: SIGKILL + restart
    #    replays the journal; the new worker serves at epoch 1 of a
    #    fresh transport incarnation
    store.simulate_crash(shard=1)
    store.restart_shard(1)
    assert all(store.get(k) == v for k, v in vals.items())
    assert store.flush_writeback(timeout=120.0)
    print("SIGKILL + restart on shard 1: journal replayed, reads ok")

    # 6. one merged observability view (docs/observability.md): worker
    #    histograms sum into the frontend's, spans from both sides of
    #    the socket stitch by trace id, and the SIGKILL'd worker's last
    #    pre-kill spans came back as dead-epoch forensics
    snap = store.snapshot_metrics()
    rpc = snap["histograms"]["rpc.roundtrip_us"]
    print(f"rpc roundtrip: n={rpc['count']} p50={rpc['p50_us']}us "
          f"p99={rpc['p99_us']}us")
    traces = {s["trace_id"] for s in snap["spans"]}
    print(f"{len(snap['spans'])} spans across {len(traces)} traces, "
          f"transport totals {snap['transport']['totals']}")
    for f in snap["forensics"]:
        kinds = {r.get("kind") for r in f["records"]}
        print(f"forensics from dead {f['source']}: "
              f"{len(f['records'])} records, kinds {sorted(kinds)}")

    assert store.close() is True
    shutil.rmtree(spill_root, ignore_errors=True)


if __name__ == "__main__":                 # REQUIRED: workers respawn the
    main()                                 # interpreter and re-import this
