"""ProcessShardedStore quickstart: worker processes, shared-memory IPC.

    PYTHONPATH=src python examples/process_store.py

`ProcessShardedStore` is `ShardedStore` with each shard moved into its
own WORKER PROCESS — per-shard interpreter owning a full `InfiniStore`
(client daemon, writeback writer, spill journal under
`<spill_dir>/shard-<i>/`) over one shared disk-backed COS root. Same
`StoreFrontend` surface, same router, same 2PC batch semantics; the
difference is where the CPU burns:

  threads (`ShardedStore`)      one interpreter — shard daemons share
                                the GIL, so aggregate encode/digest CPU
                                caps out near one core
  processes (this class)        N interpreters — daemon CPU scales with
                                cores; payloads cross on shared-memory
                                rings (one bulk memcpy in, zero-copy
                                views out), control on a pipe

When to pick which: threads for tests, small deployments, and
single-core boxes (no spawn cost, no IPC hop); processes when shard
daemons are CPU-bound and cores are available.

Shared-memory sizing: each shard gets TWO rings (request + response) of
`arena_bytes` each (default 64 MB) in /dev/shm. A ring must hold the
largest single payload you PUT or GET — bigger values fall back to
inline pickle over the pipe (correct, but with an extra copy). Size it
at a few multiples of your typical object so several transfers stay in
flight: `ProcessShardedStore(cfg, arena_bytes=256 * MB, ...)`.

Crash semantics are REAL here: `simulate_crash(shard=i)` delivers
SIGKILL to the worker (no atexit, no flush — exactly a reclaimed VM).
Acked writes survive via the shard's journal: `restart_shard(i)`
respawns the worker, whose `InfiniStore.__init__` replays the journal
before reporting ready, then the inherited 2PC sweep settles any
ticket the kill left in doubt. In-flight calls against a dead worker
fail fast with `ShardWorkerDied` (a `ConnectionError`) instead of
hanging. `close()` runs every worker's drain under one shared
deadline, escalating to terminate/kill for stuck workers, and a
finalizer + atexit hook reaps workers and /dev/shm segments even for
stores that are simply dropped.
"""
import shutil
import tempfile

import numpy as np

from repro.core import (Clock, ProcessShardedStore, ShardWorkerDied,
                        StoreConfig)
from repro.core.ec import ECConfig
from repro.core.gc_window import GCConfig

MB = 1024 * 1024


def main() -> None:
    spill_root = tempfile.mkdtemp(prefix="process-store-")
    store = ProcessShardedStore(
        StoreConfig(
            ec=ECConfig(k=4, p=2),
            function_capacity=8 * MB,
            gc=GCConfig(gc_interval=1e9),
            spill_dir=spill_root,          # per-shard journals (durable
        ),                                 # ack path + crash replay)
        num_shards=4,
        clock=Clock(),
        arena_bytes=64 * MB,               # per-direction ring, per shard
    )
    rng = np.random.default_rng(0)

    # 1. same surface as ShardedStore — but each put is served by a
    #    separate worker process (one bulk memcpy into that shard's
    #    request ring; the worker snapshots out of the ring at
    #    submission, so the slot recycles immediately)
    vals = {f"user/{i}": rng.bytes(100_000) for i in range(16)}
    for key, val in vals.items():
        assert store.put(key, val) == 1
    print(f"16 keys over 4 worker processes "
          f"(pids={store.worker_pids()}), "
          f"balance={store.shard_balance()}")

    # 2. cross-shard batches keep the all-or-nothing contract: the
    #    parent sequences 2PC, prepare/commit run inside the workers,
    #    prepared tickets are journaled durable in each worker
    batch = {f"batch/{i}": rng.bytes(50_000) for i in range(8)}
    assert all(v == 1 for v in store.put_many(batch).values())
    got = store.get_many(list(batch))
    assert all(got[k] == batch[k] for k in batch)
    print("cross-process put_many ok (2PC spans worker boundaries)")

    # 3. a REAL crash: SIGKILL one worker with acked writes still
    #    pending, survivors keep serving, restart replays the journal
    store.pause_writeback()
    more = {f"late/{i}": rng.bytes(80_000) for i in range(8)}
    for key, val in more.items():
        store.put(key, val)
    store.simulate_crash(shard=2)          # kill -9, not a simulation
    try:
        victim_key = next(k for k in vals
                          if store.router.shard_of(k) == 2)
        store.get(victim_key)
    except ShardWorkerDied as e:
        print(f"dead worker fails fast: {type(e).__name__}: {e}")
    store.restart_shard(2)                 # respawn + journal replay
    assert all(store.get(k) == v for k, v in {**vals, **more}.items())
    assert store.indoubt_tickets() == []
    store.resume_writeback()
    assert store.flush_writeback(timeout=120.0)
    print("SIGKILLed worker 2 mid-stream, restarted: zero acked loss")

    # 4. aggregate stats fan in from every worker over the control pipe
    print("aggregate stats: puts={s.puts} gets={s.gets} "
          "hit_ratio={s.hit_ratio:.2f}".format(s=store.stats))
    assert store.close() is True           # joins + reaps every worker
    shutil.rmtree(spill_root, ignore_errors=True)


if __name__ == "__main__":                 # REQUIRED: workers respawn the
    main()                                 # interpreter and re-import this
