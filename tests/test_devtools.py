"""istore-lint + LockWitness test suite (PR 9 tentpole).

Each rule gets a positive fixture (a synthetic module seeded with the
violation — lint must report it and `main()` must exit non-zero) and a
negative fixture (the idiomatic-correct variant — lint must stay
silent).  On top of the per-rule checks: pragma and baseline waiver
semantics, lock-hierarchy extraction over the real tree, the runtime
witness's dynamic/static inversion detection, and the zero-findings
gate over ``src/repro`` itself — the same invocation `scripts/ci.sh`
runs.
"""
import threading
from pathlib import Path

import pytest

from repro.core import locks
from repro.core.faults import FaultPoint
from repro.devtools import lint, lockgraph
from repro.devtools.scan import scan_tree
from repro.devtools.witness import LockWitness

REPO = Path(__file__).resolve().parent.parent
SRC = REPO / "src" / "repro"


def _lint_dir(tmp_path, **files):
    """Write `name -> source` files, lint the directory with no
    baseline, return the new findings."""
    tmp_path.mkdir(parents=True, exist_ok=True)
    for name, src in files.items():
        (tmp_path / f"{name}.py").write_text(src)
    new, _tm = lint.run([str(tmp_path)], root=tmp_path,
                        baseline_path=tmp_path / "absent.json")
    return new


def _rules(findings):
    return sorted({f.rule for f in findings})


# ---------------------------------------------------------------------------
# rule: lock-order
# ---------------------------------------------------------------------------

CYCLE_SRC = """\
import threading

class A:
    def __init__(self):
        self._l1 = threading.Lock()
        self._l2 = threading.Lock()

    def f(self):
        with self._l1:
            with self._l2:
                pass

    def g(self):
        with self._l2:
            with self._l1:
                pass
"""


def test_lock_order_cycle_detected(tmp_path):
    new = _lint_dir(tmp_path, m=CYCLE_SRC)
    assert _rules(new) == ["lock-order"]
    assert any("cycle" in f.detail for f in new)


def test_lock_order_consistent_nesting_clean(tmp_path):
    src = CYCLE_SRC.replace("with self._l2:\n            with self._l1:",
                            "with self._l1:\n            with self._l2:")
    assert _lint_dir(tmp_path, m=src) == []


def test_lock_order_plain_lock_self_deadlock(tmp_path):
    src = """\
import threading

class B:
    def __init__(self):
        self._l = threading.Lock()

    def outer(self):
        with self._l:
            self.inner()

    def inner(self):
        with self._l:
            pass
"""
    new = _lint_dir(tmp_path, m=src)
    assert any(f.rule == "lock-order" and f.detail.startswith("self:")
               for f in new)
    # the same shape over an RLock is reentrant — clean
    rl = _lint_dir(tmp_path / "rlock", m=src.replace(
        "threading.Lock()", "threading.RLock()"))
    assert rl == []


def test_lock_order_factory_name_drift(tmp_path):
    src = """\
from repro.core.locks import make_lock

class C:
    def __init__(self):
        self._l = make_lock("othermodule.C._l")
"""
    new = _lint_dir(tmp_path, m=src)
    assert any(f.rule == "lock-order" and "name-drift" in f.detail
               for f in new)
    good = src.replace("othermodule.C._l", "m.C._l")
    assert _lint_dir(tmp_path / "ok", m=good) == []


# ---------------------------------------------------------------------------
# rule: blocking-under-lock
# ---------------------------------------------------------------------------

SLEEP_UNDER_LOCK = """\
import threading
import time

class C:
    def __init__(self):
        self._l = threading.Lock()

    def f(self):
        with self._l:
            time.sleep(0.1)
"""


def test_blocking_under_lock_direct(tmp_path):
    new = _lint_dir(tmp_path, m=SLEEP_UNDER_LOCK)
    assert _rules(new) == ["blocking-under-lock"]
    assert "time.sleep" in new[0].message


def test_blocking_outside_lock_clean(tmp_path):
    src = """\
import threading
import time

class C:
    def __init__(self):
        self._l = threading.Lock()

    def f(self):
        with self._l:
            pass
        time.sleep(0.1)
"""
    assert _lint_dir(tmp_path, m=src) == []


def test_blocking_under_lock_via_callee(tmp_path):
    src = """\
import threading
import time

class C:
    def __init__(self):
        self._l = threading.Lock()

    def _helper(self):
        time.sleep(0.1)

    def f(self):
        with self._l:
            self._helper()
"""
    new = _lint_dir(tmp_path, m=src)
    assert any("may block" in f.message for f in new)


def test_release_reacquire_window_not_flagged(tmp_path):
    # the writeback.flush idiom: drop the lock around the blocking
    # call, retake it in finally — must NOT be flagged even when the
    # release/acquire pair sits below while/if/try nesting
    src = """\
import threading
import time

class C:
    def __init__(self):
        self._l = threading.Lock()

    def f(self):
        with self._l:
            while True:
                if True:
                    self._l.release()
                    try:
                        time.sleep(0.1)
                    finally:
                        self._l.acquire()
"""
    assert _lint_dir(tmp_path, m=src) == []


# ---------------------------------------------------------------------------
# rule: fault-site
# ---------------------------------------------------------------------------

MANIFEST_SRC = """\
FAULT_SITES = frozenset({"cos.put", "net.drop"})
"""


def test_fault_site_unguarded_and_typo(tmp_path):
    src = """\
class D:
    def __init__(self, faults=None):
        self.faults = faults

    def ok(self, key):
        if self.faults is not None:
            self.faults.fire("cos.put", key)

    def unguarded(self, key):
        self.faults.fire("cos.put", key)

    def typo(self, key):
        if self.faults is not None:
            self.faults.fire("cos.putt", key)
"""
    new = _lint_dir(tmp_path, faults=MANIFEST_SRC, m=src)
    details = {f.detail for f in new}
    assert "unguarded:self.faults" in details
    assert "unregistered:cos.putt" in details
    # the guarded, registered call produced nothing
    assert not any(f.line == 7 for f in new)


def test_fault_site_net_point_requires_match(tmp_path):
    src = """\
def plan():
    return [FaultPoint(site="net.drop", action="drop", hits=(1,))]
"""
    new = _lint_dir(tmp_path, faults=MANIFEST_SRC, m=src)
    assert any(f.detail == "point-no-match:net.drop" for f in new)
    good = src.replace('hits=(1,)', 'hits=(1,), match="op:put:"')
    assert _lint_dir(tmp_path / "ok", faults=MANIFEST_SRC, m=good) == []


def test_faultpoint_runtime_match_validation():
    # satellite: __post_init__ mirrors the static rule at runtime
    with pytest.raises(ValueError, match="must set match"):
        FaultPoint(site="net.drop", action="drop", hits=(1,))
    with pytest.raises(ValueError, match="must set match"):
        FaultPoint(site="hb", action="transient", hits=(1,))
    FaultPoint(site="net.drop", action="drop", hits=(1,), match="op:put:")
    FaultPoint(site="cos.put", action="transient", hits=(1,))


# ---------------------------------------------------------------------------
# rule: atomic-counter
# ---------------------------------------------------------------------------

def test_atomic_counter_rmw_flagged(tmp_path):
    src = """\
from repro.core.store import StoreStats

class E:
    def __init__(self):
        self.stats = StoreStats()

    def bad(self):
        self.stats.puts += 1

    def good(self):
        self.stats.inc("puts")
"""
    new = _lint_dir(tmp_path, m=src)
    assert _rules(new) == ["atomic-counter"]
    assert len(new) == 1 and "inc('puts')" in new[0].message


# ---------------------------------------------------------------------------
# rule: resource-lifecycle
# ---------------------------------------------------------------------------

THREAD_LEAK = """\
import threading

class F:
    def __init__(self):
        self._t = threading.Thread(target=self._run, daemon=True)

    def _run(self):
        pass
"""


def test_resource_lifecycle_leak_flagged(tmp_path):
    new = _lint_dir(tmp_path, m=THREAD_LEAK)
    assert _rules(new) == ["resource-lifecycle"]
    assert "self._t" in new[0].message


def test_resource_lifecycle_joined_clean(tmp_path):
    src = THREAD_LEAK + """\

    def close(self):
        self._t.join(timeout=1.0)
"""
    assert _lint_dir(tmp_path, m=src) == []


def test_resource_lifecycle_teardown_via_helper(tmp_path):
    # join reachable transitively from close() counts
    src = THREAD_LEAK + """\

    def _stop(self):
        self._t.join(timeout=1.0)

    def close(self):
        self._stop()
"""
    assert _lint_dir(tmp_path, m=src) == []


# ---------------------------------------------------------------------------
# pragma + baseline semantics
# ---------------------------------------------------------------------------

def test_pragma_with_reason_waives(tmp_path):
    src = SLEEP_UNDER_LOCK.replace(
        "time.sleep(0.1)",
        "time.sleep(0.1)  # lint: allow(blocking-under-lock): test waiver")
    assert _lint_dir(tmp_path, m=src) == []


def test_pragma_on_line_above_waives(tmp_path):
    src = SLEEP_UNDER_LOCK.replace(
        "            time.sleep(0.1)",
        "            # lint: allow(blocking-under-lock): test waiver\n"
        "            time.sleep(0.1)")
    assert _lint_dir(tmp_path, m=src) == []


def test_pragma_without_reason_does_not_waive(tmp_path):
    src = SLEEP_UNDER_LOCK.replace(
        "time.sleep(0.1)",
        "time.sleep(0.1)  # lint: allow(blocking-under-lock)")
    new = _lint_dir(tmp_path, m=src)
    assert len(new) == 1
    assert new[0].detail.endswith("|no-reason")
    assert "gives no reason" in new[0].message


def test_baseline_roundtrip_waives_and_is_line_independent(tmp_path):
    (tmp_path / "m.py").write_text(SLEEP_UNDER_LOCK)
    base = tmp_path / "base.json"
    # 1) finding is new without a baseline
    new, tm = lint.run([str(tmp_path)], root=tmp_path, baseline_path=base)
    assert len(new) == 1
    # 2) write the baseline; the same run is now clean
    lint.write_baseline(base, new)
    new2, _ = lint.run([str(tmp_path)], root=tmp_path, baseline_path=base)
    assert new2 == []
    # 3) shift every line down: fingerprints are line-independent
    (tmp_path / "m.py").write_text("# moved\n# moved\n" + SLEEP_UNDER_LOCK)
    new3, _ = lint.run([str(tmp_path)], root=tmp_path, baseline_path=base)
    assert new3 == []


def test_main_exit_codes_per_rule(tmp_path):
    """A seeded synthetic violation of EACH rule exits non-zero via
    the same CLI entry ci.sh uses; a clean tree exits zero."""
    violations = {
        "lock-order": {"m": CYCLE_SRC},
        "blocking-under-lock": {"m": SLEEP_UNDER_LOCK},
        "fault-site": {"faults": MANIFEST_SRC,
                       "m": "def f(faults, key):\n"
                            "    faults.fire('cos.put', key)\n"},
        "atomic-counter": {"m": "class E:\n"
                                "    def __init__(self):\n"
                                "        self.stats = StoreStats()\n"
                                "    def bad(self):\n"
                                "        self.stats.puts += 1\n"},
        "resource-lifecycle": {"m": THREAD_LEAK},
    }
    for rule, files in violations.items():
        d = tmp_path / rule
        d.mkdir()
        for name, src in files.items():
            (d / f"{name}.py").write_text(src)
        assert lint.main([str(d), "--no-baseline", "-q"]) == 1, rule
    good = tmp_path / "good"
    good.mkdir()
    (good / "m.py").write_text("x = 1\n")
    assert lint.main([str(good), "--no-baseline", "-q"]) == 0


# ---------------------------------------------------------------------------
# the real tree: zero-findings gate + hierarchy extraction
# ---------------------------------------------------------------------------

def test_real_tree_lints_clean():
    """The CI gate itself: src/repro with the checked-in baseline must
    produce zero new findings."""
    new, tm = lint.run([str(SRC)], root=REPO)
    assert new == [], "\n".join(f.render() for f in new)
    assert len(tm.locks) >= 25          # the tree's locks were modeled


def test_real_tree_hierarchy_edges():
    tm = scan_tree([str(SRC)], root=REPO)
    edges, findings = lockgraph.build_edges(tm)
    pairs = set(edges)
    # the proxy stages payloads under _order_lock, then registers the
    # rid under _state_lock: the hierarchy must order them
    assert ("host._ShardProxy._order_lock",
            "host._ShardProxy._state_lock") in pairs
    # reconnect takes _conn_lock then publishes under _lock
    assert ("transport.TcpTransport._conn_lock",
            "transport.TcpTransport._lock") in pairs
    # and the graph is acyclic: no lock-order cycle findings
    cycle, _ = lockgraph.check(tm)
    assert not [f for f in cycle if "cycle" in f.detail]


def test_hierarchy_doc_is_current(tmp_path):
    """docs/lock_hierarchy.md is generated — fail if someone edited
    the lock structure without regenerating it."""
    tm = scan_tree([str(SRC)], root=REPO)
    edges, _ = lockgraph.build_edges(tm)
    want = lockgraph.render_hierarchy(tm, edges)
    have = (REPO / "docs" / "lock_hierarchy.md").read_text()
    assert have == want, ("docs/lock_hierarchy.md is stale — regenerate "
                          "with: PYTHONPATH=src python -m "
                          "repro.devtools.lint src/repro "
                          "--emit-hierarchy docs/lock_hierarchy.md")


# ---------------------------------------------------------------------------
# runtime LockWitness
# ---------------------------------------------------------------------------

@pytest.fixture
def witness_installed():
    assert locks.current_witness() is None
    yield
    locks.install_witness(None)


def test_witness_detects_dynamic_inversion(witness_installed):
    w = LockWitness()
    locks.install_witness(w)
    a = locks.make_lock("t.a")
    b = locks.make_lock("t.b")
    with a:
        with b:
            pass
    with b:
        with a:                      # reverse order: inversion
            pass
    inv = w.inversions()
    assert len(inv) == 1 and inv[0].kind == "dynamic"
    assert (inv[0].first, inv[0].second) == ("t.b", "t.a")
    with pytest.raises(AssertionError, match="inversions"):
        w.assert_clean()


def test_witness_consistent_order_clean(witness_installed):
    w = LockWitness()
    locks.install_witness(w)
    a = locks.make_lock("t.a")
    b = locks.make_lock("t.b")
    for _ in range(3):
        with a:
            with b:
                pass
    assert w.pairs_observed == 1
    w.assert_clean()


def test_witness_detects_static_inversion(witness_installed):
    # static model says a-before-b; runtime does b-then-a just once —
    # the dynamic check alone can't see it, the static one must
    w = LockWitness(order={"t.a": frozenset({"t.b"})})
    locks.install_witness(w)
    a = locks.make_lock("t.a")
    b = locks.make_lock("t.b")
    with b:
        with a:
            pass
    inv = w.inversions()
    assert len(inv) == 1 and inv[0].kind == "static"
    # same single order, but consistent with the model: clean
    w2 = LockWitness(order={"t.a": frozenset({"t.b"})})
    locks.install_witness(w2)
    a2 = locks.make_lock("t.a")
    b2 = locks.make_lock("t.b")
    with a2:
        with b2:
            pass
    w2.assert_clean()


def test_witness_rlock_reentrancy_not_a_pair(witness_installed):
    w = LockWitness()
    locks.install_witness(w)
    r = locks.make_rlock("t.r")
    with r:
        with r:                      # reentrant: not an ordered pair
            pass
    assert w.pairs_observed == 0
    w.assert_clean()


def test_witness_condition_over_witnessed_lock(witness_installed):
    # threading.Condition must work over the proxy, both flavors
    w = LockWitness()
    locks.install_witness(w)
    for mk in (locks.make_lock, locks.make_rlock):
        lk = mk("t.c")
        cond = threading.Condition(lk)
        fired = []

        def waiter():
            with cond:
                while not fired:
                    cond.wait(timeout=1.0)

        t = threading.Thread(target=waiter)
        t.start()
        with cond:
            fired.append(1)
            cond.notify()
        t.join(timeout=2.0)
        assert not t.is_alive()
    w.assert_clean()


def test_make_lock_without_witness_is_raw(witness_installed):
    lk = locks.make_lock("t.raw")
    assert type(lk) is type(threading.Lock())


def test_witness_threads_have_independent_stacks(witness_installed):
    # two threads each holding one of the locks is NOT an ordering
    w = LockWitness()
    locks.install_witness(w)
    a = locks.make_lock("t.a")
    b = locks.make_lock("t.b")
    gate = threading.Barrier(2, timeout=5.0)

    def hold(lk):
        with lk:
            gate.wait()              # both held concurrently
            gate.wait()

    ts = [threading.Thread(target=hold, args=(lk,)) for lk in (a, b)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=5.0)
    assert w.pairs_observed == 0
    w.assert_clean()
