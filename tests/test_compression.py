"""int8 error-feedback gradient compression (optim/compression.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.optim import compression as C


def test_quantize_roundtrip_bounded_error():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 3.0
    q, s = C.quantize_int8(x)
    xh = C.dequantize(q, s)
    assert q.dtype == jnp.int8
    # error bounded by half an LSB
    assert float(jnp.max(jnp.abs(x - xh))) <= float(s) * 0.5 + 1e-6


def test_error_feedback_accumulates_to_truth():
    """Repeatedly compressing the SAME gradient with error feedback must
    converge: sum of transmitted values -> sum of true values."""
    g = jax.random.normal(jax.random.PRNGKey(1), (512,)) * 0.01
    err = jnp.zeros_like(g)
    sent = jnp.zeros_like(g)
    for _ in range(20):
        xhat, err = C.compress_decompress(g + err)
        sent = sent + xhat
    np.testing.assert_allclose(np.asarray(sent / 20), np.asarray(g),
                               atol=1e-4)


def test_psum_compressed_single_pod_identity():
    """With one pod the compressed exchange must return ~the input."""
    from repro.launch.mesh import compat_make_mesh, compat_shard_map
    mesh = compat_make_mesh((1,), ("pod",))
    g = {"w": jax.random.normal(jax.random.PRNGKey(2), (64,))}
    e = {"w": jnp.zeros((64,))}

    def f(g, e):
        return C.psum_compressed(g, "pod", e)

    out, new_e = compat_shard_map(f, mesh=mesh, axis_names={"pod"},
                                  in_specs=(P(), P()),
                                  out_specs=(P(), P()))(g, e)
    np.testing.assert_allclose(np.asarray(out["w"] + new_e["w"]),
                               np.asarray(g["w"]), atol=1e-5)


def test_dcn_bytes_estimate():
    params = {"a": jnp.zeros((1000,)), "b": jnp.zeros((50, 50))}
    full = C.dcn_bytes_per_step(params, compressed=False)
    comp = C.dcn_bytes_per_step(params, compressed=True)
    assert full == 4 * 3500
    assert comp < full / 3.9        # ~4x reduction
