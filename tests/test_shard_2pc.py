"""2PC in-doubt closure (`repro.core.shard` + `repro.core.store`):
durable prepare records, the leader's durable commit decision, and the
`resolve_indoubt` sweep that rolls every interrupted cross-shard batch
forward (decision durable) or back (presumed abort) — under injected
leader deaths, lost commit submissions, and full-store crashes."""
import numpy as np
import pytest

from repro.core import (Clock, FaultPlan, FaultPoint, InjectedCrash,
                        ShardedStore, StoreConfig, TransientCOSError)
from repro.core.ec import ECConfig
from repro.core.gc_window import GCConfig

MB = 1024 * 1024


def make_sharded(num_shards=2, *, spill_dir=None, cos_root=None,
                 faults=None, seed=0, **kw):
    cfg = StoreConfig(ec=ECConfig(k=4, p=2),
                      function_capacity=8 * MB,
                      fragment_bytes=1 * MB,
                      gc=GCConfig(gc_interval=1e9),
                      num_recovery_functions=4,
                      spill_dir=spill_dir, faults=faults, **kw)
    return ShardedStore(cfg, num_shards=num_shards, clock=Clock(),
                        cos_root=cos_root, seed=seed)


def cross_shard_batch(st, n_per_shard=2, tag="b", rng=None):
    """A batch with >= n_per_shard keys on EVERY shard (so put_many
    takes the leader-sequenced two-round path)."""
    rng = rng or np.random.default_rng(0)
    per = {sid: 0 for sid in range(st.num_shards)}
    out = {}
    i = 0
    while any(c < n_per_shard for c in per.values()):
        k = f"{tag}{i}"
        i += 1
        sid = st.router.shard_of(k)
        if per[sid] >= n_per_shard:
            continue
        per[sid] += 1
        out[k] = rng.bytes(12_000)
    return out


def test_leader_death_after_decision_rolls_forward(tmp_path):
    plan = FaultPlan(seed=1).add(
        FaultPoint(site="shard.leader_death", action="crash", hits=(2,)))
    st = make_sharded(2, spill_dir=str(tmp_path / "spill"), faults=plan)
    try:
        rng = np.random.default_rng(1)
        pre = cross_shard_batch(st, tag="k", rng=rng)
        assert all(v == 1 for v in st.put_many(pre).values())
        new = {k: rng.bytes(12_000) for k in pre}
        with pytest.raises(InjectedCrash):
            st.put_many(new)                   # dies between the rounds
        # the batch is in doubt on every shard: new versions stay
        # PENDING, readers keep the old values — never half-visible
        tickets = st.indoubt_tickets()
        assert tickets
        for k, v in pre.items():
            assert st.get(k) == v
        # the sweep finds the durable decision and rolls ALL forward
        resolved = st.resolve_indoubt()
        assert set(resolved.values()) == {"commit"}
        assert st.indoubt_tickets() == []
        for k, v in new.items():
            assert st.get(k) == v, f"in-doubt key {k} not rolled forward"
        # decision records retired once every participant resolved
        assert st._decisions == {}
        # and the keyspace is fully writable again
        assert all(v == 3 for v in st.put_many(
            {k: b"x" * 9_000 for k in pre}).values())
    finally:
        st.close()


def test_commit_submission_failure_swept_forward():
    # journal-less store: decisions fall back to COS stubs
    plan = FaultPlan(seed=2).add(
        FaultPoint(site="shard.commit_submit", action="transient",
                   hits=(3,)))      # hits 1-2: the baseline batch
    st = make_sharded(2, faults=plan)
    try:
        rng = np.random.default_rng(2)
        pre = cross_shard_batch(st, tag="c", rng=rng)
        assert all(v == 1 for v in st.put_many(pre).values())
        new = {k: rng.bytes(12_000) for k in pre}
        with pytest.raises(TransientCOSError):
            st.put_many(new)                   # one submission lost
        assert st.indoubt_tickets()
        # gc_tick doubles as the in-doubt retry point
        st.gc_tick()
        assert st.indoubt_tickets() == []
        for k, v in new.items():
            assert st.get(k) == v
    finally:
        st.close()


def test_leader_death_before_decision_presumed_abort(tmp_path):
    plan = FaultPlan(seed=3).add(
        FaultPoint(site="shard.decision", action="crash", hits=(2,)))
    st = make_sharded(2, spill_dir=str(tmp_path / "spill"), faults=plan)
    try:
        rng = np.random.default_rng(3)
        pre = cross_shard_batch(st, tag="a", rng=rng)
        assert all(v == 1 for v in st.put_many(pre).values())
        new = {k: rng.bytes(12_000) for k in pre}
        with pytest.raises(InjectedCrash):
            st.put_many(new)                   # dies BEFORE the decision
        # no decision was ever durable: the live path aborted everywhere
        assert st.indoubt_tickets() == []
        assert st._decisions == {}
        for k, v in pre.items():
            assert st.get(k) == v              # batch fully invisible
        # no PENDING residue: the retry commits everywhere
        assert all(v >= 2 for v in st.put_many(new).values())
        for k, v in new.items():
            assert st.get(k) == v
    finally:
        st.close()


def test_full_crash_after_decision_restart_rolls_forward(tmp_path):
    spill = str(tmp_path / "spill")
    cosr = str(tmp_path / "cos")
    plan = FaultPlan(seed=4).add(
        FaultPoint(site="shard.leader_death", action="crash", hits=(2,)))
    st = make_sharded(2, spill_dir=spill, cos_root=cosr, faults=plan)
    rng = np.random.default_rng(4)
    pre = cross_shard_batch(st, tag="r", rng=rng)
    assert all(v == 1 for v in st.put_many(pre).values())
    new = {k: rng.bytes(12_000) for k in pre}
    with pytest.raises(InjectedCrash):
        st.put_many(new)
    assert st.indoubt_tickets()
    st.simulate_crash()                        # whole store dies in doubt
    # a rebuilt store replays the leader decision journal + every
    # shard's prepared/<ticket> records and resolves at construction
    st2 = make_sharded(2, spill_dir=spill, cos_root=cosr)
    try:
        assert st2.indoubt_tickets() == []
        for k, v in new.items():
            assert st2.get(k) == v, f"acked decision lost for {k}"
        assert st2.flush_writeback(timeout=120.0)
    finally:
        st2.close()


def test_full_crash_before_decision_restart_presumed_abort(tmp_path):
    spill = str(tmp_path / "spill")
    cosr = str(tmp_path / "cos")
    st = make_sharded(2, spill_dir=spill, cos_root=cosr)
    rng = np.random.default_rng(5)
    pre = cross_shard_batch(st, tag="p", rng=rng)
    assert all(v == 1 for v in st.put_many(pre).values())
    # prepare a ticketed sub-batch directly on one shard (the leader
    # never records a decision — exactly a leader death mid-prepare)
    sub = [(k, b"n" * 9_000) for k in pre
           if st.router.shard_of(k) == 0][:2]
    prep = st.shards[0].prepare_put_many_async(sub, ticket=901).result()
    assert prep is not None
    assert 901 in st.shards[0].indoubt_tickets()
    st.simulate_crash()
    st2 = make_sharded(2, spill_dir=spill, cos_root=cosr)
    try:
        # no decision record anywhere: presumed abort on restart
        assert st2.indoubt_tickets() == []
        for k, v in pre.items():
            assert st2.get(k) == v, f"aborted batch leaked into {k}"
        # the abandoned ticket left no PENDING head: same keys writable
        out = st2.put_many({k: b"w" * 9_000 for k, _ in sub})
        assert all(v >= 2 for v in out.values())
    finally:
        st2.close()


def test_ticket_sequence_reseeded_past_replayed_state(tmp_path):
    spill = str(tmp_path / "spill")
    cosr = str(tmp_path / "cos")
    st = make_sharded(2, spill_dir=spill, cos_root=cosr)
    rng = np.random.default_rng(6)
    pre = cross_shard_batch(st, tag="t", rng=rng)
    st.put_many(pre)
    prep = st.shards[0].prepare_put_many_async(
        [(next(iter(pre)), b"z" * 9_000)], ticket=500).result()
    assert prep is not None
    st.simulate_crash()
    st2 = make_sharded(2, spill_dir=spill, cos_root=cosr)
    try:
        # reusing ticket 500 would supersede a live prepared/<t> record
        # mid-doubt: the rebuilt sequence must start past it
        assert next(st2._tickets) > 500
    finally:
        st2.close()


def test_chaos_schedule_reproducible_and_zero_acked_loss(tmp_path):
    """Two runs of the same seeded chaos schedule produce byte-identical
    fault logs, and every acked write stays readable through slab kills,
    COS blips, a lost commit submission, and a full restart."""

    def run(tag):
        spill = str(tmp_path / f"spill-{tag}")
        cosr = str(tmp_path / f"cos-{tag}")
        plan = FaultPlan(seed=77, points=(
            FaultPoint(site="sms.store", action="reclaim", prob=0.04),
            FaultPoint(site="cos.get", action="transient", prob=0.10,
                       times=6),
            FaultPoint(site="shard.commit_submit", action="transient",
                       hits=(3,)),   # batch 2's first submission
        ))
        # serial read path + recovery off: every fire() comes from one
        # deterministic call sequence, so the LOG ORDER is comparable
        st = make_sharded(2, spill_dir=spill, cos_root=cosr,
                          faults=plan, pipelined_get=False,
                          enable_recovery=False)
        rng = np.random.default_rng(77)
        acked = {}
        for i in range(20):
            k = f"s{i}"
            acked[k] = rng.bytes(15_000)
            assert st.put(k, acked[k]) == 1
        batch = cross_shard_batch(st, tag="x", rng=rng)
        st.put_many(batch)                     # batch 1 commits clean
        acked.update(batch)
        batch2 = {k: rng.bytes(12_000) for k in batch}
        try:
            st.put_many(batch2)                # batch 2 loses a commit
        except TransientCOSError:
            st.resolve_indoubt()               # ...and is swept forward
        acked.update(batch2)
        for k, v in acked.items():
            assert st.get(k) == v, f"acked write {k} lost pre-crash"
        st.simulate_crash()
        st2 = make_sharded(2, spill_dir=spill, cos_root=cosr)
        try:
            assert st2.indoubt_tickets() == []
            for k, v in acked.items():
                assert st2.get(k) == v, f"acked write {k} lost at restart"
        finally:
            st2.close()
        return plan.snapshot()

    a, b = run("a"), run("b")
    assert a["fired"] > 0                      # the chaos was real
    assert a["log"] == b["log"]                # byte-identical schedule
