"""Observability plane tests (`repro.obs`): histogram math, the
Prometheus/JSON export, the crash-surviving flight recorder, and —
the end-to-end contract — cross-process trace stitching: one traced
`put_many` against every conformance frontend must yield ONE trace
whose spans cover client AND daemon stages, across the process
boundary for the process/tcp frontends, with worker spans recovered
as dead-epoch forensics after a real SIGKILL."""
import struct

import numpy as np
import pytest

from repro.core import (Clock, InfiniStore, ProcessShardedStore,
                        ShardedStore, StoreConfig)
from repro.core.ec import ECConfig
from repro.core.gc_window import GCConfig
from repro.core.store import StoreStats
from repro.devtools import lint
from repro.obs import (HISTOGRAM_SITES, NBUCKETS, NOOP_CM, FlightRecorder,
                       LatencyHistogram, ObsPlane, merge_counts,
                       merge_metric_snapshots, parse_prometheus,
                       quantile_us, summarize, to_prometheus)
from repro.obs.metrics import BOUNDS_US, bucket_of

MB = 1024 * 1024

# ---------------------------------------------------------------------------
# histogram math
# ---------------------------------------------------------------------------


def test_bucket_bounds_monotonic_with_overflow():
    assert bucket_of(0.0) == 0 and bucket_of(1.0) == 0
    assert bucket_of(BOUNDS_US[0] * 1.0001) == 1
    prev = 0
    for v in (1.2, 5.0, 100.0, 1e4, 1e6, 5e8):
        b = bucket_of(v)
        assert prev <= b < NBUCKETS
        prev = b
    assert bucket_of(1e12) == NBUCKETS - 1    # overflow bucket


def test_quantiles_within_bucket_resolution():
    h = LatencyHistogram()
    for _ in range(1000):
        h.record(1000.0)
    s = summarize(h.snapshot())
    assert s["count"] == 1000
    # log-spaced buckets at 2^(1/4): every quantile lands within ~10%
    for key in ("p50_us", "p99_us", "p999_us"):
        assert abs(s[key] - 1000.0) / 1000.0 < 0.11


def test_merge_counts_is_bucketwise_sum():
    a, b = LatencyHistogram(), LatencyHistogram()
    for v in (10.0, 50.0, 900.0):
        a.record(v)
    for v in (10.0, 7e9):
        b.record(v)
    merged = merge_counts([a.snapshot(), b.snapshot()])
    assert sum(merged) == 5
    assert merged[bucket_of(10.0)] == 2
    assert merged[NBUCKETS - 1] == 1          # overflow survived the merge
    assert quantile_us(merged, 0.5) > 0


def test_summarize_empty_is_zeroes():
    assert summarize([0] * NBUCKETS) == {
        "count": 0, "p50_us": 0.0, "p99_us": 0.0, "p999_us": 0.0}


def test_histogram_concurrent_record_no_lost_updates():
    import threading
    h = LatencyHistogram()

    def hammer():
        for _ in range(5000):
            h.record(100.0)

    threads = [threading.Thread(target=hammer) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert h.count() == 20_000


# ---------------------------------------------------------------------------
# export: Prometheus text + merge
# ---------------------------------------------------------------------------


def test_prometheus_roundtrip_covers_registry():
    plane = ObsPlane(name="t-prom")
    plane.record("put.ack_us", 123.0)
    snap = plane.snapshot()
    snap["counters"] = {"puts": 3}
    parsed = parse_prometheus(to_prometheus(snap))
    for site in HISTOGRAM_SITES:              # zero-count sites included
        name = "istore_" + site.replace(".", "_")
        assert name in parsed and f"{name}_count" in parsed
    assert parsed["istore_put_ack_us_count"] == {"": 1.0}
    assert parsed["istore_puts"] == {"": 3.0}
    assert parsed["istore_obs_enabled"] == {"": 1.0}


def test_parse_prometheus_rejects_malformed():
    with pytest.raises(ValueError):
        parse_prometheus("metric notanumber")
    with pytest.raises(ValueError):
        parse_prometheus('metric{q="0.5" 1.0')


def test_merge_metric_snapshots_sums_and_concats():
    a, b = ObsPlane(name="a"), ObsPlane(name="b")
    a.record("put.ack_us", 10.0)
    b.record("put.ack_us", 10.0)
    b.event("fault.fire", n=1)
    with a.span("daemon.put_many"):
        pass
    sa, sb = a.snapshot(), b.snapshot()
    sa["counters"], sb["counters"] = {"puts": 1}, {"puts": 2}
    m = merge_metric_snapshots([sa, sb])
    assert m["histograms"]["put.ack_us"]["count"] == 2
    assert len(m["spans"]) == 1 and len(m["events"]) == 1
    assert m["events"][0]["source"] == "b"    # provenance survives merge
    assert m["counters"] == {"puts": 3}


# ---------------------------------------------------------------------------
# flight recorder
# ---------------------------------------------------------------------------

_HDR_SIZE = struct.calcsize("<IHH")
_SLOT = 256                                   # recorder.DEFAULT_SLOT_SIZE


def test_flight_file_roundtrip_and_wraparound(tmp_path):
    p = str(tmp_path / "flight.bin")
    r = FlightRecorder(capacity=4)
    assert r.bind(p) is True
    assert r.bind(p) is False                 # first bind wins
    for i in range(6):                        # 6 events, 4 slots: 0,1 evicted
        r.event("fault.fire", n=i)
    r.close()
    recs = FlightRecorder.read_file(p)
    assert [rec["n"] for rec in recs] == [2, 3, 4, 5]
    assert [rec["seq"] for rec in recs] == sorted(rec["seq"] for rec in recs)


def test_flight_torn_slot_loses_one_record_only(tmp_path):
    p = str(tmp_path / "flight.bin")
    r = FlightRecorder(capacity=8)
    r.bind(p)
    for i in range(5):
        r.event("fault.fire", n=i)
    r.close()
    blob = bytearray(open(p, "rb").read())
    off = _HDR_SIZE + 1 * _SLOT               # tear slot 1 (event n=1)
    blob[off:off + 2] = struct.pack("<H", 12)
    blob[off + 2:off + 14] = b"\xff" * 12
    open(p, "wb").write(bytes(blob))
    recs = FlightRecorder.read_file(p)
    assert [rec["n"] for rec in recs] == [0, 2, 3, 4]


def test_flight_oversize_record_truncates_parseably(tmp_path):
    p = str(tmp_path / "flight.bin")
    r = FlightRecorder(capacity=4)
    r.bind(p)
    r.event("fault.fire", blob="x" * 4 * _SLOT)
    r.close()
    (rec,) = FlightRecorder.read_file(p)
    assert rec["kind"] == "fault.fire" and rec["truncated"] is True


def test_flight_read_missing_or_foreign_file(tmp_path):
    assert FlightRecorder.read_file(str(tmp_path / "absent.bin")) == []
    junk = tmp_path / "junk.bin"
    junk.write_bytes(b"not a flight file at all")
    assert FlightRecorder.read_file(str(junk)) == []


def test_disabled_plane_is_inert(tmp_path):
    plane = ObsPlane(enabled=False, name="off")
    assert plane.span("daemon.put_many") is NOOP_CM
    plane.record("put.ack_us", 5.0)
    plane.event("fault.fire", n=1)
    assert plane.ctx() is None
    assert plane.bind_flight(str(tmp_path / "f.bin")) is False
    snap = plane.snapshot()
    assert snap["enabled"] is False
    assert sum(h["count"] for h in snap["histograms"].values()) == 0
    assert snap["spans"] == [] and snap["events"] == []


# ---------------------------------------------------------------------------
# end-to-end: traced put_many across every conformance frontend
# ---------------------------------------------------------------------------

FRONTENDS = ("single", "sharded", "process", "tcp")


def _cfg(spill, plane):
    return StoreConfig(ec=ECConfig(k=4, p=2), function_capacity=8 * MB,
                       fragment_bytes=1 * MB,
                       gc=GCConfig(gc_interval=1e9),
                       num_recovery_functions=4, spill_dir=spill,
                       obs=plane)


def _build(kind, tmp_path, plane):
    cfg = _cfg(str(tmp_path / f"spill-{kind}"), plane)
    if kind == "single":
        return InfiniStore(cfg, clock=Clock(), seed=0)
    if kind == "sharded":
        return ShardedStore(cfg, num_shards=2, clock=Clock(), seed=0)
    if kind == "process":
        return ProcessShardedStore(cfg, num_shards=2, clock=Clock(), seed=0)
    if kind == "tcp":
        return ProcessShardedStore(cfg, num_shards=2, clock=Clock(),
                                   seed=0, transport="tcp")
    raise ValueError(kind)


@pytest.mark.parametrize("kind", FRONTENDS)
def test_traced_put_many_yields_one_stitched_trace(kind, tmp_path):
    plane = ObsPlane(name=f"t-{kind}")
    st = _build(kind, tmp_path, plane)
    try:
        rng = np.random.default_rng(0)
        st.put_many({f"k{i}": rng.bytes(8_000) for i in range(6)})
        st.put("solo", rng.bytes(8_000))      # single-shard ack path
        snap = st.snapshot_metrics()
        spans = snap["spans"]
        roots = [s for s in spans if s["site"] == "client.put_many"]
        assert roots, "no client root span recorded"
        tid = roots[-1]["trace_id"]
        trace = [s for s in spans if s["trace_id"] == tid]
        sites = {s["site"] for s in trace}
        assert "client.put_many" in sites
        if kind == "single":
            assert "daemon.put_many" in sites
        else:
            # a 6-key batch spans both shards: the 2PC path, leader and
            # both participant rounds, all stitched into the one trace
            assert {"leader.2pc", "daemon.2pc_prepare",
                    "daemon.2pc_commit"} <= sites
        # every daemon-side stage parents into this trace, not a fresh one
        ids = {s["span_id"] for s in trace}
        daemon = [s for s in trace if s["site"].startswith("daemon.")]
        assert daemon and all(s["parent_id"] in ids for s in daemon)
        assert snap["histograms"]["put.ack_us"]["count"] > 0
        if kind in ("process", "tcp"):
            # the trace crossed the transport: worker pids differ from
            # the frontend's, and the RPC roundtrip histogram saw it
            assert len({s["pid"] for s in trace}) >= 2
            assert snap["histograms"]["rpc.roundtrip_us"]["count"] > 0
            totals = st.transport_metrics()["totals"]
            assert isinstance(totals, dict)
    finally:
        st.close()


def test_sigkill_worker_leaves_dead_epoch_forensics(tmp_path):
    """A REAL SIGKILL of a worker must not lose its trace: the flight
    file's page-cache writes survive, and `restart_shard` surfaces the
    dead worker's spans/events as forensics tagged with their epoch."""
    plane = ObsPlane(name="t-forensics")
    st = _build("process", tmp_path, plane)
    try:
        rng = np.random.default_rng(1)
        st.put_many({f"k{i}": rng.bytes(8_000) for i in range(8)})
        st.simulate_crash(shard=0)
        st.restart_shard(0)
        snap = st.snapshot_metrics()
        forens = [f for f in snap["forensics"] if f["source"] == "shard-0"]
        assert forens, "no forensics recovered from the dead worker"
        assert forens[0]["dead"] is True and forens[0]["shard"] == 0
        recs = forens[0]["records"]
        kinds = {r.get("kind") for r in recs}
        assert "store.open" in kinds, "worker boot anchor missing"
        span_recs = [r for r in recs if r.get("kind") == "span"]
        assert span_recs, "dead worker's spans were lost"
        # shm workers pin epoch 1; the dead spans must carry it
        assert any(r.get("epoch") == 1 for r in span_recs)
        # the restarted shard still serves
        assert st.get("k0") is not None or st.get("k1") is not None
    finally:
        st.close()


def test_obs_none_store_works_and_exports_counters_only(tmp_path):
    st = _build("single", tmp_path, None)
    try:
        st.put("k", b"v" * 8_000)
        assert st.get("k") == b"v" * 8_000
        snap = st.snapshot_metrics()
        assert snap["enabled"] is False
        assert snap["counters"]["puts"] >= 1
        parse_prometheus(to_prometheus(snap))     # still a valid dump
    finally:
        st.close()


# ---------------------------------------------------------------------------
# derived stats ratios (single-snapshot consistency)
# ---------------------------------------------------------------------------


def test_stats_derived_ratios_from_one_snapshot():
    snap = {"sms_chunk_hits": 3, "sms_chunk_misses": 1,
            "prefetch_hits": 2, "prefetch_wasted": 2,
            "gets": 4, "cos_fallback_reads": 2, "decode_batches": 8}
    d = StoreStats.derived(snap)
    assert d == {"hit_ratio": 0.75, "prefetch_efficiency": 0.5,
                 "cos_fallback_per_get": 0.5, "decode_batches_per_get": 2.0}
    zero = {k: 0 for k in snap}
    assert all(v == 0.0 for v in StoreStats.derived(zero).values())


def test_snapshot_metadata_derived_matches_stats_block(tmp_path):
    st = _build("single", tmp_path, None)
    try:
        rng = np.random.default_rng(2)
        for i in range(4):
            st.put(f"k{i}", rng.bytes(8_000))
            st.get(f"k{i}")
        snap = st.snapshot_metadata()
        # the ratios must be computable from the SAME stats dict the
        # snapshot reports — one counter pass, internally consistent
        assert snap["derived"] == StoreStats.derived(snap["stats"])
    finally:
        st.close()


# ---------------------------------------------------------------------------
# metric_site lint rule
# ---------------------------------------------------------------------------

_SITES_SRC = 'METRIC_SITES = frozenset({"ok.site_us"})\n'

_BAD_OBS_SRC = '''\
class C:
    obs = None

    def unguarded(self):
        obs = self.obs
        obs.record("ok.site_us", 1.0)

    def unregistered(self):
        obs = self.obs
        if obs is not None:
            obs.record("typo.site_us", 1.0)

    def nonliteral(self, site):
        obs = self.obs
        if obs is not None:
            obs.event(site)
'''

_CLEAN_OBS_SRC = '''\
class C:
    obs = None

    def guarded(self):
        obs = self.obs
        if obs is not None:
            obs.record("ok.site_us", 1.0)

    def compound_guard(self, ready):
        obs = self.obs
        if obs is not None and ready:
            obs.event("ok.site_us", n=1)

    def callback_bound(self):
        obs = self.obs
        if obs is not None:
            def cb(v, obs=obs):
                obs.record("ok.site_us", v)
            cb(1.0)
'''


def _lint_dir(tmp_path, **files):
    for name, src in files.items():
        (tmp_path / f"{name}.py").write_text(src)
    new, _tm = lint.run([str(tmp_path)], root=tmp_path,
                        baseline_path=tmp_path / "absent.json")
    return new


def test_metric_site_rule_flags_bad_sites(tmp_path):
    new = [f for f in _lint_dir(tmp_path, sites=_SITES_SRC, m=_BAD_OBS_SRC)
           if f.rule == "metric-site"]
    details = sorted(f.detail.split(":")[0] for f in new)
    assert details == ["nonliteral", "unguarded", "unregistered"]


def test_metric_site_rule_clean_patterns_pass(tmp_path):
    new = _lint_dir(tmp_path, sites=_SITES_SRC, m=_CLEAN_OBS_SRC)
    assert [f for f in new if f.rule == "metric-site"] == []
