"""Batched EC data path: encode_many/decode_many round-trips over every
survivor subset, decode-matrix LRU accounting, bit-sliced kernel
equivalence, and store-level put_many/get_many."""
from itertools import combinations

import numpy as np
import pytest

from repro.core.ec import ECConfig, RSCodec
from repro.kernels.rs_gf256.kernel import (gf256_matmul_bitsliced,
                                           gf256_matmul_pallas_ladder)
from repro.kernels.rs_gf256.ref import (gf256_matmul_ref, gf_matmul_np,
                                        gf_matmul_table)


# ---------------------------------------------------------------------------
# codec: batched round-trips
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k,p", [(2, 1), (3, 2), (4, 2)])
def test_roundtrip_all_survivor_subsets(k, p):
    rng = np.random.default_rng(k * 10 + p)
    codec = RSCodec(ECConfig(k=k, p=p))
    for size in (0, 1, 3, 100, 4097):
        payload = rng.bytes(size)
        chunks = codec.encode(payload)
        assert len(chunks) == k + p
        for surv in combinations(range(k + p), k):
            got = codec.decode({i: chunks[i] for i in surv})
            assert got == payload, (k, p, size, surv)


def test_encode_many_matches_encode():
    rng = np.random.default_rng(7)
    codec = RSCodec(ECConfig(k=4, p=2))
    payloads = [rng.bytes(s) for s in (10, 999, 0, 4096, 1, 123_457)]
    batched = codec.encode_many(payloads)
    for payload, chunks in zip(payloads, batched):
        assert chunks == codec.encode(payload)


def test_decode_many_mixed_survivor_sets():
    rng = np.random.default_rng(8)
    codec = RSCodec(ECConfig(k=4, p=2))
    payloads = [rng.bytes(s) for s in (50, 2048, 7, 0)]
    batched = codec.encode_many(payloads)
    cmaps, want = [], []
    for payload, chunks in zip(payloads, batched):
        for drop in ((), (0,), (1, 5), (2, 3), (4, 5)):
            cmaps.append({i: c for i, c in enumerate(chunks)
                          if i not in drop})
            want.append(payload)
    assert codec.decode_many(cmaps) == want


def test_decode_many_empty_and_too_few():
    codec = RSCodec(ECConfig(k=4, p=2))
    assert codec.decode_many([]) == []
    chunks = codec.encode(b"hello")
    with pytest.raises(ValueError):
        codec.decode_many([{0: chunks[0], 1: chunks[1], 2: chunks[2]}])


# ---------------------------------------------------------------------------
# codec: decode-matrix LRU cache accounting
# ---------------------------------------------------------------------------

def test_repeated_degraded_reads_invert_once():
    codec = RSCodec(ECConfig(k=4, p=2))
    chunks = codec.encode(b"x" * 5000)
    surv = {i: c for i, c in enumerate(chunks) if i not in (0, 5)}
    for _ in range(6):
        assert codec.decode(surv) == b"x" * 5000
    info = codec.cache_info()
    assert info["inversions"] == 1
    assert info["misses"] == 1
    assert info["hits"] == 5


def test_cache_keys_by_survivor_tuple_and_evicts_lru():
    codec = RSCodec(ECConfig(k=3, p=2), inv_cache_size=2)
    chunks = codec.encode(bytes(range(100)))
    survivor_sets = [(0, 1, 3), (0, 1, 4), (0, 2, 3)]   # 3 distinct keys
    for surv in survivor_sets:
        codec.decode({i: chunks[i] for i in surv})
    assert codec.cache_info()["inversions"] == 3
    assert codec.cache_info()["size"] == 2              # LRU evicted one
    # oldest key (0,1,3) was evicted -> re-decoding re-inverts
    codec.decode({i: chunks[i] for i in survivor_sets[0]})
    assert codec.cache_info()["inversions"] == 4


def test_identity_decode_skips_matmul_and_cache():
    codec = RSCodec(ECConfig(k=4, p=2))
    chunks = codec.encode(b"abcdef" * 100)
    codec.decode({i: chunks[i] for i in range(4)})       # all data rows
    info = codec.cache_info()
    assert info["inversions"] == 0 and info["hits"] == 0


# ---------------------------------------------------------------------------
# kernel: bit-sliced vs oracles (bit-identical)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(6))
def test_bitsliced_bit_identical_randomized(seed):
    rng = np.random.default_rng(seed)
    m = int(rng.integers(1, 8))
    k = int(rng.integers(1, 13))
    L = int(rng.integers(1, 9000))
    G = rng.integers(0, 256, (m, k)).astype(np.uint8)
    X = rng.integers(0, 256, (k, L)).astype(np.uint8)
    want = gf_matmul_np(G, X)
    assert np.array_equal(np.asarray(gf256_matmul_ref(G, X)), want)
    assert np.array_equal(gf_matmul_table(G, X), want)
    got = np.asarray(gf256_matmul_bitsliced(G, X, interpret=True))
    assert np.array_equal(got, want)


def test_bitsliced_matches_ladder():
    rng = np.random.default_rng(42)
    G = rng.integers(0, 256, (4, 6)).astype(np.uint8)
    X = rng.integers(0, 256, (6, 2048 + 77)).astype(np.uint8)
    a = np.asarray(gf256_matmul_bitsliced(G, X, interpret=True))
    b = np.asarray(gf256_matmul_pallas_ladder(G, X, interpret=True))
    assert np.array_equal(a, b)


# ---------------------------------------------------------------------------
# store: batch APIs
# ---------------------------------------------------------------------------

def test_put_many_get_many_roundtrip(tiny_store):
    store, clock = tiny_store
    rng = np.random.default_rng(3)
    items = {f"k{i}": rng.bytes(int(rng.integers(1, 200_000)))
             for i in range(5)}
    vers = store.put_many(items)
    assert all(v >= 1 for v in vers.values())
    got = store.get_many(list(items) + ["absent"])
    for key, want in items.items():
        assert got[key] == want
    assert got["absent"] is None


def test_put_many_replaces_chunks_refused_by_drifted_slabs():
    """Regression: batch placement runs before any slab write, so the
    ledger/slab drift resync of the sequential path can't trigger at
    place time — a refused chunk must be re-placed, not fail the PUT."""
    from repro.core import Clock, InfiniStore, StoreConfig
    from repro.core.ec import ECConfig
    MB = 1024 * 1024
    store = InfiniStore(StoreConfig(ec=ECConfig(k=2, p=1),
                                    function_capacity=2 * MB,
                                    fragment_bytes=1 * MB), clock=Clock())
    rng = np.random.default_rng(5)
    for i in range(30):                 # builds ledger-vs-slab drift
        store.put(f"k{i % 7}", rng.bytes(int(rng.integers(1, 300_000))))
    big = rng.bytes(2_500_000)
    out = store.put_many([("big1", big), ("tiny", b"t")])
    assert out == {"big1": 1, "tiny": 1}
    assert store.get("big1") == big
    assert store.get("tiny") == b"t"


def test_put_many_rejects_duplicate_keys(tiny_store):
    store, _ = tiny_store
    with pytest.raises(ValueError):
        store.put_many([("k", b"a"), ("k", b"b")])


def test_store_configs_are_not_shared():
    """Regression: the cfg default must be per-instance, not a shared
    dataclass default evaluated once at def time."""
    from repro.core import InfiniStore
    s1, s2 = InfiniStore(), InfiniStore()
    assert s1.cfg is not s2.cfg
    s1.cfg.fragment_bytes = 1
    assert s2.cfg.fragment_bytes != 1
