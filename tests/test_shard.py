"""Sharded multi-daemon scale-out (`repro.core.shard`): router
determinism, the full StoreFrontend contract at the sharded surface,
concurrent multi-threaded clients (uniform + hot-shard skew), the
crash-one-shard -> restart -> zero-acked-loss contract, and cross-shard
`put_many` atomicity under injected shard failure."""
import threading

import numpy as np
import pytest

from repro.core import (Clock, HashRouter, RangeRouter, ShardedStore,
                        StoreConfig, StoreFrontend)
from repro.core.ec import ECConfig
from repro.core.gc_window import GCConfig
from repro.core.store import AtomicCounter, StoreStats

MB = 1024 * 1024


def make_sharded(num_shards=4, *, spill_dir=None, cos_root=None,
                 router="hash", range_boundaries=None, **kw):
    cfg = StoreConfig(ec=ECConfig(k=4, p=2),
                      function_capacity=8 * MB,
                      fragment_bytes=1 * MB,
                      gc=GCConfig(gc_interval=1e9),
                      num_recovery_functions=4,
                      spill_dir=spill_dir, **kw)
    return ShardedStore(cfg, num_shards=num_shards, router=router,
                        range_boundaries=range_boundaries,
                        clock=Clock(), cos_root=cos_root)


# ---------------------------------------------------------------------------
# routers
# ---------------------------------------------------------------------------

def test_hash_router_deterministic_and_covering():
    r = HashRouter(8)
    keys = [f"obj/{i}" for i in range(512)]
    a = [r.shard_of(k) for k in keys]
    b = [r.shard_of(k) for k in keys]
    assert a == b                                  # stable across calls
    assert set(a) == set(range(8))                 # every shard used
    counts = np.bincount(a, minlength=8)
    assert counts.min() > 0.3 * counts.mean()      # roughly uniform


def test_range_router_contiguous():
    r = RangeRouter(["g", "n", "t"])
    assert r.num_shards == 4
    assert r.shard_of("apple") == 0
    assert r.shard_of("g") == 1                    # boundary -> right shard
    assert r.shard_of("horse") == 1
    assert r.shard_of("queen") == 2
    assert r.shard_of("zebra") == 3


def test_router_config_validation():
    with pytest.raises(ValueError):
        HashRouter(0)
    with pytest.raises(ValueError):
        make_sharded(router="range")               # boundaries required
    with pytest.raises(ValueError):
        make_sharded(router="bogus")


# ---------------------------------------------------------------------------
# StoreFrontend contract at the sharded surface
# ---------------------------------------------------------------------------

def test_sharded_store_is_a_store_frontend():
    st = make_sharded(2)
    try:
        assert isinstance(st, StoreFrontend)
    finally:
        st.close()


def test_put_get_roundtrip_across_shards():
    st = make_sharded(4)
    rng = np.random.default_rng(0)
    vals = {f"k{i}": rng.bytes(40_000) for i in range(24)}
    try:
        for k, v in vals.items():
            assert st.put(k, v) == 1
        for k, v in vals.items():
            assert st.get(k) == v
            arr = st.get_array(k)
            assert bytes(arr) == v
        assert st.get("missing") is None
        # versioned update routes to the same shard
        st.put("k0", b"v2" * 1000)
        assert st.get("k0") == b"v2" * 1000
        assert st.stats.puts == len(vals) + 1
        bal = st.shard_balance()
        assert sum(bal) == len(vals)
        assert st.flush_writeback(timeout=60.0)
    finally:
        assert st.close()


def test_cross_shard_put_many_and_batched_gets():
    st = make_sharded(4)
    rng = np.random.default_rng(1)
    batch = {f"b{i}": rng.bytes(25_000) for i in range(16)}
    try:
        out = st.put_many(batch)
        assert all(v == 1 for v in out.values())
        # one leader ticket for the whole cross-shard batch
        assert st.tickets_issued() == 1
        assert st.stats.commit_tickets == len(
            {st.router.shard_of(k) for k in batch})
        got = st.get_many(list(batch))
        assert all(got[k] == batch[k] for k in batch)
        arrs = st.get_many_arrays(list(batch))
        assert all(bytes(arrs[k]) == batch[k] for k in batch)
        snap = st.snapshot_metadata()
        assert snap["num_shards"] == 4
        assert sum(snap["balance"]) == len(batch)
        assert snap["commit_tickets_issued"] == 1
        assert len(snap["shards"]) == 4
    finally:
        assert st.close()


def test_single_shard_batch_skips_leader():
    """A batch that lands on one shard takes the fast path: no ticket."""
    st = make_sharded(4, router="range", range_boundaries=["g", "n", "t"])
    try:
        batch = {f"a{i}": b"x" * 1000 for i in range(6)}   # all shard 0
        out = st.put_many(batch)
        assert all(v == 1 for v in out.values())
        assert st.tickets_issued() == 0
        assert st.stats.commit_tickets == 0
        assert st.shard_balance() == [6, 0, 0, 0]
    finally:
        assert st.close()


def test_async_futures_pipeline():
    st = make_sharded(4)
    rng = np.random.default_rng(2)
    vals = {f"p{i}": rng.bytes(20_000) for i in range(12)}
    try:
        futs = [st.put_async(k, v) for k, v in vals.items()]
        assert [f.result() for f in futs] == [1] * len(vals)
        gfut = st.get_many_async(list(vals))
        got = gfut.result()
        assert all(got[k] == vals[k] for k in vals)
    finally:
        assert st.close()


# ---------------------------------------------------------------------------
# concurrent multi-threaded clients
# ---------------------------------------------------------------------------

def _hammer(st, n_threads, per_thread, key_fn, nbytes=8_000):
    """n_threads clients, each PUTs then verifies its own keys."""
    errors = []
    barrier = threading.Barrier(n_threads)

    def client(t):
        try:
            rng = np.random.default_rng(t)
            mine = {key_fn(t, i): rng.bytes(nbytes)
                    for i in range(per_thread)}
            barrier.wait(timeout=30)
            futs = [st.put_async(k, v) for k, v in mine.items()]
            for f in futs:
                assert f.result(timeout=60) == 1
            got = st.get_many_async(list(mine)).result(timeout=60)
            for k, v in mine.items():
                assert got[k] == v, f"bad readback {k}"
        except BaseException as e:                 # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=client, args=(t,))
               for t in range(n_threads)]
    for th in threads:
        th.start()
    for th in threads:
        th.join(timeout=120)
    assert not errors, errors[:3]


def test_concurrent_clients_uniform_keys():
    st = make_sharded(4)
    try:
        _hammer(st, n_threads=8, per_thread=12,
                key_fn=lambda t, i: f"u/{t}/{i}")
        assert st.stats.puts == 8 * 12
        # uniform keys spread over every shard
        assert all(b > 0 for b in st.shard_balance())
        assert st.flush_writeback(timeout=120.0)
    finally:
        assert st.close()


def test_concurrent_clients_hot_shard_skew():
    """Every client hammers ONE shard's keyspace (range-routed): the
    owning daemon serializes correctly under contention and the other
    shards stay empty."""
    st = make_sharded(4, router="range", range_boundaries=["g", "n", "t"])
    try:
        _hammer(st, n_threads=8, per_thread=10,
                key_fn=lambda t, i: f"zz/{t}/{i}")   # all -> last shard
        bal = st.shard_balance()
        assert bal == [0, 0, 0, 80]
        assert st.flush_writeback(timeout=120.0)
    finally:
        assert st.close()


def test_concurrent_cross_shard_batches():
    """Parallel cross-shard put_many batches: every batch fully commits
    and tickets are unique per batch."""
    st = make_sharded(4)
    errors = []

    def client(t):
        try:
            batch = {f"cb/{t}/{i}": bytes([t]) * 4000 for i in range(8)}
            out = st.put_many(batch)
            assert all(v == 1 for v in out.values())
            got = st.get_many(list(batch))
            assert all(got[k] == batch[k] for k in batch)
        except BaseException as e:                 # noqa: BLE001
            errors.append(e)

    try:
        threads = [threading.Thread(target=client, args=(t,))
                   for t in range(6)]
        for th in threads:
            th.start()
        for th in threads:
            th.join(timeout=120)
        assert not errors, errors[:3]
        assert st.tickets_issued() == 6
    finally:
        assert st.close()


# ---------------------------------------------------------------------------
# crash one shard mid-stream -> survivors serve -> restart -> zero loss
# ---------------------------------------------------------------------------

def test_crash_one_shard_survivors_serve_restart_zero_loss(tmp_path):
    st = make_sharded(4, spill_dir=str(tmp_path / "spill"))
    rng = np.random.default_rng(3)
    vals = {f"k{i}": rng.bytes(30_000) for i in range(32)}
    try:
        st.pause_writeback()           # everything acked-but-unpersisted
        for k, v in vals.items():
            assert st.put(k, v) == 1
        victim = 1
        dead_keys = [k for k in vals if st.router.shard_of(k) == victim]
        assert dead_keys                           # scenario is real
        st.simulate_crash(shard=victim)
        # survivors keep serving THEIR keyspaces while shard 1 is down
        for k, v in vals.items():
            if st.router.shard_of(k) != victim:
                assert st.get(k) == v
        # restart replays the dead shard's journal: zero acked loss
        st.restart_shard(victim)
        for k, v in vals.items():
            assert st.get(k) == v, f"lost acked write {k}"
        replayed = st.shards[victim].stats.spill_replayed_writes
        assert replayed > 0
        assert st.flush_writeback(timeout=120.0)
    finally:
        st.close()


def test_whole_store_crash_restart_zero_loss(tmp_path):
    spill = str(tmp_path / "spill")
    cosr = str(tmp_path / "cos")
    st = make_sharded(4, spill_dir=spill, cos_root=cosr)
    rng = np.random.default_rng(4)
    vals = {f"w{i}": rng.bytes(20_000) for i in range(16)}
    for k, v in vals.items():
        st.put(k, v)
    root = st.simulate_crash()
    assert root == spill
    st2 = make_sharded(4, spill_dir=spill, cos_root=cosr)
    try:
        for k, v in vals.items():
            assert st2.get(k) == v, f"lost {k} across full restart"
        assert st2.flush_writeback(timeout=120.0)
    finally:
        st2.close()


# ---------------------------------------------------------------------------
# cross-shard put_many atomicity under injected shard failure
# ---------------------------------------------------------------------------

def _failing_prepare(st, sid, exc=None):
    exc = exc or RuntimeError("injected shard failure")

    def boom(items, **kw):
        raise exc
    st.shards[sid]._put_many_prepare = boom
    return exc


def test_cross_shard_atomicity_prepare_failure():
    """One shard fails to prepare -> the whole batch raises and NO key
    of it becomes visible on ANY shard (readers keep the old values)."""
    st = make_sharded(4)
    rng = np.random.default_rng(5)
    pre = {f"x{i}": rng.bytes(10_000) for i in range(16)}
    try:
        assert all(v == 1 for v in st.put_many(pre).values())
        _failing_prepare(st, sid=2)
        new = {k: rng.bytes(10_000) for k in pre}
        with pytest.raises(RuntimeError, match="injected shard failure"):
            st.put_many(new)
        # never half-visible: every shard still serves the OLD values
        got = st.get_many(list(pre))
        for k, v in pre.items():
            assert got[k] == v, f"half-visible batch at {k}"
    finally:
        st.close()


def test_cross_shard_atomicity_dead_shard():
    """A crashed (not just failing) shard also aborts the whole batch;
    surviving shards roll back their prepared sub-batches."""
    st = make_sharded(4)
    rng = np.random.default_rng(6)
    pre = {f"y{i}": rng.bytes(8_000) for i in range(16)}
    try:
        assert all(v == 1 for v in st.put_many(pre).values())
        st.simulate_crash(shard=3)
        new = {k: rng.bytes(8_000) for k in pre}
        with pytest.raises(BaseException):
            st.put_many(new)
        for k, v in pre.items():
            if st.router.shard_of(k) != 3:
                assert st.get(k) == v, f"half-visible batch at {k}"
    finally:
        st.close()


def test_retry_after_aborted_batch_commits():
    """An aborted cross-shard batch leaves no PENDING heads behind: the
    immediate retry commits everywhere."""
    st = make_sharded(4)
    try:
        _failing_prepare(st, sid=0)
        batch = {f"r{i}": bytes([i]) * 5000 for i in range(12)}
        with pytest.raises(RuntimeError):
            st.put_many(batch)
        del st.shards[0]._put_many_prepare        # restore class impl
        out = st.put_many(batch)
        assert all(v >= 1 for v in out.values())
        got = st.get_many(list(batch))
        assert all(got[k] == batch[k] for k in batch)
    finally:
        assert st.close()


# ---------------------------------------------------------------------------
# lock-free stats (satellite: atomic counters)
# ---------------------------------------------------------------------------

def test_atomic_counter_concurrent_increments_exact():
    c = AtomicCounter()
    N, T = 20_000, 8

    def worker():
        for _ in range(N):
            c.add()
    threads = [threading.Thread(target=worker) for _ in range(T)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == N * T                        # zero lost updates


def test_store_stats_concurrent_inc_exact():
    s = StoreStats()
    N, T = 5_000, 8

    def worker():
        for _ in range(N):
            s.inc("puts")
            s.inc("sms_chunk_hits", 3)
    threads = [threading.Thread(target=worker) for _ in range(T)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert s.puts == N * T
    assert s.sms_chunk_hits == 3 * N * T
    assert s.as_dict()["puts"] == N * T
    # reseed semantics used by the prefetch mirror
    s.prefetch_hits = 17
    assert s.prefetch_hits == 17


# ---------------------------------------------------------------------------
# program-level integrations ride the StoreFrontend protocol
# ---------------------------------------------------------------------------

def test_checkpointer_over_sharded_store():
    from repro.checkpoint.checkpointer import CheckpointConfig, Checkpointer
    st = make_sharded(4)
    try:
        ck = Checkpointer(st, CheckpointConfig(prefix="ck", keep=2,
                                               leaf_shard_bytes=64 * 1024))
        rng = np.random.default_rng(7)
        state = {"w": rng.standard_normal((64, 64)).astype(np.float32),
                 "b": rng.standard_normal(256).astype(np.float32)}
        ck.save(1, state)
        assert ck.latest_step() == 1
        back = ck.restore(1)
        np.testing.assert_array_equal(back["w"], state["w"])
        np.testing.assert_array_equal(back["b"], state["b"])
        # shard keys scattered across daemons
        assert sum(1 for b in st.shard_balance() if b > 0) > 1
    finally:
        st.close()


def test_kv_cache_store_backend_roundtrip():
    from repro.configs import get_config, reduced
    from repro.serving.kv_cache import SMSPagedKV
    import dataclasses
    cfg = dataclasses.replace(
        reduced(get_config("qwen1.5-0.5b")), dtype="float32")
    st = make_sharded(2)
    try:
        kv = SMSPagedKV(cfg, batch_slots=2, max_len=128, page_size=32,
                        store=st)
        phys = kv.alloc_page(0, "seq-a", 0)
        import jax.numpy as jnp
        kv.k_pool = kv.k_pool.at[:, 0, phys].set(
            jnp.ones_like(kv.k_pool[:, 0, phys]))
        key = kv._key("seq-a", 0)
        kv.evict_page_to_cos(key)
        assert kv.stats.pages_evicted_to_cos == 1
        assert st.stats.puts == 1                  # rode the store path
        kv.restore_pages(0, "seq-a", [0])
        assert kv.stats.pages_restored == 1
        assert bool((np.asarray(kv.k_pool[:, 0, kv.pages[key][2]])
                     == 1.0).all())
    finally:
        st.close()


# ---------------------------------------------------------------------------
# review regressions: 2PC window behavior + commit-failure cleanup
# ---------------------------------------------------------------------------

def test_get_during_2pc_window_serves_previous_version_fast():
    """A GET between prepare and commit must NOT block the shard daemon
    on the prepared head (the commit is queued behind it): it serves
    the previous committed version immediately."""
    import time
    st = make_sharded(2)
    try:
        sid = st.router.shard_of("k2pc")
        shard = st.shards[sid]
        assert st.put("k2pc", b"old" * 1000) == 1
        prep = shard.prepare_put_many_async([("k2pc", b"new" * 1000)]).result()
        t0 = time.perf_counter()
        assert st.get("k2pc") == b"old" * 1000     # uncommitted invisible
        assert time.perf_counter() - t0 < 2.0      # and no 5 s stall
        # a concurrent writer conflicts immediately instead of stalling
        t0 = time.perf_counter()
        out = shard.put_many([("k2pc", b"loser")])
        assert out["k2pc"] == -1
        assert time.perf_counter() - t0 < 2.0
        out = shard.commit_put_many_async(prep, ticket=1).result()
        assert out["k2pc"] == 2
        assert st.get("k2pc") == b"new" * 1000
    finally:
        st.close()


def test_commit_failure_rolls_forward_via_resolver():
    """A commit-side failure AFTER the leader's decision is durable
    leaves the shard in doubt (never half-aborted): reads still resolve
    fast (the PENDING head is skipped, the previous version serves),
    and the next resolve_indoubt sweep — here via gc_tick — retries the
    idempotent commit so the batch converges to fully-committed."""
    st = make_sharded(4)
    rng = np.random.default_rng(8)
    pre = {f"cf{i}": rng.bytes(6_000) for i in range(12)}
    try:
        assert all(v == 1 for v in st.put_many(pre).values())
        sids = {st.router.shard_of(k) for k in pre}
        victim = sorted(sids)[0]

        def boom(prep, *, ticket=None):
            raise RuntimeError("injected commit failure")
        st.shards[victim]._put_many_commit = boom
        new = {k: rng.bytes(6_000) for k in pre}
        with pytest.raises(RuntimeError, match="injected commit failure"):
            st.put_many(new)
        del st.shards[victim]._put_many_commit
        # in doubt, not stuck: reads resolve fast — committed shards
        # serve the new value, the in-doubt shard its previous one
        for k in pre:
            assert st.get(k) in (pre[k], new[k])
        assert st.indoubt_tickets()                # the batch is in doubt
        # the sweep rolls the in-doubt sub-batch FORWARD (the decision
        # was durable), so the un-acked batch converges to committed
        resolved = st.resolve_indoubt()
        assert "commit" in resolved.values()
        assert st.indoubt_tickets() == []
        for k in pre:
            assert st.get(k) == new[k]
        out = st.put_many({k: rng.bytes(6_000) for k in pre})
        assert all(v == 3 for v in out.values())
    finally:
        st.close()


def test_snapshot_value_copies_once():
    """The sharded front-end snapshots mutable payloads at its surface;
    the shard's own snapshot pass must be a no-op on them."""
    from repro.core import InfiniStore
    arr = np.arange(4096, dtype=np.uint8)
    snap = InfiniStore._snapshot_value(arr)
    assert snap is not arr                         # private copy taken
    assert not snap.flags.writeable
    assert InfiniStore._snapshot_value(snap) is snap   # second pass: no-op
    assert InfiniStore._snapshot_value(b"imm") == b"imm"
