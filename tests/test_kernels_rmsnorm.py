"""Fused RMSNorm kernel vs oracle — shape/dtype sweep."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.rmsnorm.kernel import rms_norm_pallas
from repro.kernels.rmsnorm.ref import rms_norm_ref


@pytest.mark.parametrize("shape", [(4, 128), (3, 7, 256), (1, 512),
                                   (300, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matches_ref(shape, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(sum(shape)))
    x = jax.random.normal(k1, shape, dtype)
    scale = jax.random.normal(k2, shape[-1:], dtype) * 0.1 + 1.0
    want = np.asarray(rms_norm_ref(x, scale), np.float32)
    got = np.asarray(rms_norm_pallas(x, scale, interpret=True), np.float32)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(got, want, atol=tol, rtol=tol)
