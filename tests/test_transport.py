"""Networked shard transport: framing, heartbeat failure detection,
epoch-fenced reconnect, per-RPC deadlines, deterministic `net.*` fault
injection, and partition-tolerant 2PC over TCP loopback.

Timing discipline: the container is single-core, so heartbeat configs
here run HOT (50ms pings, sub-second death) and every liveness wait is
a bounded poll, never a bare sleep."""
import os
import signal
import socket
import threading
import time

import numpy as np
import pytest

from repro.core import (Clock, FaultPlan, FaultPoint,
                        ProcessShardedStore, StoreConfig)
from repro.core.ec import ECConfig
from repro.core.gc_window import GCConfig
from repro.core.host import ShardWorkerDied
from repro.core.transport import (CONNECTED, DOWN, FrameError,
                                  HeartbeatConfig, TcpTransport,
                                  recv_frame, send_frame)

MB = 1024 * 1024

#: hot detector for tests: 50ms pings, DOWN in 400ms, fast reconnect
HOT = HeartbeatConfig(interval_s=0.05, suspect_after_s=0.15,
                      dead_after_s=0.4, connect_timeout_s=5.0,
                      rpc_deadline_s=2.0, reconnect_max_attempts=40,
                      reconnect_backoff_base_s=0.05,
                      reconnect_backoff_cap_s=0.2, partition_s=0.8)


def _cfg(spill_dir=None, faults=None):
    return StoreConfig(ec=ECConfig(k=4, p=2), function_capacity=8 * MB,
                       fragment_bytes=1 * MB,
                       gc=GCConfig(gc_interval=1e9),
                       num_recovery_functions=4, spill_dir=spill_dir,
                       faults=faults)


def _tcp_store(tmp_path, *, num_shards=2, hb=HOT, faults=None, seed=0):
    return ProcessShardedStore(
        _cfg(str(tmp_path / "spill"), faults=faults),
        num_shards=num_shards, clock=Clock(), seed=seed,
        transport="tcp", heartbeat=hb)


def _poll(pred, timeout=15.0, interval=0.05, what="condition"):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return
        time.sleep(interval)
    raise AssertionError(f"timed out waiting for {what}")


# ---------------------------------------------------------------------------
# wire format
# ---------------------------------------------------------------------------

class TestFraming:
    def test_roundtrip_with_payload_section(self):
        a, b = socket.socketpair()
        try:
            send_frame(a, (3, "val", 7, ("o", 0, 4)), (b"abcd", b"ef"))
            ctrl, payload = recv_frame(b)
            assert ctrl == (3, "val", 7, ("o", 0, 4))
            assert payload == b"abcdef"
        finally:
            a.close()
            b.close()

    def test_bad_magic_raises_frame_error(self):
        a, b = socket.socketpair()
        try:
            a.sendall(b"\x00" * 16)
            with pytest.raises(FrameError):
                recv_frame(b)
        finally:
            a.close()
            b.close()

    def test_eof_mid_frame_raises_frame_error(self):
        a, b = socket.socketpair()
        try:
            a.sendall(b"\x49")
            a.close()
            with pytest.raises(FrameError):
                recv_frame(b)
        finally:
            b.close()


# ---------------------------------------------------------------------------
# error taxonomy (satellite: unified disconnect mapping)
# ---------------------------------------------------------------------------

class TestShardWorkerDied:
    def test_carries_context_fields(self):
        e = ShardWorkerDied("gone", shard_id=3, epoch=2, op="put")
        assert (e.shard_id, e.epoch, e.op) == (3, 2, "put")
        assert isinstance(e, ConnectionError)

    def test_pickles_with_context(self):
        import pickle
        e = pickle.loads(pickle.dumps(
            ShardWorkerDied("gone", shard_id=1, epoch=4, op="get")))
        assert (e.shard_id, e.epoch, e.op) == (1, 4, "get")

    def test_thread_frontend_dead_daemon_maps_to_it(self, tmp_path):
        from repro.core import InfiniStore
        st = InfiniStore(_cfg(str(tmp_path / "s")), clock=Clock(),
                         seed=0)
        st.put("k", b"x" * 9_000)
        st.close()
        with pytest.raises(ShardWorkerDied):
            st.put_async("k2", b"y" * 9_000)


# ---------------------------------------------------------------------------
# basic TCP data plane
# ---------------------------------------------------------------------------

class TestTcpRoundtrip:
    def test_put_get_and_health_surface(self, tmp_path):
        st = _tcp_store(tmp_path)
        try:
            rng = np.random.default_rng(0)
            data = {f"k{i}": rng.bytes(9_000) for i in range(4)}
            for k, v in data.items():
                assert st.put(k, v) == 1
            for k, v in data.items():
                assert st.get(k) == v
            health = st.shard_transport_health()
            assert len(health) == 2
            for h in health:
                assert h["kind"] == "tcp"
                assert h["state"] in (CONNECTED, "SUSPECT")
                assert h["epoch"] == 1
                assert h["last_heartbeat_age_s"] is not None
            snap = st.snapshot_metadata()
            ts = snap["health"]["shard_transports"]
            assert [t["kind"] for t in ts] == ["tcp", "tcp"]
            # per-shard snapshot overlays the same dict
            assert snap["shards"][0]["health"]["transport"]["epoch"] == 1
        finally:
            st.close()

    def test_worker_fencing_counters_clean_run(self, tmp_path):
        st = _tcp_store(tmp_path)
        try:
            st.put("k", b"x" * 9_000)
            xs = st.shards[0].transport_stats()
            assert xs["epoch"] == 1
            assert xs["stale_acks_suppressed"] == 0
            assert xs["fenced_connects"] == 0
        finally:
            st.close()


# ---------------------------------------------------------------------------
# failure detection + reconnect
# ---------------------------------------------------------------------------

class TestFailureDetection:
    def test_sigstop_declares_down_sigcont_reconnects(self, tmp_path):
        """A frozen (not dead) worker: heartbeats stop ponging, the
        detector declares DOWN (SHARD_DOWN without process death —
        satellite 2), and the thaw reconnects at a higher epoch."""
        st = _tcp_store(tmp_path, num_shards=1)
        try:
            st.put("a", b"a" * 9_000)
            pid = st.shards[0].pid
            os.kill(pid, signal.SIGSTOP)
            try:
                _poll(lambda: st.shard_transport_health()[0]["state"]
                      in (DOWN, "RECONNECTING"),
                      what="heartbeat-timeout DOWN")
                snap = st.snapshot_metadata()
                assert snap["health"]["state"] == "SHARD_DOWN"
            finally:
                os.kill(pid, signal.SIGCONT)
            _poll(lambda: st.shard_transport_health()[0]["state"]
                  == CONNECTED and
                  st.shard_transport_health()[0]["epoch"] >= 2,
                  what="reconnect at a new epoch")
            assert st.get("a") == b"a" * 9_000
            assert st.put("b", b"b" * 9_000) == 1
        finally:
            st.close()

    def test_sigkill_then_restart_shard_replays(self, tmp_path):
        """Worker death proper: reconnect exhausts (nothing listens),
        restart_shard spawns a fresh worker that replays the journal —
        acked writes survive."""
        hb = HeartbeatConfig(interval_s=0.05, suspect_after_s=0.15,
                             dead_after_s=0.4, connect_timeout_s=1.0,
                             rpc_deadline_s=2.0,
                             reconnect_max_attempts=2,
                             reconnect_backoff_base_s=0.05,
                             reconnect_backoff_cap_s=0.1)
        st = _tcp_store(tmp_path, num_shards=2, hb=hb)
        try:
            rng = np.random.default_rng(1)
            data = {f"k{i}": rng.bytes(9_000) for i in range(6)}
            for k, v in data.items():
                assert st.put(k, v) == 1
            st.simulate_crash(shard=0)
            _poll(lambda: not st.shards[0].is_alive(),
                  what="proxy to observe the death")
            with pytest.raises(ShardWorkerDied) as ei:
                while True:      # racing reconnect-loop teardown
                    for k in data:
                        st.put(k + "-post", b"x" * 9_000)
            assert ei.value.shard_id is not None
            st.restart_shard(0)
            for k, v in data.items():
                assert st.get(k) == v
            assert st.put("fresh", b"f" * 9_000) == 1
        finally:
            st.close()

    def test_connect_deadline_bounds_silent_server(self):
        """Satellite 3: a listener that never completes the handshake
        cannot hang start() past connect_timeout_s."""
        lsock = socket.create_server(("127.0.0.1", 0))
        try:
            port = lsock.getsockname()[1]
            t = TcpTransport(
                shard_id=0, addr=("127.0.0.1", port),
                hb=HeartbeatConfig(connect_timeout_s=1.0,
                                   reconnect=False))
            t0 = time.monotonic()
            with pytest.raises(ShardWorkerDied) as ei:
                t.start(on_message=lambda m: None,
                        on_down=lambda e: None)
            assert time.monotonic() - t0 < 5.0
            assert ei.value.op == "connect"
            t.reap(deadline=time.monotonic() + 2.0)
        finally:
            lsock.close()

    def test_close_bounded_against_half_connected_shard(self, tmp_path):
        """close() against a store whose worker froze mid-session must
        respect deadline_s, not hang on the dead socket."""
        st = _tcp_store(tmp_path, num_shards=1)
        pid = st.shards[0].pid
        st.put("a", b"a" * 9_000)
        os.kill(pid, signal.SIGSTOP)
        try:
            t0 = time.monotonic()
            st.close(flush=False, deadline_s=8.0)
            assert time.monotonic() - t0 < 30.0
        finally:
            try:
                os.kill(pid, signal.SIGCONT)
            except ProcessLookupError:
                pass                 # reap already killed it


# ---------------------------------------------------------------------------
# epoch fencing
# ---------------------------------------------------------------------------

class TestEpochFencing:
    def test_stale_epoch_ack_suppressed(self, tmp_path):
        """An RPC issued at epoch 1, partitioned, reconnected at epoch
        2: the worker's late reply carries an epoch-1 rid and MUST be
        swallowed, not delivered."""
        # slow COS writes keep the flush barrier in flight long enough
        # to straddle the partition + reconnect
        st = ProcessShardedStore(
            _cfg(str(tmp_path / "spill")), num_shards=1, clock=Clock(),
            seed=0, transport="tcp", heartbeat=HOT,
            cos_latency={"put_delay_base_s": 0.8})
        try:
            proxy = st.shards[0]
            st.put("a", b"a" * 9_000)
            st.put("b", b"b" * 9_000)
            fut = proxy.flush_async(timeout=3.0)
            proxy._t._force_partition(0.9)
            with pytest.raises(ShardWorkerDied):
                fut.result(timeout=15.0)
            _poll(lambda: proxy.transport_health()["state"] == CONNECTED
                  and proxy.transport_health()["epoch"] >= 2,
                  what="post-partition reconnect")
            # the worker's flush (epoch-1 rid) times out at ~t+3s and
            # replies into epoch 2: it must be fenced, not delivered
            _poll(lambda: proxy.transport_stats()
                  ["stale_acks_suppressed"] >= 1, timeout=10.0,
                  what="stale-epoch ack suppression")
            assert proxy.flush_writeback(timeout=60.0) is True
            assert st.get("b") == b"b" * 9_000
        finally:
            st.close()

    def test_zombie_socket_cannot_reconnect_at_old_epoch(self, tmp_path):
        """A raw hello at a stale epoch is refused ("fenced") — a
        zombie's connection cannot take the shard over."""
        st = _tcp_store(tmp_path, num_shards=1)
        try:
            st.put("a", b"a" * 9_000)
            addr = st.shards[0].transport_health()["addr"]
            z = socket.create_connection(tuple(addr), timeout=5.0)
            try:
                z.settimeout(5.0)
                send_frame(z, (1, "hello", 0, None))  # epoch 1 = stale
                ctrl, _ = recv_frame(z)
                assert ctrl[1] == "fenced"
            finally:
                z.close()
            # the real connection is untouched
            assert st.get("a") == b"a" * 9_000
            xs = st.shards[0].transport_stats()
            assert xs["fenced_connects"] >= 1
            assert st.shards[0].transport_health()["epoch"] == 1
        finally:
            st.close()


# ---------------------------------------------------------------------------
# deterministic net.* fault injection
# ---------------------------------------------------------------------------

class TestNetFaults:
    def test_drop_fails_rpc_by_deadline_retry_succeeds(self, tmp_path):
        plan = FaultPlan(seed=5, points=[
            FaultPoint(site="net.drop", action="drop", hits=(1,),
                       match="op:put:")])
        hb = HOT
        st = _tcp_store(tmp_path, num_shards=1, hb=hb, faults=plan)
        try:
            with pytest.raises(ShardWorkerDied) as ei:
                st.put("d", b"d" * 9_000)
            assert ei.value.op == "put"
            # the frame never arrived, so the retry is version 1
            assert st.put("d", b"d" * 9_000) == 1
            assert st.get("d") == b"d" * 9_000
            assert ("net.drop", 1, "drop") in plan.log
        finally:
            st.close()

    def test_dup_deduped_by_worker_rid(self, tmp_path):
        plan = FaultPlan(seed=5, points=[
            FaultPoint(site="net.dup", action="dup", hits=(1, 2),
                       match="op:put:")])
        st = _tcp_store(tmp_path, num_shards=1, faults=plan)
        try:
            assert st.put("x", b"x" * 9_000) == 1   # dup'd frame
            assert st.put("y", b"y" * 9_000) == 1   # dup'd frame
            assert st.get("x") == b"x" * 9_000
            xs = st.shards[0].transport_stats()
            assert xs["dup_frames_dropped"] >= 2
        finally:
            st.close()

    def test_same_seed_same_schedule_byte_identical_log(self, tmp_path):
        """Two runs of one seeded net.* schedule produce byte-identical
        fault logs and identical per-op outcomes (satellite 4)."""
        def run(tag):
            plan = FaultPlan(seed=11, points=[
                FaultPoint(site="net.drop", action="drop", hits=(2, 5),
                           match="op:put:"),
                FaultPoint(site="net.delay", action="delay", every=3,
                           latency_s=0.01, match="op:put:"),
                FaultPoint(site="net.dup", action="dup", hits=(4,),
                           match="op:put:")])
            st = _tcp_store(tmp_path / tag, num_shards=1, faults=plan)
            outcomes = []
            try:
                rng = np.random.default_rng(7)
                payloads = [rng.bytes(8_000) for _ in range(8)]
                for i, v in enumerate(payloads):
                    try:
                        st.put(f"k{i}", v)
                        outcomes.append((i, "ok"))
                    except ShardWorkerDied:
                        outcomes.append((i, "died"))
                reads = {f"k{i}": st.get(f"k{i}")
                         for i, o in outcomes if o == "ok"}
                for i, o in outcomes:
                    if o == "ok":
                        assert reads[f"k{i}"] == payloads[i]
            finally:
                st.close()
            return outcomes, list(plan.log)

        out1, log1 = run("r1")
        out2, log2 = run("r2")
        assert out1 == out2
        assert log1 == log2
        assert repr(log1) == repr(log2)          # byte-identical
        assert any(s == "net.drop" for s, _, _ in log1)
        assert any(s == "net.dup" for s, _, _ in log1)

    def test_heartbeat_traffic_does_not_shift_op_schedule(self, tmp_path):
        """The drop targets put hit #3: with match-filtered points the
        interleaved ping stream consumes no hit indices, so exactly
        puts 1–2 succeed and put 3 drops — regardless of timing."""
        plan = FaultPlan(seed=3, points=[
            FaultPoint(site="net.drop", action="drop", hits=(3,),
                       match="op:put:")])
        st = _tcp_store(tmp_path, num_shards=1, faults=plan)
        try:
            assert st.put("p1", b"1" * 8_000) == 1
            time.sleep(0.3)          # let heartbeats interleave
            assert st.put("p2", b"2" * 8_000) == 1
            with pytest.raises(ShardWorkerDied):
                st.put("p3", b"3" * 8_000)
        finally:
            st.close()


# ---------------------------------------------------------------------------
# partition-tolerant 2PC (satellite 4 tentpole test)
# ---------------------------------------------------------------------------

class TestPartitionDuring2PC:
    def test_partition_after_decision_rolls_forward(self, tmp_path):
        """The leader journals decision/<ticket>, the partition eats
        shard 0's commit frame, and the reconnect sweep at epoch 2
        rolls the ticket forward: all keys committed, no PENDING keys,
        zero stale-epoch acks."""
        plan = FaultPlan(seed=21, points=[
            FaultPoint(site="net.partition", action="partition",
                       hits=(1,), match="op:commit2pc:s0")])
        hb = HeartbeatConfig(interval_s=0.05, suspect_after_s=0.15,
                             dead_after_s=0.4, connect_timeout_s=5.0,
                             rpc_deadline_s=1.0,
                             reconnect_max_attempts=40,
                             reconnect_backoff_base_s=0.05,
                             reconnect_backoff_cap_s=0.2,
                             partition_s=1.2)
        st = _tcp_store(tmp_path, num_shards=2, hb=hb, faults=plan)
        try:
            rng = np.random.default_rng(9)
            # span both shards so the batch runs the 2PC ticket path
            batch, per_shard = {}, {0: 0, 1: 0}
            i = 0
            while min(per_shard.values()) < 2:
                k = f"t{i}"
                sid = st.router.shard_of(k)
                if per_shard[sid] < 2:
                    batch[k] = rng.bytes(8_000)
                    per_shard[sid] += 1
                i += 1
            with pytest.raises(Exception):
                # commit frame to s0 is eaten + link blackholed: the
                # ticketed commit round reports the stranded shard
                st.put_many(batch, raise_on_conflict=True)
            assert ("net.partition", 1, "partition") in plan.log
            _poll(lambda:
                  st.shard_transport_health()[0]["state"] == CONNECTED
                  and st.shard_transport_health()[0]["epoch"] >= 2,
                  what="shard 0 reconnect after the partition")

            def settled():
                if st.indoubt_tickets():
                    st.resolve_indoubt()
                    return False
                got = st.get_many(list(batch))
                return all(got[k] == v for k, v in batch.items())
            _poll(settled, timeout=20.0,
                  what="ticket rolled forward on every shard")
            assert st.indoubt_tickets() == []
            # zero PENDING keys: every key reads at its batch value
            got = st.get_many(list(batch))
            assert all(got[k] == v for k, v in batch.items())
            # zero stale-epoch acks anywhere
            for proxy in st.shards:
                assert proxy.transport_stats()[
                    "stale_acks_suppressed"] == 0
        finally:
            st.close()
