"""Per-arch REDUCED-config smoke tests: one forward/train step on CPU,
asserting output shapes + no NaNs; plus decode consistency. The FULL
configs are exercised only via the dry-run (ShapeDtypeStruct)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config, reduced
from repro.models import build_model

REDUCED_LAYERS = {"recurrentgemma-2b": 3}   # needs a full (rec,rec,attn) unit


def tiny(name):
    cfg = reduced(get_config(name), layers=REDUCED_LAYERS.get(name, 2))
    if cfg.moe is not None:
        # drop-free capacity so decode == teacher forcing exactly
        # (capacity-drop behaviour is covered separately in test_moe.py)
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    return dataclasses.replace(cfg, dtype="float32")


def make_train_batch(cfg, B=2, S=16):
    rng = np.random.default_rng(0)
    if cfg.frontend.kind == "audio":
        C = cfg.frontend.num_codebooks
        return {"frame_embeds": jnp.asarray(
                    rng.standard_normal((B, S, cfg.d_model)), jnp.float32),
                "labels": jnp.asarray(
                    rng.integers(0, cfg.vocab_size, (B, S, C)), jnp.int32)}
    if cfg.frontend.kind == "vlm":
        Pn = cfg.frontend.num_prefix_embeds
        return {"tokens": jnp.asarray(
                    rng.integers(0, cfg.vocab_size, (B, S - Pn)), jnp.int32),
                "patch_embeds": jnp.asarray(rng.standard_normal(
                    (B, Pn, cfg.frontend.patch_embed_dim)), jnp.float32),
                "labels": jnp.asarray(
                    rng.integers(0, cfg.vocab_size, (B, S - Pn)), jnp.int32)}
    return {"tokens": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
            "labels": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_and_loss_no_nan(name):
    cfg = tiny(name)
    m = build_model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    batch = make_train_batch(cfg)
    loss, metrics = m.loss_fn(params, batch)
    assert jnp.isfinite(loss), name
    logits, _ = m.forward(params, batch)
    assert not jnp.any(jnp.isnan(logits)), name
    if cfg.frontend.kind == "audio":
        assert logits.shape[-1] >= cfg.vocab_size
        assert logits.shape[2] == cfg.frontend.num_codebooks
    else:
        assert logits.shape[-1] >= cfg.vocab_size   # padded vocab


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_one_train_step_updates_params(name):
    from repro.launch.steps import make_train_step
    from repro.optim import adamw
    cfg = tiny(name)
    m = build_model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    opt = adamw.adamw_init(params)
    batch = make_train_batch(cfg)
    batch = jax.tree.map(lambda x: x[None], batch)     # 1 microbatch
    step = make_train_step(m, adamw.AdamWConfig(lr=1e-3, warmup_steps=1))
    new_params, new_opt, metrics = step(params, opt, batch)
    assert jnp.isfinite(metrics["loss"]) and jnp.isfinite(
        metrics["grad_norm"]), name
    delta = max(float(jnp.max(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(new_params)))
    assert delta > 0, f"{name}: params unchanged"
    assert not any(bool(jnp.any(jnp.isnan(p)))
                   for p in jax.tree.leaves(new_params)), name


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_decode_matches_teacher_forcing(name):
    cfg = tiny(name)
    m = build_model(cfg, kv_layout="paged", page_size=4, wkv_impl="scan")
    params = m.init_params(jax.random.PRNGKey(0))
    B, S = 2, 12
    rng = np.random.default_rng(1)
    if cfg.frontend.kind == "audio":
        emb = jnp.asarray(rng.standard_normal((B, S + 1, cfg.d_model)),
                          jnp.float32)
        full, _ = m.forward(params, {"frame_embeds": emb,
                                     "labels": jnp.zeros(
                                         (B, S + 1, 4), jnp.int32)})
        _, cache = m.prefill(params, {"frame_embeds": emb[:, :S]},
                             max_len=16)
        lg, _ = m.decode_step(params, {"frame_embed": emb[:, S:S + 1]},
                              cache)
    elif cfg.frontend.kind == "vlm":
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + 1)),
                           jnp.int32)
        pe = jnp.asarray(rng.standard_normal(
            (B, cfg.frontend.num_prefix_embeds,
             cfg.frontend.patch_embed_dim)), jnp.float32)
        full, _ = m.forward(params, {"tokens": toks, "patch_embeds": pe,
                                     "labels": jnp.zeros_like(toks)})
        _, cache = m.prefill(params, {"tokens": toks[:, :S],
                                      "patch_embeds": pe}, max_len=32)
        lg, _ = m.decode_step(params, {"token": toks[:, S:S + 1]}, cache)
    else:
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + 1)),
                           jnp.int32)
        full, _ = m.forward(params, {"tokens": toks,
                                     "labels": jnp.zeros_like(toks)})
        _, cache = m.prefill(params, {"tokens": toks[:, :S]}, max_len=16)
        lg, _ = m.decode_step(params, {"token": toks[:, S:S + 1]}, cache)
    err = float(jnp.max(jnp.abs(lg[:, 0] - full[:, -1])))
    assert err < 5e-4, f"{name}: decode mismatch {err}"
