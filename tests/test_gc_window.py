"""Sliding-window GC-bucket lifecycle (paper §5.3, Fig. 4)."""
import numpy as np

from repro.core.clock import Clock
from repro.core.gc_window import BucketState, GCConfig, SlidingWindow


def make_window(M=2, N=3, interval=10.0):
    clock = Clock()
    cfg = GCConfig(gc_interval=interval, active_intervals=M,
                   degraded_intervals=N)
    return SlidingWindow(cfg, clock), clock


def test_horizon():
    w, _ = make_window(M=6, N=12, interval=600.0)
    assert w.cfg.horizon == 18 * 600.0   # paper IBM config: H = 3 hours


def test_bucket_aging_active_degraded_released():
    w, clock = make_window(M=2, N=3, interval=10.0)
    b0 = w.latest
    # after M intervals the bucket becomes degraded
    for _ in range(2):
        clock.advance(10.0)
        w.run_gc()
    assert b0.state == BucketState.DEGRADED
    # after M+N intervals it is released
    for _ in range(3):
        clock.advance(10.0)
        w.run_gc()
    assert b0.state == BucketState.RELEASED


def test_released_functions_reported():
    w, clock = make_window(M=1, N=1, interval=10.0)
    w.latest.add_function(7, 0)
    released = set()
    for _ in range(3):
        clock.advance(10.0)
        ev = w.run_gc()
        released |= ev.released_functions
    assert 7 in released


def test_new_bucket_every_gc():
    w, clock = make_window()
    seen = {w.latest.index}
    for _ in range(5):
        clock.advance(10.0)
        w.run_gc()
        assert w.latest.index not in seen
        seen.add(w.latest.index)


def test_mark_and_compaction_round():
    w, _ = make_window()
    rng = np.random.default_rng(0)
    for i in range(10):
        w.mark(f"c{i}")
    picked = w.take_compaction_round(rng)
    assert len(picked) == 5                    # 50% per round
    assert set(picked) <= {f"c{i}" for i in range(10)}
    rest = w.take_compaction_round(rng)
    assert set(rest).isdisjoint(picked)


def test_warmup_period_by_state():
    w, clock = make_window(M=1, N=1, interval=10.0)
    w.latest.add_function(1, 0)
    assert w.warmup_period(1) == w.cfg.active_warmup
    clock.advance(10.0)
    w.run_gc()
    assert w.warmup_period(1) == w.cfg.degraded_warmup
    clock.advance(10.0)
    w.run_gc()
    assert w.warmup_period(1) is None          # released


def test_run_gc_never_duplicates_buckets():
    """Regression: run_gc used to end with a guarded re-append of the
    new bucket that would have duplicated it had it ever fired."""
    w, clock = make_window(M=1, N=1, interval=10.0)
    for step in range(8):
        w.latest.add_function(step, step)
        clock.advance(10.0)
        w.run_gc()
        ids = [id(b) for b in w._buckets]
        assert len(ids) == len(set(ids))           # no duplicate objects
        indexes = [b.index for b in w._buckets]
        assert len(indexes) == len(set(indexes))   # no duplicate indexes


def test_state_of_function_latest_wins():
    w, clock = make_window()
    w.latest.add_function(3, 0)
    clock.advance(10.0)
    ev = w.run_gc()
    # function carried over into the new bucket => state ACTIVE again
    ev.new_bucket.add_function(3, 0)
    assert w.state_of_function(3) == BucketState.ACTIVE
