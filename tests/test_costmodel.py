"""Pay-per-access cost ledger (paper §6.1.1 accounting)."""
from repro.core.costmodel import (CostLedger, LAMBDA_GBS, LAMBDA_INVOKE,
                                  S3_GET, S3_PUT, elasticache_cost)


def test_invocation_billing():
    led = CostLedger()
    led.invoke("request", gb=1.5, seconds=2.0)
    d = led.dollars()
    assert abs(d["request"] - (1.5 * 2.0 * LAMBDA_GBS + LAMBDA_INVOKE)) < 1e-12


def test_categories_are_separate():
    led = CostLedger()
    led.invoke("request", gb=1.5, seconds=1.0)
    led.invoke("warmup", gb=1.5, seconds=0.001)
    led.invoke("recovery", gb=3.0, seconds=5.0)
    d = led.dollars()
    assert d["recovery"] > d["request"] > d["warmup"] > 0


def test_pay_per_access_overhead_metric():
    led = CostLedger()
    led.invoke("request", gb=1.5, seconds=10.0)
    led.cos_op("put", 100)
    led.invoke("warmup", gb=1.5, seconds=1.0)
    led.invoke("recovery", gb=1.5, seconds=1.5)
    d = led.dollars()
    want = (d["recovery"] + d["warmup"]) / (d["request"] + d["cos"])
    assert abs(led.pay_per_access_overhead() - want) < 1e-12


def test_cos_costs():
    led = CostLedger()
    led.cos_op("put", 1000)
    led.cos_op("get", 1000)
    d = led.dollars()
    assert abs(d["cos"] - (1000 * S3_PUT + 1000 * S3_GET)) < 1e-12


def test_static_baseline():
    # ElastiCache storage-cluster cost (paper: 36.30x InfiniStore)
    assert elasticache_cost(0.821, 12, 50) == 0.821 * 12 * 50
