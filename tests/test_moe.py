"""MoE: group-local capacity dispatch vs dense oracle + conservation."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.configs import get_config, reduced
from repro.models import moe as M
from repro.models.transformer import _split_layers, init_params


def tiny_moe(cap_factor=8.0, name="granite-moe-1b-a400m"):
    cfg = dataclasses.replace(reduced(get_config(name)), dtype="float32")
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=cap_factor))


def layer_params(cfg, seed=0):
    p = init_params(cfg, jax.random.PRNGKey(seed))
    _, lyr = _split_layers(p)
    return {k: v[0] for k, v in lyr.items()}


def test_matches_dense_oracle_no_drops():
    cfg = tiny_moe(8.0)
    lp = layer_params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 10, cfg.d_model))
    y1, a1 = M.moe_ffn(cfg, lp, x)
    y2, a2 = M.moe_ffn_dense(cfg, lp, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-4, rtol=1e-4)
    assert abs(float(a1 - a2)) < 1e-6


def test_shared_expert_arch_matches_oracle():
    cfg = tiny_moe(8.0, "qwen2-moe-a2.7b")
    lp = layer_params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 8, cfg.d_model))
    y1, _ = M.moe_ffn(cfg, lp, x)
    y2, _ = M.moe_ffn_dense(cfg, lp, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-4, rtol=1e-4)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 50), S=st.integers(4, 24))
def test_dispatch_conservation(seed, S):
    """Every (token, expert) pair is either placed in exactly one slot with
    its gate weight, or dropped by capacity — never duplicated."""
    cfg = tiny_moe(1.0)
    m = cfg.moe
    rng = jax.random.PRNGKey(seed)
    probs = jax.nn.softmax(jax.random.normal(rng, (S, m.num_experts)))
    gate_vals, ids = jax.lax.top_k(probs, m.top_k)
    cap = M.capacity(cfg, S)
    disp, gate_slot = M.dispatch_indices(ids, gate_vals, m.num_experts, cap)
    disp = np.asarray(disp)
    gate_slot = np.asarray(gate_slot)
    placed = disp[disp < S]
    # each placed (slot) corresponds to a unique (token, expert) pair
    pairs = set()
    for slot, tok in enumerate(disp):
        if tok >= S:
            continue
        e = slot // cap
        assert (tok, e) not in pairs, "duplicate dispatch"
        pairs.add((tok, e))
        assert gate_slot[slot] > 0
    # capacity respected
    for e in range(m.num_experts):
        assert (disp[e * cap:(e + 1) * cap] < S).sum() <= cap


def test_capacity_drops_are_graceful():
    """With capacity factor << 1, output degrades but never NaNs."""
    cfg = tiny_moe(0.1)
    lp = layer_params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 32, cfg.d_model))
    y, aux = M.moe_ffn(cfg, lp, x)
    assert not bool(jnp.any(jnp.isnan(y)))
    assert jnp.isfinite(aux)
