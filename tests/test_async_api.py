"""Futures-based client API: put_async/get_async semantics, grouped GET
invokes (at most one invoke per function per gather), multi-key CAS
batching, and zero-copy device/array payloads end-to-end."""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Clock, ConcurrentPutError, InfiniStore, StoreConfig,
                        StoreFuture)
from repro.core.ec import ECConfig
from repro.core.gc_window import GCConfig

MB = 1024 * 1024


def make_store(k=4, p=2, fragment_bytes=1 * MB, capacity=64 * MB):
    cfg = StoreConfig(ec=ECConfig(k=k, p=p),
                      function_capacity=capacity,
                      fragment_bytes=fragment_bytes,
                      gc=GCConfig(gc_interval=1e9),
                      num_recovery_functions=4)
    return InfiniStore(cfg, clock=Clock())


# ---------------------------------------------------------------------------
# futures semantics
# ---------------------------------------------------------------------------

def test_put_async_future_resolves_to_version():
    st = make_store()
    fut = st.put_async("k", b"hello" * 1000)
    assert isinstance(fut, StoreFuture)
    assert fut.result(timeout=10.0) == 1
    assert fut.version == 1
    assert fut.done() and fut.exception() is None
    fut2 = st.put_async("k", b"world" * 1000)
    assert fut2.result(timeout=10.0) == 2


def test_get_async_future_resolves_to_payload():
    st = make_store()
    data = np.random.default_rng(0).bytes(50_000)
    st.put_async("k", data)                       # pipelined: no result()
    got = st.get_async("k").result(timeout=10.0)
    assert got == data                            # ordered behind the PUT
    assert st.get_async("missing").result(timeout=10.0) is None


def test_done_callback_fires():
    st = make_store()
    seen = []
    ev = threading.Event()

    def cb(f):
        seen.append(f.result())
        ev.set()

    st.put_async("k", b"x" * 100).add_done_callback(cb)
    assert ev.wait(timeout=10.0)
    assert seen == [1]


def test_pipelined_puts_then_batched_get():
    st = make_store()
    rng = np.random.default_rng(1)
    objs = {f"k{i}": rng.bytes(20_000) for i in range(10)}
    futs = [st.put_async(k, v) for k, v in objs.items()]
    assert [f.result(timeout=10.0) for f in futs] == [1] * 10
    out = st.get_many_async(list(objs)).result(timeout=10.0)
    assert out == objs


def test_put_async_conflict_raises_via_future():
    st = make_store()
    st.put("x", b"base")
    # simulate an in-flight PUT by inserting a PENDING head
    c = st.mt.prepare("x", 1)
    c.revise(2)
    st.mt.cas("x", c)
    t = threading.Timer(0.05, lambda: c.done(True))
    t.start()
    fut = st.put_async("x", b"conflict")
    with pytest.raises(ConcurrentPutError):
        fut.result(timeout=10.0)
    t.join()


def test_sync_wrappers_match_async():
    st = make_store()
    data = b"z" * 30_000
    assert st.put("a", data) == st.put_async("b", data).result()
    assert st.get("a") == st.get_async("b").result() == data


# ---------------------------------------------------------------------------
# grouped GET: at most one invoke per function per gather
# ---------------------------------------------------------------------------

def test_get_invokes_at_most_once_per_function():
    # 4 fragments x (k=2 reads each) land on 3 functions: a per-chunk
    # GET would invoke 8 times; the grouped gather may invoke each
    # function at most once
    st = make_store(k=2, p=1, fragment_bytes=64 * 1024)
    data = np.random.default_rng(2).bytes(256 * 1024)     # 4 fragments
    st.put("big", data)
    nfuncs = len(st.sms.slabs)
    assert nfuncs == 3                            # one FG, chunks stacked
    before = {fid: s.stats.invocations for fid, s in st.sms.slabs.items()}
    g0 = st.stats.gather_invokes
    assert st.get("big") == data
    per_slab = {fid: s.stats.invocations - before[fid]
                for fid, s in st.sms.slabs.items()}
    assert all(d <= 1 for d in per_slab.values()), per_slab
    assert st.stats.gather_invokes - g0 <= nfuncs


def test_get_many_groups_across_keys():
    st = make_store(k=2, p=1, fragment_bytes=64 * 1024)
    rng = np.random.default_rng(3)
    objs = {f"o{i}": rng.bytes(100_000) for i in range(5)}
    for k, v in objs.items():
        st.put(k, v)
    nfuncs = len(st.sms.slabs)
    g0 = st.stats.gather_invokes
    assert st.get_many(list(objs)) == objs
    # 5 objects x 2 fragments x 2 chunks = 20 reads, but at most one
    # invoke per function for the whole batched gather
    assert st.stats.gather_invokes - g0 <= nfuncs


# ---------------------------------------------------------------------------
# multi-key CAS batching
# ---------------------------------------------------------------------------

def test_put_many_single_cas_round():
    st = make_store()
    rng = np.random.default_rng(4)
    items = [(f"k{i}", rng.bytes(10_000)) for i in range(8)]
    r0 = st.stats.cas_rounds
    out = st.put_many(items)
    assert all(v == 1 for v in out.values())
    assert st.stats.cas_rounds - r0 == 1          # ONE metadata round
    # updates still batch: all 8 keys revise to ver 2 in one extra round
    r1 = st.stats.cas_rounds
    out = st.put_many(items)
    assert all(v == 2 for v in out.values())
    assert st.stats.cas_rounds - r1 <= 2


def test_cas_many_independent_failures():
    st = make_store()
    st.put("a", b"1")
    c = st.mt.prepare("b", 1)
    st.mt.cas("b", c)                             # leave b PENDING
    threading.Timer(0.05, lambda: c.done(True)).start()
    out = st.put_many([("a", b"2"), ("b", b"x"), ("c", b"3")])
    assert out["a"] == 2 and out["c"] == 1
    assert out["b"] == -1                         # only b failed


# ---------------------------------------------------------------------------
# zero-copy device/array payloads
# ---------------------------------------------------------------------------

def test_numpy_payload_roundtrip():
    st = make_store()
    arr = np.arange(40_000, dtype=np.float32)
    a0 = st.stats.array_payload_puts
    assert st.put("w", arr) == 1
    assert st.stats.array_payload_puts - a0 == 1
    got = st.get_array("w")
    assert isinstance(got, np.ndarray) and got.dtype == np.uint8
    np.testing.assert_array_equal(got.view(np.float32), arr)
    # bytes view of the same object matches too
    assert st.get("w") == arr.tobytes()


def test_jax_array_payload_roundtrip():
    st = make_store()
    arr = jnp.asarray(np.random.default_rng(5).standard_normal(
        (64, 64)).astype(np.float32))
    assert st.put("dev", arr) == 1
    assert st.stats.array_payload_puts >= 1
    got = st.get_array("dev")
    np.testing.assert_array_equal(
        got.view(np.float32).reshape(64, 64), np.asarray(arr))


def test_bfloat16_device_payload_roundtrip():
    st = make_store()
    arr = jnp.arange(4096, dtype=jnp.bfloat16)
    st.put("bf16", arr)
    got = st.get_array("bf16")
    np.testing.assert_array_equal(
        np.asarray(got.view(jnp.bfloat16)), np.asarray(arr))


def test_multifragment_array_get():
    st = make_store(fragment_bytes=64 * 1024)
    arr = np.random.default_rng(6).integers(
        0, 255, size=300_000, dtype=np.uint8)     # 5 fragments
    st.put("frag", arr)
    np.testing.assert_array_equal(st.get_array("frag"), arr)


def test_checkpoint_device_payloads_use_array_path():
    """Checkpoint save/restore moves jax.Array leaves end-to-end through
    the array payload path (no intermediate bytes serialization)."""
    from repro.checkpoint import Checkpointer
    st = make_store(capacity=32 * MB, fragment_bytes=4 * MB)
    ck = Checkpointer(st)
    params = {"w": jnp.asarray(np.random.default_rng(7).standard_normal(
        (128, 32)).astype(np.float32)),
        "b16": jnp.arange(2048, dtype=jnp.bfloat16)}
    a0 = st.stats.array_payload_puts
    ck.save(3, params)
    assert st.stats.array_payload_puts - a0 >= len(params)
    out = ck.restore(3, like=params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_mutable_array_payload_snapshotted_at_ack():
    """Mutating a numpy payload after the PUT acks must not corrupt
    read-after-write GETs (the persistent buffer owns a snapshot)."""
    st = make_store()
    st.writeback.pause()                          # hold the pb entry live
    arr = np.full(30_000, 7, dtype=np.uint8)
    st.put("mut", arr)
    arr[:] = 0                                    # caller mutates post-ack
    got = st.get_array("mut")
    np.testing.assert_array_equal(got, np.full(30_000, 7, dtype=np.uint8))
    st.writeback.resume()


def test_put_async_snapshots_at_submission():
    """The payload is captured when put_async RETURNS — mutating the
    buffer before the future resolves must not corrupt the write."""
    st = make_store()
    arr = np.full(50_000, 9, dtype=np.uint8)
    futs = [st.put_async(f"p{i}", b"x" * 10_000) for i in range(4)]
    fut = st.put_async("mut", arr)                # queued behind the others
    arr[:] = 0                                    # immediate buffer reuse
    assert fut.result(timeout=10.0) == 1
    [f.result(timeout=10.0) for f in futs]
    np.testing.assert_array_equal(
        st.get_array("mut"), np.full(50_000, 9, dtype=np.uint8))


def test_get_array_results_are_read_only():
    st = make_store()
    st.put("ro", np.arange(20_000, dtype=np.uint8))
    got = st.get_array("ro")
    assert not got.flags.writeable
    with pytest.raises(ValueError):
        got[0] = 1


def test_durable_after_flush_with_array_payloads():
    st = make_store()
    arr = np.arange(25_000, dtype=np.int32)
    st.put("arr", arr)
    assert st.flush_writeback(timeout=10.0)
    for fid in list(st.sms.slabs):
        st.inject_failure(fid)
    np.testing.assert_array_equal(
        st.get_array("arr").view(np.int32), arr)
