"""Paged decode-attention kernel vs jnp oracle — shape/dtype sweep."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.paged_attention.kernel import paged_decode_attention_pallas
from repro.kernels.paged_attention.ref import paged_decode_attention_ref


def _case(seed, B, P, ps, K, G, hd, dtype):
    H = K * G
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    kp = jax.random.normal(ks[1], (B, P, ps, K, hd), dtype)
    vp = jax.random.normal(ks[2], (B, P, ps, K, hd), dtype)
    tbl = jnp.stack([jax.random.permutation(jax.random.fold_in(ks[3], b), P)
                     for b in range(B)]).astype(jnp.int32)
    lens = (jax.random.randint(jax.random.fold_in(ks[3], 99),
                               (B,), 1, P * ps + 1)).astype(jnp.int32)
    return q, kp, vp, tbl, lens


@pytest.mark.parametrize("B,P,ps,K,G,hd", [
    (1, 2, 4, 1, 1, 8),
    (2, 4, 8, 2, 2, 16),
    (3, 5, 8, 2, 3, 16),
    (2, 8, 16, 4, 1, 32),
])
def test_matches_ref_f32(B, P, ps, K, G, hd):
    args = _case(B * 100 + P, B, P, ps, K, G, hd, jnp.float32)
    want = np.asarray(paged_decode_attention_ref(*args))
    got = np.asarray(paged_decode_attention_pallas(*args, interpret=True))
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_matches_ref_bf16():
    args = _case(7, 2, 4, 8, 2, 2, 16, jnp.bfloat16)
    want = np.asarray(paged_decode_attention_ref(*args), dtype=np.float32)
    got = np.asarray(paged_decode_attention_pallas(*args, interpret=True),
                     dtype=np.float32)
    np.testing.assert_allclose(got, want, atol=3e-2, rtol=3e-2)


def test_permutation_invariance():
    """Physical page placement must not affect the result — the SMS
    compaction guarantee."""
    q, kp, vp, tbl, lens = _case(11, 2, 6, 4, 2, 2, 16, jnp.float32)
    out1 = paged_decode_attention_pallas(q, kp, vp, tbl, lens,
                                         interpret=True)
    # apply a permutation to physical pages + table
    perm = jax.random.permutation(jax.random.PRNGKey(5), 6)
    inv = jnp.argsort(perm)
    kp2 = kp[:, perm]
    vp2 = vp[:, perm]
    tbl2 = inv[tbl]
    out2 = paged_decode_attention_pallas(q, kp2, vp2, tbl2, lens,
                                         interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               atol=1e-5, rtol=1e-5)
