"""The HLO analyzer: scan multipliers, collective parsing, trip counts."""
import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.analysis.hlo import analyze_hlo, parse_hlo


def test_scan_flops_multiplied_by_trip_count():
    def scanned(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        return lax.scan(body, x, None, length=10)[0]

    x = jnp.ones((128, 128))
    w = jnp.ones((128, 128))
    txt = jax.jit(scanned).lower(x, w).compile().as_text()
    a = analyze_hlo(txt)
    assert a.while_trips == [10]
    np.testing.assert_allclose(a.flops, 10 * 2 * 128**3, rtol=0.01)


def test_trip_count_ignores_clamp_constants():
    """Index-clamping constants (e.g. 32767) inside the loop body must not
    inflate the trip count — only the compare bound counts."""
    def f(x, big):
        def body(c, i):
            j = jnp.clip(i * 3, 0, 32767)       # clamp constant in body
            return c + lax.dynamic_index_in_dim(big, j % 8, 0, False), None
        out, _ = lax.scan(body, x, jnp.arange(5))
        return out

    x = jnp.ones((16,))
    big = jnp.ones((8, 16))
    txt = jax.jit(f).lower(x, big).compile().as_text()
    a = analyze_hlo(txt)
    assert a.while_trips == [5], a.while_trips


def test_nested_scan_multiplies():
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = lax.scan(inner, c, None, length=3)
            return ci, None
        return lax.scan(outer, x, None, length=4)[0]

    x = jnp.ones((64, 64))
    w = jnp.ones((64, 64))
    txt = jax.jit(f).lower(x, w).compile().as_text()
    a = analyze_hlo(txt)
    np.testing.assert_allclose(a.flops, 12 * 2 * 64**3, rtol=0.01)


def test_parse_finds_entry_and_instructions():
    def f(x):
        return jnp.tanh(x).sum()
    txt = jax.jit(f).lower(jnp.ones((8, 8))).compile().as_text()
    comps, entry = parse_hlo(txt)
    assert entry is not None and entry in comps
    assert len(comps[entry].instrs) > 0


def test_collective_ring_bytes_model():
    from repro.analysis.hlo import CollectiveStat, Instr, _collective_stat
    line = ("%all-gather.1 = bf16[16,1024]{1,0} all-gather(%x), "
            "replica_groups={{0,1,2,3}}, dimensions={0}")
    instr = Instr(name="all-gather.1", opcode="all-gather",
                  shapes=[("bf16", (16, 1024))], operands=["x"],
                  attrs="", line=line)
    st = _collective_stat(instr, 2.0, pod_stride=256)
    assert st.group_size == 4 and st.count == 2.0
    assert st.result_bytes == 2 * 16 * 1024 * 2
    np.testing.assert_allclose(st.ring_bytes,
                               2 * (16 * 1024 * 2) * 3 / 4)
    assert not st.dcn
