"""Insertion logs: terms, hash chain, snapshots, manifests (§5.5.1)."""
from repro.core.clock import Clock
from repro.core.cos import COS
from repro.core.insertion_log import InsertionLog, PutRecord


def make_log(snapshot_every=3):
    cos = COS(Clock())
    return InsertionLog(1, cos, snapshot_every=snapshot_every), cos


def test_terms_monotonic_and_hash_chained():
    log, _ = make_log()
    n1 = log.append([PutRecord("a", 10, 1)])
    n2 = log.append([PutRecord("b", 20, 1)])
    assert (n1.term, n2.term) == (1, 2)
    assert n2.prev_hash == n1.hash
    assert log.last_hash == n2.hash


def test_diff_rank_counts_all_records_including_deletes():
    log, _ = make_log()
    log.append([PutRecord("a", 10, 1), PutRecord("b", 10, 1)])
    log.append([PutRecord("a", 0, 1, delete=True)])
    assert log.diff_rank == 3
    assert log.live_keys() == {"b"}


def test_manifest_replays_snapshot_plus_tail():
    log, cos = make_log(snapshot_every=2)
    log.append([PutRecord("a", 1, 1)])
    log.append([PutRecord("b", 1, 1)])          # snapshot at term 2
    assert log.snapshot_term == 2
    log.append([PutRecord("c", 1, 1)])
    log.append([PutRecord("a", 0, 1, delete=True)])  # snapshot at term 4
    log.append([PutRecord("d", 1, 1)])
    assert log.manifest() == ["b", "c", "d"]


def test_manifest_readable_by_fresh_instance():
    """A recovering instance reconstructs the manifest purely from COS."""
    log, cos = make_log(snapshot_every=100)     # no snapshot
    log.append([PutRecord("x", 1, 1)])
    log.append([PutRecord("y", 1, 1)])
    fresh = InsertionLog(1, cos)
    assert fresh.manifest() == ["x", "y"]


def test_piggyback_fields():
    log, _ = make_log()
    log.append([PutRecord("a", 5, 1)])
    pb = log.piggyback()
    assert pb.term == 1 and pb.diff_rank == 1
    assert pb.hash == log.last_hash
    assert pb.last_node_size > 0
