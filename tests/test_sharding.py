"""Sharding rules: evenness fallback, per-arch adjustments, spec trees."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed.sharding import make_rules, spec_for, tree_shardings
from repro.launch.mesh import make_test_mesh


class FakeMesh:
    """Shape-only stand-in (avoids needing 256 devices in unit tests)."""
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
POD_MESH = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_rules_dense_gqa_uneven_kv():
    cfg = get_config("qwen3-14b")       # kv=8 -> not divisible by 16
    rules = make_rules(cfg, MESH)
    assert rules["kv_heads"] is None
    assert rules["head_dim"] == "model"


def test_rules_mha_even_kv():
    cfg = get_config("qwen1.5-0.5b")    # kv=16
    rules = make_rules(cfg, MESH)
    assert rules["kv_heads"] == "model"
    assert rules["head_dim"] is None


def test_rules_moe_modes():
    granite = make_rules(get_config("granite-moe-1b-a400m"), MESH)
    assert granite["experts"] == "model"      # 32 % 16 == 0
    qwen = make_rules(get_config("qwen2-moe-a2.7b"), MESH)
    assert qwen["experts"] is None and qwen["expert_ff"] == "model"


def test_multi_pod_batch_axes():
    cfg = get_config("qwen1.5-0.5b")
    rules = make_rules(cfg, POD_MESH)
    assert rules["batch"] == ("pod", "data")


def test_spec_evenness_fallback():
    cfg = get_config("qwen3-14b")
    rules = make_rules(cfg, MESH)
    # 40 heads over 16-way model axis: dropped for ARGUMENT shardings
    spec = spec_for(("layers", "embed", "heads", None), rules,
                    shape=(40, 5120, 40, 128), mesh=MESH)
    assert spec == P(None, "data", None, None)
    # but kept when no shape given (activation constraints may stay uneven)
    spec2 = spec_for(("layers", "embed", "heads", None), rules)
    assert spec2 == P(None, "data", "model", None)


def test_tree_shardings_structure_match():
    cfg = get_config("qwen1.5-0.5b")
    from repro.models.transformer import abstract_params, logical_axes
    mesh = make_test_mesh(1, 1)
    rules = make_rules(cfg, mesh)
    ap = abstract_params(cfg)
    sh = tree_shardings(logical_axes(cfg), mesh, rules, ap)
    assert set(sh.keys()) == set(ap.keys())


def test_vocab_padding_is_lane_aligned():
    from repro.configs.base import padded_vocab
    assert padded_vocab(151655) % 128 == 0
    assert padded_vocab(151936) == 151936        # already aligned
    assert padded_vocab(49155) % 16 == 0
