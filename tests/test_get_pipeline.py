"""GET pipeline: overlapped gather+decode, parallel COS fallback,
sequential-scan prefetch, and the read-path maintenance guards.

Covers the pipelined data path (`StoreConfig(pipelined_get=True)`, the
default) against the legacy serial path, the bounded I/O fan-out for
demand reads under the S3-like latency model, scan detection +
cancellation, degraded reads with prefetch warming, and the no-scale-out
guarantees of `_demand_cache` / `_migrate_chunks`.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import Clock, InfiniStore, StoreConfig
from repro.core.ec import ECConfig
from repro.core.gc_window import BucketState, GCConfig
from repro.core.prefetch import (PrefetchConfig, SequentialPrefetcher,
                                 split_key)

MB = 1024 * 1024


def make_store(**kw):
    kw.setdefault("ec", ECConfig(k=4, p=2))
    kw.setdefault("function_capacity", 8 * MB)
    kw.setdefault("fragment_bytes", 1 * MB)
    kw.setdefault("gc", GCConfig(gc_interval=10.0, active_intervals=2,
                                 degraded_intervals=6))
    kw.setdefault("num_recovery_functions", 3)
    clock = Clock()
    return InfiniStore(StoreConfig(**kw), clock=clock), clock


def fail_all_slabs(st):
    for fid in list(st.sms.slabs):
        st.inject_failure(fid)


# ---------------------------------------------------------------------------
# sequential-scan detection (policy unit tests)
# ---------------------------------------------------------------------------

def test_split_key_trailing_index():
    assert split_key("ckpt/8/w/s12") == ("ckpt/8/w/s", 12, 0)
    assert split_key("kv/seq0/p4") == ("kv/seq0/p", 4, 0)
    assert split_key("shard/s007") == ("shard/s", 7, 3)
    assert split_key("no-index/") is None


def test_detector_predicts_after_min_run():
    pf = SequentialPrefetcher(PrefetchConfig(min_run=3, depth=2))
    assert pf.observe(["a/s0"]) == []
    assert pf.observe(["a/s1"]) == []
    assert pf.observe(["a/s2"]) == [("a/s3", "a/s"), ("a/s4", "a/s")]
    assert pf.stats.runs_detected == 1
    # zero-padded indices keep their padding in predictions
    pf2 = SequentialPrefetcher(PrefetchConfig(min_run=2, depth=1))
    pf2.observe(["m/s08"])
    assert pf2.observe(["m/s09"]) == [("m/s10", "m/s")]


def test_detector_batch_observe_predicts_ahead():
    pf = SequentialPrefetcher(PrefetchConfig(min_run=3, depth=2))
    preds = pf.observe([f"x/p{i}" for i in range(6)])
    # one ordered batch: predictions dedup and extend past the batch head
    assert ("x/p6", "x/p") in preds and ("x/p7", "x/p") in preds


def test_detector_cancels_on_random_access_and_counts_waste():
    pf = SequentialPrefetcher(PrefetchConfig(min_run=3, depth=2))
    pf.observe(["a/s0", "a/s1", "a/s2"])
    pf.record_issued("a/s3|1/f0#0", "a/s")
    pf.record_issued("a/s3|1/f0#1", "a/s")
    # random access breaks the run: outstanding warms become waste
    pf.observe(["a/s0"])
    assert pf.stats.runs_cancelled == 1
    assert pf.stats.wasted == 2
    assert pf.outstanding == 0
    # consumed warms are hits, not waste
    pf.observe(["b/s0", "b/s1", "b/s2"])
    pf.record_issued("b/s3|1/f0#0", "b/s")
    assert pf.consume("b/s3|1/f0#0") is True
    assert pf.consume("b/s3|1/f0#0") is False      # once only
    assert pf.stats.hits == 1


# ---------------------------------------------------------------------------
# overlapped gather + decode: ordering/correctness vs the serial path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pipelined", [True, False])
def test_roundtrip_matches_serial(pipelined):
    # recovery off so reclaimed slabs exercise the COS fallback itself
    st, _ = make_store(pipelined_get=pipelined, enable_recovery=False)
    rng = np.random.default_rng(0)
    objs = {"tiny": rng.bytes(1000),
            "one": rng.bytes(300_000),
            "multi": rng.bytes(int(2.5 * MB))}      # 3 fragments
    for k, v in objs.items():
        st.put(k, v)
    got = st.get_many(list(objs) + ["missing"])
    for k, v in objs.items():
        assert got[k] == v
    assert got["missing"] is None
    # degraded: reclaim everything, reads fall back to COS
    st.flush_writeback()
    fail_all_slabs(st)
    got = st.get_many(list(objs))
    for k, v in objs.items():
        assert got[k] == v
    assert st.stats.cos_fallback_reads > 0
    if pipelined:
        assert st.stats.decode_batches > 0
    st.close()


def test_ready_order_decode_batches_and_array_path():
    st, _ = make_store(decode_batch_fragments=2)
    rng = np.random.default_rng(1)
    objs = {f"k{i}": rng.bytes(120_000) for i in range(7)}
    st.put_many(objs)
    st.flush_writeback()
    before = st.stats.decode_batches
    got = st.get_many_arrays(list(objs))
    for k, v in objs.items():
        assert bytes(got[k]) == v
        assert not got[k].flags.writeable
    # 7 fragments, batch size 2 -> at least 4 ready-order decode calls
    assert st.stats.decode_batches - before >= 4
    st.close()


# ---------------------------------------------------------------------------
# parallel COS fallback
# ---------------------------------------------------------------------------

class ConcurrencyProbe:
    """Wraps cos.get, tracking the max number of concurrent readers."""

    def __init__(self, cos, sleep_s=0.01):
        self._orig = cos.get
        self._sleep = sleep_s
        self._lock = threading.Lock()
        self.cur = 0
        self.max = 0
        cos.get = self

    def __call__(self, key):
        with self._lock:
            self.cur += 1
            self.max = max(self.max, self.cur)
        try:
            time.sleep(self._sleep)
            return self._orig(key)
        finally:
            with self._lock:
                self.cur -= 1


def test_cos_fallback_fans_out_concurrently():
    st, _ = make_store(enable_recovery=False, get_io_workers=6)
    rng = np.random.default_rng(2)
    objs = {f"o{i}": rng.bytes(200_000) for i in range(3)}
    st.put_many(objs)
    st.flush_writeback()
    fail_all_slabs(st)
    probe = ConcurrencyProbe(st.cos)
    got = st.get_many(list(objs))
    for k, v in objs.items():
        assert got[k] == v
    assert probe.max > 1, "demand reads did not overlap"
    assert st.stats.cos_fallback_reads >= st.cfg.ec.k * len(objs)
    st.close()


def test_serial_fallback_stays_serial():
    st, _ = make_store(pipelined_get=False, enable_recovery=False)
    rng = np.random.default_rng(3)
    st.put("o", rng.bytes(200_000))
    st.flush_writeback()
    fail_all_slabs(st)
    probe = ConcurrencyProbe(st.cos)
    assert st.get("o") is not None
    assert probe.max == 1
    st.close()


def test_fallback_masks_visibility_lag_with_backoff():
    """The consistency loop's capped exponential backoff (derived from
    cos_visibility_lag) must advance the logical clock past the lag."""
    st, clock = make_store(enable_recovery=False, cos_visibility_lag=5.0)
    rng = np.random.default_rng(4)
    data = rng.bytes(150_000)
    st.put("lagged", data)
    st.flush_writeback()                 # persisted, but not yet visible
    fail_all_slabs(st)
    assert clock.now() < 5.0
    assert st.get("lagged") == data      # backoff masked the lag
    st.close()


# ---------------------------------------------------------------------------
# sequential-scan prefetch on the degraded read path
# ---------------------------------------------------------------------------

def test_prefetch_warms_sequential_scan():
    st, _ = make_store(enable_recovery=False)
    rng = np.random.default_rng(5)
    objs = {f"shard/s{i}": rng.bytes(100_000) for i in range(8)}
    st.put_many(objs)
    st.flush_writeback()
    fail_all_slabs(st)
    for i in range(8):                   # ordered scan, one GET at a time
        key = f"shard/s{i}"
        assert st.get(key) == objs[key]
    assert st.prefetcher.stats.runs_detected == 1
    assert st.stats.prefetch_hits > 0, "scan never consumed a warm chunk"
    # warmed chunks land in bucket cache space -> re-reads hit SMS
    miss0 = st.stats.sms_chunk_misses
    assert st.get("shard/s5") == objs["shard/s5"]
    assert st.stats.sms_chunk_hits > 0
    del miss0
    st.close()


def test_random_access_cancels_prefetch_and_counts_waste():
    st, _ = make_store(enable_recovery=False)
    rng = np.random.default_rng(6)
    objs = {f"r/s{i}": rng.bytes(80_000) for i in range(8)}
    st.put_many(objs)
    st.flush_writeback()
    fail_all_slabs(st)
    for i in range(5):                   # run established; s5/s6 predicted
        assert st.get(f"r/s{i}") == objs[f"r/s{i}"]
    assert st.prefetcher.outstanding > 0 or st.stats.prefetch_hits > 0
    st.get("r/s0")                       # random access: cancel the run
    assert st.prefetcher.stats.runs_cancelled >= 1
    assert st.prefetcher.outstanding == 0
    # the cancelled run's warm fetches were withdrawn from the executor
    assert not any(ck.split("|")[0] in ("r/s5", "r/s6")
                   for ck in st._prefetch_inflight)
    # any warmed-but-unconsumed chunks were counted as waste
    assert st.stats.prefetch_wasted == st.prefetcher.stats.wasted
    st.close()


def test_prefetch_disabled_under_serial_path():
    st, _ = make_store(pipelined_get=False)
    rng = np.random.default_rng(7)
    for i in range(6):
        st.put(f"q/s{i}", rng.bytes(50_000))
    for i in range(6):
        st.get(f"q/s{i}")
    assert st.prefetcher.stats.predicted == 0
    assert st.stats.prefetch_hits == 0
    st.close()


# ---------------------------------------------------------------------------
# read-path maintenance: no scale-out, migration off the critical path
# ---------------------------------------------------------------------------

def test_demand_cache_never_forces_scaleout():
    st, _ = make_store(enable_recovery=False)
    rng = np.random.default_rng(8)
    data = rng.bytes(150_000)
    st.put("guarded", data)
    st.flush_writeback()
    for fg_id in list(st.placement.open_fg_ids):
        st.placement.seal_fg(fg_id)      # no open FG anywhere
    fail_all_slabs(st)
    scale_outs = st.placement.stats.scale_outs
    assert st.get("guarded") == data     # COS fallback, cache skipped
    assert st.placement.stats.scale_outs == scale_outs, \
        "demand caching spun up a function group for cache-space bytes"
    st.close()


def test_try_place_chunk_never_scales_out():
    from repro.core.placement import PlacementManager
    pm = PlacementManager(3, 1000)
    pm.get_open_funcs(2)                 # exactly one FG
    scale_outs = pm.stats.scale_outs
    assert pm.try_place_chunk(0, 800) is not None
    assert pm.try_place_chunk(0, 800) is not None  # crossing write seals
    # sealed FG, no open functions left: place_chunk would scale out here
    assert pm.try_place_chunk(0, 800) is None
    assert pm.stats.scale_outs == scale_outs


def test_migrate_chunks_skips_without_open_fg():
    st, _ = make_store()
    rng = np.random.default_rng(9)
    st.put("m", rng.bytes(100_000))
    st.flush_writeback()
    for fg_id in list(st.placement.open_fg_ids):
        st.placement.seal_fg(fg_id)
    ckey = "m|1/f0#0"
    st.window.mark(ckey)
    scale_outs = st.placement.stats.scale_outs
    st.gc_tick()                         # compaction round hits the guard
    assert st.placement.stats.scale_outs == scale_outs
    assert ckey in st.window.marked()    # re-marked for a later round
    st.close()


def age_first_bucket_to_degraded(st, clock):
    """Seal the data-holding FGs, open a fresh FG, and age the sealed
    bucket to DEGRADED (open FGs carry over and stay ACTIVE)."""
    for fg_id in list(st.placement.open_fg_ids):
        st.placement.seal_fg(fg_id)
    st.put("opener", b"x" * 1000)        # spins up a fresh open FG
    st.flush_writeback()
    for _ in range(3):
        clock.advance(10.0)
        st.gc_tick()


def test_degraded_hit_migration_deferred_to_gc_tick():
    st, clock = make_store()
    rng = np.random.default_rng(10)
    data = rng.bytes(200_000)
    st.put("hot", data)
    st.flush_writeback()
    age_first_bucket_to_degraded(st, clock)
    fid = st.chunk_map["hot|1/f0#0"]
    assert st.window.state_of_function(fid) == BucketState.DEGRADED
    assert st.get("hot") == data         # in-memory DEGRADED-bucket hit
    assert st.stats.degraded_hits > 0
    snap = st.snapshot_metadata()["get_pipeline"]
    assert snap["pending_migrations"] > 0, "migration ran on the GET path"
    assert st.stats.compactions == 0
    st.gc_tick()                         # the deferred round runs here
    assert st.stats.compactions > 0
    assert st.snapshot_metadata()["get_pipeline"]["pending_migrations"] == 0
    st.close()


def test_serial_path_still_migrates_inline():
    st, clock = make_store(pipelined_get=False)
    rng = np.random.default_rng(11)
    data = rng.bytes(200_000)
    st.put("hot", data)
    st.flush_writeback()
    age_first_bucket_to_degraded(st, clock)
    assert st.get("hot") == data
    assert st.stats.compactions > 0      # legacy: migrated during the GET
    st.close()
