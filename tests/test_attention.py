"""Chunked/flash attention vs naive reference; window + tri variants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L


def naive(q, k, v, *, causal=True, window=None):
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(D)
    qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


def _qkv(seed, B, S, H, D, K=None):
    K = K or H
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, K, D))
    v = jax.random.normal(ks[2], (B, S, K, D))
    return q, L.expand_kv(k, H), L.expand_kv(v, H)


@pytest.mark.parametrize("S,bq,bk", [(16, 4, 4), (37, 8, 16), (64, 64, 64),
                                     (100, 32, 8)])
def test_chunked_matches_naive(S, bq, bk):
    q, k, v = _qkv(S, 2, S, 4, 16)
    want = naive(q, k, v)
    got = L.chunked_attention(q, k, v, block_q=bq, block_k=bk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_tri_matches_masked():
    q, k, v = _qkv(7, 2, 64, 4, 16)
    a = L.chunked_attention(q, k, v, block_q=16, block_k=16, impl="masked")
    b = L.chunked_attention(q, k, v, block_q=16, block_k=16, impl="tri")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("S,w", [(64, 16), (100, 32), (32, 64)])
def test_local_window_matches_naive(S, w):
    q, k, v = _qkv(S + w, 2, S, 4, 16)
    want = naive(q, k, v, window=w)
    got = L.local_chunked_attention(q, k, v, window=w, block_q=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_decode_attention_matches_last_row():
    B, S, H, D = 2, 24, 4, 16
    q, k, v = _qkv(3, B, S, H, D)
    want = naive(q, k, v)[:, -1:]
    got = L.decode_attention(q[:, -1:], k, v, S)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_gqa_expand_kv_grouping():
    """expand_kv must repeat each kv head H/K times in order."""
    k = jnp.arange(2 * 3 * 2 * 4).reshape(2, 3, 2, 4).astype(jnp.float32)
    e = L.expand_kv(k, 6)
    assert e.shape == (2, 3, 6, 4)
    for g in range(3):
        np.testing.assert_array_equal(np.asarray(e[:, :, g]),
                                      np.asarray(k[:, :, 0]))
        np.testing.assert_array_equal(np.asarray(e[:, :, 3 + g]),
                                      np.asarray(k[:, :, 1]))
