"""Shared `StoreFrontend` conformance suite, run against every
front-end — `InfiniStore`, `ShardedStore` (threads), and
`ProcessShardedStore` over both transports (shm rings and TCP
loopback) — so the surfaces cannot drift: one parametrized fixture,
one set of contract tests.

Each test gets a FRESH store (crash/restart tests mutate liveness);
the process store spawns real workers, so the per-test cost is a few
hundred ms — the suite keeps batches small."""
import threading

import numpy as np
import pytest

from repro.core import (Clock, ConcurrentPutError, InfiniStore,
                        ProcessShardedStore, ShardedStore, StoreConfig,
                        StoreFrontend)
from repro.core.ec import ECConfig
from repro.core.gc_window import GCConfig
from repro.core.writeback import StoreFuture

MB = 1024 * 1024

FRONTENDS = ("single", "sharded", "process", "tcp")


def _cfg(spill_dir=None):
    return StoreConfig(ec=ECConfig(k=4, p=2), function_capacity=8 * MB,
                       fragment_bytes=1 * MB,
                       gc=GCConfig(gc_interval=1e9),
                       num_recovery_functions=4, spill_dir=spill_dir)


def _build(kind, tmp_path):
    spill = str(tmp_path / f"spill-{kind}")
    if kind == "single":
        return InfiniStore(_cfg(spill), clock=Clock(), seed=0)
    if kind == "sharded":
        return ShardedStore(_cfg(spill), num_shards=2, clock=Clock(),
                            seed=0)
    if kind == "process":
        return ProcessShardedStore(_cfg(spill), num_shards=2,
                                   clock=Clock(), seed=0)
    if kind == "tcp":
        return ProcessShardedStore(_cfg(spill), num_shards=2,
                                   clock=Clock(), seed=0,
                                   transport="tcp")
    raise ValueError(kind)


@pytest.fixture
def lock_witness():
    """Runtime lock-order witness over every store the test builds:
    locks created while it is installed report acquisitions, and any
    inversion against the static hierarchy fails the test at teardown
    (after close(), so shutdown-path orders are witnessed too)."""
    from repro.core import locks
    from repro.devtools.witness import LockWitness
    w = LockWitness.with_static_order()
    locks.install_witness(w)
    try:
        yield w
    finally:
        locks.install_witness(None)


@pytest.fixture(params=FRONTENDS)
def frontend(request, tmp_path, lock_witness):
    st = _build(request.param, tmp_path)
    yield st
    st.close()
    lock_witness.assert_clean()


def test_conforms_to_protocol(frontend):
    assert isinstance(frontend, StoreFrontend)


def test_put_get_roundtrip_and_versions(frontend):
    rng = np.random.default_rng(0)
    data = {f"k{i}": rng.bytes(9_000) for i in range(6)}
    for k, v in data.items():
        assert frontend.put(k, v) == 1
    for k, v in data.items():
        assert frontend.get(k) == v
    # overwrite bumps the version; readers see the newest
    assert frontend.put("k0", b"x" * 9_000) == 2
    assert frontend.get("k0") == b"x" * 9_000
    assert frontend.get("absent") is None


def test_async_futures_resolve_with_versions(frontend):
    fut = frontend.put_async("a", b"a" * 9_000)
    assert isinstance(fut, StoreFuture)
    assert fut.result() == 1
    assert fut.version == 1
    gf = frontend.get_async("a")
    assert gf.result() == b"a" * 9_000


def test_array_payloads_roundtrip(frontend):
    arr = np.arange(40_000, dtype=np.uint8)
    assert frontend.put("arr", arr) == 1
    got = frontend.get_array("arr")
    assert got is not None and got.dtype == np.uint8
    assert np.array_equal(got, arr)
    assert frontend.get_array("absent") is None
    out = frontend.get_many_arrays(["arr", "absent"])
    assert np.array_equal(out["arr"], arr) and out["absent"] is None


def test_payload_captured_at_submission(frontend):
    """The async contract: once put_async returns, the caller may
    scribble over its buffer — the store must already own the bytes."""
    buf = np.full(30_000, 7, dtype=np.uint8)
    want = buf.tobytes()
    fut = frontend.put_async("snap", buf)
    buf[:] = 0                       # caller reuses the buffer
    assert fut.result() == 1
    assert frontend.get("snap") == want


def test_put_many_get_many_batch(frontend):
    rng = np.random.default_rng(1)
    batch = {f"b{i}": rng.bytes(8_000) for i in range(8)}
    out = frontend.put_many(batch)
    assert set(out) == set(batch) and all(v == 1 for v in out.values())
    got = frontend.get_many(list(batch) + ["nope"])
    assert got["nope"] is None
    assert all(got[k] == v for k, v in batch.items())


def test_put_many_duplicate_keys_rejected(frontend):
    with pytest.raises(ValueError):
        frontend.put_many([("d", b"1" * 8_000), ("d", b"2" * 8_000)])


def test_put_many_version_contract_on_rewrite(frontend):
    """A batch rewriting an existing key bumps that key's version and
    versions fresh keys at 1 — the per-key CAS contract holds at every
    surface (ConcurrentPutError is the cross-surface conflict type;
    see test_host for it crossing the process boundary)."""
    frontend.put("c0", b"base" * 2_000)
    out = frontend.put_many({"c0": b"n" * 8_000, "c1": b"n" * 8_000})
    assert out["c0"] == 2 and out["c1"] == 1


def test_flush_writeback_barrier_then_cos_visible(frontend):
    rng = np.random.default_rng(2)
    for i in range(4):
        frontend.put(f"f{i}", rng.bytes(8_000))
    assert frontend.flush_writeback(timeout=60.0) is True
    # after the barrier, chunks are durable in COS under each key's
    # namespace — cos_keys must surface them
    keys = frontend.cos_keys()
    assert any("f0" in k for k in keys)


def test_gc_tick_safe_anytime(frontend):
    frontend.put("g0", b"g" * 8_000)
    frontend.gc_tick()
    assert frontend.get("g0") == b"g" * 8_000


def test_snapshot_metadata_health_surface(frontend):
    frontend.put("h0", b"h" * 8_000)
    snap = frontend.snapshot_metadata()
    assert snap["health"]["state"] == "OK"
    assert snap["health"]["indoubt_tickets"] == []
    assert snap["stats"]["puts"] >= 1 if "stats" in snap else True


def test_stats_counters_aggregate(frontend):
    for i in range(3):
        frontend.put(f"s{i}", b"s" * 8_000)
        frontend.get(f"s{i}")
    st = frontend.stats
    assert st.puts >= 3 and st.gets >= 3


def test_concurrent_clients_linearize_per_key(frontend):
    """N threads hammering disjoint keys: every ack is version 1 and
    every readback matches — across threads, shards, and processes."""
    errs = []

    def client(t):
        rng = np.random.default_rng(t)
        try:
            for i in range(4):
                k = f"t{t}-{i}"
                v = rng.bytes(8_000)
                assert frontend.put(k, v) == 1
                assert frontend.get(k) == v
        except Exception as e:                        # noqa: BLE001
            errs.append(e)

    threads = [threading.Thread(target=client, args=(t,))
               for t in range(4)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert not errs


def test_close_idempotent_and_final(frontend):
    frontend.put("z", b"z" * 8_000)
    assert frontend.close() is True
    assert frontend.close() is True  # second close is a no-op
