"""Failure detection + local/parallel recovery (paper §5.5, Figs. 19-21)."""
import numpy as np

from repro.core import Clock, InfiniStore, StoreConfig
from repro.core.ec import ECConfig
from repro.core.gc_window import GCConfig
from repro.core.recovery import _chunk_shard


def big_store(num_recovery=4):
    cfg = StoreConfig(ec=ECConfig(k=4, p=2),
                      function_capacity=64 * 1024 * 1024,
                      gc=GCConfig(gc_interval=1e9),
                      num_recovery_functions=num_recovery)
    return InfiniStore(cfg, clock=Clock())


def test_detection_on_term_mismatch(tiny_store):
    st, _ = tiny_store
    st.put("a", b"x" * 50_000)
    st.flush_writeback()       # drain the buffer: GET must hit the slabs
    fid = st.chunk_map["a|1/f0#0"]
    st.inject_failure(fid)
    before = st.recovery.stats.detections
    assert st.get("a") == b"x" * 50_000
    assert st.recovery.stats.detections > before


def test_local_recovery_when_few_chunks(tiny_store):
    st, _ = tiny_store
    st.put("a", b"y" * 10_000)
    st.flush_writeback()       # drain the buffer: GET must hit the slabs
    fid = st.chunk_map["a|1/f0#1"]
    st.inject_failure(fid)
    st.get("a")
    assert st.recovery.stats.local_recoveries >= 1
    assert st.recovery.stats.parallel_recoveries == 0


def test_parallel_recovery_when_many_chunks():
    st = big_store(num_recovery=4)
    rng = np.random.default_rng(0)
    payloads = {}
    for i in range(40):
        payloads[f"o{i}"] = rng.bytes(20_000)
        st.put(f"o{i}", payloads[f"o{i}"])
    st.flush_writeback()       # drain the buffer: GET must hit the slabs
    # every object's chunk 0 lands on slot-0 functions; kill one with many
    fid = st.chunk_map["o0|1/f0#0"]
    n_chunks = len(st.sms.get(fid).storage)
    assert n_chunks > st.cfg.num_recovery_functions
    st.inject_failure(fid)
    assert st.get("o0") == payloads["o0"]
    assert st.recovery.stats.parallel_recoveries >= 1
    # the failed function's full content was restored
    assert len(st.sms.get(fid).storage) == n_chunks


def test_hash_partition_covers_all_chunks():
    keys = [f"k{i}" for i in range(100)]
    R = 7
    shards = {k: _chunk_shard(k, R) for k in keys}
    assert set(shards.values()) <= set(range(R))
    # partition: every key in exactly one shard; roughly balanced
    counts = np.bincount(list(shards.values()), minlength=R)
    assert counts.sum() == 100
    assert counts.max() <= 3 * counts.mean()


def test_ec_masks_unrecovered_chunk(tiny_store):
    """GETs tolerate p in-flight losses without the recovered data (the
    paper: EC 'greatly reduces the possibility that instance reclamation
    impacts GET latency')."""
    st, _ = tiny_store
    data = b"z" * 200_000
    st.put("a", data)
    # drop BOTH parity-slot functions' entries for this object
    for idx in (4, 5):
        fid = st.chunk_map[f"a|1/f0#{idx}"]
        st.sms.get(fid).delete(f"a|1/f0#{idx}")
    assert st.get("a") == data           # decoded from k=4 data chunks


def test_recovered_data_served_during_recovery():
    st = big_store(num_recovery=2)
    rng = np.random.default_rng(1)
    for i in range(30):
        st.put(f"o{i}", rng.bytes(10_000))
    st.flush_writeback()       # drain the buffer: GET must hit the slabs
    fid = st.chunk_map["o5|1/f0#2"]
    st.inject_failure(fid)
    st.get("o5")
    assert st.recovery.stats.chunks_recovered > 0
