"""Failure detection + local/parallel recovery (paper §5.5, Figs. 19-21)."""
import numpy as np

from repro.core import Clock, InfiniStore, StoreConfig
from repro.core.ec import ECConfig
from repro.core.gc_window import GCConfig
from repro.core.recovery import _chunk_shard


def big_store(num_recovery=4):
    cfg = StoreConfig(ec=ECConfig(k=4, p=2),
                      function_capacity=64 * 1024 * 1024,
                      gc=GCConfig(gc_interval=1e9),
                      num_recovery_functions=num_recovery)
    return InfiniStore(cfg, clock=Clock())


def test_detection_on_term_mismatch(tiny_store):
    st, _ = tiny_store
    st.put("a", b"x" * 50_000)
    st.flush_writeback()       # drain the buffer: GET must hit the slabs
    fid = st.chunk_map["a|1/f0#0"]
    st.inject_failure(fid)
    before = st.recovery.stats.detections
    assert st.get("a") == b"x" * 50_000
    assert st.recovery.stats.detections > before


def test_local_recovery_when_few_chunks(tiny_store):
    st, _ = tiny_store
    st.put("a", b"y" * 10_000)
    st.flush_writeback()       # drain the buffer: GET must hit the slabs
    fid = st.chunk_map["a|1/f0#1"]
    st.inject_failure(fid)
    st.get("a")
    assert st.recovery.stats.local_recoveries >= 1
    assert st.recovery.stats.parallel_recoveries == 0


def test_parallel_recovery_when_many_chunks():
    st = big_store(num_recovery=4)
    rng = np.random.default_rng(0)
    payloads = {}
    for i in range(40):
        payloads[f"o{i}"] = rng.bytes(20_000)
        st.put(f"o{i}", payloads[f"o{i}"])
    st.flush_writeback()       # drain the buffer: GET must hit the slabs
    # every object's chunk 0 lands on slot-0 functions; kill one with many
    fid = st.chunk_map["o0|1/f0#0"]
    n_chunks = len(st.sms.get(fid).storage)
    assert n_chunks > st.cfg.num_recovery_functions
    st.inject_failure(fid)
    assert st.get("o0") == payloads["o0"]
    assert st.recovery.stats.parallel_recoveries >= 1
    # the failed function's full content was restored
    assert len(st.sms.get(fid).storage) == n_chunks


def test_hash_partition_covers_all_chunks():
    keys = [f"k{i}" for i in range(100)]
    R = 7
    shards = {k: _chunk_shard(k, R) for k in keys}
    assert set(shards.values()) <= set(range(R))
    # partition: every key in exactly one shard; roughly balanced
    counts = np.bincount(list(shards.values()), minlength=R)
    assert counts.sum() == 100
    assert counts.max() <= 3 * counts.mean()


def test_ec_masks_unrecovered_chunk(tiny_store):
    """GETs tolerate p in-flight losses without the recovered data (the
    paper: EC 'greatly reduces the possibility that instance reclamation
    impacts GET latency')."""
    st, _ = tiny_store
    data = b"z" * 200_000
    st.put("a", data)
    # drop BOTH parity-slot functions' entries for this object
    for idx in (4, 5):
        fid = st.chunk_map[f"a|1/f0#{idx}"]
        st.sms.get(fid).delete(f"a|1/f0#{idx}")
    assert st.get("a") == data           # decoded from k=4 data chunks


def test_recovered_data_served_during_recovery():
    st = big_store(num_recovery=2)
    rng = np.random.default_rng(1)
    for i in range(30):
        st.put(f"o{i}", rng.bytes(10_000))
    st.flush_writeback()       # drain the buffer: GET must hit the slabs
    fid = st.chunk_map["o5|1/f0#2"]
    st.inject_failure(fid)
    st.get("o5")
    assert st.recovery.stats.chunks_recovered > 0


def test_temporary_placements_expire_after_retain_seconds():
    """§5.5.2: recovery-group cache placements are TEMPORARY — after
    retain_seconds the gc_tick sweep evicts them and drops the finished
    session."""
    cfg = StoreConfig(ec=ECConfig(k=4, p=2),
                      function_capacity=64 * 1024 * 1024,
                      gc=GCConfig(gc_interval=1e9),
                      num_recovery_functions=4,
                      recovery_retain_seconds=30.0)
    clock = Clock()
    st = InfiniStore(cfg, clock=clock)
    rng = np.random.default_rng(2)
    payloads = {f"o{i}": rng.bytes(20_000) for i in range(40)}
    for k, v in payloads.items():
        st.put(k, v)
    st.flush_writeback()
    fid = st.chunk_map["o0|1/f0#0"]
    st.inject_failure(fid)
    assert st.get("o0") == payloads["o0"]         # parallel recovery
    session = st.recovery.sessions[fid]
    assert session.done and session.placements
    rfid, ckey = session.placements[0]
    assert st.sms.get(rfid).cache.get(ckey) is not None
    st.gc_tick()                                  # before expiry: retained
    assert fid in st.recovery.sessions
    clock.advance(31.0)
    st.gc_tick()                                  # past retain_seconds
    assert fid not in st.recovery.sessions        # session dropped
    for rfid2, ckey2 in session.placements:
        assert st.sms.get(rfid2).cache.get(ckey2) is None
    # the sweep's cache_delete kept cached_bytes honest (no over-report)
    for rfid2, _ in session.placements:
        slab = st.sms.get(rfid2)
        assert slab.stats.cached_bytes == \
            sum(len(v) for v in slab.cache.values())
    # the restored storage function still serves the data
    assert st.get("o0") == payloads["o0"]


def test_refailure_overwrite_evicts_prior_session_placements():
    """A re-failure of the same fid inside retain_seconds replaces the
    finished session in `sessions`; the replaced session's temporary
    placements must be evicted at that point — sweep_expired can no
    longer reach them."""
    cfg = StoreConfig(ec=ECConfig(k=4, p=2),
                      function_capacity=64 * 1024 * 1024,
                      gc=GCConfig(gc_interval=1e9),
                      num_recovery_functions=4,
                      recovery_retain_seconds=30.0)
    st = InfiniStore(cfg, clock=Clock())
    rng = np.random.default_rng(5)
    payloads = {f"o{i}": rng.bytes(20_000) for i in range(40)}
    for k, v in payloads.items():
        st.put(k, v)
    st.flush_writeback()
    fid = st.chunk_map["o0|1/f0#0"]
    st.inject_failure(fid)
    assert st.get("o0") == payloads["o0"]
    s1 = st.recovery.sessions[fid]
    assert s1.done and s1.placements
    # a placement the second recovery will NOT re-create: it must be
    # gone after the overwrite, not stranded in the recovery slab
    rfid, _ = s1.placements[0]
    st.sms.get(rfid).cache_put("stale-recovery-chunk", b"z" * 64)
    s1.placements.append((rfid, "stale-recovery-chunk"))
    st.inject_failure(fid)
    assert st.get("o0") == payloads["o0"]         # second recovery
    assert st.recovery.sessions[fid] is not s1    # session replaced
    assert st.sms.get(rfid).cache.get("stale-recovery-chunk") is None


def test_close_shuts_down_recovery_pool():
    """InfiniStore.close() must release the recovery worker threads (it
    used to leak up to 8 recovery-* threads per store)."""
    st = big_store(num_recovery=2)
    # force the pool to actually spin up workers
    st.recovery._pool.submit(lambda: None).result()
    workers = list(st.recovery._pool._threads)
    assert workers and any(t.is_alive() for t in workers)
    st.close()
    assert st.recovery._pool._shutdown
    assert not any(t.is_alive() for t in workers)


def test_was_dead_invoke_counts_as_detection():
    """A reclaimed instance observed dead at invocation is a real
    detection even when term/hash match (nothing was ever appended) —
    the was_dead path used to bypass stats.detections."""
    st = big_store()
    st.put("a", b"x" * 20_000)
    fid = next(iter(st.sms.slabs))
    st.inject_failure(fid)
    # daemon view agrees with the zeroed slab: check_failed sees nothing
    from repro.core.insertion_log import Piggyback
    st.daemon_view[fid] = Piggyback()
    before = st.recovery.stats.detections
    st._invoke(fid, 0, "request")
    assert st.recovery.stats.detections == before + 1


def test_parallel_recovery_races_live_puts_and_gets():
    """`recover_parallel` restoring a failed instance while live clients
    keep mutating and reading THE SAME keys: no exception escapes, no
    stale resurrection (every key reads back as one of its acked
    payloads, and keys overwritten during recovery read back NEW)."""
    import threading

    st = big_store(num_recovery=4)
    rng = np.random.default_rng(11)
    keys = [f"race{i}" for i in range(24)]
    v1 = {k: rng.bytes(20_000) for k in keys}
    for k, v in v1.items():
        st.put(k, v)
    st.flush_writeback()
    fid = st.chunk_map[f"{keys[0]}|1/f0#0"]
    assert len(st.sms.get(fid).storage) > st.cfg.num_recovery_functions
    st.inject_failure(fid)

    v2 = {k: rng.bytes(20_000) for k in keys[:12]}   # overwritten mid-
    errors = []                                      # recovery

    def mutator():
        try:
            for k, v in v2.items():
                assert st.put(k, v) == 2
        except BaseException as e:                   # noqa: BLE001
            errors.append(e)

    def reader():
        try:
            for _ in range(3):
                for k in keys:
                    got = st.get(k)
                    assert got in (v1[k], v2.get(k)), k
        except BaseException as e:                   # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=mutator)] + \
        [threading.Thread(target=reader) for _ in range(2)]
    for t in threads:
        t.start()
    # the recovery session races the mutations: the GET detects the
    # dead instance and runs parallel recovery inline
    assert st.get(keys[0]) in (v1[keys[0]], v2[keys[0]])
    for t in threads:
        t.join()
    assert not errors, errors
    assert st.recovery.stats.parallel_recoveries >= 1
    # settled state: overwrites won, untouched keys were fully restored
    for k in keys:
        expect = v2.get(k, v1[k])
        assert st.get(k) == expect, f"lost or resurrected {k}"
    st.close()
