"""InfiniStore-backed checkpointing: roundtrip, failure recovery,
restart determinism, elastic restore."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer, CheckpointConfig
from repro.configs import ShapeConfig, get_config, reduced
from repro.core import Clock, InfiniStore, StoreConfig
from repro.core.ec import ECConfig
from repro.core.gc_window import GCConfig
from repro.launch.train import train
from repro.models import build_model


def small_store():
    cfg = StoreConfig(ec=ECConfig(k=4, p=2),
                      function_capacity=32 * 1024 * 1024,
                      fragment_bytes=4 * 1024 * 1024,
                      gc=GCConfig(gc_interval=1e9))
    return InfiniStore(cfg, clock=Clock())


def tiny_cfg():
    return dataclasses.replace(reduced(get_config("qwen1.5-0.5b")),
                               dtype="float32")


def test_roundtrip():
    st = small_store()
    ck = Checkpointer(st)
    cfg = tiny_cfg()
    m = build_model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    ck.save(5, {"params": params})
    out = ck.restore(5, like={"params": params})
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(out["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_after_slab_failures():
    """Kill several slabs after save: restore must succeed via EC/COS."""
    st = small_store()
    ck = Checkpointer(st)
    cfg = tiny_cfg()
    m = build_model(cfg)
    params = m.init_params(jax.random.PRNGKey(1))
    ck.save(1, {"params": params})
    st.flush_writeback()       # drain the buffer: restore must hit slabs/COS
    for fid in list(st.sms.slabs)[::2]:
        st.inject_failure(fid)
    out = ck.restore(1, like={"params": params})
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(out["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert (st.recovery.stats.local_recoveries
            + st.recovery.stats.parallel_recoveries) > 0


def test_train_restart_is_deterministic():
    """Train 6 steps straight vs 3 + checkpoint + restart + 3: identical
    losses (deterministic pipeline + exact state restore)."""
    cfg = tiny_cfg()
    shape = ShapeConfig("t", seq_len=16, global_batch=4, kind="train")
    full = train(cfg, shape, steps=6, seed=3)

    st = small_store()
    ck = Checkpointer(st)
    train(cfg, shape, steps=3, seed=3, checkpointer=ck, checkpoint_every=3)
    resumed = train(cfg, shape, steps=6, seed=3, checkpointer=ck,
                    resume=True)
    assert resumed.restored_from == 3
    np.testing.assert_allclose(full.losses[3:], resumed.losses,
                               rtol=2e-4, atol=2e-4)


def test_latest_step():
    st = small_store()
    ck = Checkpointer(st)
    assert ck.latest_step() is None
    cfg = tiny_cfg()
    m = build_model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    ck.save(2, {"params": params})
    ck.save(7, {"params": params})
    assert ck.latest_step() == 7
    # a FRESH checkpointer over the same store must discover the steps
    # from COS keys (incl. the pending writeback map), not process state
    ck2 = Checkpointer(st)
    assert ck2.latest_step() == 7
