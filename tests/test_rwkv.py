"""RWKV6 WKV: chunked production path vs sequential oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.models.rwkv6 import wkv_chunked, wkv_scan


def _inputs(seed, B, S, H, hs, decay_scale=1.5):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    r, k, v = (jax.random.normal(ks[i], (B, S, H, hs)) for i in range(3))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, S, H, hs))
                         * decay_scale - 1.0))
    u = jax.random.normal(ks[4], (H, hs)) * 0.1
    s0 = jax.random.normal(jax.random.PRNGKey(seed + 1), (B, H, hs, hs)) * 0.1
    return r, k, v, w, u, s0


@pytest.mark.parametrize("S,chunk", [(16, 4), (37, 16), (64, 32), (7, 8)])
def test_chunked_matches_scan(S, chunk):
    args = _inputs(S, 2, S, 3, 8)
    y1, st1 = wkv_scan(*args)
    y2, st2 = wkv_chunked(*args, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(st1), np.asarray(st2),
                               atol=2e-3, rtol=2e-3)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 100), S=st.integers(2, 40),
       chunk=st.sampled_from([4, 8, 16]))
def test_chunked_matches_scan_property(seed, S, chunk):
    args = _inputs(seed, 1, S, 2, 4)
    y1, st1 = wkv_scan(*args)
    y2, st2 = wkv_chunked(*args, chunk=chunk)
    assert np.allclose(np.asarray(y1), np.asarray(y2), atol=3e-3, rtol=3e-3)
    assert np.allclose(np.asarray(st1), np.asarray(st2), atol=3e-3,
                       rtol=3e-3)


def test_state_carries_across_segments():
    """prefill(x[:a]) then prefill(x[a:]) == prefill(x) (state passing)."""
    r, k, v, w, u, s0 = _inputs(9, 1, 24, 2, 4)
    y_full, st_full = wkv_scan(r, k, v, w, u, s0)
    a = 11
    y1, st_mid = wkv_scan(r[:, :a], k[:, :a], v[:, :a], w[:, :a], u, s0)
    y2, st_end = wkv_scan(r[:, a:], k[:, a:], v[:, a:], w[:, a:], u, st_mid)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(st_end), np.asarray(st_full),
                               atol=1e-5, rtol=1e-5)
