"""Async COS writeback (paper §5.3.2): WritebackQueue unit semantics
(retry/backoff, flush barriers, pending map) and the store-level
durability contract — a PUT acks before COS persistence, and an instance
failure in that window must lose nothing."""
import threading
import time

import numpy as np
import pytest

from repro.core import Clock, InfiniStore, StoreConfig
from repro.core.cos import COS
from repro.core.ec import ECConfig
from repro.core.gc_window import GCConfig
from repro.core.writeback import WritebackQueue

MB = 1024 * 1024


class FlakyCOS:
    """COS facade whose put fails the first `fail_first` times."""

    def __init__(self, fail_first: int = 0):
        self.inner = COS(Clock())
        self.fail_first = fail_first
        self.attempts = 0

    def put(self, key, data):
        self.attempts += 1
        if self.attempts <= self.fail_first:
            raise IOError("simulated COS outage")
        self.inner.put(key, data)

    def get(self, key):
        return self.inner.get(key)


def make_store(**kw):
    cfg = StoreConfig(ec=ECConfig(k=4, p=2),
                      function_capacity=8 * MB,
                      fragment_bytes=1 * MB,
                      gc=GCConfig(gc_interval=1e9),
                      num_recovery_functions=4, **kw)
    return InfiniStore(cfg, clock=Clock())


# ---------------------------------------------------------------------------
# WritebackQueue unit semantics
# ---------------------------------------------------------------------------

def test_writeback_basic_persist_and_flush():
    cos = COS(Clock())
    wb = WritebackQueue(cos)
    wb.enqueue("a", b"x" * 100)
    wb.enqueue("b", b"y" * 100)
    assert wb.flush(timeout=5.0)
    assert cos.get("a") == b"x" * 100 and cos.get("b") == b"y" * 100
    assert wb.stats.persisted == 2 and wb.depth == 0
    assert wb.peek("a") is None                   # pending map drained
    wb.close()


def test_writeback_pending_serves_reads_before_persist():
    cos = COS(Clock())
    wb = WritebackQueue(cos, start_thread=False)   # nothing drains yet
    wb.enqueue("k", b"payload")
    assert cos.get("k") is None                   # not persisted
    assert wb.peek("k") == b"payload"             # but readable
    assert wb.pending_keys() == ["k"]
    assert wb.drain() == 1                        # gc_tick-style drain
    assert cos.get("k") == b"payload"
    assert wb.peek("k") is None


def test_writeback_retry_with_backoff():
    cos = FlakyCOS(fail_first=3)
    wb = WritebackQueue(cos, max_retries=8, backoff_base_s=0.001)
    wb.enqueue("k", b"v")
    assert wb.flush(timeout=10.0)
    assert cos.get("k") == b"v"
    assert wb.stats.retries >= 3                  # 3 failed attempts
    assert wb.stats.persisted == 1
    assert wb.stats.failures == 0
    wb.close()


def test_writeback_gives_up_after_max_retries():
    cos = FlakyCOS(fail_first=10 ** 9)            # permanently down
    wb = WritebackQueue(cos, max_retries=2, backoff_base_s=0.0,
                        start_thread=False)
    wb.enqueue("k", b"v")
    # flush terminates but reports the barrier did NOT fully persist
    assert wb.flush(timeout=5.0) is False
    assert wb.stats.failures == 1
    assert wb.errors() and "k" in wb.errors()[0]


def test_writeback_pause_resume():
    cos = COS(Clock())
    wb = WritebackQueue(cos)
    wb.pause()
    wb.enqueue("k", b"v")
    time.sleep(0.05)
    assert cos.get("k") is None and wb.depth == 1
    assert wb.drain() == 0                        # drain respects pause
    wb.resume()
    assert wb.flush(timeout=5.0)
    assert cos.get("k") == b"v"
    wb.close()


def test_writeback_backpressure_bounded_depth():
    cos = COS(Clock())
    wb = WritebackQueue(cos, max_depth=2)
    wb.pause()
    wb.enqueue("a", b"1")
    wb.enqueue("b", b"2")
    done = threading.Event()

    def third():
        wb.enqueue("c", b"3")                     # must block: queue full
        done.set()

    t = threading.Thread(target=third, daemon=True)
    t.start()
    time.sleep(0.05)
    assert not done.is_set()                      # blocked on backpressure
    wb.resume()
    assert done.wait(timeout=5.0)
    assert wb.flush(timeout=5.0)
    assert cos.get("c") == b"3"
    wb.close()


def test_writeback_newer_write_supersedes_pending():
    cos = COS(Clock())
    wb = WritebackQueue(cos, start_thread=False)
    wb.enqueue("k", b"v1")
    wb.enqueue("k", b"v2")
    assert wb.peek("k") == b"v2"                  # latest wins for reads
    wb.drain()
    assert cos.get("k") == b"v2"
    # the stale write was dropped, not persisted-then-overwritten — a
    # retried old write can never clobber a newer one in COS
    assert wb.stats.superseded == 1
    assert wb.stats.persisted == 1


# ---------------------------------------------------------------------------
# store-level durability under async writeback
# ---------------------------------------------------------------------------

def test_put_acks_before_cos_persistence():
    st = make_store()
    st.writeback.pause()                          # hold all chunk writes
    data = np.random.default_rng(0).bytes(300_000)
    ver = st.put("obj", data)                     # must ack regardless
    assert ver == 1
    assert st.cos.list_keys("chunk/obj") == []    # nothing persisted yet
    # chunks + insertion-log nodes are queued, none persisted
    assert st.writeback.depth >= st.cfg.ec.n
    assert st.get("obj") == data                  # read-your-writes
    st.writeback.resume()
    assert st.flush_writeback(timeout=10.0)
    assert len(st.cos.list_keys("chunk/obj")) == st.cfg.ec.n


def test_durability_failure_after_ack_before_persist():
    """Kill EVERY function after PUT-ack but before any COS persistence:
    GET must still return the object (persistent buffer + pending map +
    recovery), the paper's §5.3.2 durability contract."""
    st = make_store()
    st.writeback.pause()
    rng = np.random.default_rng(1)
    objs = {f"k{i}": rng.bytes(150_000) for i in range(6)}
    for k, v in objs.items():
        assert st.put(k, v) == 1
    assert st.cos.list_keys("chunk/k") == []      # zero chunks persisted
    for fid in list(st.sms.slabs):
        st.inject_failure(fid)                    # provider reclaims ALL
    for k, v in objs.items():
        assert st.get(k) == v, f"lost {k} before writeback completed"
    # after the queue drains, the persistent buffer is fully released
    st.writeback.resume()
    assert st.flush_writeback(timeout=10.0)
    assert st.pb.size_bytes == 0


def test_recovery_restores_unpersisted_chunks_from_pending():
    """Parallel recovery must find acked-but-unpersisted chunks in the
    writeback pending map (COS doesn't have them yet)."""
    cfg = StoreConfig(ec=ECConfig(k=4, p=2),
                      function_capacity=64 * MB,
                      gc=GCConfig(gc_interval=1e9),
                      num_recovery_functions=2)
    st = InfiniStore(cfg, clock=Clock())
    st.writeback.pause()
    rng = np.random.default_rng(2)
    payloads = {}
    for i in range(30):
        payloads[f"o{i}"] = rng.bytes(20_000)
        st.put(f"o{i}", payloads[f"o{i}"])
    fid = st.chunk_map["o0|1/f0#0"]
    n_chunks = len(st.sms.get(fid).storage)
    assert n_chunks > st.cfg.num_recovery_functions
    st.inject_failure(fid)
    # drop o0's buffer entry so the GET takes the chunk-gather path and
    # the invoke-time failure detection fires (otherwise the persistent
    # buffer would serve the read without touching the failed function)
    st.pb.release_all("o0|1/f0")
    assert st.get("o0") == payloads["o0"]
    assert st.recovery.stats.parallel_recoveries >= 1
    # full restoration happened even though COS had nothing
    assert len(st.sms.get(fid).storage) == n_chunks


def test_persistent_buffer_drains_as_chunks_persist():
    st = make_store()
    st.writeback.pause()
    st.put("x", b"q" * 200_000)
    assert st.pb.size_bytes > 0                   # held while unpersisted
    st.writeback.resume()
    assert st.flush_writeback(timeout=10.0)
    assert st.pb.size_bytes == 0                  # refs drained
    # and the object now survives total reclamation via COS alone
    for fid in list(st.sms.slabs):
        st.inject_failure(fid)
    assert st.get("x") == b"q" * 200_000


def test_sync_mode_persists_inline():
    st = make_store(async_writeback=False)
    st.put("x", b"v" * 100_000)
    assert len(st.cos.list_keys("chunk/x")) == st.cfg.ec.n
    assert st.writeback.depth == 0
    assert st.pb.size_bytes == 0
    assert st.get("x") == b"v" * 100_000


def test_store_close_releases_threads():
    st = make_store()
    st.put("x", b"d" * 50_000)
    st.close()
    assert st.writeback.depth == 0                # flushed on close
    assert len(st.cos.list_keys("chunk/x")) == st.cfg.ec.n


def test_gc_tick_drains_writeback():
    st = make_store()
    # no writer-thread race: pause, then drain exclusively via gc_tick
    st.writeback.pause()
    st.put("x", b"d" * 120_000)
    assert st.cos.list_keys("chunk/x") == []
    st.writeback.resume()
    # resume alone lets the thread race gc_tick; drain() is what gc_tick
    # calls — exercise it directly through the public tick
    deadline = time.monotonic() + 5.0
    while len(st.cos.list_keys("chunk/x")) < st.cfg.ec.n:
        st.gc_tick()
        if time.monotonic() > deadline:
            pytest.fail("gc_tick never drained the writeback queue")
