import os
import sys

# tests must see the default (single) CPU device — the 512-device flag is
# set ONLY inside launch/dryrun.py
os.environ.pop("XLA_FLAGS", None)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# tests import the _hypothesis_compat shim as a top-level module
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import dataclasses  # noqa: E402

import pytest  # noqa: E402


@pytest.fixture
def tiny_store():
    """Small-geometry InfiniStore on a logical clock."""
    from repro.core import Clock, InfiniStore, StoreConfig
    from repro.core.ec import ECConfig
    from repro.core.gc_window import GCConfig
    MB = 1024 * 1024
    cfg = StoreConfig(
        ec=ECConfig(k=4, p=2),
        function_capacity=4 * MB,
        fragment_bytes=1 * MB,
        gc=GCConfig(gc_interval=10.0, active_intervals=2,
                    degraded_intervals=2, active_warmup=5.0,
                    degraded_warmup=20.0),
        num_recovery_functions=4,
    )
    clock = Clock()
    return InfiniStore(cfg, clock=clock), clock


def reduced_f32(name: str, **kw):
    from repro.configs import get_config, reduced
    cfg = reduced(get_config(name), **kw)
    return dataclasses.replace(cfg, dtype="float32")
