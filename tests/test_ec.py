"""Reed-Solomon codec: roundtrip under any <= p erasures (property)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.ec import ECConfig, RSCodec


@settings(max_examples=40, deadline=None)
@given(
    k=st.integers(2, 10),
    p=st.integers(1, 4),
    size=st.integers(0, 5000),
    seed=st.integers(0, 2**31 - 1),
)
def test_roundtrip_with_erasures(k, p, size, seed):
    rng = np.random.default_rng(seed)
    codec = RSCodec(ECConfig(k=k, p=p))
    payload = rng.integers(0, 256, size).astype(np.uint8).tobytes()
    chunks = codec.encode(payload)
    assert len(chunks) == k + p
    assert len({len(c) for c in chunks}) == 1        # equal-size chunks
    lost = rng.choice(k + p, size=rng.integers(0, p + 1), replace=False)
    surviving = {i: c for i, c in enumerate(chunks) if i not in lost}
    assert codec.decode(surviving) == payload


def test_too_few_chunks_raises():
    codec = RSCodec(ECConfig(k=4, p=2))
    chunks = codec.encode(b"hello world")
    with pytest.raises(ValueError):
        codec.decode({0: chunks[0], 1: chunks[1], 2: chunks[2]})


def test_parity_only_decode():
    """All data chunks lost, k survivors include all parity."""
    codec = RSCodec(ECConfig(k=3, p=2))
    payload = bytes(range(256)) * 7
    chunks = codec.encode(payload)
    surviving = {0: chunks[0], 3: chunks[3], 4: chunks[4]}
    assert codec.decode(surviving) == payload


def test_paper_config_10_2():
    codec = RSCodec(ECConfig(k=10, p=2))
    payload = np.random.default_rng(1).integers(
        0, 256, 1_000_000).astype(np.uint8).tobytes()
    chunks = codec.encode(payload)
    surviving = {i: c for i, c in enumerate(chunks) if i not in (2, 11)}
    assert codec.decode(surviving) == payload


def test_pallas_backend_matches_numpy():
    payload = np.random.default_rng(2).integers(
        0, 256, 10000).astype(np.uint8).tobytes()
    c_np = RSCodec(ECConfig(k=4, p=2), backend="numpy")
    c_pl = RSCodec(ECConfig(k=4, p=2), backend="pallas")
    assert c_np.encode(payload) == c_pl.encode(payload)
    chunks = dict(enumerate(c_np.encode(payload)))
    del chunks[1], chunks[4]
    assert c_pl.decode(chunks) == payload
