"""Pallas GF(256) matmul kernel vs jnp oracle vs numpy — shape sweep."""
import numpy as np
import pytest

from repro.kernels.rs_gf256.kernel import gf256_matmul_pallas
from repro.kernels.rs_gf256.ref import (cauchy_parity_matrix,
                                        gf256_matmul_ref, gf_matmul_np,
                                        gf_mul_np, gf_inv_np)


def test_field_axioms():
    a = np.arange(1, 256, dtype=np.uint8)
    assert np.all(gf_mul_np(a, gf_inv_np(a)) == 1)
    # distributivity over a sample
    rng = np.random.default_rng(0)
    x, y, z = (rng.integers(0, 256, 100).astype(np.uint8) for _ in range(3))
    assert np.all(gf_mul_np(x, y ^ z) == (gf_mul_np(x, y) ^ gf_mul_np(x, z)))


@pytest.mark.parametrize("m,k", [(2, 10), (4, 4), (1, 2), (6, 12)])
@pytest.mark.parametrize("L", [1, 100, 1024, 2048 + 77])
def test_kernel_matches_oracle(m, k, L):
    rng = np.random.default_rng(m * 1000 + k * 10 + L)
    G = rng.integers(0, 256, (m, k)).astype(np.uint8)
    X = rng.integers(0, 256, (k, L)).astype(np.uint8)
    want = gf_matmul_np(G, X)
    ref = np.asarray(gf256_matmul_ref(G, X))
    pal = np.asarray(gf256_matmul_pallas(G, X, interpret=True))
    assert np.array_equal(ref, want)
    assert np.array_equal(pal, want)


def test_cauchy_rows_invertible_property():
    """Every k x k submatrix of [I; C] must be invertible (MDS)."""
    from itertools import combinations
    from repro.kernels.rs_gf256.ref import gf_inv_matrix_np
    k, p = 4, 2
    G = np.concatenate([np.eye(k, dtype=np.uint8),
                        cauchy_parity_matrix(k, p)], 0)
    for rows in combinations(range(k + p), k):
        gf_inv_matrix_np(G[list(rows)])   # raises if singular
