"""Whole-store linearizability-ish property test: random op sequences
(put / get / provider failure / clock advance / gc) against a dict model.
The store must never return stale or corrupt data."""
import numpy as np
from _hypothesis_compat import (HealthCheck, given, settings,
                                strategies as st)

from repro.core import Clock, InfiniStore, StoreConfig
from repro.core.ec import ECConfig
from repro.core.gc_window import GCConfig

KEYS = ["a", "b", "c"]

op = st.one_of(
    st.tuples(st.just("put"), st.sampled_from(KEYS),
              st.integers(1, 40_000)),
    st.tuples(st.just("get"), st.sampled_from(KEYS), st.just(0)),
    st.tuples(st.just("fail"), st.integers(0, 10), st.just(0)),
    st.tuples(st.just("tick"), st.integers(1, 30), st.just(0)),
)


@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(ops=st.lists(op, min_size=1, max_size=30),
       seed=st.integers(0, 1000))
def test_store_matches_model(ops, seed):
    clock = Clock()
    cfg = StoreConfig(ec=ECConfig(k=2, p=1),
                      function_capacity=2 * 1024 * 1024,
                      gc=GCConfig(gc_interval=20.0, active_intervals=2,
                                  degraded_intervals=2),
                      num_recovery_functions=2)
    store = InfiniStore(cfg, clock=clock, seed=seed)
    model = {}
    rng = np.random.default_rng(seed)
    for kind, a, b in ops:
        if kind == "put":
            data = rng.bytes(b)
            ver = store.put(a, data)
            assert ver == len([k for k in model if k == a]) \
                or ver >= 1            # version monotonic
            model[a] = data
        elif kind == "get":
            got = store.get(a)
            want = model.get(a)
            assert got == want, (
                f"stale/corrupt read for {a}: "
                f"got {None if got is None else len(got)}B, "
                f"want {None if want is None else len(want)}B")
        elif kind == "fail":
            fids = sorted(store.sms.slabs)
            if fids:
                store.inject_failure(fids[a % len(fids)])
        else:  # tick
            clock.advance(float(a))
            store.gc_tick()
    # closing sweep: every object still readable despite failures + GC
    for k, want in model.items():
        assert store.get(k) == want
