"""Versioning + persistent buffer (paper Appendix A) semantics."""
import threading

import numpy as np
import pytest

from repro.core import Clock, ConcurrentPutError, InfiniStore, StoreConfig
from repro.core.ec import ECConfig
from repro.core.gc_window import GCConfig
from repro.core.versioning import MetadataTable, MetaStatus, PersistentBuffer


def test_cas_versions_monotonic():
    mt = MetadataTable()
    c1 = mt.prepare("k")
    m, ok = mt.cas("k", c1)
    assert ok and m.ver == 1
    c1.done(True)
    c2 = mt.prepare("k")
    m, ok = mt.cas("k", c2)
    assert not ok                      # must revise to ver 2 first
    c2.revise(m.ver + 1)
    m, ok = mt.cas("k", c2)
    assert ok and m.ver == 2 and m.prev_ver == 1


def test_pending_blocks_new_cas():
    mt = MetadataTable()
    c1 = mt.prepare("k")
    mt.cas("k", c1)                    # still PENDING
    c2 = mt.prepare("k")
    m, ok = mt.cas("k", c2)
    assert not ok and m is c1 and not m.is_done()


def test_persistent_buffer_read_after_write():
    pb = PersistentBuffer()
    pb.create("k|1", b"payload")
    assert pb.load("k|1") == b"payload"
    pb.release("k|1")
    assert pb.load("k|1") is None
    assert pb.hits == 1


def test_store_updates_create_versions(tiny_store):
    st, _ = tiny_store
    assert st.put("x", b"v1" * 100) == 1
    assert st.put("x", b"v2" * 100) == 2
    assert st.get("x") == b"v2" * 100


def test_concurrent_put_raises_retry(tiny_store):
    st, _ = tiny_store
    st.put("x", b"base")
    # simulate an in-flight PUT by inserting a PENDING head
    c = st.mt.prepare("x", 1)
    c.revise(2)
    st.mt.cas("x", c)

    def finish():
        c.done(True)

    t = threading.Timer(0.05, finish)
    t.start()
    with pytest.raises(ConcurrentPutError):
        st.put("x", b"conflict")
    t.join()


def test_consistency_increasing_cos_read():
    """The SCFS-style retry loop must mask COS visibility lag."""
    clock = Clock()
    cfg = StoreConfig(ec=ECConfig(k=2, p=1),
                      function_capacity=4 * 1024 * 1024,
                      gc=GCConfig(gc_interval=10.0),
                      cos_visibility_lag=5.0)
    st = InfiniStore(cfg, clock=clock)
    st.cos.put("chunk/z", b"lagged")
    assert st.cos.get("chunk/z") is None          # not yet visible
    assert st._cos_read_consistent("chunk/z") == b"lagged"


def test_get_after_total_reclaim_with_lag():
    """Everything reclaimed + laggy COS: GET still returns the payload
    (recovery replays insertion logs through the consistency loop)."""
    clock = Clock()
    cfg = StoreConfig(ec=ECConfig(k=2, p=1),
                      function_capacity=4 * 1024 * 1024,
                      gc=GCConfig(gc_interval=10.0, active_intervals=1,
                                  degraded_intervals=1),
                      cos_visibility_lag=5.0)
    st = InfiniStore(cfg, clock=clock)
    payload = np.random.default_rng(0).bytes(5000)
    st.put("y", payload)
    clock.advance(6.0)                            # COS writes visible
    for slab in st.sms.slabs.values():
        slab.reclaim()
    assert st.get("y") == payload
