"""Deterministic fault-injection plane + unified retry policy
(`repro.core.faults`) and the degradation machinery built on it:
FaultPlan schedule semantics and seed-reproducibility, RetryPolicy
classification/backoff/deadlines, writeback DEGRADED_WRITEBACK
enter/heal, permanent-failure surfacing through store health, spill
async-writer error propagation, torn-close tails, slab kills, and
OpDeadlineExceeded surfaced through GET futures."""
import threading
import time

import numpy as np
import pytest

from repro.core import (Clock, InfiniStore, StoreConfig,
                        COSThrottleError, FaultPlan, FaultPoint,
                        OpDeadlineExceeded, RetryPolicy,
                        TransientCOSError)
from repro.core.ec import ECConfig
from repro.core.faults import InjectedFault
from repro.core.gc_window import GCConfig
from repro.core.sms import Slab
from repro.core.spill import SpillJournal
from repro.core.writeback import WritebackQueue

MB = 1024 * 1024


def make_store(*, faults=None, **kw):
    kw.setdefault("ec", ECConfig(k=4, p=2))
    kw.setdefault("function_capacity", 8 * MB)
    kw.setdefault("fragment_bytes", 1 * MB)
    kw.setdefault("gc", GCConfig(gc_interval=1e9))
    kw.setdefault("num_recovery_functions", 4)
    clock = Clock()
    return InfiniStore(StoreConfig(faults=faults, **kw), clock=clock), clock


# ---------------------------------------------------------------------------
# FaultPoint / FaultPlan schedule semantics
# ---------------------------------------------------------------------------

def test_fault_point_hits_every_after_times():
    plan = FaultPlan(seed=7)
    plan.add(FaultPoint(site="a", action="transient", hits=(2, 5)))
    outcomes = []
    for _ in range(6):
        try:
            plan.fire("a")
            outcomes.append(None)
        except TransientCOSError:
            outcomes.append("boom")
    assert outcomes == [None, "boom", None, None, "boom", None]

    plan = FaultPlan().add(FaultPoint(site="b", every=3))
    fires = [i for i in range(1, 10)
             if _fires(plan, "b")]
    assert fires == [3, 6, 9]

    plan = FaultPlan().add(FaultPoint(site="c", after=4, times=2))
    fires = [i for i in range(1, 10) if _fires(plan, "c")]
    assert fires == [5, 6]                      # `times` caps total fires
    assert plan.fired("c") == 2
    assert plan.fired() == 2


def _fires(plan, site, key=""):
    try:
        return plan.fire(site, key) is not None
    except Exception:                           # noqa: BLE001
        return True


def test_fault_plan_prob_deterministic_across_runs_and_threads():
    def trigger_hits(threads):
        plan = FaultPlan(seed=42)
        plan.add(FaultPoint(site="s", action="transient", prob=0.3))
        if threads == 1:
            for _ in range(400):
                _fires(plan, "s")
        else:
            def worker(n):
                for _ in range(n):
                    _fires(plan, "s")
            ts = [threading.Thread(target=worker, args=(50,))
                  for _ in range(8)]
            for t in ts:
                t.start()
            for t in ts:
                t.join()
        return sorted(h for _, h, _ in plan.log)

    serial = trigger_hits(1)
    assert 40 < len(serial) < 200               # prob actually selective
    # the triggering hit-index SET is a pure function of the seed: the
    # same schedule triggers on the same indices even when 8 threads
    # race on which call draws which index
    assert trigger_hits(8) == serial
    assert trigger_hits(1) == serial            # and run-to-run

    other = FaultPlan(seed=43)
    other.add(FaultPoint(site="s", action="transient", prob=0.3))
    for _ in range(400):
        _fires(other, "s")
    assert sorted(h for _, h, _ in other.log) != serial


def test_fault_plan_match_filter_does_not_count_unmatched_keys():
    plan = FaultPlan().add(FaultPoint(site="s", hits=(1,), match="tgt"))
    plan.fire("s", "other-key")                 # filtered: consumes no hit
    assert plan.fired() == 0
    with pytest.raises(TransientCOSError):
        plan.fire("s", "the-tgt-key")           # first counted hit
    assert plan.log == [("s", 1, "transient")]


def test_fault_plan_advisory_actions_and_latency():
    slept = []
    plan = FaultPlan().add(FaultPoint(site="s", action="reclaim",
                                      hits=(1,), latency_s=0.25))
    plan._sleep = slept.append
    assert plan.fire("s") == "reclaim"          # returned, not raised
    assert slept == [0.25]
    assert plan.fire("s") is None
    snap = plan.snapshot()
    assert snap["fired"] == 1
    assert snap["log"] == [("s", 1, "reclaim")]


def test_fault_plan_unscheduled_site_is_free():
    plan = FaultPlan().add(FaultPoint(site="s", hits=(1,)))
    assert plan.fire("unscheduled") is None
    assert plan.fired() == 0                    # no hit consumed, no log
    with pytest.raises(ValueError):
        FaultPoint(site="s", action="segfault")


# ---------------------------------------------------------------------------
# RetryPolicy
# ---------------------------------------------------------------------------

def test_retry_policy_classification():
    p = RetryPolicy()
    assert p.classify(COSThrottleError("slow")) == RetryPolicy.THROTTLE
    assert p.classify(TransientCOSError("503")) == RetryPolicy.TRANSIENT
    assert p.classify(ConnectionError()) == RetryPolicy.TRANSIENT
    assert p.classify(TimeoutError()) == RetryPolicy.TRANSIENT
    assert p.classify(OSError(5, "eio")) == RetryPolicy.TRANSIENT
    assert p.classify(ValueError("corrupt")) == RetryPolicy.PERMANENT
    assert p.retryable(TransientCOSError(""))
    assert not p.retryable(KeyError("k"))


def test_retry_policy_delay_shape_and_determinism():
    p = RetryPolicy(backoff_base_s=0.01, backoff_cap_s=0.1, jitter=0.25,
                    seed=3)
    delays = [p.delay(a) for a in range(1, 8)]
    assert delays == [p.delay(a) for a in range(1, 8)]   # deterministic
    for a, d in enumerate(delays, start=1):
        ideal = min(0.01 * 2.0 ** (a - 1), 0.1)
        assert ideal * 0.75 <= d <= ideal * 1.25         # jitter bounded
    # throttle starts at the cap: the provider asked us to slow down
    assert p.delay(1, RetryPolicy.THROTTLE) >= 0.1 * 0.75
    assert RetryPolicy(jitter=0.0).delay(1) == 0.01


def test_retry_policy_run_success_and_permanent():
    p = RetryPolicy(max_attempts=5)
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise TransientCOSError("blip")
        return "ok"

    assert p.run(flaky, sleep=lambda s: None) == "ok"
    assert len(calls) == 3

    calls.clear()

    def broken():
        calls.append(1)
        raise ValueError("corrupt payload")

    with pytest.raises(ValueError):
        p.run(broken, sleep=lambda s: None)
    assert len(calls) == 1                      # permanent: never retried


def test_retry_policy_run_exhaustion_reraises_last():
    p = RetryPolicy(max_attempts=4)
    calls = []

    def always():
        calls.append(1)
        raise TransientCOSError(f"blip {len(calls)}")

    with pytest.raises(TransientCOSError, match="blip 4"):
        p.run(always, sleep=lambda s: None)
    assert len(calls) == 4


def test_retry_policy_deadline_raises_opdeadline():
    p = RetryPolicy(max_attempts=100, backoff_base_s=0.5,
                    backoff_cap_s=0.5, jitter=0.0)
    clk = [0.0]
    retried = []

    def sleep(s):
        clk[0] += s

    with pytest.raises(OpDeadlineExceeded) as ei:
        p.run(lambda: (_ for _ in ()).throw(TransientCOSError("down")),
              deadline_s=1.2, sleep=sleep, now=lambda: clk[0],
              on_retry=lambda a, e: retried.append(a))
    assert isinstance(ei.value.__cause__, TransientCOSError)
    assert clk[0] <= 1.2                        # never slept past it
    assert len(retried) >= 1


# ---------------------------------------------------------------------------
# writeback: DEGRADED_WRITEBACK enter / heal, permanent failures
# ---------------------------------------------------------------------------

class _DictCOS:
    def __init__(self):
        self.data = {}

    def put(self, key, data):
        self.data[key] = data

    def get(self, key):
        return self.data.get(key)


def test_writeback_degraded_enters_and_heals():
    plan = FaultPlan(seed=1).add(
        FaultPoint(site="writeback.persist", action="transient",
                   after=0, times=5))
    cos = _DictCOS()
    wb = WritebackQueue(cos, start_thread=False, degraded_after=3,
                        faults=plan)
    wb.enqueue("chunk/x", b"payload")
    assert wb.health()["state"] == "OK"
    assert wb.flush(timeout=30.0)               # outage ends, write lands
    h = wb.health()
    assert h["state"] == "OK"                   # healed
    assert h["degraded_entries"] == 1
    assert h["recoveries"] == 1
    assert h["permanent_failures"] == 0         # outage burned no budget
    assert h["failed_keys"] == []
    assert cos.data["chunk/x"] == b"payload"
    assert wb.stats.retries == 5
    wb.close()


def test_writeback_throttle_counted_and_budget_frozen_in_outage():
    plan = FaultPlan(seed=2).add(
        FaultPoint(site="writeback.persist", action="throttle",
                   after=0, times=8))
    cos = _DictCOS()
    # max_retries far below the 8 injected failures: outside an outage
    # the write would permanently fail, inside one the budget is frozen
    wb = WritebackQueue(cos, start_thread=False, max_retries=2,
                        degraded_after=2, faults=plan)
    wb.enqueue("chunk/t", b"v")
    assert wb.flush(timeout=30.0)
    assert wb.stats.throttled == 8
    assert wb.stats.failures == 0
    assert cos.data["chunk/t"] == b"v"
    wb.close()


def test_writeback_permanent_failure_records_keys():
    plan = FaultPlan().add(
        FaultPoint(site="writeback.persist", action="crash", hits=(1,)))
    cos = _DictCOS()
    wb = WritebackQueue(cos, start_thread=False, faults=plan)
    wb.enqueue("chunk/dead", b"lost")
    wb.enqueue("chunk/ok", b"kept")
    assert wb.flush(timeout=30.0) is False      # a write failed out
    h = wb.health()
    assert h["permanent_failures"] == 1
    assert h["failed_keys"] == ["chunk/dead"]
    assert wb.errors() and "chunk/dead" in wb.errors()[0]
    assert cos.data == {"chunk/ok": b"kept"}
    wb.close(flush=False)


def test_store_health_surfaces_permanent_failures(caplog):
    # satellite: flush_writeback's False path names the at-risk keys
    plan = FaultPlan().add(
        FaultPoint(site="writeback.persist", action="crash", hits=(1,)))
    st, _ = make_store(faults=plan)
    st.writeback.pause()          # fail inside the flush barrier
    st.put("k", b"z" * 50_000)
    with caplog.at_level("WARNING", logger="repro.core.store"):
        assert st.flush_writeback(timeout=30.0) is False
    assert "permanently-failed" in caplog.text
    assert st.stats.writeback_permanent_failures == 1
    health = st.snapshot_metadata()["health"]
    assert health["writeback"]["permanent_failures"] == 1
    assert len(health["writeback"]["failed_keys"]) == 1
    assert st.get("k") == b"z" * 50_000         # slabs still serve it
    st.close(flush=False)


# ---------------------------------------------------------------------------
# spill journal: async-writer errors, torn close
# ---------------------------------------------------------------------------

def test_spill_async_writer_error_surfaces_original_type(tmp_path):
    plan = FaultPlan().add(
        FaultPoint(site="spill.io", action="oserror", hits=(1,)))
    j = SpillJournal(tmp_path / "j", sync_each=False, async_writer=True,
                     faults=plan)
    j.append("k", b"v")
    t0 = time.monotonic()
    with pytest.raises(OSError) as ei:          # the ORIGINAL type
        j.sync()
    assert isinstance(ei.value, InjectedFault)
    # the writer notifies the barrier on failure — no 50 ms poll ticks
    assert time.monotonic() - t0 < 1.0
    j.append("k2", b"v2")                       # journal still usable
    j.sync()
    j.close(reclaim=True)


def test_spill_torn_close_drops_only_unsynced_tail(tmp_path):
    plan = FaultPlan().add(
        FaultPoint(site="spill.torn_close", action="torn", hits=(1,)))
    j = SpillJournal(tmp_path / "j", sync_each=False, faults=plan)
    j.append("acked", b"a" * 100)
    j.sync()                                    # durability point
    j.append("unsynced", b"b" * 100)
    j.close(reclaim=False, hard=True)           # SIGKILL with a torn tail
    assert plan.fired("spill.torn_close") == 1
    j2 = SpillJournal(tmp_path / "j")
    pending = j2.take_pending()
    assert [k for _, k, _ in pending] == ["acked"]
    assert pending[0][2] == b"a" * 100          # acked frame intact
    j2.close(reclaim=True)


# ---------------------------------------------------------------------------
# SMS slab kills (function death mid-store / mid-load)
# ---------------------------------------------------------------------------

def test_slab_reclaim_advisory_mid_store_and_mid_load():
    plan = FaultPlan().add(
        FaultPoint(site="sms.store", action="reclaim", hits=(1,))).add(
        FaultPoint(site="sms.load", action="reclaim", hits=(2,)))
    slab = Slab(0, 1 * MB, Clock())
    slab.faults = plan
    assert slab.store("c0", b"x" * 100) is False    # died mid-store
    assert not slab.alive
    slab.invoke()                                   # cold restart
    assert slab.store("c1", b"y" * 100)
    assert slab.load("c1") == b"y" * 100
    assert slab.load("c1") is None                  # died mid-gather
    assert not slab.alive


def test_store_survives_slab_kill_during_put():
    # one slab dies mid-PUT; the chunk is re-placed or served from the
    # persistent buffer/COS — the PUT still acks and the data reads back
    plan = FaultPlan(seed=9).add(
        FaultPoint(site="sms.store", action="reclaim", hits=(3,)))
    st, _ = make_store(faults=plan)
    rng = np.random.default_rng(0)
    vals = {f"k{i}": rng.bytes(40_000) for i in range(8)}
    for k, v in vals.items():
        assert st.put(k, v) >= 1
    assert plan.fired("sms.store") == 1
    for k, v in vals.items():
        assert st.get(k) == v
    st.close()


# ---------------------------------------------------------------------------
# per-op deadlines surfaced through the async API
# ---------------------------------------------------------------------------

def test_get_deadline_surfaces_opdeadline_through_future():
    plan = FaultPlan().add(
        FaultPoint(site="cos.get", action="transient", after=0,
                   match="chunk/"))
    st, _ = make_store(faults=plan, enable_recovery=False,
                       cos_op_deadline_s=0.05)
    st.put("k", b"q" * 50_000)
    st.flush_writeback()
    for fid in list(st.sms.slabs):              # force the COS read path
        st.inject_failure(fid)
    fut = st.get_async("k")
    with pytest.raises(OpDeadlineExceeded):
        fut.result(timeout=30.0)
    assert isinstance(fut.exception(), OpDeadlineExceeded)
    st.close(flush=False)


def test_disabled_plane_leaves_layers_unwired():
    st, _ = make_store(faults=None)
    assert st.cos.faults is None
    assert st.sms.faults is None
    assert st.writeback.faults is None
    st.put("k", b"v" * 10_000)
    assert st.get("k") == b"v" * 10_000
    st.close()
