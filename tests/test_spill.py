"""Crash-consistent writeback spill journal (paper §5.3.2 durability):
SpillJournal framing/truncation/rotation unit semantics, and the
store-level kill/restart contract — a daemon crash between ack and COS
persistence must lose nothing once the store is rebuilt on the same
spill_dir, including when the crash tore the tail record."""
import json
import os

import numpy as np
import pytest

from repro.core import Clock, InfiniStore, StoreConfig
from repro.core.ec import ECConfig
from repro.core.gc_window import GCConfig
from repro.core.spill import SpillJournal

MB = 1024 * 1024


def make_store(spill_dir, **kw):
    cfg = StoreConfig(ec=ECConfig(k=4, p=2),
                      function_capacity=8 * MB,
                      fragment_bytes=1 * MB,
                      gc=GCConfig(gc_interval=1e9),
                      num_recovery_functions=4,
                      spill_dir=spill_dir, **kw)
    return InfiniStore(cfg, clock=Clock())


def newest_segment(d):
    segs = sorted(p for p in os.listdir(d) if p.endswith(".wal"))
    assert segs, f"no segments in {d}"
    return os.path.join(d, segs[-1])


# ---------------------------------------------------------------------------
# SpillJournal unit semantics
# ---------------------------------------------------------------------------

def test_journal_append_replay_roundtrip(tmp_path):
    j = SpillJournal(tmp_path)
    s1 = j.append("a", b"payload-a")
    s2 = j.append("b", np.frombuffer(b"payload-b", np.uint8))  # array path
    assert s2 > s1
    j.close(reclaim=False)
    j2 = SpillJournal(tmp_path)
    got = j2.take_pending()
    assert [(k, bytes(p)) for _, k, p in got] == \
        [("a", b"payload-a"), ("b", b"payload-b")]
    assert j2.stats.replayed_records == 2


def test_journal_mark_persisted_truncates(tmp_path):
    j = SpillJournal(tmp_path)
    s1 = j.append("a", b"1")
    j.append("b", b"2")
    assert j.mark_persisted(s1)
    assert not j.mark_persisted(s1)              # idempotent no-op
    j.close(reclaim=False)
    j2 = SpillJournal(tmp_path)
    assert [k for _, k, _ in j2.take_pending()] == ["b"]


def test_journal_fully_persisted_reclaims_disk(tmp_path):
    j = SpillJournal(tmp_path)
    seqs = [j.append(f"k{i}", b"x" * 1000) for i in range(4)]
    for s in seqs:
        j.mark_persisted(s)
    # nothing live: the active segment is truncated in place
    assert j.pending_count == 0
    assert os.path.getsize(newest_segment(tmp_path)) == 0
    j.close()                                    # graceful: files deleted
    assert [p for p in os.listdir(tmp_path) if p.endswith(".wal")] == []


def test_journal_torn_tail_rejected_by_checksum(tmp_path):
    j = SpillJournal(tmp_path)
    j.append("good", b"g" * 500)
    j.append("torn", b"t" * 500)
    j.close(reclaim=False)
    seg = newest_segment(tmp_path)
    with open(seg, "r+b") as f:                  # crash mid-append: tear
        f.truncate(os.path.getsize(seg) - 7)     # the tail record
    j2 = SpillJournal(tmp_path)
    assert [k for _, k, _ in j2.take_pending()] == ["good"]
    assert j2.stats.torn_records == 1


def test_journal_corrupt_payload_rejected_by_crc(tmp_path):
    j = SpillJournal(tmp_path)
    j.append("k", b"A" * 256)
    j.close(reclaim=False)
    seg = newest_segment(tmp_path)
    with open(seg, "r+b") as f:                  # flip one payload byte
        f.seek(os.path.getsize(seg) - 10)
        f.write(b"Z")
    j2 = SpillJournal(tmp_path)
    assert j2.take_pending() == []
    assert j2.stats.torn_records == 1


def test_journal_segment_rotation_and_reclaim(tmp_path):
    j = SpillJournal(tmp_path, segment_bytes=4096, compact_below=0)
    seqs = [j.append(f"k{i}", b"d" * 2000) for i in range(8)]
    assert j.stats.segments_created >= 3         # rotated several times
    for s in seqs[:6]:
        j.mark_persisted(s)
    assert j.stats.segments_reclaimed >= 2       # drained segments deleted
    j.close(reclaim=False)
    j2 = SpillJournal(tmp_path)
    assert [k for _, k, _ in j2.take_pending()] == ["k6", "k7"]


def test_journal_same_key_append_supersedes(tmp_path):
    j = SpillJournal(tmp_path)
    j.append("k", b"v1")
    j.append("k", b"v2")
    assert j.pending_count == 1
    j.close(reclaim=False)
    j2 = SpillJournal(tmp_path)
    assert [(k, bytes(p)) for _, k, p in j2.take_pending()] == [("k", b"v2")]


def test_journal_compaction_rewrites_pinned_segment(tmp_path):
    # a sealed segment pinned by one tiny live record is rewritten into
    # the active segment and its file reclaimed
    j = SpillJournal(tmp_path, segment_bytes=4096, compact_below=200)
    big = [j.append("big0", b"B" * 1800), j.append("big1", b"B" * 1800)]
    j.append("tiny", b"t" * 16)
    big.append(j.append("big2", b"B" * 1800))    # crosses 4096: seals seg 1
    for s in big:
        j.mark_persisted(s)                      # leaves tiny pinning it
    assert j.stats.segments_compacted >= 1
    j.close(reclaim=False)
    j2 = SpillJournal(tmp_path)
    assert [(k, bytes(p)) for _, k, p in j2.take_pending()] == \
        [("tiny", b"t" * 16)]


def test_journal_crash_right_after_compaction_keeps_live_records(tmp_path):
    """Compaction must flush the copied frames BEFORE unlinking the
    sealed source: in group-commit mode nothing else flushes until the
    next sync() barrier, so a crash immediately after a compaction
    would otherwise lose the pinned (acked) record entirely."""
    j = SpillJournal(tmp_path, segment_bytes=4096, compact_below=200,
                     sync_each=False)
    big = [j.append("big0", b"B" * 1800), j.append("big1", b"B" * 1800)]
    j.append("tiny", b"t" * 16)
    big.append(j.append("big2", b"B" * 1800))    # crosses 4096: seals seg 1
    j.sync()                                     # the ack barrier
    for s in big:
        j.mark_persisted(s)                      # drains seg 1 down to tiny
    assert j.stats.segments_compacted >= 1       # ... which compacted
    j.close(reclaim=False, hard=True)            # SIGKILL right here
    j2 = SpillJournal(tmp_path)
    assert [(k, bytes(p)) for _, k, p in j2.take_pending()] == \
        [("tiny", b"t" * 16)]


def test_journal_dir_locked_against_concurrent_journal(tmp_path):
    """A restart racing a not-yet-dead daemon on the same spill_dir must
    fail fast, not corrupt the journal; close releases the lock."""
    j = SpillJournal(tmp_path)
    j.append("k", b"v")
    with pytest.raises(RuntimeError, match="locked"):
        SpillJournal(tmp_path)
    j.close(reclaim=False)
    j2 = SpillJournal(tmp_path)                  # lock released on close
    assert [k for _, k, _ in j2.take_pending()] == ["k"]
    j2.close()


def test_journal_hard_close_releases_dir_lock(tmp_path):
    """The crash-simulation close must release the lock the way real
    process death would, so the kill/restart tests (and real restarts)
    can rebuild on the same directory."""
    j = SpillJournal(tmp_path)
    j.append("k", b"v")
    j.close(reclaim=False, hard=True)
    j2 = SpillJournal(tmp_path)
    assert [k for _, k, _ in j2.take_pending()] == ["k"]
    j2.close()


def test_journal_hard_close_discards_unsynced_tail(tmp_path):
    """Group-commit crash realism: frames appended after the last sync()
    barrier live in the writer buffer; a hard close (SIGKILL stand-in)
    must lose exactly those — and only those."""
    j = SpillJournal(tmp_path, sync_each=False)
    j.append("acked", b"A" * 500)
    j.sync()                                     # the ack barrier
    j.append("unacked", b"U" * 500)              # buffered, never synced
    j.close(reclaim=False, hard=True)
    j2 = SpillJournal(tmp_path)
    assert [k for _, k, _ in j2.take_pending()] == ["acked"]


# ---------------------------------------------------------------------------
# store-level kill/restart durability
# ---------------------------------------------------------------------------

def put_acked_unpersisted(st, n=5, nbytes=150_000, seed=0):
    """Acked writes held pre-persistence: pause the writer, PUT, verify
    COS is empty."""
    st.writeback.pause()
    rng = np.random.default_rng(seed)
    objs = {f"k{i}": rng.bytes(nbytes) for i in range(n)}
    for k, v in objs.items():
        assert st.put(k, v) == 1                 # ack point
    assert st.cos.list_keys("chunk/") == []      # nothing persisted
    return objs


def test_daemon_crash_loses_no_acked_writes(tmp_path):
    st = make_store(str(tmp_path))
    objs = put_acked_unpersisted(st)
    spill_dir = st.simulate_crash()              # queue + daemon dropped
    st2 = make_store(spill_dir)
    assert st2.stats.spill_replayed_writes > 0
    assert st2.stats.spill_replayed_metas == len(objs)
    # replayed pending data serves post-restart GETs like live pending
    for k, v in objs.items():
        assert st2.get(k) == v, f"lost {k} across daemon restart"
    # ... and eventually becomes COS-persistent
    assert st2.flush_writeback(timeout=30.0)
    assert len(st2.cos.list_keys("chunk/")) == len(objs) * st2.cfg.ec.n
    for k, v in objs.items():
        assert st2.get(k) == v
    st2.close()


def test_daemon_crash_with_torn_tail_record(tmp_path):
    """A torn tail frame is rejected by checksum and costs AT MOST the
    final PUT — the one whose frames a real crash could actually tear
    mid-append, i.e. one that never acked (the ack-point sync() flushes
    every frame first). All earlier acked PUTs replay intact."""
    st = make_store(str(tmp_path))
    objs = put_acked_unpersisted(st)             # k0..k4, journaled order
    spill_dir = st.simulate_crash()
    seg = newest_segment(spill_dir)
    with open(seg, "r+b") as f:                  # tear into the tail: the
        f.truncate(os.path.getsize(seg) - 13)    # last PUT's meta frame
    st2 = make_store(spill_dir)
    assert st2.spill.stats.torn_records == 1
    for k, v in objs.items():
        if k == "k4":
            continue                             # the torn-into PUT
        assert st2.get(k) == v, f"lost {k} to the torn tail"
    # the torn PUT is dropped CLEANLY: no half-restored version
    assert st2.get("k4") is None
    assert st2.flush_writeback(timeout=30.0)
    for k, v in objs.items():
        if k != "k4":
            assert st2.get(k) == v
    st2.close()


def test_replayed_pending_feeds_recovery_download(tmp_path):
    """RecoveryManager._download must see replayed pending chunks (the
    pending map read-through) exactly like live pending chunks."""
    st = make_store(str(tmp_path))
    put_acked_unpersisted(st, n=2)
    pending = [k[len("chunk/"):] for k in st.writeback.pending_keys()
               if k.startswith("chunk/")]
    spill_dir = st.simulate_crash()
    # hold the new store's writer from the instant replay fills the
    # queue, so nothing persists before the assertion (determinism)
    orig = InfiniStore._replay_spill

    def pause_then_replay(self):
        self.writeback.pause()
        orig(self)
    InfiniStore._replay_spill = pause_then_replay
    try:
        st2 = make_store(spill_dir)
    finally:
        InfiniStore._replay_spill = orig
    got = st2.recovery._download(pending)
    assert set(got) == set(pending)              # COS has none of these
    assert st2.cos.list_keys("chunk/") == []
    st2.writeback.resume()
    st2.close(flush=False)


def test_graceful_close_then_restart_serves_from_cos(tmp_path):
    """Metadata records outlive chunk persistence: after flush + close,
    a store rebuilt on the same spill_dir + cos_root resolves the object
    from the journaled metadata and reads chunks back from COS."""
    spill_dir, cos_root = str(tmp_path / "spill"), str(tmp_path / "cos")
    cfg = StoreConfig(ec=ECConfig(k=4, p=2), function_capacity=8 * MB,
                      fragment_bytes=1 * MB, gc=GCConfig(gc_interval=1e9),
                      num_recovery_functions=4, spill_dir=spill_dir)
    st = InfiniStore(cfg, clock=Clock(), cos_root=cos_root)
    data = np.random.default_rng(3).bytes(200_000)
    st.put("x", data)
    assert st.close()                            # flushes, keeps metadata
    cfg2 = StoreConfig(ec=ECConfig(k=4, p=2), function_capacity=8 * MB,
                       fragment_bytes=1 * MB, gc=GCConfig(gc_interval=1e9),
                       num_recovery_functions=4, spill_dir=spill_dir)
    st2 = InfiniStore(cfg2, clock=Clock(), cos_root=cos_root)
    assert st2.stats.spill_replayed_metas == 1
    assert st2.stats.spill_replayed_writes == 0  # all chunks persisted
    assert st2.cos.exists("chunk/x|1/f0#0")      # restart adoption
    assert st2.get("x") == data                  # COS fallback reads
    st2.close()


def test_daemon_crash_right_after_compaction_resolves_all_versions(tmp_path):
    """After a full writeback flush, the small journaled metadata
    records pin sealed segments and get compacted into the active one; a
    crash immediately afterwards must still resolve every acked object
    version on restart (the compacted copy must be durable before the
    sealed source is destroyed)."""
    spill_dir, cos_root = str(tmp_path / "spill"), str(tmp_path / "cos")

    def cfg():
        return StoreConfig(ec=ECConfig(k=4, p=2),
                           function_capacity=8 * MB, fragment_bytes=1 * MB,
                           gc=GCConfig(gc_interval=1e9),
                           num_recovery_functions=4, spill_dir=spill_dir,
                           spill_segment_bytes=64 * 1024)
    st = InfiniStore(cfg(), clock=Clock(), cos_root=cos_root)
    rng = np.random.default_rng(11)
    objs = {f"k{i}": rng.bytes(150_000) for i in range(4)}
    for k, v in objs.items():
        assert st.put(k, v) == 1
    assert st.flush_writeback(timeout=30.0)       # chunk records truncate,
    assert st.spill.stats.segments_compacted >= 1  # metas compact forward
    st.simulate_crash()                           # SIGKILL right here
    st2 = InfiniStore(cfg(), clock=Clock(), cos_root=cos_root)
    assert st2.stats.spill_replayed_metas == len(objs)
    for k, v in objs.items():
        assert st2.get(k) == v, f"{k} unresolvable after post-compaction crash"
    st2.close()


def test_flush_truncates_chunk_records(tmp_path):
    st = make_store(str(tmp_path))
    st.put("x", b"q" * 200_000)
    assert st.flush_writeback(timeout=30.0)
    # only the live version's metadata record stays journaled
    assert st.spill.pending_keys() == ["meta/x|1"]
    st.close(flush=False)


def test_version_supersession_truncates_old_meta(tmp_path):
    st = make_store(str(tmp_path))
    st.writeback.pause()
    st.put("k", b"a" * 50_000)
    st.put("k", b"b" * 50_000)                   # supersedes version 1
    metas = [k for k in st.spill.pending_keys() if k.startswith("meta/")]
    assert metas == ["meta/k|2"]                 # v1 meta truncated
    spill_dir = st.simulate_crash()
    st2 = make_store(spill_dir)
    assert st2.get("k") == b"b" * 50_000         # newest version wins
    assert st2.flush_writeback(timeout=30.0)
    assert st2.get("k") == b"b" * 50_000
    st2.close(flush=False)


def test_replay_redrops_superseded_meta_resurrected_by_torn_persist(tmp_path):
    """meta/k|2's APPEND lands before the PERSIST frame that truncates
    meta/k|1, so a tail tear can resurrect BOTH on replay. The live put
    path only ever truncates the head's direct predecessor, so the
    restored v1 record must be re-dropped at replay or it pins its
    segment (and is replayed, and re-compacted) forever."""
    st = make_store(str(tmp_path))
    st.writeback.pause()
    st.put("k", b"a" * 50_000)
    seq1 = {r.key: s for s, r in st.spill._records.items()}["meta/k|1"]
    st.put("k", b"b" * 50_000)                   # supersedes: PERSIST(seq1)
    spill_dir = st.simulate_crash()
    seg = newest_segment(spill_dir)
    with open(seg, "rb") as f:
        data = f.read()
    frames, off = [], 0
    while off < len(data):                       # locate that PERSIST
        fr = SpillJournal._parse_frame(data, off)
        assert fr is not None
        frames.append((off,) + fr)
        off += fr[-1]
    (t_off,) = [o for o, rtype, seq, *_ in frames if rtype == 2
                and seq == seq1]
    meta2 = [o for o, rtype, _, key, *_ in frames if rtype == 1
             and key == "meta/k|2"]
    assert meta2 and meta2[0] < t_off            # v2 survives the tear
    with open(seg, "r+b") as f:
        f.truncate(t_off)                        # tear from the PERSIST on
    st2 = make_store(spill_dir)
    metas = [k for k in st2.spill.pending_keys() if k.startswith("meta/")]
    assert metas == ["meta/k|2"]                 # resurrected v1 re-dropped
    assert st2.get("k") == b"b" * 50_000
    assert st2.flush_writeback(timeout=30.0)
    spill_dir2 = st2.simulate_crash()
    st3 = make_store(spill_dir2)                 # ... and never comes back
    assert st3.stats.spill_replayed_metas == 1
    assert st3.get("k") == b"b" * 50_000
    st3.close(flush=False)


def test_meta_journals_after_payload_frames(tmp_path):
    """Ordering regression: the meta record must be appended AFTER its
    version's fragment/stub frames, so a torn tail can never restore a
    head version whose data frames were lost (which would shadow the
    older durable version)."""
    st = make_store(str(tmp_path))
    st.writeback.pause()
    st.put("k", b"d" * 100_000)
    seq_of = {r.key: s for s, r in st.spill._records.items()}
    payload_seqs = [s for k, s in seq_of.items()
                    if k.startswith(("frag/", "chunk/"))]
    assert payload_seqs and seq_of["meta/k|1"] > max(payload_seqs)
    st.writeback.resume()
    st.close()


def test_mixed_failure_batch_keeps_surviving_frag_records(tmp_path):
    """Regression: a batch where ONE key's fragment fails must kill only
    that fragment's journal records — the surviving key's fragment
    payload record stays live (else a crash loses acked data)."""
    from repro.core.sms import Slab
    st = make_store(str(tmp_path))
    st.writeback.pause()
    orig = Slab.store

    def selective(self, key, data):
        if isinstance(key, str) and key.startswith("bad|"):
            return False                         # slab refuses bad's chunks
        return orig(self, key, data)
    Slab.store = selective
    try:
        out = st.put_many({"good": b"g" * 100_000, "bad": b"b" * 100_000})
    finally:
        Slab.store = orig
    assert out["good"] == 1 and out["bad"] == -1
    keys = st.spill.pending_keys()
    assert "frag/good|1/f0" in keys              # survivor journaled
    assert "meta/good|1" in keys
    assert "frag/bad|1/f0" not in keys           # failed fragment dead
    assert not any(k.startswith("chunk/bad|") for k in keys)
    assert not any(k.startswith("meta/bad") for k in keys)
    # and the survivor replays after a crash
    spill_dir = st.simulate_crash()
    st2 = make_store(spill_dir)
    assert st2.get("good") == b"g" * 100_000
    st2.close(flush=False)


def test_spill_dir_none_restores_memory_only_behavior():
    st = make_store(None)
    assert st.spill is None and st.spill_dir is None
    assert st.writeback.spill is None
    st.writeback.pause()
    st.put("x", b"m" * 100_000)
    assert st.get("x") == b"m" * 100_000         # pending map still serves
    st.writeback.resume()
    assert st.flush_writeback(timeout=30.0)
    st.close()


def test_auto_spill_dir_created_and_reclaimed_on_close():
    st = make_store("auto")
    d = st.spill_dir
    assert d is not None and os.path.isdir(d)
    st.put("x", b"z" * 50_000)
    st.close()
    assert not os.path.exists(d)                 # tempdir reclaimed


def test_ack_journals_before_return(tmp_path):
    """The durability point: by the time put() returns, the journal
    holds the object's metadata and every chunk + log write."""
    st = make_store(str(tmp_path))
    st.writeback.pause()
    st.put("obj", b"d" * 120_000)
    keys = st.spill.pending_keys()
    assert "meta/obj|1" in keys
    assert sum(k.startswith("chunk/obj|1") for k in keys) == st.cfg.ec.n
    assert any(k.startswith("ilog/") for k in keys)
    st.writeback.resume()
    assert st.flush_writeback(timeout=30.0)
    st.close()


def test_failed_writeback_stays_journaled(tmp_path):
    """A write that exhausts its retries keeps its journal record — the
    restart, not /dev/null, owns it."""
    st = make_store(str(tmp_path), writeback_retries=1)
    st.writeback.pause()
    st.put("x", b"w" * 100_000)
    boom = RuntimeError("simulated COS outage")

    def failing_put(key, data):
        raise boom
    st.cos.put = failing_put
    st.writeback.resume()
    assert st.flush_writeback(timeout=30.0) is False
    assert st.writeback.stats.failures > 0
    keys = st.spill.pending_keys()
    # the fragment payload stays journaled (its buffer entry never
    # drained), and every failed queue task keeps its own record
    assert "frag/x|1/f0" in keys
    failed = [k for k in keys if not k.startswith(("meta/", "frag/"))]
    assert len(failed) == st.writeback.stats.failures
    st.close(flush=False)


def test_snapshot_metadata_surfaces_spill(tmp_path):
    st = make_store(str(tmp_path))
    st.put("x", b"s" * 50_000)
    snap = st.snapshot_metadata()["spill"]
    assert snap["appends"] > 0
    assert snap["dir"] == str(tmp_path)
    st.close()
    assert make_store(None).snapshot_metadata()["spill"] is None


# ---------------------------------------------------------------------------
# size-bounded metadata log: snapshot + journal generations
# ---------------------------------------------------------------------------

def test_journal_rotate_is_a_generation_boundary(tmp_path):
    j = SpillJournal(tmp_path)
    g0 = j.generation
    j.append("a", b"1")
    assert j.rotate() == g0 + 1                  # forced seal + new segment
    assert j.rotate() == g0 + 1                  # empty active: no-op
    j.append("b", b"2")
    assert j.generation == g0 + 1
    j.close(reclaim=False)
    j2 = SpillJournal(tmp_path)                  # both generations replay
    assert [k for _, k, _ in j2.take_pending()] == ["a", "b"]
    j2.close(reclaim=False)


def test_meta_snapshot_caps_individual_records(tmp_path):
    """Once enough meta records accumulate, gc_tick folds them into ONE
    metasnap record at a fresh generation and truncates the originals."""
    st = make_store(str(tmp_path), spill_meta_snapshot_records=8)
    for i in range(12):
        st.put(f"k{i}", b"v" * 10_000)
    assert st.flush_writeback(timeout=30.0)
    gen0 = st.spill.generation
    st.gc_tick()
    assert st.stats.spill_meta_snapshots == 1
    assert st.spill.generation > gen0            # new journal generation
    log = st.snapshot_metadata()["meta_log"]
    assert log["individual_records"] == 0        # all folded away
    assert log["snapshot_covered"] == 12
    keys = st.spill.pending_keys()
    assert "metasnap" in keys
    assert not any(k.startswith("meta/") for k in keys)
    st.close()


def test_meta_snapshot_survives_crash_restart(tmp_path):
    """Snapshot-covered metadata + post-snapshot tail records + pending
    writes all replay: zero acked loss for a long-lived daemon."""
    spill = str(tmp_path / "spill")
    cos_root = str(tmp_path / "cos")

    def mk():
        cfg = StoreConfig(ec=ECConfig(k=4, p=2), function_capacity=8 * MB,
                          fragment_bytes=1 * MB,
                          gc=GCConfig(gc_interval=1e9),
                          num_recovery_functions=4, spill_dir=spill,
                          spill_meta_snapshot_records=8)
        return InfiniStore(cfg, clock=Clock(), cos_root=cos_root)

    st = mk()
    vals = {}
    for i in range(20):                          # supersessions included
        k = f"k{i % 10}"
        vals[k] = bytes([i]) * 15_000
        st.put(k, vals[k])
    assert st.flush_writeback(timeout=30.0)
    st.gc_tick()
    assert st.stats.spill_meta_snapshots == 1
    for i in range(3):                           # tail: meta + tombstones
        k = f"k{i}"
        vals[k] = bytes([100 + i]) * 9_000
        st.put(k, vals[k])
    st.simulate_crash()
    st2 = mk()
    # the snapshot restored the covered table, tail records the rest
    assert st2.stats.spill_replayed_metas == 13
    for k, v in vals.items():
        assert st2.get(k) == v, f"lost {k} across snapshot restart"
    assert st2.flush_writeback(timeout=60.0)
    st2.close()


def test_meta_snapshot_tombstones_fold_at_next_generation(tmp_path):
    """A supersession of a snapshot-covered meta journals a tombstone
    (the snapshot copy cannot be individually truncated); the NEXT
    snapshot truncates the tombstones and the stale copies — and a
    restart never resurrects the superseded version."""
    spill = str(tmp_path / "spill")
    cos_root = str(tmp_path / "cos")

    def mk():
        cfg = StoreConfig(ec=ECConfig(k=4, p=2), function_capacity=8 * MB,
                          fragment_bytes=1 * MB,
                          gc=GCConfig(gc_interval=1e9),
                          num_recovery_functions=4, spill_dir=spill,
                          spill_meta_snapshot_records=6)
        return InfiniStore(cfg, clock=Clock(), cos_root=cos_root)

    st = mk()
    for i in range(8):
        st.put(f"k{i}", b"a" * 8_000)
    assert st.flush_writeback(timeout=30.0)
    st.gc_tick()                                 # snapshot #1 covers all
    assert st.stats.spill_meta_snapshots == 1
    st.put("k0", b"B" * 8_000)                   # tombstone for k0|1
    assert st.snapshot_metadata()["meta_log"]["tombstones"] == 1
    for i in range(6):
        st.put(f"m{i}", b"c" * 8_000)            # force snapshot #2
    assert st.flush_writeback(timeout=30.0)
    st.gc_tick()
    assert st.stats.spill_meta_snapshots == 2
    log = st.snapshot_metadata()["meta_log"]
    assert log["tombstones"] == 0                # folded away
    assert log["individual_records"] == 0
    st.simulate_crash()
    st2 = mk()
    assert st2.get("k0") == b"B" * 8_000         # head, not the stale v1
    m = st2.mt.load("k0")
    assert m is not None and m.ver == 2
    st2.close()


def test_meta_snapshot_disabled_keeps_pr4_baseline(tmp_path):
    st = make_store(str(tmp_path), spill_meta_snapshot_records=0)
    for i in range(20):
        st.put(f"k{i}", b"v" * 5_000)
    assert st.flush_writeback(timeout=30.0)
    st.gc_tick()
    assert st.stats.spill_meta_snapshots == 0
    assert sum(1 for k in st.spill.pending_keys()
               if k.startswith("meta/")) == 20   # retained until superseded
    st.close()


def test_replay_truncates_meta_superseded_by_snapshot(tmp_path):
    """Torn-PERSIST window: an individual `meta/` record AND a snapshot
    covering the same obj both survive a crash. Replay must truncate
    the stale individual record, or it pins its segment (and is
    re-replayed) forever."""
    j = SpillJournal(tmp_path)
    entry = {"key": "k", "ver": 1, "prev_ver": 0,
             "num_fragments": 1, "size": 0}
    j.append("meta/k|1", json.dumps(entry).encode())
    j.append("metasnap", json.dumps([entry]).encode())
    j.close(reclaim=False)
    st = make_store(str(tmp_path))
    assert st.stats.spill_replayed_metas == 2     # both restored (idempotent)
    keys = st.spill.pending_keys()
    assert "metasnap" in keys
    assert "meta/k|1" not in keys                 # stale record truncated
    st.close()
