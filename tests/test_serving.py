"""Serving engine: SMS-paged decode == plain decode; page lifecycle."""
import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core.clock import Clock
from repro.serving import ServeConfig, ServeEngine


def make_engine(clock=None):
    cfg = dataclasses.replace(reduced(get_config("qwen1.5-0.5b")),
                              dtype="float32")
    scfg = ServeConfig(batch_slots=2, max_len=64, page_size=8,
                       gc_interval=30.0)
    return ServeEngine(cfg, scfg, clock=clock or Clock())


def plain_generate(eng, prompts, n):
    m = eng.model
    logits, cache = m.prefill(eng.params, {"tokens": jnp.asarray(prompts)},
                              max_len=64)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    out = []
    for _ in range(n):
        lg, cache = m.decode_step(eng.params, {"token": tok}, cache)
        nt = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)
        out.append(np.asarray(nt))
        tok = nt[:, None]
    return np.stack(out, 1)


def test_engine_matches_plain_decode():
    eng = make_engine()
    prompts = np.random.default_rng(0).integers(
        0, eng.cfg.vocab_size, (2, 12)).astype(np.int32)
    got = eng.generate(prompts, 6)
    want = plain_generate(eng, prompts, 6)
    np.testing.assert_array_equal(got, want)


def test_page_lifecycle_release_and_resume():
    clock = Clock()
    eng = make_engine(clock)
    prompts = np.random.default_rng(1).integers(
        0, eng.cfg.vocab_size, (2, 12)).astype(np.int32)
    eng.generate(prompts, 4)
    assert eng.kv.stats.pages_allocated > 0
    # sequences done -> pages cool -> released + persisted to COS
    for _ in range(8):
        clock.advance(30.0)
        eng.kv.gc_tick()
    assert eng.kv.stats.pages_evicted_to_cos > 0
    # freed slots are reusable
    assert any(len(f) > 0 for f in eng.kv._free)
    # on-demand migration restores the sequence
    n = eng.resume("seq0", 0)
    assert n > 0
    assert eng.kv.stats.pages_restored == n


def test_active_sequences_stay_hot():
    """Pages touched each decode step must not be released mid-generation."""
    clock = Clock()
    eng = make_engine(clock)
    prompts = np.random.default_rng(2).integers(
        0, eng.cfg.vocab_size, (2, 12)).astype(np.int32)

    # interleave clock advances with generation via the gc hook
    orig_tick = eng.kv.gc_tick

    def tick_with_time():
        clock.advance(10.0)
        orig_tick()

    eng.kv.gc_tick = tick_with_time
    out = eng.generate(prompts, 8)
    want = plain_generate(make_engine(), prompts, 8)
    np.testing.assert_array_equal(out, want)
