"""End-to-end behaviour of the full InfiniStore system (paper §5/§6):
put/get under GC aging, provider reclamation, compaction, hit-ratio and
cost accounting — the system-level contract everything else builds on."""
import numpy as np
import pytest

from repro.core import BucketState, Clock, InfiniStore, StoreConfig
from repro.core.ec import ECConfig
from repro.core.gc_window import GCConfig


def make_system(visibility_lag=0.0):
    cfg = StoreConfig(
        ec=ECConfig(k=4, p=2),
        function_capacity=8 * 1024 * 1024,
        fragment_bytes=1024 * 1024,
        gc=GCConfig(gc_interval=10.0, active_intervals=2,
                    degraded_intervals=2, active_warmup=5.0,
                    degraded_warmup=20.0),
        num_recovery_functions=3,
        cos_visibility_lag=visibility_lag,
    )
    clock = Clock()
    return InfiniStore(cfg, clock=clock), clock


def test_roundtrip_small_and_large():
    st, _ = make_system()
    rng = np.random.default_rng(0)
    small = rng.bytes(10_000)
    large = rng.bytes(3 * 1024 * 1024)       # > fragment_bytes -> 3 frags
    st.put("small", small)
    st.put("large", large)
    assert st.get("small") == small
    assert st.get("large") == large
    assert st.stats.large_requests >= 1 and st.stats.small_requests >= 1


def test_chunks_spread_one_per_function():
    st, _ = make_system()
    st.put("o", b"q" * 100_000)
    fids = [st.chunk_map[f"o|1/f0#{i}"] for i in range(6)]
    assert len(set(fids)) == 6               # PlaceChunk guarantee


def test_working_set_capture_and_elastic_shrink():
    """Hot data survives GC via compaction; cold data ages out of SMS and
    is still readable via COS — the paper's elasticity claim.

    Note: cold data only leaves SMS once its FG SEALS (open FGs carry
    over across GCs, Fig. 4c), so the test fills the first FG to HARDCAP
    with filler objects."""
    cfg = StoreConfig(
        ec=ECConfig(k=4, p=2),
        function_capacity=1024 * 1024,       # small HARDCAP -> FGs seal
        gc=GCConfig(gc_interval=10.0, active_intervals=2,
                    degraded_intervals=2),
        num_recovery_functions=3,
    )
    clock = Clock()
    st = InfiniStore(cfg, clock=clock)
    rng = np.random.default_rng(1)
    hot = rng.bytes(200_000)
    cold = rng.bytes(200_000)
    st.put("hot", hot)
    st.put("cold", cold)
    for i in range(24):                      # filler seals the early FGs
        st.put(f"fill{i}", rng.bytes(200_000))
    st.flush_writeback()       # drain the buffer so GETs hit the slabs
    for i in range(6):
        clock.advance(10.0)
        _ = st.get("hot")                    # keep hot in the window
        st.gc_tick()
    hits_before = st.stats.sms_chunk_hits
    assert st.get("hot") == hot
    hot_hits = st.stats.sms_chunk_hits - hits_before
    assert hot_hits >= st.cfg.ec.k           # served from memory
    miss_before = st.stats.sms_chunk_misses
    assert st.get("cold") == cold            # COS on-demand migration
    assert st.stats.sms_chunk_misses > miss_before
    assert st.stats.compactions > 0


def test_survives_mass_reclamation():
    st, _ = make_system()
    rng = np.random.default_rng(2)
    objs = {f"k{i}": rng.bytes(50_000) for i in range(10)}
    for k, v in objs.items():
        st.put(k, v)
    for fid in list(st.sms.slabs):
        st.inject_failure(fid)               # provider reclaims EVERYTHING
    for k, v in objs.items():
        assert st.get(k) == v, f"lost {k} after mass reclamation"


def test_hit_ratio_accounting():
    st, clock = make_system()
    rng = np.random.default_rng(3)
    for i in range(5):
        st.put(f"x{i}", rng.bytes(30_000))
    st.flush_writeback()       # drain the buffer so GETs hit the slabs
    for _ in range(3):
        for i in range(5):
            st.get(f"x{i}")
    assert st.stats.hit_ratio > 0.95         # everything hot


def test_cost_is_pay_per_access():
    """More accesses => proportionally more request cost; idle time only
    accrues (small) warmup cost."""
    st, clock = make_system()
    st.put("a", b"d" * 100_000)
    d1 = st.ledger.dollars()
    for _ in range(50):
        st.get("a")
    d2 = st.ledger.dollars()
    assert d2["request"] > d1["request"] * 5
    clock.advance(10.0)
    st.gc_tick()                             # idle tick: warmup/compaction
    d3 = st.ledger.dollars()
    # idle-tick request cost (a compaction round) is a tiny fraction of
    # access-driven cost — the pay-per-access property
    assert (d3["request"] - d2["request"]) < 0.05 * d2["request"]


def test_buffer_serves_read_after_write():
    st, _ = make_system(visibility_lag=100.0)
    data = np.random.default_rng(4).bytes(80_000)
    st.put("raw", data)
    assert st.get("raw") == data             # SMS/persistent-buffer path
