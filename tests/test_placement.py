"""PlaceChunk (paper Fig. 5) invariants — property-based."""
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.placement import PlacementManager


def make_pm(fg_size=6, cap=1000):
    return PlacementManager(fg_size, cap)


def test_distinct_functions_per_object():
    pm = make_pm(fg_size=6)
    fids = [pm.place_chunk(i, 100) for i in range(6)]
    assert len(set(fids)) == 6


@settings(max_examples=50, deadline=None)
@given(
    fg_size=st.integers(2, 12),
    objects=st.lists(st.integers(50, 400), min_size=1, max_size=40),
)
def test_no_two_chunks_share_function(fg_size, objects):
    """The paper's guarantee: PlaceChunk never places two chunks of one
    object on the same function (probe stride == fg_size)."""
    pm = make_pm(fg_size=fg_size, cap=1000)
    for size in objects:
        fids = [pm.place_chunk(i, size) for i in range(fg_size)]
        assert len(set(fids)) == fg_size


@settings(max_examples=30, deadline=None)
@given(fg_size=st.integers(2, 8), n=st.integers(1, 60))
def test_slot_alignment(fg_size, n):
    """Chunk i always lands on slot i of some FG."""
    pm = make_pm(fg_size=fg_size, cap=500)
    for _ in range(n):
        for i in range(fg_size):
            fid = pm.place_chunk(i, 120)
            assert pm.functions[fid].slot == i


def test_seal_on_hardcap_seals_whole_fg():
    pm = make_pm(fg_size=3, cap=100)
    fid = pm.place_chunk(0, 100)     # exactly at capacity -> sealed
    fg = pm.functions[fid].fg_id
    assert pm.fgs[fg].sealed
    assert all(pm.functions[f].sealed for f in pm.fgs[fg].fids)
    # next placement must scale out a new FG
    fid2 = pm.place_chunk(0, 50)
    assert pm.functions[fid2].fg_id != fg


def test_greedy_oldest_open_fg_first():
    pm = make_pm(fg_size=2, cap=300)
    first = pm.place_chunk(0, 100)
    pm.scale_out()                    # a second, newer FG exists
    nxt = pm.place_chunk(0, 100)
    assert pm.functions[nxt].fg_id == pm.functions[first].fg_id


def test_get_open_funcs_scales_fg_at_a_time():
    pm = make_pm(fg_size=4)
    funcs = pm.get_open_funcs(9)      # needs >= 10 slots -> 3 FGs
    assert len(funcs) >= 10
    assert len(funcs) % 4 == 0
    assert pm.stats.scale_outs == 3


def test_chunk_id_out_of_range():
    pm = make_pm(fg_size=4)
    with pytest.raises(ValueError):
        pm.place_chunk(4, 10)
