"""Import-or-fallback shim for `hypothesis`.

`hypothesis` is a dev-only dependency (requirements-dev.txt). When it is
installed, this module re-exports the real API unchanged. When it is NOT
installed, test collection must still succeed and the property tests must
still run as deterministic example-based tests — so a minimal stand-in of
`given` / `settings` / `strategies` / `HealthCheck` is provided that
draws a fixed, seeded set of examples (seeded by test name, so runs are
reproducible). Shrinking, the database, and health checks are not
emulated; the fallback trades search power for zero dependencies.
"""
from __future__ import annotations

try:
    from hypothesis import HealthCheck, given, settings  # noqa: F401
    from hypothesis import strategies  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:                                      # pragma: no cover
    import functools
    import inspect
    import random
    import types

    HAVE_HYPOTHESIS = False
    _FALLBACK_MAX_EXAMPLES = 10      # cap: deterministic CI stays fast

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

    def _integers(min_value, max_value):
        return _Strategy(lambda r: r.randint(min_value, max_value))

    def _sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda r: seq[r.randrange(len(seq))])

    def _just(value):
        return _Strategy(lambda r: value)

    def _tuples(*ss):
        return _Strategy(lambda r: tuple(s._draw(r) for s in ss))

    def _one_of(*ss):
        return _Strategy(lambda r: ss[r.randrange(len(ss))]._draw(r))

    def _lists(elements, min_size=0, max_size=None):
        hi = min_size + 10 if max_size is None else max_size
        return _Strategy(
            lambda r: [elements._draw(r)
                       for _ in range(r.randint(min_size, hi))])

    def _booleans():
        return _Strategy(lambda r: bool(r.randrange(2)))

    def _floats(min_value, max_value):
        return _Strategy(lambda r: r.uniform(min_value, max_value))

    strategies = types.SimpleNamespace(
        integers=_integers, sampled_from=_sampled_from, just=_just,
        tuples=_tuples, one_of=_one_of, lists=_lists, booleans=_booleans,
        floats=_floats)

    class HealthCheck:
        too_slow = "too_slow"
        data_too_large = "data_too_large"
        filter_too_much = "filter_too_much"

    def given(*args, **strategy_kwargs):
        if args:
            raise TypeError(
                "fallback @given supports keyword strategies only")

        def decorate(fn):
            @functools.wraps(fn)
            def wrapper(*wargs, **wkwargs):
                rnd = random.Random(fn.__qualname__)   # deterministic
                n = min(getattr(wrapper, "_shim_max_examples",
                                _FALLBACK_MAX_EXAMPLES),
                        _FALLBACK_MAX_EXAMPLES)
                for _ in range(n):
                    drawn = {name: s._draw(rnd)
                             for name, s in strategy_kwargs.items()}
                    fn(*wargs, **dict(wkwargs, **drawn))
            wrapper.hypothesis_fallback = True
            # hide the drawn parameters from pytest's fixture resolution
            # (functools.wraps exposes the wrapped signature otherwise)
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            return wrapper
        return decorate

    def settings(max_examples=_FALLBACK_MAX_EXAMPLES, **_ignored):
        def decorate(fn):
            fn._shim_max_examples = max_examples
            return fn
        return decorate
