"""Multi-process shard host (`repro.core.host` + `repro.core.ipc`):
shared-memory ring mechanics, worker lifecycle hygiene (no stray
processes or /dev/shm segments), and REAL-SIGKILL durability — the
cross-process version of test_shard_2pc: kill a worker with a prepared
2PC ticket outstanding, survivors keep serving, restart replays the
journal, and the sweep leaves zero PENDING keys."""
import gc
import os
import pickle
import signal
import threading
import time

import numpy as np
import pytest

from repro.core import (Clock, ConcurrentPutError, FaultPlan, FaultPoint,
                        InjectedCrash, ProcessShardedStore,
                        ShardWorkerDied, ShmArena, StoreConfig)
from repro.core.ec import ECConfig
from repro.core.gc_window import GCConfig
from repro.core.ipc import ArenaBroken, pack_payload, unpack_payload

MB = 1024 * 1024


def make_host(num_shards=2, *, spill_dir=None, cos_root=None,
              faults=None, seed=0, **kw):
    cfg = StoreConfig(ec=ECConfig(k=4, p=2),
                      function_capacity=8 * MB,
                      fragment_bytes=1 * MB,
                      gc=GCConfig(gc_interval=1e9),
                      num_recovery_functions=4,
                      spill_dir=spill_dir, faults=faults, **kw)
    return ProcessShardedStore(cfg, num_shards=num_shards, clock=Clock(),
                               cos_root=cos_root, seed=seed)


def cross_shard_batch(st, n_per_shard=2, tag="b", rng=None):
    rng = rng or np.random.default_rng(0)
    per = {sid: 0 for sid in range(st.num_shards)}
    out = {}
    i = 0
    while any(c < n_per_shard for c in per.values()):
        k = f"{tag}{i}"
        i += 1
        sid = st.router.shard_of(k)
        if per[sid] >= n_per_shard:
            continue
        per[sid] += 1
        out[k] = rng.bytes(12_000)
    return out


def _pids_gone(pids, timeout=15.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        alive = []
        for pid in pids:
            try:
                os.kill(pid, 0)
                alive.append(pid)
            except ProcessLookupError:
                pass
        if not alive:
            return True
        time.sleep(0.05)
    return False


def _shm_segments():
    try:
        return {n for n in os.listdir("/dev/shm")
                if n.startswith("infinistore-")}
    except FileNotFoundError:                # non-Linux: can't observe
        return set()


# ---------------------------------------------------------------------------
# ShmArena ring mechanics (no processes)
# ---------------------------------------------------------------------------

def test_arena_alloc_wraps_and_releases():
    a = ShmArena.create(1024, tag="t")
    try:
        positions = []
        for i in range(3):
            pos, view = a.alloc(400)
            view[:] = i
            del view                 # views must not outlive close()
            positions.append(pos)
            a.release_to(pos + 400)  # reader consumed immediately
        # two slots per revolution: the third alloc wrapped past the
        # physical end via padding, positions stay monotonic
        assert positions == sorted(positions)
        assert positions[2] % 1024 == 0     # padded to the wrap point
    finally:
        a.close()


def test_arena_blocks_until_release_then_fails_when_broken():
    a = ShmArena.create(1024, tag="t")
    try:
        pos, _ = a.alloc(1000)
        got = []

        def writer():
            try:
                got.append(a.alloc(1000, timeout=30.0)[0])
            except ArenaBroken as e:
                got.append(e)
        th = threading.Thread(target=writer)
        th.start()
        time.sleep(0.1)
        assert not got                       # full: writer is parked
        a.release_to(pos + 1000)
        th.join(timeout=10.0)
        assert got and isinstance(got[0], int)
        # a broken arena wakes + fails any parked writer
        th2 = threading.Thread(target=writer)
        th2.start()
        time.sleep(0.1)
        a.fail(ArenaBroken("peer died"))
        th2.join(timeout=10.0)
        assert isinstance(got[1], ArenaBroken)
    finally:
        a.close()


def test_payload_pack_zero_copy_and_inline_fallback():
    a = ShmArena.create(64 * 1024, tag="t")
    try:
        small = np.arange(100, dtype=np.uint8)
        d = pack_payload(a, small)
        assert d[0] == "a"
        view = unpack_payload(a, d)
        assert view.base is not None         # a VIEW into the ring
        assert np.array_equal(view, small)
        del view                             # must not outlive close()
        # oversized payloads fall back to inline bytes
        big = b"z" * (128 * 1024)
        d2 = pack_payload(a, big)
        assert d2[0] == "i" and unpack_payload(a, d2) == big
    finally:
        a.close()


def test_exceptions_cross_process_boundary():
    e = pickle.loads(pickle.dumps(ConcurrentPutError("kx")))
    assert isinstance(e, ConcurrentPutError) and e.key == "kx"
    from repro.core import TransientCOSError
    plan = FaultPlan(seed=7).add(
        FaultPoint(site="cos.put", action="transient", hits=(1,)))
    with pytest.raises(TransientCOSError):
        plan.fire("cos.put", "warm")         # hit 1 fires
    clone = pickle.loads(pickle.dumps(plan))
    assert clone.seed == 7
    # the log and hit counters resume from the serialized position —
    # each process then advances its own independent copy
    assert clone.snapshot()["log"] == plan.snapshot()["log"]
    assert clone.fire("cos.put", "warm") is None   # hit 2: unscheduled


# ---------------------------------------------------------------------------
# worker lifecycle hygiene
# ---------------------------------------------------------------------------

def test_close_reaps_workers_and_segments(tmp_path):
    before = _shm_segments()
    st = make_host(2, spill_dir=str(tmp_path / "spill"))
    pids = list(st.worker_pids())
    assert all(isinstance(p, int) for p in pids)
    assert len(_shm_segments() - before) == 4   # 2 rings x 2 shards
    st.put("k", b"k" * 9_000)
    assert st.close() is True
    assert _pids_gone(pids)
    assert _shm_segments() - before == set()


def test_abandoned_store_reaped_by_finalizer(tmp_path):
    """No stray processes or /dev/shm segments may survive a store the
    caller simply dropped (satellite: atexit/finalizer orphan reaping;
    the same hook runs at interpreter exit for still-referenced ones)."""
    before = _shm_segments()
    st = make_host(2, spill_dir=str(tmp_path / "spill"))
    pids = list(st.worker_pids())
    st.put("k", b"k" * 9_000)
    del st
    gc.collect()
    assert _pids_gone(pids)
    assert _shm_segments() - before == set()


def test_close_escalates_past_stuck_worker(tmp_path):
    """A worker that cannot answer its close RPC (SIGSTOPped here) must
    not hold the host hostage: the shared deadline expires and reaping
    escalates to terminate/kill."""
    st = make_host(2, spill_dir=str(tmp_path / "spill"))
    pids = list(st.worker_pids())
    os.kill(pids[0], signal.SIGSTOP)
    try:
        t0 = time.monotonic()
        ok = st.close(deadline_s=2.0)
        elapsed = time.monotonic() - t0
    finally:
        try:
            os.kill(pids[0], signal.SIGCONT)
        except ProcessLookupError:
            pass
    assert ok is False               # the stuck shard didn't confirm
    assert elapsed < 60.0
    assert _pids_gone(pids)


def test_dead_worker_raises_shard_worker_died(tmp_path):
    st = make_host(2, spill_dir=str(tmp_path / "spill"))
    try:
        keys = {st.router.shard_of(f"k{i}"): f"k{i}" for i in range(32)}
        st.simulate_crash(shard=0)
        with pytest.raises(ShardWorkerDied):
            st.put(keys[0], b"x" * 9_000)
        # in-flight futures fail fast instead of hanging; survivors OK
        assert st.put(keys[1], b"y" * 9_000) == 1
        assert st.workers_alive() == [False, True]
        snap = st.snapshot_metadata()
        assert snap["health"]["state"] == "SHARD_DOWN"
    finally:
        st.close()


# ---------------------------------------------------------------------------
# real-SIGKILL durability (cross-process test_shard_2pc)
# ---------------------------------------------------------------------------

def test_sigkill_worker_mid_2pc_prepared_ticket_swept(tmp_path):
    """THE tentpole scenario: a cross-shard put_many whose leader died
    after the commit decision became durable (both shards hold prepared
    tickets), then a REAL SIGKILL of one in-doubt worker. Survivors
    keep serving the old values, restart_shard replays the journal
    (prepared/<ticket> record included), and the sweep rolls the whole
    batch forward — zero PENDING keys, zero stranded tickets."""
    plan = FaultPlan(seed=1).add(
        FaultPoint(site="shard.leader_death", action="crash", hits=(2,)))
    st = make_host(2, spill_dir=str(tmp_path / "spill"), faults=plan)
    try:
        rng = np.random.default_rng(1)
        pre = cross_shard_batch(st, tag="k", rng=rng)
        assert all(v == 1 for v in st.put_many(pre).values())
        new = {k: rng.bytes(12_000) for k in pre}
        with pytest.raises(InjectedCrash):
            st.put_many(new)         # leader dies between the rounds
        tickets = st.indoubt_tickets()
        assert tickets
        # REAL kill of an in-doubt participant, prepared ticket live
        st.simulate_crash(shard=0)
        # survivors keep serving — and the batch is still invisible
        for k, v in pre.items():
            if st.router.shard_of(k) == 1:
                assert st.get(k) == v
        # respawn: journal replay + inherited sweep find the durable
        # decision and roll EVERY participant forward
        st.restart_shard(0)
        assert st.indoubt_tickets() == []
        for k, v in new.items():
            assert st.get(k) == v, f"in-doubt key {k} not rolled forward"
        # keyspace fully writable again — no PENDING residue anywhere
        assert all(v == 3 for v in st.put_many(
            {k: b"x" * 9_000 for k in pre}).values())
    finally:
        st.close()


def test_sigkill_mid_put_many_presumed_abort(tmp_path):
    """Kill a worker holding a prepared ticket whose decision was NEVER
    recorded: restart + sweep must presume abort — the batch stays
    invisible and its keys stay writable."""
    st = make_host(2, spill_dir=str(tmp_path / "spill"))
    try:
        rng = np.random.default_rng(2)
        pre = cross_shard_batch(st, tag="p", rng=rng)
        assert all(v == 1 for v in st.put_many(pre).values())
        sub = [(k, b"n" * 9_000) for k in pre
               if st.router.shard_of(k) == 0][:2]
        prep = st.shards[0].prepare_put_many_async(
            sub, ticket=901).result()
        assert prep is not None
        assert 901 in st.shards[0].indoubt_tickets()
        st.simulate_crash(shard=0)   # SIGKILL, ticket outstanding
        st.restart_shard(0)
        assert st.indoubt_tickets() == []
        for k, v in pre.items():
            assert st.get(k) == v, f"aborted batch leaked into {k}"
        out = st.put_many({k: b"w" * 9_000 for k, _ in sub})
        assert all(v >= 2 for v in out.values())
    finally:
        st.close()


def test_sigkill_under_concurrent_load_zero_acked_loss(tmp_path):
    """Client threads hammer PUTs while one worker is SIGKILLed
    mid-stream: every write that ACKED (put returned) must survive the
    restart; in-flight writes may fail but only with ShardWorkerDied."""
    st = make_host(2, spill_dir=str(tmp_path / "spill"))
    try:
        acked = {}
        alock = threading.Lock()
        errs = []

        def client(t):
            rng = np.random.default_rng(t)
            for i in range(12):
                k = f"w{t}-{i}"
                v = rng.bytes(10_000)
                try:
                    st.put(k, v)
                except ConnectionError:
                    continue         # killed mid-flight: never acked
                except Exception as e:                # noqa: BLE001
                    errs.append(e)
                    return
                with alock:
                    acked[k] = v
        threads = [threading.Thread(target=client, args=(t,))
                   for t in range(4)]
        for th in threads:
            th.start()
        time.sleep(0.25)             # let traffic build
        st.simulate_crash(shard=0)
        for th in threads:
            th.join()
        assert not errs
        st.restart_shard(0)
        lost = [k for k, v in acked.items() if st.get(k) != v]
        assert not lost, f"acked writes lost: {lost}"
        assert st.indoubt_tickets() == []
    finally:
        st.close()


def test_whole_host_crash_then_rebuild_zero_loss(tmp_path):
    """simulate_crash() of the whole host (every worker SIGKILLed) then
    a rebuild on the same spill + COS roots replays every shard's
    journal — the PR-4 restart contract, now across processes."""
    spill = str(tmp_path / "spill")
    cosr = str(tmp_path / "cos")
    st = make_host(2, spill_dir=spill, cos_root=cosr)
    rng = np.random.default_rng(3)
    acked = {f"r{i}": rng.bytes(11_000) for i in range(10)}
    for k, v in acked.items():
        assert st.put(k, v) == 1
    pids = list(st.worker_pids())
    st.simulate_crash()
    assert _pids_gone(pids)
    st2 = make_host(2, spill_dir=spill, cos_root=cosr)
    try:
        for k, v in acked.items():
            assert st2.get(k) == v, f"acked write {k} lost at restart"
        assert st2.indoubt_tickets() == []
    finally:
        st2.close()


def test_worker_fault_plan_fires_in_worker(tmp_path):
    """StoreConfig(faults=...) serializes into workers: a scheduled
    worker-side COS fault actually fires there (surfaced through the
    writeback health), proving the chaos plane crossed the boundary."""
    plan = FaultPlan(seed=5).add(
        FaultPoint(site="cos.put", action="transient", every=1,
                   times=1_000_000))
    st = make_host(1, spill_dir=str(tmp_path / "spill"), faults=plan)
    try:
        st.put("f0", b"f" * 9_000)   # acks from SMS+journal
        assert st.get("f0") == b"f" * 9_000
        ok = st.flush_writeback(timeout=3.0)
        assert ok is False           # the injected COS outage is real
        state = st.snapshot_metadata()["health"]["state"]
        assert state in ("DEGRADED_WRITEBACK", "OK")
    finally:
        st.close(flush=False)
