"""Workload synthesizers must match the paper's §2 characterization."""
from repro.data.traces import (azure_blob_trace, ibm_registry_trace,
                               trace_stats)


def test_ibm_trace_shape():
    ev = ibm_registry_trace(num_objects=200, num_requests=2000,
                            duration=3600.0, seed=0)
    s = trace_stats(ev)
    assert s["num_events"] == 2000
    # heavy tail: a sizeable fraction of events touch >10MB objects
    assert 0.1 < s["frac_large"] < 0.7
    # bursty: most multi-access objects have CoV > 1 (paper Fig. 1d)
    assert s["frac_cov_gt1"] > 0.5
    # strong temporal reuse: p80 reuse well under the trace duration
    assert s["reuse_p80"] < 3600.0 / 3


def test_azure_trace_shorter_reuse():
    ibm = trace_stats(ibm_registry_trace(seed=1))
    az = trace_stats(azure_blob_trace(seed=1))
    assert az["reuse_p50"] < ibm["reuse_p50"]


def test_events_sorted_and_valid():
    ev = azure_blob_trace(num_objects=50, num_requests=500, seed=2)
    assert all(e.op in ("get", "put") and e.size > 0 for e in ev)
    assert all(ev[i].t <= ev[i + 1].t for i in range(len(ev) - 1))


def test_first_touch_is_put():
    ev = ibm_registry_trace(num_objects=100, num_requests=1000, seed=3)
    seen = set()
    for e in ev:
        if e.key not in seen:
            assert e.op == "put", "first access must create the object"
            seen.add(e.key)
