"""Optimized-HLO analyzer for the roofline pass.

XLA's `compiled.cost_analysis()` visits each `while` body ONCE, so a model
whose layers run under `lax.scan` is undercounted by num_layers x (verified
in tests/test_hlo_analysis.py). This module re-walks the HLO call graph
with loop trip-count multipliers and reports:

  * dot/convolution FLOPs          (compute roofline term)
  * per-instruction bytes accessed (memory roofline term proxy)
  * collective bytes by op type and mesh axis (collective roofline term),
    with ring-traffic adjustment and ICI/DCN classification from
    replica_groups.

Pure text parsing (numpy only) — no jax dependency, so it can run over
dumped HLO from any backend.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "collective-broadcast", "ragged-all-to-all")

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_CALL_ATTR_RE = re.compile(
    r"(?:calls|to_apply|body|condition|branch_computations)=\{?%?([\w.\-]+)")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")


@dataclass
class Instr:
    name: str
    opcode: str
    shapes: List[Tuple[str, Tuple[int, ...]]]   # result shape(s)
    operands: List[str]
    attrs: str
    line: str

    def result_bytes(self) -> int:
        return sum(DTYPE_BYTES.get(dt, 4) * int(np.prod(dims or (1,)))
                   for dt, dims in self.shapes)


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    by_name: Dict[str, Instr] = field(default_factory=dict)


@dataclass
class CollectiveStat:
    opcode: str
    count: float = 0.0
    result_bytes: float = 0.0      # sum of result sizes x multiplier
    ring_bytes: float = 0.0        # per-device ring traffic x multiplier
    dcn: bool = False
    group_size: int = 1


@dataclass
class HloAnalysis:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collectives: List[CollectiveStat] = field(default_factory=list)
    while_trips: List[int] = field(default_factory=list)
    transcendentals: float = 0.0

    @property
    def collective_result_bytes(self) -> float:
        return sum(c.result_bytes for c in self.collectives)

    def ring_bytes(self, dcn: Optional[bool] = None) -> float:
        return sum(c.ring_bytes for c in self.collectives
                   if dcn is None or c.dcn == dcn)

    def summary(self) -> Dict[str, float]:
        return {
            "flops": self.flops,
            "bytes_accessed": self.bytes_accessed,
            "collective_bytes": self.collective_result_bytes,
            "ici_ring_bytes": self.ring_bytes(dcn=False),
            "dcn_ring_bytes": self.ring_bytes(dcn=True),
            "num_collectives": float(sum(c.count for c in self.collectives)),
        }


# --------------------------------------------------------------------------
# Parsing
# --------------------------------------------------------------------------

def _parse_shapes(text: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt in DTYPE_BYTES:
            dims_t = tuple(int(x) for x in dims.split(",") if x) if dims else ()
            out.append((dt, dims_t))
    return out


def _split_result_and_rest(line: str):
    """'%x = <type> opcode(...), attrs' -> (result_type_str, opcode, rest)."""
    eq = line.find(" = ")
    if eq < 0:
        return None
    rest = line[eq + 3:]
    if rest.startswith("("):
        depth, i = 0, 0
        for i, ch in enumerate(rest):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                break
        type_str, rest = rest[:i + 1], rest[i + 1:].lstrip()
    else:
        m = re.match(r"[a-z0-9\[\],{}:\* ]*?(?=[a-z][a-z0-9\-]*\()", rest)
        if m:
            type_str, rest = rest[:m.end()], rest[m.end():]
        else:
            sp = rest.find(" ")
            type_str, rest = rest[:sp], rest[sp + 1:]
    m = re.match(r"([a-z][a-z0-9\-]*)\(", rest)
    if not m:
        return None
    opcode = m.group(1)
    args = rest[m.end():]
    return type_str, opcode, args


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.strip()
        if not line or line.startswith(("//", "HloModule")):
            continue
        m = re.match(r"(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{$", line)
        if m and " = " not in line.split("{")[0]:
            cur = Computation(m.group(2))
            comps[cur.name] = cur
            if m.group(1):
                entry = cur.name
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None or " = " not in line:
            continue
        nm = re.match(r"(ROOT\s+)?%?([\w.\-]+)\s+=", line)
        if not nm:
            continue
        parsed = _split_result_and_rest(line)
        if not parsed:
            continue
        type_str, opcode, args = parsed
        # operand names: %foo references in the argument list (before attrs)
        arg_end = 0
        depth = 0
        for i, ch in enumerate(args):
            depth += ch == "("
            depth -= ch == ")"
            if depth < 0:
                arg_end = i
                break
        operand_names = re.findall(r"%([\w.\-]+)", args[:arg_end])
        instr = Instr(name=nm.group(2), opcode=opcode,
                      shapes=_parse_shapes(type_str),
                      operands=operand_names,
                      attrs=args[arg_end:], line=line)
        cur.instrs.append(instr)
        cur.by_name[instr.name] = instr
    return comps, entry


# --------------------------------------------------------------------------
# Graph walk
# --------------------------------------------------------------------------

def _called_computations(instr: Instr) -> List[str]:
    return _CALL_ATTR_RE.findall(instr.line)


def _int_const(instr: Optional[Instr]) -> Optional[int]:
    if instr is not None and instr.opcode == "constant":
        m = re.search(r"constant\((-?\d+)\)", instr.line)
        if m:
            return int(m.group(1))
    return None


def _while_trip_count(comps, cond_name: str) -> int:
    """Trip count of a jax scan/while: the integer constant compared
    against the loop counter (`i < N`). Only constants that actually feed
    a `compare` are considered — NOT arbitrary literals in the condition
    (index-clamping constants would wildly inflate the multiplier)."""
    comp = comps.get(cond_name)
    if comp is None:
        return 1
    candidates: List[int] = []

    def scan_comp(c: Computation, operand_resolver):
        for ins in c.instrs:
            if ins.opcode == "compare":
                for nm in ins.operands:
                    v = operand_resolver(nm)
                    if v is not None and v > 0:
                        candidates.append(v)
            elif ins.opcode in ("fusion", "call"):
                for callee_name in _called_computations(ins):
                    callee = comps.get(callee_name)
                    if callee is None:
                        continue
                    # map callee params -> caller operands
                    params = [i for i in callee.instrs
                              if i.opcode == "parameter"]
                    params.sort(key=lambda i: int(
                        re.search(r"parameter\((\d+)\)", i.line).group(1)))

                    def resolver(nm, _c=c, _ins=ins, _params=params):
                        cal = next((p for p in _params if p.name == nm), None)
                        if cal is not None:
                            idx = _params.index(cal)
                            if idx < len(_ins.operands):
                                return _int_const(
                                    _c.by_name.get(_ins.operands[idx]))
                            return None
                        callee_comp = comps.get(
                            _called_computations(_ins)[0])
                        return _int_const(callee_comp.by_name.get(nm)
                                          if callee_comp else None)

                    scan_comp(callee, resolver)

    scan_comp(comp, lambda nm: _int_const(comp.by_name.get(nm)))
    return max(candidates) if candidates else 1


def _dot_flops(comp: Computation, instr: Instr) -> float:
    out_elems = sum(int(np.prod(d or (1,))) for _, d in instr.shapes)
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.line)
    lhs = comp.by_name.get(instr.operands[0]) if instr.operands else None
    if lhs is None or not lhs.shapes:
        # operand declared elsewhere (rare) — assume square-ish
        return 2.0 * out_elems
    lhs_dims = lhs.shapes[0][1]
    contract = 1
    if mc and mc.group(1):
        for d in mc.group(1).split(","):
            di = int(d)
            if di < len(lhs_dims):
                contract *= lhs_dims[di]
    return 2.0 * out_elems * contract


def _conv_flops(comp: Computation, instr: Instr) -> float:
    out_elems = sum(int(np.prod(d or (1,))) for _, d in instr.shapes)
    rhs = comp.by_name.get(instr.operands[1]) if len(instr.operands) > 1 else None
    kernel = int(np.prod(rhs.shapes[0][1] or (1,))) if rhs and rhs.shapes else 1
    return 2.0 * out_elems * max(kernel, 1) / max(
        1, (rhs.shapes[0][1][-1] if rhs and rhs.shapes and rhs.shapes[0][1]
            else 1))


def _collective_stat(instr: Instr, mult: float, pod_stride: int
                     ) -> CollectiveStat:
    opcode = instr.opcode.replace("-start", "")
    rb = instr.result_bytes()
    gsize, dcn = 1, False
    m = _GROUPS_LIST_RE.search(instr.line)
    if m:
        first = m.group(1).split("},{")[0].strip("{}")
        ids = [int(x) for x in first.split(",") if x.strip()]
        gsize = max(len(ids), 1)
        dcn = bool(ids) and (max(ids) - min(ids)) >= pod_stride
    else:
        m = _GROUPS_IOTA_RE.search(instr.line)
        if m:
            ng, gs = int(m.group(1)), int(m.group(2))
            dims = tuple(int(x) for x in m.group(3).split(","))
            perm = (tuple(int(x) for x in m.group(4).split(","))
                    if m.group(4) else tuple(range(len(dims))))
            ids = np.arange(int(np.prod(dims))).reshape(dims).transpose(perm)
            groups = ids.reshape(ng, gs)
            gsize = gs
            dcn = bool((groups.max(1) - groups.min(1) >= pod_stride).any())
    g = max(gsize, 1)
    if opcode == "all-reduce":
        ring = 2.0 * rb * (g - 1) / g
    elif opcode == "all-gather":
        ring = rb * (g - 1) / g          # rb is the gathered size
    elif opcode == "reduce-scatter":
        ring = rb * (g - 1)              # rb is the scattered size
    elif opcode in ("all-to-all", "ragged-all-to-all"):
        ring = rb * (g - 1) / g
    else:                                # collective-permute / broadcast
        ring = rb
    return CollectiveStat(opcode=opcode, count=mult, result_bytes=rb * mult,
                          ring_bytes=ring * mult, dcn=dcn, group_size=g)


_SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "reshape", "after-all", "partition-id",
               "replica-id", "iota", "broadcast"}


def _fusion_dus_bytes(comps, instr: Instr) -> Optional[float]:
    """If a fusion's root is a dynamic-update-slice, the buffer updates in
    place: traffic = 2x the update window (read-modify-write), not the
    whole (possibly layer-stacked) result buffer."""
    for cname in _called_computations(instr):
        c = comps.get(cname)
        if c and c.instrs:
            root = c.instrs[-1]
            if root.opcode == "dynamic-update-slice" \
                    and len(root.operands) > 1:
                upd = c.by_name.get(root.operands[1])
                if upd is not None:
                    return 2.0 * upd.result_bytes()
    return None


def bf16_upcast_f32_bytes(text: str, min_bytes: int = 128 * 2**20) -> int:
    """XLA:CPU materializes f32 shadow copies of large bf16 buffers (CPUs
    lack native bf16 dots); TPU compiles keep them bf16. Returns the total
    bytes of DISTINCT large f32 shapes produced by `convert` from bf16 —
    one buffer per shape, since XLA's buffer assignment reuses them.
    Used to derive `tpu_corrected_bytes` in the dry-run records."""
    shapes = {}
    for m in re.finditer(
            r"= f32\[([0-9,]+)\][^ ]* convert\(", text):
        dims = tuple(int(x) for x in m.group(1).split(",") if x)
        b = 4 * int(np.prod(dims))
        if b >= min_bytes:
            shapes[dims] = b
    return int(sum(shapes.values()))


def analyze_hlo(text: str, *, pod_stride: int = 256) -> HloAnalysis:
    comps, entry = parse_hlo(text)
    res = HloAnalysis()
    if entry is None:
        return res

    def operand_bytes(comp: Computation, instr: Instr,
                      cap: Optional[int] = None) -> float:
        """Sum of operand sizes; with `cap`, each operand is charged at
        most `cap` bytes — loop fusions (kLoop/kOutput) that slice a big
        loop-invariant operand only read a result-sized window per
        iteration, so charging the full operand would overcount scanned
        attention/params reads by the trip count."""
        tot = 0.0
        for nm in instr.operands:
            op = comp.by_name.get(nm)
            if op is not None:
                b = op.result_bytes()
                tot += min(b, cap) if cap is not None else b
        return tot

    seen_async: set = set()

    def walk(name: str, mult: float, count_bytes: bool = True):
        comp = comps.get(name)
        if comp is None:
            return

        def add_bytes(instr):
            if count_bytes:
                res.bytes_accessed += (instr.result_bytes()
                                       + operand_bytes(comp, instr)) * mult

        def add_bytes_n(nbytes):
            if count_bytes:
                res.bytes_accessed += nbytes * mult

        for instr in comp.instrs:
            op = instr.opcode
            base = op.replace("-start", "")
            if op.endswith("-done"):
                continue
            if base in COLLECTIVES:
                res.collectives.append(
                    _collective_stat(instr, mult, pod_stride))
                add_bytes(instr)
                continue
            if op == "while":
                conds = re.search(r"condition=%?([\w.\-]+)", instr.line)
                bodys = re.search(r"body=%?([\w.\-]+)", instr.line)
                trips = _while_trip_count(comps, conds.group(1)) if conds else 1
                res.while_trips.append(trips)
                if bodys:
                    walk(bodys.group(1), mult * trips, count_bytes)
                continue
            if op == "conditional":
                for c in _called_computations(instr):
                    walk(c, mult, count_bytes)   # upper bound: all branches
                continue
            if op == "scatter":
                upd = comp.by_name.get(instr.operands[2]) \
                    if len(instr.operands) > 2 else None
                add_bytes_n(2 * (upd.result_bytes() if upd
                                 else instr.result_bytes()))
                continue
            if op in ("fusion", "map", "reduce", "reduce-window", "sort",
                      "select-and-scatter", "custom-call"):
                # count the fusion's HBM boundary once; recurse only to
                # find dots (fusion internals stay in registers/VMEM).
                # kLoop/kOutput fusions read at most a result-sized window
                # of each operand per execution; kInput (reduction)
                # fusions read operands fully.
                for c in _called_computations(instr):
                    walk(c, mult, False)
                if count_bytes:
                    rb = instr.result_bytes()
                    dus = _fusion_dus_bytes(comps, instr)
                    if dus is not None:
                        # in-place dynamic-update-slice fusion: traffic is
                        # the updated window, not the whole buffer
                        res.bytes_accessed += dus * mult
                    else:
                        cap = None
                        if op == "fusion" and "kind=kInput" not in instr.line:
                            cap = max(rb, 1)
                        res.bytes_accessed += (rb + operand_bytes(
                            comp, instr, cap)) * mult
                if "exponential" in instr.line or "tanh" in instr.line:
                    res.transcendentals += mult
                continue
            if op == "call":
                for c in _called_computations(instr):
                    walk(c, mult, count_bytes)
                continue
            if op == "dot":
                res.flops += _dot_flops(comp, instr) * mult
                add_bytes(instr)
                continue
            if op == "convolution":
                res.flops += _conv_flops(comp, instr) * mult
                add_bytes(instr)
                continue
            if op in ("dynamic-slice", "gather", "slice"):
                # reads only the sliced region (== result), not the full
                # operand — charging the operand would overcount scanned
                # layer-param reads by num_layers x
                add_bytes_n(2 * instr.result_bytes())
                continue
            if op == "dynamic-update-slice":
                upd = comp.by_name.get(instr.operands[1]) \
                    if len(instr.operands) > 1 else None
                add_bytes_n(2 * (upd.result_bytes() if upd
                                 else instr.result_bytes()))
                continue
            if op == "convert":
                # XLA:CPU's giant bf16->f32 shadow converts don't exist on
                # TPU; skip them so the memory term stays hardware-true
                src = comp.by_name.get(instr.operands[0]) \
                    if instr.operands else None
                if (instr.result_bytes() >= 128 * 2**20 and src is not None
                        and src.shapes and src.shapes[0][0] == "bf16"):
                    continue
                add_bytes(instr)
                continue
            if op in _SKIP_BYTES:
                continue
            add_bytes(instr)

    walk(entry, 1.0)
    return res
