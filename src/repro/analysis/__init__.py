from repro.analysis.hlo import HloAnalysis, analyze_hlo  # noqa: F401
