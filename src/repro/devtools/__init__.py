"""istore-lint: repo-specific concurrency & invariant static analysis.

Pure-stdlib (``ast``) analysis of the InfiniStore core, run as::

    python -m repro.devtools.lint src/repro

Five rules (each with a ``# lint: allow(<rule>): <reason>`` pragma and
a fingerprint baseline in ``baseline.json``):

- ``lock-order``        acquisition-graph cycles / plain-Lock self-deadlock
- ``blocking-under-lock`` sleeps, socket/pipe sends, ``future.result()``,
                        journal ``sync()``, COS I/O inside a lock region
- ``fault-site``        ``FaultPlan.fire`` guard + manifest discipline,
                        ``net.*``/``hb`` points must set ``match=``
- ``atomic-counter``    read-modify-write on ``StoreStats`` counters
- ``resource-lifecycle`` threads/pools/shared memory constructed in
                        ``__init__`` must be torn down from ``close()``

`repro.devtools.witness.LockWitness` is the runtime half: it validates
the statically derived lock hierarchy against real acquisition orders
under the conformance suite and the chaos soak.
"""
