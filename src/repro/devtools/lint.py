"""istore-lint driver: run all rules, apply pragmas + baseline, report.

    python -m repro.devtools.lint src/repro
    python -m repro.devtools.lint src/repro --emit-hierarchy docs/lock_hierarchy.md
    python -m repro.devtools.lint src/repro --write-baseline

Exit status 0 iff every finding is waived by an inline pragma
(``# lint: allow(<rule>): <reason>`` on the finding's line or the line
above — the reason is mandatory) or by a fingerprint in the baseline
file (``src/repro/devtools/baseline.json`` by default).  Fingerprints
are ``rule|path|scope|detail`` — line-number independent, so routine
edits don't churn the baseline.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

from repro.devtools import lockgraph, rules
from repro.devtools.scan import Finding, TreeModel, scan_tree

DEFAULT_BASELINE = Path(__file__).with_name("baseline.json")


def collect_findings(tm: TreeModel) -> List[Finding]:
    order_findings, _edges = lockgraph.check(tm)
    out = list(order_findings)
    out += rules.blocking_under_lock(tm)
    out += rules.fault_site(tm)
    out += rules.metric_site(tm)
    out += rules.atomic_counter(tm)
    out += rules.resource_lifecycle(tm)
    return out


def apply_waivers(tm: TreeModel, findings: Sequence[Finding],
                  baseline: Dict[str, str]) -> Tuple[List[Finding],
                                                     List[Finding],
                                                     List[Finding]]:
    """-> (new, pragma_waived, baseline_waived).  A pragma with no
    reason does NOT waive — it surfaces as its own finding instead."""
    by_path = {mm.relpath: mm for mm in tm.modules.values()}
    new: List[Finding] = []
    pragma_waived: List[Finding] = []
    base_waived: List[Finding] = []
    for f in findings:
        mm = by_path.get(f.path)
        pragma = tm.pragma_for(mm, f.rule, f.line) if mm else None
        if pragma is not None:
            if not pragma[1]:
                new.append(Finding(
                    rule=f.rule, path=f.path, line=f.line, scope=f.scope,
                    detail=f.detail + "|no-reason",
                    message=(f"pragma waives this finding but gives no "
                             f"reason — `# lint: allow({f.rule}): <why>` "
                             f"(original: {f.message})")))
            else:
                pragma_waived.append(f)
            continue
        if f.fingerprint in baseline:
            base_waived.append(f)
            continue
        new.append(f)
    return new, pragma_waived, base_waived


def load_baseline(path: Path) -> Dict[str, str]:
    if not path.exists():
        return {}
    data = json.loads(path.read_text())
    return {e["fingerprint"]: e.get("reason", "")
            for e in data.get("findings", [])}


def write_baseline(path: Path, findings: Sequence[Finding]) -> None:
    entries = [{"rule": f.rule, "fingerprint": f.fingerprint,
                "reason": "baselined pre-existing finding",
                "message": f.message}
               for f in sorted(findings,
                               key=lambda f: (f.path, f.line, f.rule))]
    path.write_text(json.dumps(
        {"comment": ("istore-lint waiver baseline. Entries are "
                     "fingerprints (rule|path|scope|detail), line-number "
                     "independent. Prefer inline pragmas with reasons; "
                     "baseline only what cannot carry a pragma."),
         "findings": entries}, indent=2) + "\n")


def run(targets: Sequence[str], *, baseline_path: Optional[Path] = None,
        root: Optional[Path] = None) -> Tuple[List[Finding], TreeModel]:
    """Programmatic entry: (new findings, tree model)."""
    tm = scan_tree(list(targets), root)
    baseline = load_baseline(baseline_path or DEFAULT_BASELINE)
    new, _, _ = apply_waivers(tm, collect_findings(tm), baseline)
    return new, tm


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.devtools.lint", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("targets", nargs="*", default=["src/repro"],
                    help="files/directories to lint (default: src/repro)")
    ap.add_argument("--baseline", default=str(DEFAULT_BASELINE),
                    help="baseline JSON (default: devtools/baseline.json)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline (report everything)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite the baseline from current findings")
    ap.add_argument("--emit-hierarchy", metavar="PATH",
                    help="write the generated lock-hierarchy doc "
                         "(use '-' for stdout)")
    ap.add_argument("-q", "--quiet", action="store_true")
    args = ap.parse_args(argv)

    tm = scan_tree(args.targets)
    findings = collect_findings(tm)
    baseline = {} if args.no_baseline else load_baseline(Path(args.baseline))
    new, pragma_waived, base_waived = apply_waivers(tm, findings, baseline)

    if args.emit_hierarchy:
        _, edges = lockgraph.check(tm)
        doc = lockgraph.render_hierarchy(tm, edges)
        if args.emit_hierarchy == "-":
            sys.stdout.write(doc)
        else:
            Path(args.emit_hierarchy).write_text(doc)
            if not args.quiet:
                print(f"wrote {args.emit_hierarchy}")

    if args.write_baseline:
        write_baseline(Path(args.baseline), new)
        print(f"baselined {len(new)} findings -> {args.baseline}")
        return 0

    for f in sorted(new, key=lambda f: (f.path, f.line, f.rule)):
        print(f.render())
    stale = set(baseline) - {f.fingerprint for f in findings}
    if stale and not args.quiet:
        for fp in sorted(stale):
            print(f"note: stale baseline entry (fixed?): {fp}",
                  file=sys.stderr)
    if not args.quiet:
        mods = len(tm.modules)
        print(f"istore-lint: {mods} modules, {len(tm.locks)} locks, "
              f"{len(new)} new finding(s), "
              f"{len(pragma_waived)} pragma-waived, "
              f"{len(base_waived)} baselined", file=sys.stderr)
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
