"""Lock acquisition graph: edges, deadlock candidates, hierarchy doc.

Nodes are class-level lock identities (``module.Class.attr``).  An edge
``A -> B`` means some code path acquires B while holding A — either a
lexically nested ``with``/``.acquire()`` in one function, or a call
made under A to a function whose transitive acquisition closure
contains B (receiver types resolved through ``self.attr =
ClassName(...)`` assignments).

The rule reports:

- **cycles** in the graph (strongly connected components with more than
  one node): deadlock candidates — two threads walking the component in
  different orders can each hold what the other needs;
- **plain-Lock self-deadlock**: a call made while holding a
  non-reentrant ``threading.Lock`` into code that re-acquires the same
  lock (an RLock re-acquisition is reentrant and fine);
- **factory-name drift**: a ``make_lock("name")`` literal that does not
  match its defining ``module.Class.attr`` site — the literal is what
  the runtime `LockWitness` reports, so drift would break the
  static/runtime cross-validation.

`static_order` exports the DAG's transitive closure for the witness.
"""
from __future__ import annotations

from typing import Dict, FrozenSet, List, Optional, Set, Tuple

from repro.devtools.scan import (Finding, TreeModel, resolve_callee)

# (src, dst) -> [(path, line, scope, provenance)]
EdgeMap = Dict[Tuple[str, str], List[Tuple[str, int, str, str]]]


def build_edges(tm: TreeModel) -> Tuple[EdgeMap, List[Finding]]:
    edges: EdgeMap = {}
    findings: List[Finding] = []
    for (modname, qual), fm in tm.funcs.items():
        mm = tm.modules[modname]
        scope = f"{modname}.{qual}"
        for acq in fm.acquires:
            for h in acq.held:
                if h != acq.lock:
                    edges.setdefault((h, acq.lock), []).append(
                        (fm.path, acq.line, scope, f"nested {acq.via}"))
        for ci in fm.calls:
            if not ci.held:
                continue
            callee = resolve_callee(tm, mm, fm, ci)
            if callee is None:
                continue
            cscope = f"{callee.module}.{callee.qualname}"
            for lock in sorted(callee.acquires_closure):
                held_last = ci.held[-1]
                if lock in ci.held:
                    ld = tm.locks.get(lock)
                    if ld is not None and ld.kind == "lock":
                        f = Finding(
                            rule="lock-order", path=fm.path, line=ci.line,
                            scope=scope,
                            detail=f"self:{lock}:{cscope}",
                            message=(f"call to {cscope}() while holding "
                                     f"non-reentrant Lock {lock}, which it "
                                     f"re-acquires — self-deadlock"))
                        if tm.pragma_for(mm, "lock-order", ci.line) is None:
                            findings.append(f)
                    continue
                edges.setdefault((held_last, lock), []).append(
                    (fm.path, ci.line, scope, f"via {cscope}"))
                for h in ci.held[:-1]:
                    edges.setdefault((h, lock), []).append(
                        (fm.path, ci.line, scope, f"via {cscope}"))
    return edges, findings


def _sccs(nodes: Set[str], adj: Dict[str, Set[str]]) -> List[List[str]]:
    """Tarjan strongly-connected components (iterative)."""
    index: Dict[str, int] = {}
    low: Dict[str, int] = {}
    onstack: Set[str] = set()
    stack: List[str] = []
    out: List[List[str]] = []
    counter = [0]

    for start in sorted(nodes):
        if start in index:
            continue
        work = [(start, iter(sorted(adj.get(start, ()))))]
        index[start] = low[start] = counter[0]
        counter[0] += 1
        stack.append(start)
        onstack.add(start)
        while work:
            v, it = work[-1]
            advanced = False
            for w in it:
                if w not in index:
                    index[w] = low[w] = counter[0]
                    counter[0] += 1
                    stack.append(w)
                    onstack.add(w)
                    work.append((w, iter(sorted(adj.get(w, ())))))
                    advanced = True
                    break
                if w in onstack:
                    low[v] = min(low[v], index[w])
            if advanced:
                continue
            work.pop()
            if work:
                pv = work[-1][0]
                low[pv] = min(low[pv], low[v])
            if low[v] == index[v]:
                comp = []
                while True:
                    w = stack.pop()
                    onstack.discard(w)
                    comp.append(w)
                    if w == v:
                        break
                out.append(comp)
    return out


def check(tm: TreeModel) -> Tuple[List[Finding], EdgeMap]:
    edges, findings = build_edges(tm)
    adj: Dict[str, Set[str]] = {}
    nodes: Set[str] = set(tm.locks)
    for (a, b) in edges:
        nodes.add(a)
        nodes.add(b)
        adj.setdefault(a, set()).add(b)
    for comp in _sccs(nodes, adj):
        if len(comp) < 2:
            continue
        comp = sorted(comp)
        sites = []
        for (a, b), occ in sorted(edges.items()):
            if a in comp and b in comp:
                p, ln, scope, prov = occ[0]
                sites.append(f"{a} -> {b} at {p}:{ln} ({prov})")
        # anchor the finding at the first in-component edge site
        first = None
        for (a, b), occ in sorted(edges.items()):
            if a in comp and b in comp:
                first = occ[0]
                break
        path, line = (first[0], first[1]) if first else ("", 0)
        findings.append(Finding(
            rule="lock-order", path=path, line=line,
            scope="acquisition-graph",
            detail="cycle:" + ">".join(comp),
            message=("lock-order cycle (deadlock candidate): "
                     + " / ".join(sites))))
    # factory literal must match the defining site
    for name, ld in sorted(tm.locks.items()):
        if not ld.via_factory:
            continue
        canonical = (f"{ld.module}.{ld.cls}.{ld.attr}" if ld.cls
                     else f"{ld.module}.{ld.attr}")
        if name != canonical and not name.startswith(f"{ld.module}."):
            mm = tm.modules.get(ld.module)
            if mm is not None and tm.pragma_for(
                    mm, "lock-order", ld.line) is not None:
                continue
            findings.append(Finding(
                rule="lock-order", path=ld.path, line=ld.line,
                scope=canonical, detail=f"name-drift:{name}",
                message=(f"make_lock name {name!r} does not match its "
                         f"defining site {canonical!r} — witness and "
                         f"static model would disagree")))
    return findings, edges


def transitive_closure(edges: EdgeMap) -> Dict[str, FrozenSet[str]]:
    adj: Dict[str, Set[str]] = {}
    for (a, b) in edges:
        adj.setdefault(a, set()).add(b)
    out: Dict[str, FrozenSet[str]] = {}
    for start in adj:
        seen: Set[str] = set()
        stack = list(adj[start])
        while stack:
            v = stack.pop()
            if v in seen:
                continue
            seen.add(v)
            stack.extend(adj.get(v, ()))
        out[start] = frozenset(seen)
    return out


def static_order(targets: List[str],
                 root=None) -> Dict[str, FrozenSet[str]]:
    """Scan `targets` and return the acquisition graph's transitive
    closure: name -> every lock some path acquires after it.  The
    runtime witness treats 'acquire A while holding B' as an inversion
    when A-before-B holds here and B-before-A does not."""
    from repro.devtools.scan import scan_tree
    tm = scan_tree(targets, root)
    edges, _ = build_edges(tm)
    return transitive_closure(edges)


def render_hierarchy(tm: TreeModel, edges: EdgeMap) -> str:
    """Markdown lock-hierarchy doc (deterministic: no timestamps)."""
    adj: Dict[str, Set[str]] = {}
    rdeg: Dict[str, int] = {}
    nodes: Set[str] = set(tm.locks)
    for (a, b) in edges:
        nodes.add(a)
        nodes.add(b)
        adj.setdefault(a, set()).add(b)
    for n in nodes:
        rdeg.setdefault(n, 0)
    for (a, b) in edges:
        rdeg[b] += 1
    # Kahn levels: level(n) = longest chain of must-precede locks above n
    level: Dict[str, int] = {}
    ready = sorted(n for n in nodes if rdeg[n] == 0)
    for n in ready:
        level[n] = 0
    queue = list(ready)
    deg = dict(rdeg)
    while queue:
        n = queue.pop(0)
        for m in sorted(adj.get(n, ())):
            level[m] = max(level.get(m, 0), level[n] + 1)
            deg[m] -= 1
            if deg[m] == 0:
                queue.append(m)
    in_cycle = sorted(n for n in nodes if n not in level)

    lines = [
        "# Lock hierarchy (generated)",
        "",
        "Derived by `istore-lint` from the lock-acquisition graph of",
        "`src/repro`.  Regenerate with:",
        "",
        "    PYTHONPATH=src python -m repro.devtools.lint src/repro \\",
        "        --emit-hierarchy docs/lock_hierarchy.md",
        "",
        "An edge `A -> B` means some path acquires B while holding A;",
        "every runtime acquisition order must be consistent with this",
        "partial order (enforced by `repro.devtools.witness.LockWitness`",
        "under the conformance suite and the chaos soak).  Locks at the",
        "same level with no edge between them are unordered — a future",
        "path may pick either order, but must then keep it.",
        "",
        "## Levels (a lock may only be acquired while holding locks of a",
        "## strictly lower level along an edge path)",
        "",
    ]
    by_level: Dict[int, List[str]] = {}
    for n, lv in level.items():
        by_level.setdefault(lv, []).append(n)
    for lv in sorted(by_level):
        lines.append(f"- **level {lv}**: " +
                     ", ".join(f"`{n}`" for n in sorted(by_level[lv])))
    if in_cycle:
        lines.append("- **UNORDERED (cycle!)**: " +
                     ", ".join(f"`{n}`" for n in in_cycle))
    lines += ["", "## Edges", ""]
    if not edges:
        lines.append("(none — no nested acquisitions found)")
    for (a, b), occ in sorted(edges.items()):
        p, ln, scope, prov = occ[0]
        extra = f" (+{len(occ) - 1} more sites)" if len(occ) > 1 else ""
        lines.append(f"- `{a}` -> `{b}` — {p}:{ln} in `{scope}` "
                     f"[{prov}]{extra}")
    lines += ["", "## Lock inventory", "",
              "| lock | kind | defined at | witnessed |",
              "|---|---|---|---|"]
    for name in sorted(tm.locks):
        ld = tm.locks[name]
        lines.append(f"| `{name}` | {ld.kind} | {ld.path}:{ld.line} | "
                     f"{'yes' if ld.via_factory else 'no'} |")
    lines.append("")
    return "\n".join(lines)
