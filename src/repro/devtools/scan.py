"""AST scan producing the semantic model the lint rules consume.

One pass per module collects lock definitions (``threading.Lock`` /
``RLock`` / ``Condition`` aliases / ``make_lock("name")``), class
attribute types (``self.x = ClassName(...)``), resource constructions,
and imports.  A second pass walks every function body tracking

- the stack of locks held at each point (``with <lock>:`` regions, with
  explicit ``lock.release()`` / ``lock.acquire()`` toggling inside a
  region honored),
- every call made, with receiver chain, held-lock snapshot and the set
  of expressions guarded non-None at that point (for the fault-site
  rule),
- lock acquisition events with provenance.

Everything downstream — the acquisition graph, blocking-under-lock,
fault-site, atomic-counter and resource-lifecycle rules — reads this
model; no rule re-walks the AST.

Static model limits (documented, deliberate): lock identity is
class-level (``module.Class.attr``), not per-instance; receivers typed
only via ``self.attr = ClassName(...)`` assignments resolvable inside
the scanned tree (dict/parameter-typed objects are opaque); lambdas are
scanned in their enclosing context; nested ``def`` bodies run with an
empty held-lock stack (they execute later, not at definition).
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set, Tuple

LOCK_FACTORIES = {"make_lock": "lock", "make_rlock": "rlock"}
RAW_LOCK_CTORS = {"Lock": "lock", "RLock": "rlock"}

_PRAGMA_RE = re.compile(
    r"#\s*lint:\s*allow\(([\w\-, ]+)\)\s*(?::\s*(.*\S))?\s*$")


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str          # repo-relative posix path
    line: int
    scope: str         # module.Class.func (or module.Class / module)
    detail: str        # stable, line-independent discriminator
    message: str

    @property
    def fingerprint(self) -> str:
        return f"{self.rule}|{self.path}|{self.scope}|{self.detail}"

    def render(self) -> str:
        return (f"{self.path}:{self.line}: [{self.rule}] {self.message}"
                f"  ({self.scope})")


@dataclass
class LockDef:
    name: str          # canonical node name, e.g. "host._ShardProxy._order_lock"
    kind: str          # "lock" | "rlock"
    module: str
    cls: Optional[str]
    attr: str
    path: str
    line: int
    via_factory: bool  # created through make_lock/make_rlock


@dataclass
class CallInfo:
    line: int
    held: Tuple[str, ...]         # lock names held at the call
    recv: Optional[str]           # dotted receiver ("self.cos"), None for Name calls
    name: str                     # final attr / function name
    resolved: Optional[tuple]     # ("method",cls,meth) | ("attrmethod",cls,attr,meth)
                                  # | ("localfunc",qual) | ("func",mod,name)
    guarded: frozenset            # expr strings known non-None here
    arg0: Optional[str]           # first positional arg when a str constant
    kw_site: Optional[str]        # site= kwarg when a str constant
    kwargs: frozenset             # kwarg names present


@dataclass
class AcqEvent:
    lock: str
    line: int
    via: str                      # "with" | "acquire"
    held: Tuple[str, ...]         # locks already held when acquiring


@dataclass
class FuncModel:
    qualname: str                 # "Class.meth" | "func" | "outer.inner"
    module: str
    cls: Optional[str]
    path: str
    line: int
    acquires: List[AcqEvent] = field(default_factory=list)
    calls: List[CallInfo] = field(default_factory=list)
    # parameter names (incl. defaults-bound closure captures) — rules
    # may treat a parameter receiver as a caller-guaranteed value
    params: frozenset = frozenset()
    # fixed-point results (filled by link step)
    acquires_closure: Set[str] = field(default_factory=set)
    may_block: Optional[str] = None   # label of the first blocking call, or None


@dataclass
class ClassModel:
    name: str
    module: str
    path: str
    line: int
    methods: Set[str] = field(default_factory=set)
    # attr -> (module, Class) for self.attr = ClassName(...) assignments
    attr_types: Dict[str, Tuple[str, str]] = field(default_factory=dict)
    # attr -> lock name (includes Condition aliases)
    lock_attrs: Dict[str, str] = field(default_factory=dict)
    # resources constructed in __init__: attr -> (ctor name, line)
    init_resources: Dict[str, Tuple[str, int]] = field(default_factory=dict)
    # attrs assigned StoreStats() (for the atomic-counter rule)
    storestats_attrs: Set[str] = field(default_factory=set)


@dataclass
class ModuleModel:
    path: Path
    relpath: str
    modname: str
    tree: ast.Module
    classes: Dict[str, ClassModel] = field(default_factory=dict)
    funcs: Dict[str, FuncModel] = field(default_factory=dict)
    locks: Dict[str, LockDef] = field(default_factory=dict)
    module_lock_vars: Dict[str, str] = field(default_factory=dict)
    local_lock_vars: Dict[Tuple[str, str], str] = field(default_factory=dict)
    imports: Dict[str, Tuple[str, str]] = field(default_factory=dict)
    # line -> [(rule, reason-or-None)]
    pragmas: Dict[int, List[Tuple[str, Optional[str]]]] = \
        field(default_factory=dict)
    fault_manifest: Optional[Set[str]] = None
    metric_manifest: Optional[Set[str]] = None
    # AugAssign on <recv>.<attr>: (line, scope, recv, attr)
    augassigns: List[Tuple[int, str, str, str]] = field(default_factory=list)


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def dotted(node: ast.AST) -> Optional[str]:
    """'self.cos.put' -> 'self.cos' receiver chains; None if not a plain
    Name/Attribute chain."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_nonnull_test(test: ast.AST) -> Optional[str]:
    """'X is not None' -> dotted X."""
    if (isinstance(test, ast.Compare) and len(test.ops) == 1
            and isinstance(test.ops[0], ast.IsNot)
            and isinstance(test.comparators[0], ast.Constant)
            and test.comparators[0].value is None):
        return dotted(test.left)
    return None


def _is_null_test(test: ast.AST) -> Optional[str]:
    """'X is None' -> dotted X."""
    if (isinstance(test, ast.Compare) and len(test.ops) == 1
            and isinstance(test.ops[0], ast.Is)
            and isinstance(test.comparators[0], ast.Constant)
            and test.comparators[0].value is None):
        return dotted(test.left)
    return None


def _terminates(stmts: Sequence[ast.stmt]) -> bool:
    return bool(stmts) and isinstance(
        stmts[-1], (ast.Return, ast.Raise, ast.Continue, ast.Break))


def scan_pragmas(source: str) -> Dict[int, List[Tuple[str, Optional[str]]]]:
    out: Dict[int, List[Tuple[str, Optional[str]]]] = {}
    for i, line in enumerate(source.splitlines(), start=1):
        m = _PRAGMA_RE.search(line)
        if m:
            rules = [r.strip() for r in m.group(1).split(",") if r.strip()]
            reason = m.group(2)
            out[i] = [(r, reason) for r in rules]
    return out


# ---------------------------------------------------------------------------
# pass 1: declarations (locks, types, resources, imports, manifest)
# ---------------------------------------------------------------------------

class _DeclVisitor(ast.NodeVisitor):
    def __init__(self, mm: ModuleModel):
        self.mm = mm
        self.cls_stack: List[str] = []
        self.func_stack: List[str] = []

    # -- context -----------------------------------------------------------

    def _qual(self) -> str:
        return ".".join(self.func_stack)

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        if self.func_stack:          # classes inside functions: skip
            return
        cm = ClassModel(name=node.name, module=self.mm.modname,
                        path=self.mm.relpath, line=node.lineno)
        self.mm.classes[node.name] = cm
        self.cls_stack.append(node.name)
        self.generic_visit(node)
        self.cls_stack.pop()

    def _visit_func(self, node) -> None:
        cls = self.cls_stack[-1] if self.cls_stack else None
        if cls and not self.func_stack:
            self.mm.classes[cls].methods.add(node.name)
        self.func_stack.append(node.name)
        self.generic_visit(node)
        self.func_stack.pop()

    visit_FunctionDef = _visit_func
    visit_AsyncFunctionDef = _visit_func

    def visit_Import(self, node: ast.Import) -> None:
        for a in node.names:
            name = a.asname or a.name.split(".")[0]
            self.mm.imports[name] = (a.name, "")

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        mod = node.module or ""
        for a in node.names:
            self.mm.imports[a.asname or a.name] = (mod, a.name)

    # -- lock / type / resource extraction ---------------------------------

    def _lock_ctor(self, value: ast.AST) -> Optional[Tuple[str, Optional[str], Optional[ast.AST]]]:
        """Return (kind, factory-name-literal, condition-underlying-expr)
        when `value` constructs a lock/rlock/condition; else None."""
        if not isinstance(value, ast.Call):
            return None
        fn = value.func
        fname = fn.attr if isinstance(fn, ast.Attribute) else (
            fn.id if isinstance(fn, ast.Name) else None)
        if fname in LOCK_FACTORIES:
            lit = None
            if value.args and isinstance(value.args[0], ast.Constant) \
                    and isinstance(value.args[0].value, str):
                lit = value.args[0].value
            return (LOCK_FACTORIES[fname], lit, None)
        if fname in RAW_LOCK_CTORS and self._is_threading(fn):
            return (RAW_LOCK_CTORS[fname], None, None)
        if fname == "Condition" and self._is_threading(fn):
            under = value.args[0] if value.args else None
            return ("lock", None, under if under is not None else False)
        return None

    def _is_threading(self, fn: ast.AST) -> bool:
        if isinstance(fn, ast.Attribute):
            return dotted(fn.value) == "threading"
        if isinstance(fn, ast.Name):
            src = self.mm.imports.get(fn.id)
            return bool(src and src[0] == "threading")
        return False

    def _class_call(self, value: ast.AST) -> Optional[Tuple[str, Optional[str]]]:
        """(call name, base chain) when `value` (or a sub-expression of an
        IfExp/BoolOp) is `Name(...)` or `base.Name(...)` — e.g.
        ('Thread', 'threading'), ('create', 'ShmArena'), ('COS', None)."""
        for node in ast.walk(value) if isinstance(
                value, (ast.IfExp, ast.BoolOp)) else [value]:
            if not isinstance(node, ast.Call):
                continue
            fn = node.func
            if isinstance(fn, ast.Name):
                return (fn.id, None)
            if isinstance(fn, ast.Attribute):
                return (fn.attr, dotted(fn.value))
        return None

    def visit_Assign(self, node: ast.Assign) -> None:
        self.generic_visit(node)
        if len(node.targets) != 1:
            return
        tgt = node.targets[0]
        cls = self.cls_stack[-1] if self.cls_stack else None
        in_init = bool(self.func_stack) and self.func_stack[0] == "__init__"

        # site manifests: FAULT_SITES / METRIC_SITES = frozenset({...})
        if (isinstance(tgt, ast.Name)
                and tgt.id in ("FAULT_SITES", "METRIC_SITES")
                and not self.func_stack and not self.cls_stack):
            sites = {n.value for n in ast.walk(node.value)
                     if isinstance(n, ast.Constant)
                     and isinstance(n.value, str)}
            if tgt.id == "FAULT_SITES":
                self.mm.fault_manifest = sites
            else:
                self.mm.metric_manifest = sites
            return

        lock = self._lock_ctor(node.value)
        is_self_attr = (isinstance(tgt, ast.Attribute)
                        and isinstance(tgt.value, ast.Name)
                        and tgt.value.id == "self" and cls is not None)

        if lock is not None:
            kind, lit, cond_under = lock
            if cond_under not in (None,):
                # Condition: alias to the underlying lock when resolvable
                if cond_under is not False:
                    under = dotted(cond_under)
                    target_lock = None
                    if under and under.startswith("self.") and cls:
                        target_lock = self.mm.classes[cls].lock_attrs.get(
                            under[5:])
                    elif under:
                        target_lock = self._lookup_var(under)
                    if target_lock is not None:
                        self._bind_lock_target(tgt, cls, target_lock)
                        return
                # Condition() with its own implicit lock: fall through as
                # a fresh plain lock named after the attribute
            name = lit
            if is_self_attr:
                attr = tgt.attr
                if name is None:
                    name = f"{self.mm.modname}.{cls}.{attr}"
                self.mm.classes[cls].lock_attrs[attr] = name
                self.mm.locks[name] = LockDef(
                    name=name, kind=kind, module=self.mm.modname, cls=cls,
                    attr=attr, path=self.mm.relpath, line=node.lineno,
                    via_factory=lit is not None)
            elif isinstance(tgt, ast.Name):
                var = tgt.id
                if self.func_stack:
                    qual = (f"{cls}.{self._qual()}" if cls else self._qual())
                    if name is None:
                        name = f"{self.mm.modname}.{qual}.{var}"
                    self.mm.local_lock_vars[(qual, var)] = name
                else:
                    if name is None:
                        name = f"{self.mm.modname}.{var}"
                    self.mm.module_lock_vars[var] = name
                self.mm.locks[name] = LockDef(
                    name=name, kind=kind, module=self.mm.modname, cls=None,
                    attr=var, path=self.mm.relpath, line=node.lineno,
                    via_factory=lit is not None)
            return

        if is_self_attr:
            attr = tgt.attr
            cm = self.mm.classes[cls]
            called = self._class_call(node.value)
            if called is not None:
                cname, base = called
                if in_init and cname in ("Thread", "ThreadPoolExecutor",
                                         "SharedMemory"):
                    cm.init_resources[attr] = (cname, node.lineno)
                if cname == "StoreStats":
                    cm.storestats_attrs.add(attr)
                # `ClassName(...)` or `ClassName.classmethod(...)`
                resolved = self._resolve_class(cname)
                if resolved is None and base is not None and "." not in base:
                    resolved = self._resolve_class(base)
                if resolved is not None:
                    cm.attr_types[attr] = resolved

    def _bind_lock_target(self, tgt, cls, lockname: str) -> None:
        if (isinstance(tgt, ast.Attribute) and isinstance(tgt.value, ast.Name)
                and tgt.value.id == "self" and cls is not None):
            self.mm.classes[cls].lock_attrs[tgt.attr] = lockname
        elif isinstance(tgt, ast.Name):
            if self.func_stack:
                qual = (f"{cls}.{self._qual()}" if cls else self._qual())
                self.mm.local_lock_vars[(qual, tgt.id)] = lockname
            else:
                self.mm.module_lock_vars[tgt.id] = lockname

    def _lookup_var(self, var: str) -> Optional[str]:
        cls = self.cls_stack[-1] if self.cls_stack else None
        if self.func_stack:
            qual = (f"{cls}.{self._qual()}" if cls else self._qual())
            hit = self.mm.local_lock_vars.get((qual, var))
            if hit:
                return hit
        return self.mm.module_lock_vars.get(var)

    def _resolve_class(self, name: str) -> Optional[Tuple[str, str]]:
        """Map a local class name to (module, Class); linked globally later."""
        if name in self.mm.classes:
            return (self.mm.modname, name)
        src = self.mm.imports.get(name)
        if src and src[1]:
            return (src[0].split(".")[-1], src[1])
        return None


# ---------------------------------------------------------------------------
# pass 2: function-body walk (held locks, calls, guards, acquisitions)
# ---------------------------------------------------------------------------

class _FuncWalker:
    def __init__(self, mm: ModuleModel, fm: FuncModel,
                 lock_scope: Dict[str, str]):
        self.mm = mm
        self.fm = fm
        self.lock_scope = dict(lock_scope)   # local var -> lock name
        self.nested: List[Tuple[ast.AST, str, Dict[str, str]]] = []

    # -- lock expression resolution ----------------------------------------

    def _resolve_lock(self, expr: ast.AST) -> Optional[str]:
        d = dotted(expr)
        if d is None:
            return None
        if d.startswith("self.") and self.fm.cls:
            cm = self.mm.classes.get(self.fm.cls)
            if cm:
                return cm.lock_attrs.get(d[5:])
            return None
        if "." not in d:
            if d in self.lock_scope:
                return self.lock_scope[d]
            return self.mm.module_lock_vars.get(d)
        return None

    # -- statement walking --------------------------------------------------

    def walk(self, body: Sequence[ast.stmt], held: Tuple[str, ...],
             guards: frozenset) -> None:
        self._stmts(body, list(held), guards)

    def _stmts(self, body: Sequence[ast.stmt], held: List[str],
               guards: frozenset) -> None:
        for stmt in body:
            # bare `L.release()` / `L.acquire()` statements bracket a
            # region within this list (e.g. a with-body that explicitly
            # drops the lock around a blocking call and re-takes it in a
            # `finally`) — honored at any nesting depth
            tog = self._toggle(stmt)
            if tog is not None:
                name, op = tog
                if op == "release" and name in held:
                    held.remove(name)
                    continue
                if op == "acquire" and name not in held:
                    self.fm.acquires.append(AcqEvent(
                        lock=name, line=stmt.lineno, via="acquire",
                        held=tuple(held)))
                    held.append(name)
                    continue
            guards = self._stmt(stmt, held, guards)

    def _stmt(self, stmt: ast.stmt, held: List[str],
              guards: frozenset) -> frozenset:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            qual = f"{self.fm.qualname}.{stmt.name}"
            self.nested.append((stmt, qual, dict(self.lock_scope)))
            return guards
        if isinstance(stmt, ast.With):
            return self._with(stmt, held, guards)
        if isinstance(stmt, ast.If):
            nn = _is_nonnull_test(stmt.test)
            null = _is_null_test(stmt.test)
            self._expr(stmt.test, held, guards)
            nns = {nn} if nn else set()
            if not nns and isinstance(stmt.test, ast.BoolOp) \
                    and isinstance(stmt.test.op, ast.And):
                # `if X is not None and <...>:` — every non-null
                # conjunct guards the body
                nns = {g for g in (_is_nonnull_test(v)
                                   for v in stmt.test.values) if g}
            if nns:
                self._stmts(stmt.body, held, guards | nns)
                self._stmts(stmt.orelse, held, guards)
                return guards
            self._stmts(stmt.body, held, guards)
            self._stmts(stmt.orelse, held,
                        guards | ({null} if null else set()))
            if null and _terminates(stmt.body):
                return guards | {null}   # `if X is None: return` pattern
            return guards
        if isinstance(stmt, (ast.For, ast.AsyncFor)):
            self._expr(stmt.iter, held, guards)
            self._stmts(stmt.body, held, guards)
            self._stmts(stmt.orelse, held, guards)
            return guards
        if isinstance(stmt, ast.While):
            self._expr(stmt.test, held, guards)
            self._stmts(stmt.body, held, guards)
            self._stmts(stmt.orelse, held, guards)
            return guards
        if isinstance(stmt, ast.Try):
            self._stmts(stmt.body, held, guards)
            for h in stmt.handlers:
                self._stmts(h.body, held, guards)
            self._stmts(stmt.orelse, held, guards)
            self._stmts(stmt.finalbody, held, guards)
            return guards
        if isinstance(stmt, ast.AugAssign):
            recv_attr = stmt.target
            if isinstance(recv_attr, ast.Attribute):
                recv = dotted(recv_attr.value)
                if recv is not None:
                    self.mm.augassigns.append(
                        (stmt.lineno,
                         f"{self.mm.modname}.{self.fm.qualname}",
                         recv, recv_attr.attr))
            self._expr(stmt.value, held, guards)
            return guards
        # everything else: walk expressions for calls
        for child in ast.iter_child_nodes(stmt):
            if isinstance(child, ast.expr):
                self._expr(child, held, guards)
            elif isinstance(child, ast.stmt):
                self._stmt(child, held, guards)
        return guards

    def _with(self, stmt: ast.With, held: List[str],
              guards: frozenset) -> frozenset:
        pushed: List[str] = []
        for item in stmt.items:
            self._expr(item.context_expr, held, guards)
            lock = self._resolve_lock(item.context_expr)
            if lock is not None and lock not in held:
                self.fm.acquires.append(AcqEvent(
                    lock=lock, line=stmt.lineno, via="with",
                    held=tuple(held)))
                held.append(lock)
                pushed.append(lock)
        self._stmts(stmt.body, held, guards)
        for lock in pushed:
            if lock in held:         # a nested toggle may have dropped it
                held.remove(lock)
        return guards

    def _toggle(self, stmt: ast.stmt) -> Optional[Tuple[str, str]]:
        """`L.release()` / `L.acquire()` as a bare statement on a lock."""
        if not (isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Call)):
            return None
        call = stmt.value
        if not (isinstance(call.func, ast.Attribute)
                and call.func.attr in ("release", "acquire")):
            return None
        lock = self._resolve_lock(call.func.value)
        if lock is None:
            return None
        return (lock, call.func.attr)

    # -- expression walking --------------------------------------------------

    def _expr(self, node: ast.expr, held: List[str],
              guards: frozenset) -> None:
        if isinstance(node, ast.Call):
            self._call(node, held, guards)
            return
        if isinstance(node, ast.BoolOp) and isinstance(node.op, ast.And):
            g = guards
            for v in node.values:
                self._expr(v, held, g)
                nn = _is_nonnull_test(v)
                if nn:
                    g = g | {nn}
            return
        if isinstance(node, ast.IfExp):
            nn = _is_nonnull_test(node.test)
            self._expr(node.test, held, guards)
            self._expr(node.body, held, guards | ({nn} if nn else set()))
            self._expr(node.orelse, held, guards)
            return
        if isinstance(node, ast.Lambda):
            self._expr(node.body, held, guards)
            return
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.expr):
                self._expr(child, held, guards)
            elif isinstance(child, ast.comprehension):
                self._expr(child.iter, held, guards)
                for c in child.ifs:
                    self._expr(c, held, guards)

    def _call(self, node: ast.Call, held: List[str],
              guards: frozenset) -> None:
        fn = node.func
        recv = None
        name = None
        resolved = None
        if isinstance(fn, ast.Attribute):
            name = fn.attr
            recv = dotted(fn.value)
            if recv == "self" and self.fm.cls:
                resolved = ("method", self.fm.cls, name)
            elif recv and recv.startswith("self.") and self.fm.cls \
                    and recv.count(".") == 1:
                resolved = ("attrmethod", self.fm.cls, recv[5:], name)
            # explicit acquire events outside `with` (edge provenance)
            if name == "acquire":
                lock = self._resolve_lock(fn.value)
                if lock is not None and lock not in held:
                    self.fm.acquires.append(AcqEvent(
                        lock=lock, line=node.lineno, via="acquire",
                        held=tuple(held)))
        elif isinstance(fn, ast.Name):
            name = fn.id
            # bare-name calls resolve at link time (the target function
            # may be defined later in the file / in another module)
            resolved = ("name", name)
        if name is not None:
            arg0 = None
            if node.args and isinstance(node.args[0], ast.Constant) \
                    and isinstance(node.args[0].value, str):
                arg0 = node.args[0].value
            kw_site = None
            kwargs = set()
            for kw in node.keywords:
                if kw.arg:
                    kwargs.add(kw.arg)
                    if kw.arg == "site" and isinstance(kw.value, ast.Constant) \
                            and isinstance(kw.value.value, str):
                        kw_site = kw.value.value
            self.fm.calls.append(CallInfo(
                line=node.lineno, held=tuple(held), recv=recv, name=name,
                resolved=resolved, guarded=frozenset(guards), arg0=arg0,
                kw_site=kw_site, kwargs=frozenset(kwargs)))
        for a in node.args:
            self._expr(a, held, guards)
        for kw in node.keywords:
            self._expr(kw.value, held, guards)
        if isinstance(fn, (ast.Attribute, ast.Subscript)):
            self._expr(fn.value, held, guards)


# ---------------------------------------------------------------------------
# module + tree scan
# ---------------------------------------------------------------------------

def scan_module(path: Path, root: Path) -> ModuleModel:
    source = path.read_text()
    tree = ast.parse(source, filename=str(path))
    try:
        rel = path.relative_to(root).as_posix()
    except ValueError:
        rel = path.as_posix()
    mm = ModuleModel(path=path, relpath=rel, modname=path.stem, tree=tree)
    mm.pragmas = scan_pragmas(source)
    _DeclVisitor(mm).visit(tree)

    # queue every function (methods, module funcs), walk with nesting
    queue: List[Tuple[ast.AST, str, Optional[str], Dict[str, str]]] = []
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            queue.append((node, node.name, None, {}))
        elif isinstance(node, ast.ClassDef):
            for sub in node.body:
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    queue.append((sub, f"{node.name}.{sub.name}",
                                  node.name, {}))
    while queue:
        node, qual, cls, scope = queue.pop(0)
        # local lock vars declared anywhere in this function body
        local_scope = dict(scope)
        for (q, var), lockname in mm.local_lock_vars.items():
            if q == qual:
                local_scope[var] = lockname
        a = node.args
        params = frozenset(
            p.arg for p in (*a.posonlyargs, *a.args, *a.kwonlyargs))
        fm = FuncModel(qualname=qual, module=mm.modname, cls=cls,
                       path=mm.relpath, line=node.lineno, params=params)
        mm.funcs[qual] = fm
        walker = _FuncWalker(mm, fm, local_scope)
        walker.walk(node.body, held=(), guards=frozenset())
        for sub, subqual, subscope in walker.nested:
            queue.append((sub, subqual, cls, subscope))
    return mm


def iter_py_files(targets: Sequence[str]) -> List[Path]:
    out: List[Path] = []
    for t in targets:
        p = Path(t)
        if p.is_dir():
            out.extend(sorted(f for f in p.rglob("*.py")
                              if "__pycache__" not in f.parts))
        elif p.suffix == ".py":
            out.append(p)
    return out


@dataclass
class TreeModel:
    root: Path
    modules: Dict[str, ModuleModel]           # modname -> model
    classes: Dict[Tuple[str, str], ClassModel] = field(default_factory=dict)
    funcs: Dict[Tuple[str, str], FuncModel] = field(default_factory=dict)
    locks: Dict[str, LockDef] = field(default_factory=dict)
    fault_manifest: Set[str] = field(default_factory=set)
    metric_manifest: Set[str] = field(default_factory=set)

    def pragma_for(self, mm: ModuleModel, rule: str,
                   line: int) -> Optional[Tuple[str, Optional[str]]]:
        """A pragma waives a finding from its own line or the line above."""
        for ln in (line, line - 1):
            for r, reason in mm.pragmas.get(ln, ()):
                if r == rule:
                    return (r, reason)
        return None


def scan_tree(targets: Sequence[str], root: Optional[Path] = None) -> TreeModel:
    files = iter_py_files(targets)
    root = root or Path.cwd()
    modules: Dict[str, ModuleModel] = {}
    for f in files:
        mm = scan_module(f, root)
        if mm.modname in modules:
            # same-stem collision (e.g. package __init__): suffix it
            mm.modname = f"{f.parent.name}.{f.stem}"
        modules[mm.modname] = mm
    tm = TreeModel(root=root, modules=modules)
    for mm in modules.values():
        for cname, cm in mm.classes.items():
            tm.classes[(mm.modname, cname)] = cm
        for qual, fmod in mm.funcs.items():
            tm.funcs[(mm.modname, qual)] = fmod
        for name, ld in mm.locks.items():
            tm.locks[name] = ld
        if mm.fault_manifest:
            tm.fault_manifest |= mm.fault_manifest
        if mm.metric_manifest:
            tm.metric_manifest |= mm.metric_manifest
    _link(tm)
    return tm


# ---------------------------------------------------------------------------
# link step: resolve calls across modules, fixed-point closures
# ---------------------------------------------------------------------------

def resolve_callee(tm: TreeModel, mm: ModuleModel,
                   fm: FuncModel, ci: CallInfo) -> Optional[FuncModel]:
    r = ci.resolved
    if r is None:
        return None
    if r[0] == "method":
        return tm.funcs.get((mm.modname, f"{r[1]}.{r[2]}"))
    if r[0] == "attrmethod":
        _, cls, attr, meth = r
        cm = tm.classes.get((mm.modname, cls))
        if cm is None:
            return None
        t = cm.attr_types.get(attr)
        if t is None:
            return None
        return tm.funcs.get((t[0], f"{t[1]}.{meth}"))
    if r[0] == "name":
        name = r[1]
        # sibling nested function (closure), then module-level function,
        # then an imported module-level function
        if "." in fm.qualname:
            parent = fm.qualname.rsplit(".", 1)[0]
            hit = tm.funcs.get((mm.modname, f"{parent}.{name}"))
            if hit is not None:
                return hit
        hit = tm.funcs.get((mm.modname, name))
        if hit is not None:
            return hit
        src = mm.imports.get(name)
        if src and src[1]:
            return tm.funcs.get((src[0].split(".")[-1], src[1]))
        return None
    return None


def _link(tm: TreeModel) -> None:
    # resolve cross-module attr types: ("spill", "SpillJournal") keys are
    # already module-stem based; nothing further needed here. Compute the
    # acquires closure to a fixed point.
    for fmod in tm.funcs.values():
        fmod.acquires_closure = {a.lock for a in fmod.acquires}
    changed = True
    while changed:
        changed = False
        for (modname, _), fmod in tm.funcs.items():
            mm = tm.modules[modname]
            for ci in fmod.calls:
                callee = resolve_callee(tm, mm, fmod, ci)
                if callee is None:
                    continue
                add = callee.acquires_closure - fmod.acquires_closure
                if add:
                    fmod.acquires_closure |= add
                    changed = True
