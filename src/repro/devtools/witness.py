"""Runtime lock-order witness: validates the static hierarchy under load.

Install with `repro.core.locks.install_witness(LockWitness.with_static_order())`
BEFORE constructing the stores under test; every lock created through
`make_lock`/`make_rlock` afterwards reports its acquisitions here.  The
witness keeps a per-thread stack of held locks and, for each
acquisition of B while holding A, records the ordered pair (A, B).  An
**inversion** is flagged when

- the pair (B, A) was already observed at runtime (both orders really
  happen — a deadlock is one unlucky interleaving away), or
- the static acquisition graph orders B strictly before A (the code
  contradicts the hierarchy `istore-lint` derived — either the code or
  the model is wrong, and CI should say so before a deadlock does).

Reentrant re-acquisition of an already-held name (RLocks) is not a
pair.  Locks unknown to the static model participate in the dynamic
check only.  The witness itself is lock-protected but its internal
mutex is never held while taking a witnessed lock, so it adds no
ordering of its own.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, FrozenSet, List, Optional, Tuple

_static_order_cache: Optional[Dict[str, FrozenSet[str]]] = None


def load_static_order() -> Dict[str, FrozenSet[str]]:
    """Transitive closure of the acquisition graph of the installed
    `repro` package (cached: one AST scan, milliseconds)."""
    global _static_order_cache
    if _static_order_cache is None:
        import repro
        from repro.devtools import lockgraph
        # `repro` may be a namespace package (__file__ is None): take
        # the first __path__ entry instead.
        pkg = Path(next(iter(repro.__path__)))
        _static_order_cache = lockgraph.static_order(
            [str(pkg)], root=pkg.parent)
    return _static_order_cache


@dataclass
class Inversion:
    first: str                  # lock held
    second: str                 # lock acquired under it
    kind: str                   # "static" | "dynamic"
    thread: str
    note: str = ""

    def render(self) -> str:
        return (f"[{self.kind}] acquired {self.second} while holding "
                f"{self.first} in thread {self.thread}: {self.note}")


class LockWitness:
    """Records acquisition orders; detects inversions (see module doc)."""

    def __init__(self, order: Optional[Dict[str, FrozenSet[str]]] = None):
        # order[a] = set of locks acquired after a on some static path
        self._order = {k: frozenset(v) for k, v in (order or {}).items()}
        self._tls = threading.local()
        self._mu = threading.Lock()
        # ordered pair -> first provenance (thread name)
        self._pairs: Dict[Tuple[str, str], str] = {}
        self._inversions: List[Inversion] = []

    @classmethod
    def with_static_order(cls) -> "LockWitness":
        return cls(order=load_static_order())

    # -- static order helpers ----------------------------------------------

    def _static_before(self, a: str, b: str) -> bool:
        """True iff the static graph orders a strictly before b."""
        fwd = b in self._order.get(a, ())
        rev = a in self._order.get(b, ())
        return fwd and not rev

    # -- hook interface (called by locks._WitnessedLock) -------------------

    def _stack(self) -> List[List]:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def on_acquire(self, name: str) -> None:
        stack = self._stack()
        for entry in stack:
            if entry[0] == name:
                entry[1] += 1          # reentrant RLock re-acquisition
                return
        held = [e[0] for e in stack]
        if held:
            tname = threading.current_thread().name
            with self._mu:
                for h in held:
                    pair = (h, name)
                    if pair not in self._pairs:
                        self._pairs[pair] = tname
                    rev = self._pairs.get((name, h))
                    if rev is not None:
                        self._inversions.append(Inversion(
                            first=h, second=name, kind="dynamic",
                            thread=tname,
                            note=(f"reverse order {name} -> {h} was "
                                  f"observed earlier in thread {rev}")))
                    elif self._static_before(name, h):
                        self._inversions.append(Inversion(
                            first=h, second=name, kind="static",
                            thread=tname,
                            note=(f"the static hierarchy orders {name} "
                                  f"before {h}")))
        stack.append([name, 1])

    def on_release(self, name: str) -> None:
        stack = self._stack()
        for i in range(len(stack) - 1, -1, -1):
            if stack[i][0] == name:
                stack[i][1] -= 1
                if stack[i][1] == 0:
                    del stack[i]
                return
        # release of a lock this thread never acquired through the
        # witness (e.g. handed across threads): ignore

    # -- results -----------------------------------------------------------

    @property
    def pairs_observed(self) -> int:
        with self._mu:
            return len(self._pairs)

    def inversions(self) -> List[Inversion]:
        with self._mu:
            return list(self._inversions)

    def assert_clean(self) -> None:
        inv = self.inversions()
        if inv:
            raise AssertionError(
                "lock-order inversions observed:\n  " +
                "\n  ".join(i.render() for i in inv))

    def snapshot(self) -> dict:
        with self._mu:
            return {"pairs_observed": len(self._pairs),
                    "inversions": [i.render() for i in self._inversions]}
