"""Rules 2-5: blocking-under-lock, fault-site, atomic-counter,
resource-lifecycle.  All consume the `repro.devtools.scan` model."""
from __future__ import annotations

from typing import Dict, List, Optional, Set, Tuple

from repro.devtools.scan import (CallInfo, Finding, FuncModel, ModuleModel,
                                 TreeModel, resolve_callee)

# ---------------------------------------------------------------------------
# rule: blocking-under-lock
# ---------------------------------------------------------------------------

# attribute calls that block: sleeps, socket/pipe sends+receives,
# future.result(), journal sync(), fsync
BLOCKING_ATTRS = {
    "sleep", "sendall", "send", "recv", "recv_bytes", "recv_into",
    "accept", "connect", "create_connection", "result", "sync", "fsync",
}
# bare-name function calls that block (module-level helpers)
BLOCKING_FUNCS = {"send_frame", "recv_frame", "sleep", "fsync",
                  "create_connection"}
# COS I/O: these methods on a receiver chain ending in `cos`
COS_METHODS = {"put", "get", "put_async", "get_async", "delete",
               "list_keys", "exists", "read_through"}


def _direct_block_label(ci: CallInfo) -> Optional[str]:
    if ci.recv is not None:
        if ci.recv == "self":
            return None          # self-method calls go through propagation
        base = ci.recv.split(".")[-1]
        if ci.name in COS_METHODS and (base == "cos" or base.endswith("_cos")):
            return f"COS I/O {ci.recv}.{ci.name}()"
        if ci.name in BLOCKING_ATTRS:
            return f"{ci.recv}.{ci.name}()"
        return None
    if ci.name in BLOCKING_FUNCS:
        return f"{ci.name}()"
    return None


def _compute_may_block(tm: TreeModel) -> Dict[Tuple[str, str], str]:
    """qual-key -> label of a blocking call reachable from the function
    body (pragma'd sites excluded — a waiver covers its callers)."""
    out: Dict[Tuple[str, str], str] = {}
    for key, fm in tm.funcs.items():
        mm = tm.modules[key[0]]
        for ci in fm.calls:
            label = _direct_block_label(ci)
            if label is None:
                continue
            if tm.pragma_for(mm, "blocking-under-lock", ci.line) is not None:
                continue
            out[key] = label
            break
    changed = True
    while changed:
        changed = False
        for key, fm in tm.funcs.items():
            if key in out:
                continue
            mm = tm.modules[key[0]]
            for ci in fm.calls:
                callee = resolve_callee(tm, mm, fm, ci)
                if callee is None:
                    continue
                ckey = (callee.module, callee.qualname)
                if ckey in out:
                    if tm.pragma_for(mm, "blocking-under-lock",
                                     ci.line) is not None:
                        continue
                    out[key] = (f"{callee.module}.{callee.qualname}() "
                                f"-> {out[ckey]}")
                    changed = True
                    break
    return out


def blocking_under_lock(tm: TreeModel) -> List[Finding]:
    may_block = _compute_may_block(tm)
    findings: List[Finding] = []
    for (modname, qual), fm in tm.funcs.items():
        mm = tm.modules[modname]
        scope = f"{modname}.{qual}"
        flagged_lines: Set[int] = set()
        for ci in fm.calls:
            if not ci.held:
                continue
            label = _direct_block_label(ci)
            if label is not None:
                findings.append(Finding(
                    rule="blocking-under-lock", path=fm.path, line=ci.line,
                    scope=scope,
                    detail=f"{ci.held[-1]}|{ci.recv or ''}.{ci.name}",
                    message=(f"{label} while holding {ci.held[-1]}")))
                flagged_lines.add(ci.line)
                continue
            callee = resolve_callee(tm, mm, fm, ci)
            if callee is None:
                continue
            ckey = (callee.module, callee.qualname)
            if ckey in may_block and ci.line not in flagged_lines:
                findings.append(Finding(
                    rule="blocking-under-lock", path=fm.path, line=ci.line,
                    scope=scope,
                    detail=f"{ci.held[-1]}|call:{ckey[0]}.{ckey[1]}",
                    message=(f"call to {ckey[0]}.{ckey[1]}() while holding "
                             f"{ci.held[-1]} — it may block "
                             f"({may_block[ckey]})")))
                flagged_lines.add(ci.line)
    return findings


# ---------------------------------------------------------------------------
# rule: fault-site
# ---------------------------------------------------------------------------

def _requires_match(site: str) -> bool:
    return site.startswith("net.") or site.startswith("hb")


def fault_site(tm: TreeModel) -> List[Finding]:
    findings: List[Finding] = []
    manifest = tm.fault_manifest
    for (modname, qual), fm in tm.funcs.items():
        if modname == "faults":
            continue             # the plan's own internals are exempt
        scope = f"{modname}.{qual}"
        for ci in fm.calls:
            if ci.name == "fire" and ci.recv is not None:
                if ci.recv not in ci.guarded:
                    findings.append(Finding(
                        rule="fault-site", path=fm.path, line=ci.line,
                        scope=scope, detail=f"unguarded:{ci.recv}",
                        message=(f"{ci.recv}.fire() without an enclosing "
                                 f"`{ci.recv} is not None` guard — a "
                                 f"plan-less run would crash here")))
                if ci.arg0 is None:
                    findings.append(Finding(
                        rule="fault-site", path=fm.path, line=ci.line,
                        scope=scope, detail=f"nonliteral:{ci.recv}",
                        message=(f"{ci.recv}.fire() site is not a string "
                                 f"literal — the manifest check cannot "
                                 f"see it")))
                elif manifest and ci.arg0 not in manifest:
                    findings.append(Finding(
                        rule="fault-site", path=fm.path, line=ci.line,
                        scope=scope, detail=f"unregistered:{ci.arg0}",
                        message=(f"fire site {ci.arg0!r} is not in "
                                 f"faults.FAULT_SITES — a typo'd site "
                                 f"silently never fires")))
            if ci.name == "FaultPoint":
                site = ci.kw_site or ci.arg0
                if site is None:
                    continue
                if manifest and site not in manifest:
                    findings.append(Finding(
                        rule="fault-site", path=fm.path, line=ci.line,
                        scope=scope, detail=f"point-unregistered:{site}",
                        message=(f"FaultPoint site {site!r} is not in "
                                 f"faults.FAULT_SITES")))
                if _requires_match(site) and "match" not in ci.kwargs:
                    findings.append(Finding(
                        rule="fault-site", path=fm.path, line=ci.line,
                        scope=scope, detail=f"point-no-match:{site}",
                        message=(f"FaultPoint site {site!r} must set "
                                 f"`match=` — unmatched heartbeat traffic "
                                 f"would consume hit indices and break "
                                 f"log determinism")))
    return findings


# ---------------------------------------------------------------------------
# rule: metric-site
# ---------------------------------------------------------------------------

_OBS_CALLS = ("span", "record", "event")


def _is_obs_recv(recv: str) -> bool:
    """True for receivers that name an ObsPlane handle by convention:
    `obs`, `self.obs`, `self._obs`, `store.obs`, ..."""
    return recv.rsplit(".", 1)[-1] in ("obs", "_obs")


def metric_site(tm: TreeModel) -> List[Finding]:
    """Every `<obs>.span/record/event(...)` instrumentation site must
    (a) sit under an `<obs> is not None` guard (the plane is optional
    and off by default), and (b) pass a literal site name registered in
    `repro.obs.sites.METRIC_SITES` — a typo'd site would silently
    record into nothing (span/event) or KeyError at runtime (record)."""
    findings: List[Finding] = []
    manifest = tm.metric_manifest
    for (modname, qual), fm in tm.funcs.items():
        if "obs/" in fm.path.replace("\\", "/"):
            continue             # the plane's own internals are exempt
        scope = f"{modname}.{qual}"
        for ci in fm.calls:
            if ci.name not in _OBS_CALLS or ci.recv is None \
                    or not _is_obs_recv(ci.recv):
                continue
            # a parameter-bound plane (callback closures with `obs=obs`
            # defaults) is the caller's contract: the binding site only
            # exists inside the caller's own non-None guard
            if ci.recv not in ci.guarded and ci.recv not in fm.params:
                findings.append(Finding(
                    rule="metric-site", path=fm.path, line=ci.line,
                    scope=scope, detail=f"unguarded:{ci.recv}",
                    message=(f"{ci.recv}.{ci.name}() without an "
                             f"enclosing `{ci.recv} is not None` guard "
                             f"— a plane-less store would crash here")))
            if ci.arg0 is None:
                findings.append(Finding(
                    rule="metric-site", path=fm.path, line=ci.line,
                    scope=scope, detail=f"nonliteral:{ci.recv}",
                    message=(f"{ci.recv}.{ci.name}() site is not a "
                             f"string literal — the manifest check "
                             f"cannot see it")))
            elif manifest and ci.arg0 not in manifest:
                findings.append(Finding(
                    rule="metric-site", path=fm.path, line=ci.line,
                    scope=scope, detail=f"unregistered:{ci.arg0}",
                    message=(f"site {ci.arg0!r} is not in "
                             f"obs.METRIC_SITES — register it or fix "
                             f"the typo (unregistered names never "
                             f"surface in the export)")))
    return findings


# ---------------------------------------------------------------------------
# rule: atomic-counter
# ---------------------------------------------------------------------------

def atomic_counter(tm: TreeModel) -> List[Finding]:
    findings: List[Finding] = []
    for modname, mm in tm.modules.items():
        for (line, scope, recv, attr) in mm.augassigns:
            # scope is "module.qualname"; find the owning class
            qual = scope[len(modname) + 1:]
            fm = mm.funcs.get(qual)
            if fm is None or fm.cls is None:
                continue
            if not recv.startswith("self.") or recv.count(".") != 1:
                continue
            stats_attr = recv[5:]
            cm = mm.classes.get(fm.cls)
            if cm is None or stats_attr not in cm.storestats_attrs:
                continue
            findings.append(Finding(
                rule="atomic-counter", path=mm.relpath, line=line,
                scope=scope, detail=f"rmw:{stats_attr}.{attr}",
                message=(f"read-modify-write on StoreStats counter "
                         f"{recv}.{attr} — lost updates under "
                         f"concurrency; use {recv}.inc({attr!r})")))
    return findings


# ---------------------------------------------------------------------------
# rule: resource-lifecycle
# ---------------------------------------------------------------------------

TEARDOWN_ROOTS = ("close", "shutdown", "stop", "__exit__")
TEARDOWN_CALLS = {"join", "shutdown", "close", "unlink", "stop",
                  "terminate", "kill", "cancel"}


def _reachable_methods(tm: TreeModel, mm: ModuleModel,
                       cls: str) -> Set[str]:
    roots = [r for r in TEARDOWN_ROOTS
             if f"{cls}.{r}" in mm.funcs]
    seen: Set[str] = set(roots)
    queue = list(roots)
    while queue:
        meth = queue.pop(0)
        fm = mm.funcs.get(f"{cls}.{meth}")
        if fm is None:
            continue
        for ci in fm.calls:
            if ci.resolved and ci.resolved[0] == "method" \
                    and ci.resolved[1] == cls:
                m = ci.resolved[2]
                if m not in seen:
                    seen.add(m)
                    queue.append(m)
    return seen


def resource_lifecycle(tm: TreeModel) -> List[Finding]:
    findings: List[Finding] = []
    for (modname, cname), cm in tm.classes.items():
        if not cm.init_resources:
            continue
        mm = tm.modules[modname]
        reach = _reachable_methods(tm, mm, cname)
        torn_down: Set[str] = set()
        for meth in reach:
            fm = mm.funcs.get(f"{cname}.{meth}")
            if fm is None:
                continue
            for ci in fm.calls:
                if ci.recv and ci.recv.startswith("self.") \
                        and ci.name in TEARDOWN_CALLS:
                    torn_down.add(ci.recv[5:])
        for attr, (ctor, line) in sorted(cm.init_resources.items()):
            if attr in torn_down:
                continue
            roots = [r for r in TEARDOWN_ROOTS if r in cm.methods]
            why = (f"no {'/'.join(TEARDOWN_ROOTS[:2])} method on the class"
                   if not roots else
                   f"not reachable from {'/'.join(roots)}")
            findings.append(Finding(
                rule="resource-lifecycle", path=cm.path, line=line,
                scope=f"{modname}.{cname}", detail=f"leak:{attr}:{ctor}",
                message=(f"{ctor} in self.{attr} (constructed in __init__) "
                         f"has no join/shutdown/unlink {why} — leaked on "
                         f"close")))
    return findings
