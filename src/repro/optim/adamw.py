"""AdamW with fp32 master weights, ZeRO-sharded via the logical-axis rules.

The optimizer state mirrors the parameter tree, so the same sharding rules
apply: every 2D+ matrix is sharded over (data, model) — classic ZeRO —
without any bespoke partitioning code. Gradient clipping is global-norm.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


def adamw_init(params: PyTree) -> Dict[str, PyTree]:
    # NOTE: jnp.zeros would hand mu and nu the SAME cached constant buffer,
    # which breaks donate_argnums ("donate the same buffer twice"); route
    # through numpy so every leaf owns distinct storage.
    import numpy as np
    f32 = lambda p: jax.device_put(np.zeros(p.shape, np.float32))
    # copy=True: astype(f32) on f32 params would ALIAS them (double-donate)
    master = jax.tree.map(
        lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params)
    return {
        "mu": jax.tree.map(f32, params),
        "nu": jax.tree.map(f32, params),
        "master": master,
        "count": jnp.zeros((), jnp.int32),
    }


def abstract_opt_state(abstract_params: PyTree) -> Dict[str, PyTree]:
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(f32, abstract_params),
        "nu": jax.tree.map(f32, abstract_params),
        "master": jax.tree.map(f32, abstract_params),
        "count": jax.ShapeDtypeStruct((), jnp.int32),
    }


def opt_logical_axes(param_axes: PyTree) -> Dict[str, PyTree]:
    def is_leaf(x):
        return isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x)
    ident = jax.tree.map(lambda a: a, param_axes, is_leaf=is_leaf)
    return {"mu": ident, "nu": ident, "master": ident, "count": ()}


def _schedule(cfg: AdamWConfig, count):
    warm = jnp.minimum(count.astype(jnp.float32) / max(cfg.warmup_steps, 1),
                       1.0)
    return cfg.lr * warm


def adamw_update(cfg: AdamWConfig, grads: PyTree, state: Dict[str, PyTree],
                 params: PyTree) -> Tuple[PyTree, Dict[str, PyTree], Dict]:
    g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                         for g in jax.tree.leaves(g32)))
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    g32 = jax.tree.map(lambda g: g * scale, g32)
    count = state["count"] + 1
    lr = _schedule(cfg, count)
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, mu, nu, master):
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mhat = mu / b1c
        nhat = nu / b2c
        step = mhat / (jnp.sqrt(nhat) + cfg.eps) + cfg.weight_decay * master
        master = master - lr * step
        return mu, nu, master

    out = jax.tree.map(upd, g32, state["mu"], state["nu"], state["master"])
    mu = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    nu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    master = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree.map(lambda m, p: m.astype(p.dtype), master, params)
    new_state = {"mu": mu, "nu": nu, "master": master, "count": count}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
