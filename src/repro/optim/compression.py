"""Cross-pod gradient compression (beyond-paper distributed optimization).

On the multi-pod mesh the gradient all-reduce crosses the slow DCN
(§Roofline: the pod-spanning all-reduce dominates the collective term for
several train cells). This module reduces DCN traffic 4x by exchanging
int8-quantized gradients with per-leaf scales and *error feedback* (the
quantization residual is carried into the next step, so compression error
doesn't accumulate — Seide et al. 2014 / Karimireddy et al. 2019).

Mechanics: batch is sharded over ("pod", "data"). The train step computes
the loss over the *local pod's* half of the batch inside a
`shard_map(..., axis_names={"pod"})` region (data/model stay Auto), so
autodiff produces per-pod partial gradients; those are quantized and
`psum`-med over "pod" as int32, then dequantized. The intra-pod (ICI)
reductions remain full-precision — only the slow link is compressed.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_decompress(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Round-trip quantization; returns (xhat, residual)."""
    q, s = quantize_int8(x)
    xhat = dequantize(q, s)
    return xhat, x - xhat


def psum_compressed(grads: PyTree, axis_name: str,
                    errors: PyTree) -> Tuple[PyTree, PyTree]:
    """Error-feedback compressed mean over `axis_name` (call inside
    shard_map). Exchanges int8 payloads + one f32 scale per leaf.

    Returns (mean_grads, new_errors)."""
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        g = g.astype(jnp.float32) + e            # error feedback
        q, s = quantize_int8(g)
        new_e = g - dequantize(q, s)
        # wire exchange is INT8: all-gather the payloads (+ one f32 scale
        # each) and reduce locally — per-pod scales make a direct int
        # psum impossible, and all-gather(int8) is what actually crosses
        # the DCN (visible as an s8 all-gather in the compiled HLO)
        qs = jax.lax.all_gather(q, axis_name)            # (n, ...) s8
        ss = jax.lax.all_gather(s, axis_name)            # (n,) f32
        total = jnp.tensordot(ss, qs.astype(jnp.float32), axes=(0, 0))
        return total / n, new_e

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(errors)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    mean = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_err = jax.tree.unflatten(treedef, [o[1] for o in out])
    return mean, new_err


def dcn_bytes_per_step(params: PyTree, *, compressed: bool) -> int:
    """Analytic per-step cross-pod traffic (for EXPERIMENTS.md napkin
    math): f32 grads vs int8+scale."""
    total = sum(int(jnp.size(p)) if isinstance(p, jax.Array)
                else int(_prod(p.shape)) for p in jax.tree.leaves(params))
    return total + 4 * len(jax.tree.leaves(params)) if compressed \
        else 4 * total


def _prod(shape):
    out = 1
    for s in shape:
        out *= int(s)
    return out
