"""qwen2-moe-a2.7b [moe] — 24L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=151936, MoE 60 routed experts top-4 + 4 shared experts.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]

The 4 shared experts are merged into one always-active expert of hidden
size 4×1408=5632 (matching the HF shared_expert_intermediate_size).
Expert sharding: 60 % 16 != 0, so the per-expert FFN hidden dim (1408) is
sharded over the model axis instead ("ffn" mode).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,                # routed-expert hidden size
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    moe=MoEConfig(num_experts=60, top_k=4, d_expert=1408,
                  num_shared_experts=4, d_shared=5632,
                  expert_sharding="ffn", renorm_topk=False),
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
)
