"""internvl2-1b [vlm] — 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655 — InternViT + (Qwen2-0.5B) LM backbone. [arXiv:2404.16821; hf]

Per the assignment, the VLM entry specifies the transformer BACKBONE only;
the InternViT modality frontend is a STUB — input_specs() provides
precomputed patch embeddings prepended to the token stream.
"""
from repro.configs.base import FrontendConfig, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151655,
    qkv_bias=True,            # Qwen2-family backbone keeps QKV bias
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    frontend=FrontendConfig(kind="vlm", patch_embed_dim=1024,
                            num_prefix_embeds=256),
    source="arXiv:2404.16821 (InternVL2-1B: InternViT-300M + Qwen2-0.5B)",
)
