"""recurrentgemma-2b [hybrid] — 26L d_model=2560 10H (GQA kv=1, MQA)
d_ff=7680 vocab=256000 — RG-LRU + local attention, 1 attention : 2 recurrent.
[arXiv:2402.19427; hf]

Sub-quadratic: local attention window 2048 + O(1) RG-LRU state, so
long_500k runs for this arch.
"""
from repro.configs.base import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    act="gelu",               # GeGLU MLP
    full_attention=False,
    tie_embeddings=True,
    logit_softcap=30.0,
    scale_embed=True,
    rglru=RGLRUConfig(lru_width=2560, conv_width=4,
                      block_pattern=("recurrent", "recurrent", "attention"),
                      attention_window=2048),
    source="arXiv:2402.19427 (RecurrentGemma-2B / Griffin)",
)
