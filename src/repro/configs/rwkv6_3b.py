"""rwkv6-3b [ssm] — 32L d_model=2560 (attention-free) d_ff=8960
vocab=65536 — Finch, data-dependent decay. [arXiv:2404.05892; hf]

Attention-free: long_500k runs (O(1) recurrent state). The SMS paged-KV
technique is inapplicable to this family (DESIGN.md §5); the EC-checkpoint
and state-snapshot paths apply instead.
"""
from repro.configs.base import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=40,             # d_model / head_size
    num_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    full_attention=False,
    act="relu2",              # RWKV channel-mix uses squared ReLU
    rwkv=RWKVConfig(head_size=64, decay_lora=64, mix_lora=32),
    source="arXiv:2404.05892 (RWKV-6 Finch 3B)",
)
