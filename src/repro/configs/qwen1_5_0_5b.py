"""qwen1.5-0.5b [dense] — 24L d_model=1024 16H (GQA kv=16, i.e. MHA)
d_ff=2816 vocab=151936, QKV bias. [hf:Qwen/Qwen1.5-0.5B; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=2816,
    vocab_size=151936,
    qkv_bias=True,
    qk_norm=False,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    source="hf:Qwen/Qwen1.5-0.5B",
)
