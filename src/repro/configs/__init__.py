"""Architecture config registry: ``get_config("qwen3-14b")`` etc."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import (ALL_SHAPES, DECODE_32K, LONG_500K, ModelConfig,
                                PREFILL_32K, SHAPES_BY_NAME, ShapeConfig,
                                TRAIN_4K, reduced, shapes_for)

_MODULES = {
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "qwen3-14b": "qwen3_14b",
    "qwen1.5-110b": "qwen1_5_110b",
    "qwen3-1.7b": "qwen3_1_7b",
    "internvl2-1b": "internvl2_1b",
    "rwkv6-3b": "rwkv6_3b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "granite-moe-1b-a400m": "granite_moe_1b",
    "musicgen-large": "musicgen_large",
}

ARCH_NAMES = tuple(_MODULES)
_cache: Dict[str, ModelConfig] = {}


def get_config(name: str) -> ModelConfig:
    if name not in _cache:
        if name not in _MODULES:
            raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
        mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
        _cache[name] = mod.CONFIG
    return _cache[name]


def all_configs() -> Dict[str, ModelConfig]:
    return {n: get_config(n) for n in ARCH_NAMES}


__all__ = [
    "ALL_SHAPES", "ARCH_NAMES", "DECODE_32K", "LONG_500K", "ModelConfig",
    "PREFILL_32K", "SHAPES_BY_NAME", "ShapeConfig", "TRAIN_4K",
    "all_configs", "get_config", "reduced", "shapes_for",
]
