"""Config schema for the repro framework.

Every assigned architecture is a `ModelConfig`; input shapes are
`ShapeConfig`s. Full configs are only ever lowered via the dry-run
(ShapeDtypeStruct, no allocation); smoke tests use `reduced()` variants.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Sequence, Tuple


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block configuration."""
    num_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden size
    num_shared_experts: int = 0   # always-active shared experts
    d_shared: int = 0             # hidden size of the (merged) shared expert
    router_aux_coef: float = 0.001
    capacity_factor: float = 1.25
    renorm_topk: bool = True
    # "expert": shard the expert dim over the model axis (requires
    # num_experts % tp == 0); "ffn": shard each expert's hidden dim instead.
    expert_sharding: str = "expert"

    def __post_init__(self):
        if self.expert_sharding not in ("expert", "ffn"):
            raise ValueError(f"bad expert_sharding {self.expert_sharding}")


@dataclass(frozen=True)
class RWKVConfig:
    """RWKV6 (Finch) time-mix configuration."""
    head_size: int = 64
    # low-rank sizes for the data-dependent decay / token-shift mixers
    decay_lora: int = 64
    mix_lora: int = 32
    gate_lora: int = 64


@dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma (Griffin) recurrent-block configuration."""
    lru_width: int = 2560
    conv_width: int = 4
    block_pattern: Tuple[str, ...] = ("recurrent", "recurrent", "attention")
    attention_window: int = 2048


@dataclass(frozen=True)
class FrontendConfig:
    """Modality frontend STUB (vlm/audio): input_specs() supplies
    precomputed frame/patch embeddings; the frontend itself is not built."""
    kind: str = "none"            # none | vlm | audio
    num_codebooks: int = 1        # audio: EnCodec codebooks (parallel heads)
    patch_embed_dim: int = 0      # vlm: dimension of incoming patch embeds
    num_prefix_embeds: int = 0    # vlm: patch embeds prepended to the text


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    rms_eps: float = 1e-6
    tie_embeddings: bool = False
    act: str = "silu"             # silu (swiglu) | gelu (geglu) | relu2
    mlp_glu: bool = True          # False → classic 2-matrix MLP (e.g. musicgen)
    logit_softcap: float = 0.0    # Gemma-style tanh logit cap (0 = off)
    scale_embed: bool = False     # multiply embeddings by sqrt(d_model)
    moe: Optional[MoEConfig] = None
    rwkv: Optional[RWKVConfig] = None
    rglru: Optional[RGLRUConfig] = None
    frontend: FrontendConfig = field(default_factory=FrontendConfig)
    dtype: str = "bfloat16"
    # set False for archs whose attention is sub-quadratic / absent
    full_attention: bool = True
    source: str = ""              # provenance tag

    # ---- derived helpers -------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def param_count(self) -> int:
        """Analytic parameter count (matches the built model; see tests)."""
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        emb = V * d
        head = 0 if self.tie_embeddings else V * d
        if self.frontend.kind == "audio" and self.frontend.num_codebooks > 1:
            head *= self.frontend.num_codebooks
        per_layer = 0
        if self.family == "ssm":  # rwkv6
            rw = self.rwkv or RWKVConfig()
            H = d // rw.head_size
            per_layer = (
                5 * d * d                       # r,k,v,g,o (time-mix)
                + 6 * rw.mix_lora * d + rw.mix_lora * 5 + 6 * d  # ddlerp mixers
                + 2 * rw.decay_lora * d + d     # decay lora + base
                + H * rw.head_size              # bonus u
                + 2 * d                         # ln_x scale/bias (groupnorm)
                + d * self.d_ff + self.d_ff * d + d   # channel mix r + kv
                + 2 * d                         # 2 layernorm scales
            )
            return emb + head + L * per_layer + d
        # attention (or hybrid) families
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.qkv_bias:
            attn += self.q_dim + 2 * self.kv_dim
        if self.qk_norm:
            attn += 2 * self.head_dim
        glu = (3 if self.mlp_glu else 2) * d * self.d_ff  # up[, gate], down
        if self.moe is not None:
            m = self.moe
            glu = d * m.num_experts  # router
            glu += m.num_experts * 3 * d * m.d_expert
            if m.num_shared_experts:
                glu += 3 * d * m.d_shared + d  # shared expert + gate
        per_layer = attn + glu + 2 * d  # 2 rmsnorm scales
        if self.family == "hybrid":
            rg = self.rglru or RGLRUConfig()
            W = rg.lru_width
            rec = (
                2 * d * W + W * d               # in x2 (x & gate), out
                + rg.conv_width * W             # conv1d
                + 2 * W * W // 1                # rg-lru input & rec gates (block-diag approx: W*W/heads*heads) — see models/rglru.py
                + 2 * W                         # a_param, gate biases
            )
            n_attn = sum(1 for b in rg.block_pattern if b == "attention")
            n_rec = len(rg.block_pattern) - n_attn
            frac_attn = n_attn / len(rg.block_pattern)
            per_layer = (frac_attn * (attn + 2 * d)
                         + (1 - frac_attn) * (rec + 2 * d)
                         + 3 * d * self.d_ff + d)  # MLP shared by both + final norm share
            return int(emb + head + L * per_layer + d)
        return emb + head + L * per_layer + d  # final norm


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode
    # decode shapes: cache of seq_len tokens, one new token generated
    num_microbatches: int = 1     # train only: gradient accumulation


# The four assigned LM shapes (identical for every arch; applicability
# filtering happens in launch/dryrun.py per DESIGN.md §5).
TRAIN_4K = ShapeConfig("train_4k", seq_len=4096, global_batch=256, kind="train")
PREFILL_32K = ShapeConfig("prefill_32k", seq_len=32768, global_batch=32, kind="prefill")
DECODE_32K = ShapeConfig("decode_32k", seq_len=32768, global_batch=128, kind="decode")
LONG_500K = ShapeConfig("long_500k", seq_len=524288, global_batch=1, kind="decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


def reduced(cfg: ModelConfig, *, layers: int = 2, d_model: int = 64,
            heads: int = 4, kv_heads: Optional[int] = None, d_ff: int = 128,
            vocab: int = 256) -> ModelConfig:
    """Smoke-test variant of a config: same family/features, tiny dims."""
    kv = kv_heads if kv_heads is not None else max(1, heads // max(1, cfg.num_heads // max(cfg.num_kv_heads, 1)))
    kv = max(1, min(kv, heads))
    head_dim = d_model // heads
    kw = dict(
        num_layers=layers, d_model=d_model, num_heads=heads,
        num_kv_heads=kv, head_dim=head_dim, d_ff=d_ff, vocab_size=vocab,
        name=cfg.name + "-reduced",
    )
    if cfg.moe is not None:
        kw["moe"] = replace(cfg.moe, num_experts=8,
                            top_k=min(cfg.moe.top_k, 4), d_expert=32,
                            d_shared=64 if cfg.moe.num_shared_experts else 0)
    if cfg.rwkv is not None:
        kw["rwkv"] = replace(cfg.rwkv, head_size=16, decay_lora=8, mix_lora=8)
    if cfg.rglru is not None:
        kw["rglru"] = replace(cfg.rglru, lru_width=d_model, conv_width=4,
                              attention_window=32)
    if cfg.frontend.kind == "vlm":
        kw["frontend"] = replace(cfg.frontend, patch_embed_dim=d_model,
                                 num_prefix_embeds=4)
    return replace(cfg, **kw)


def shapes_for(cfg: ModelConfig) -> Sequence[ShapeConfig]:
    """Applicable shapes for an arch (DESIGN.md §5): long_500k only for
    sub-quadratic families."""
    if cfg.full_attention:
        return (TRAIN_4K, PREFILL_32K, DECODE_32K)
    return ALL_SHAPES


def to_dict(cfg) -> dict:
    return dataclasses.asdict(cfg)


def padded_vocab(vocab_size: int, multiple: int = 128) -> int:
    """Build-time vocab padding (MaxText-style): embedding/head tables are
    padded to a lane- and TP-friendly multiple; pad logits are masked to
    -inf so semantics are unchanged (tests assert this)."""
    return -(-vocab_size // multiple) * multiple
