"""musicgen-large [audio] — 48L d_model=2048 32H (kv=32, MHA) d_ff=8192
vocab=2048 — decoder-only over EnCodec tokens. [arXiv:2306.05284; hf]

Backbone only: the EnCodec frontend is a STUB. Four codebooks are summed at
the input (input_specs() provides the precomputed frame embeddings) and four
parallel LM heads (one per codebook) project the output, per the paper's
delay interleaving pattern. Classic 2-matrix GELU MLP (no GLU).
"""
from repro.configs.base import FrontendConfig, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    act="gelu",
    mlp_glu=False,
    frontend=FrontendConfig(kind="audio", num_codebooks=4),
    source="arXiv:2306.05284 (MusicGen-large)",
)
