"""SMS-managed paged KV cache (the paper's technique applied to LLM
serving; DESIGN.md §2.1).

KV pages are InfiniStore chunks: `PlaceChunk` assigns each page to a slab
(HBM capacity unit), the sliding GC window ages pages (active sequences
keep their pages hot; finished sequences' pages cool and are RELEASED),
and released pages' device slots are freed for reuse. Page payloads stay
on device (`sms.Ref` entries); a host-side COS copy enables eviction +
on-demand restore when an evicted sequence resumes — the paper's
on-demand migration.

The eviction tier is pluggable: by default pages round-trip through a
private raw `COS`, but passing `store=` (any `StoreFrontend` —
`InfiniStore` or the keyspace-partitioned `ShardedStore`) routes
evict/restore through the full store data path instead: erasure-coded,
versioned, crash-journaled, and — under a `ShardedStore` — served by
whichever shard daemon owns each `kv/<seq>/p<j>` key, so KV eviction
traffic from many sequences fans out across daemons instead of
serializing on one.

The device pool uses the same layout the dry-run lowers:
k/v (L, B, P, ps, K, hd) with per-sequence block tables (B, P) mapping
logical page -> physical slot within the sequence's region.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.clock import Clock
from repro.core.cos import COS
from repro.core.gc_window import BucketState, GCConfig, SlidingWindow
from repro.core.payload import as_u8
from repro.core.placement import PlacementManager
from repro.core.sms import SMS, Ref


@dataclass
class KVStats:
    pages_allocated: int = 0
    pages_released: int = 0
    pages_evicted_to_cos: int = 0
    pages_restored: int = 0
    compactions: int = 0


class SMSPagedKV:
    """Host control plane for one device-resident paged KV pool."""

    def __init__(self, cfg: ModelConfig, *, batch_slots: int,
                 max_len: int, page_size: int = 64,
                 gc: Optional[GCConfig] = None,
                 pages_per_slab: int = 64,
                 clock: Optional[Clock] = None,
                 store=None):
        self.cfg = cfg
        self.B = batch_slots
        self.ps = page_size
        self.P = -(-max_len // page_size)
        self.clock = clock or Clock()
        # optional StoreFrontend eviction tier (see module docstring);
        # None keeps the raw private-COS baseline. With a store, the
        # private COS (and its worker pool) is never built — every
        # evict/restore path routes through the store instead.
        self.store = store
        self.cos = COS(self.clock) if store is None else None
        self.sms = SMS(self.clock)
        gc = gc or GCConfig(gc_interval=60.0, active_intervals=2,
                            degraded_intervals=2)
        self.window = SlidingWindow(gc, self.clock)
        K, hd, L = cfg.num_kv_heads, cfg.head_dim, cfg.num_layers
        self.page_bytes = L * page_size * K * hd * 2 * 2   # k+v bf16
        self.placement = PlacementManager(
            1, self.page_bytes * pages_per_slab,
            new_function_cb=self._on_new_slab)
        dt = jnp.dtype(cfg.dtype)
        self.k_pool = jnp.zeros((L, self.B, self.P, page_size, K, hd), dt)
        self.v_pool = jnp.zeros((L, self.B, self.P, page_size, K, hd), dt)
        self.table = np.tile(np.arange(self.P, dtype=np.int32)[None],
                             (self.B, 1))
        # free physical slots per sequence region
        self._free: List[Set[int]] = [set(range(self.P))
                                      for _ in range(self.B)]
        # chunk key ("kv/<seq>/p<j>") -> (slot b, logical j, phys, fid)
        self.pages: Dict[str, Tuple[int, int, int, int]] = {}
        self.seq_of_slot: Dict[int, str] = {}
        self.stats = KVStats()
        self.rng = np.random.default_rng(0)

    def _on_new_slab(self, fid: int, fg_id: int, capacity: int) -> None:
        self.sms.add(fid, capacity)
        self.window.latest.add_function(fid, fg_id)

    # ---- page lifecycle ---------------------------------------------------

    def _key(self, seq_id: str, j: int) -> str:
        return f"kv/{seq_id}/p{j}"

    def alloc_page(self, b: int, seq_id: str, j: int) -> int:
        """Allocate logical page j for the sequence in slot b; returns the
        physical slot. PlaceChunk picks the slab (capacity accounting +
        auto-scaling); the physical slot comes from the slot's region."""
        key = self._key(seq_id, j)
        if key in self.pages:
            return self.pages[key][2]
        if not self._free[b]:
            self._reclaim_released(b)
        if not self._free[b]:
            raise MemoryError(f"no free KV page slots in region {b}")
        phys = min(self._free[b])
        self._free[b].discard(phys)
        fid = self.placement.place_chunk(0, self.page_bytes)
        self.sms.get(fid).store(key, Ref(self.page_bytes))
        self.pages[key] = (b, j, phys, fid)
        self.table[b, j] = phys
        self.stats.pages_allocated += 1
        return phys

    def touch_sequence(self, seq_id: str, num_pages: int) -> None:
        """Decode touched all pages of this sequence: mark hot."""
        for j in range(num_pages):
            key = self._key(seq_id, j)
            if key in self.pages:
                self.window.mark(key)
                fid = self.pages[key][3]
                slab = self.sms.slabs.get(fid)
                if slab is not None:
                    slab.invoke(0.0)

    def evict_page_to_cos(self, key: str) -> None:
        """Copy the page to host (COS) and free its device slot. The
        payload rides the uint8 Payload protocol: one device-to-host
        transfer per pool + one concat — no intermediate `bytes`."""
        b, j, phys, fid = self.pages[key]
        payload = np.concatenate([as_u8(self.k_pool[:, b, phys]),
                                  as_u8(self.v_pool[:, b, phys])])
        if self.store is not None:
            # store-backed tier: versioned, erasure-coded, journaled;
            # under a sharded store the owning shard daemon serves it
            self.store.put(key, payload)
        else:
            self.cos.put(key, payload)
        self._free[b].add(phys)
        slab = self.sms.slabs.get(fid)
        if slab is not None:
            slab.delete(key)
        del self.pages[key]
        self.stats.pages_evicted_to_cos += 1

    def restore_page(self, b: int, seq_id: str, j: int) -> int:
        """On-demand migration: bring an evicted page back from COS into
        a free slot of region b (paper §5.3.3)."""
        key = self._key(seq_id, j)
        raw = self.store.get_array(key) if self.store is not None \
            else self.cos.get(key)
        if raw is None:
            raise KeyError(f"page {key} not in COS")
        return self._install_page(b, seq_id, j, raw)

    def restore_pages(self, b: int, seq_id: str, js: List[int]) -> int:
        """Batched on-demand migration for a resuming sequence: the
        missing pages' COS payloads are fetched with one bounded parallel
        fan-out (the KV mirror of the store's pipelined demand reads)
        and installed in page order. Returns the pages restored."""
        todo = [(j, self._key(seq_id, j)) for j in js
                if self._key(seq_id, j) not in self.pages]
        if not todo:
            return 0
        if self.store is not None:
            # one batched gather: the store groups SMS reads per
            # function, fans COS fallbacks out on its I/O executor, and
            # a sharded store splits the batch across shard daemons
            arrs = self.store.get_many_arrays([key for _, key in todo])
            for j, key in todo:
                raw = arrs.get(key)
                if raw is None:
                    raise KeyError(f"page {key} not in COS")
                self._install_page(b, seq_id, j, raw)
            return len(todo)
        # COS's own worker pool does the fan-out: no per-call executor
        futs = [(j, key, self.cos.get_async(key)) for j, key in todo]
        for j, key, fut in futs:
            raw = fut.result()
            if raw is None:
                raise KeyError(f"page {key} not in COS")
            self._install_page(b, seq_id, j, raw)
        return len(todo)

    def _install_page(self, b: int, seq_id: str, j: int, raw) -> int:
        L, _, _, ps, K, hd = self.k_pool.shape
        buf = as_u8(raw)                       # bytes or uint8 view alike
        half = buf.size // 2
        dt = self.k_pool.dtype
        kp = buf[:half].view(dt).reshape(L, ps, K, hd)
        vp = buf[half:].view(dt).reshape(L, ps, K, hd)
        phys = self.alloc_page(b, seq_id, j)
        self.k_pool = self.k_pool.at[:, b, phys].set(jnp.asarray(kp))
        self.v_pool = self.v_pool.at[:, b, phys].set(jnp.asarray(vp))
        self.stats.pages_restored += 1
        return phys

    def _reclaim_released(self, b: int) -> None:
        """Free device slots whose pages' buckets were RELEASED (their
        content persists in COS)."""
        for key, (bb, j, phys, fid) in list(self.pages.items()):
            if bb != b:
                continue
            state = self.window.state_of_function(fid)
            if state in (None, BucketState.RELEASED) \
                    or not self.sms.slabs.get(fid, None) \
                    or not self.sms.get(fid).alive:
                self.evict_page_to_cos(key)
                self.stats.pages_released += 1

    # ---- GC tick -----------------------------------------------------------

    def gc_tick(self) -> None:
        if self.window.due():
            ev = self.window.run_gc()
            for fg_id in self.placement.carry_over_open_fgs():
                for fid in self.placement.fgs[fg_id].fids:
                    ev.new_bucket.add_function(fid, fg_id)
            for fid in ev.released_functions:
                slab = self.sms.slabs.get(fid)
                if slab is not None:
                    # persist + free every page on the released slab
                    for key in list(slab.keys()):
                        if key in self.pages:
                            self.evict_page_to_cos(key)
                            self.stats.pages_released += 1
                    slab.reclaim()
        # compaction round: re-place marked-hot pages into the latest
        # bucket's slabs (control-plane move; device slot unchanged)
        for key in self.window.take_compaction_round(self.rng):
            if key not in self.pages:
                continue
            b, j, phys, old_fid = self.pages[key]
            state = self.window.state_of_function(old_fid)
            if state in (BucketState.ACTIVE, None):
                continue
            new_fid = self.placement.place_chunk(0, self.page_bytes)
            self.sms.get(new_fid).store(key, Ref(self.page_bytes))
            old = self.sms.slabs.get(old_fid)
            if old is not None:
                old.delete(key)
            self.pages[key] = (b, j, phys, new_fid)
            self.stats.compactions += 1

    # ---- views ------------------------------------------------------------

    def device_cache(self, length: int):
        """Cache pytree for transformer.decode_step."""
        return {"k": self.k_pool, "v": self.v_pool,
                "block_table": jnp.asarray(self.table),
                "len": jnp.asarray(length, jnp.int32)}

    def absorb(self, cache) -> None:
        """Write back updated pools after a decode step."""
        self.k_pool = cache["k"]
        self.v_pool = cache["v"]
