from repro.serving.engine import ServeEngine, ServeConfig  # noqa: F401
from repro.serving.kv_cache import SMSPagedKV  # noqa: F401
