"""Batched serving engine over the SMS-paged KV cache.

Lockstep continuous batching: a batch of sequences prefills into SMS-
managed pages, decodes greedily, and the GC window handles page
lifecycle — active sequences stay hot, finished sequences' pages cool,
get RELEASED, and their device slots are reused by the next batch; an
evicted sequence can resume via on-demand restore from COS (the paper's
demand-caching path). The two-queue scheme separates short decode steps
from long prefill work so prefill bursts don't convoy decodes.

Per-sequence position tracking (non-lockstep) is future work; the SMS
page lifecycle — the paper's contribution — is fully exercised.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.clock import Clock
from repro.core.gc_window import GCConfig
from repro.models.registry import Model, build_model
from repro.serving.kv_cache import SMSPagedKV


@dataclass
class ServeConfig:
    batch_slots: int = 4
    max_len: int = 256
    page_size: int = 32
    gc_interval: float = 60.0
    active_intervals: int = 2
    degraded_intervals: int = 2
    small_queue_max_tokens: int = 8     # decode batch = small queue


@dataclass
class ServeStats:
    prefills: int = 0
    decode_steps: int = 0
    tokens_generated: int = 0
    prefill_seconds: float = 0.0
    decode_seconds: float = 0.0


class ServeEngine:
    def __init__(self, cfg: ModelConfig, scfg: ServeConfig = ServeConfig(),
                 *, params=None, seed: int = 0,
                 clock: Optional[Clock] = None):
        self.cfg = cfg
        self.scfg = scfg
        self.clock = clock or Clock()
        self.model: Model = build_model(cfg, kv_layout="paged",
                                        page_size=scfg.page_size)
        self.params = params if params is not None else \
            self.model.init_params(jax.random.PRNGKey(seed))
        self.kv = SMSPagedKV(
            cfg, batch_slots=scfg.batch_slots, max_len=scfg.max_len,
            page_size=scfg.page_size, clock=self.clock,
            gc=GCConfig(gc_interval=scfg.gc_interval,
                        active_intervals=scfg.active_intervals,
                        degraded_intervals=scfg.degraded_intervals))
        self.stats = ServeStats()
        def _step(p, b, c):
            logits, cache = self.model.decode_step(p, b, c)
            return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32), cache

        self._decode_fn = jax.jit(_step)
        self._seq_len: Dict[str, int] = {}

    # ---- serving ------------------------------------------------------------

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 seq_ids: Optional[List[str]] = None) -> np.ndarray:
        """prompts: (B, S) int32, B == batch_slots (lockstep batch).
        Returns generated tokens (B, max_new_tokens)."""
        B, S = prompts.shape
        assert B == self.scfg.batch_slots
        seq_ids = seq_ids or [f"seq{i}" for i in range(B)]
        t0 = time.monotonic()
        # large queue: prefill. Allocate pages ahead of the fill.
        total = S + max_new_tokens
        for b, sid in enumerate(seq_ids):
            for j in range(-(-total // self.scfg.page_size)):
                self.kv.alloc_page(b, sid, j)
            self._seq_len[sid] = S
        logits, cache = self.model.prefill(
            self.params, {"tokens": jnp.asarray(prompts)},
            max_len=self.scfg.max_len)
        # prefill produced identity-table pools; scatter into SMS layout
        self._absorb_prefill(cache, seq_ids)
        self.stats.prefills += B
        self.stats.prefill_seconds += time.monotonic() - t0

        # small queue: decode loop
        t0 = time.monotonic()
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        out = []
        length = S
        for step in range(max_new_tokens):
            cache = self.kv.device_cache(length)
            next_tok, cache = self._decode_fn(
                self.params, {"token": tok}, cache)
            self.kv.absorb(cache)
            out.append(np.asarray(next_tok).reshape(B))
            tok = next_tok.reshape(B, 1)
            length += 1
            for b, sid in enumerate(seq_ids):
                self._seq_len[sid] = length
                self.kv.touch_sequence(
                    sid, -(-length // self.scfg.page_size))
            self.kv.gc_tick()
        self.stats.decode_steps += max_new_tokens
        self.stats.tokens_generated += max_new_tokens * B
        self.stats.decode_seconds += time.monotonic() - t0
        return np.stack(out, axis=1)

    def _absorb_prefill(self, cache, seq_ids: List[str]) -> None:
        """Map prefill's identity-layout pools into the SMS pool via each
        sequence's block table."""
        k, v = cache["k"], cache["v"]         # (L, B, P', ps, K, hd)
        Pp = k.shape[2]
        for b, sid in enumerate(seq_ids):
            for j in range(min(Pp, self.kv.P)):
                key = self.kv._key(sid, j)
                if key not in self.kv.pages:
                    continue
                phys = self.kv.pages[key][2]
                self.kv.k_pool = self.kv.k_pool.at[:, b, phys].set(k[:, b, j])
                self.kv.v_pool = self.kv.v_pool.at[:, b, phys].set(v[:, b, j])

    def resume(self, seq_id: str, slot: int) -> int:
        """Bring an evicted sequence's pages back (on-demand migration),
        fetched from COS as ONE batched parallel fan-out instead of a
        page-at-a-time loop. Returns the number of restored pages."""
        length = self._seq_len.get(seq_id, 0)
        n = -(-length // self.scfg.page_size)
        return self.kv.restore_pages(slot, seq_id, list(range(n)))
