"""RecurrentGemma / Griffin (arXiv:2402.19427): RG-LRU recurrent blocks
interleaved with local (sliding-window) attention, 1 attention : 2 recurrent.

Layers are grouped into scanned *superlayers* of one pattern unit
(recurrent, recurrent, attention); `num_layers % 3` trailing blocks are
unrolled. The RG-LRU recurrence runs as a `jax.lax.associative_scan`
(O(log S) depth) for train/prefill and as a single fused update for decode.

Sub-quadratic: prefill attention touches only O(S·window) pairs
(`local_chunked_attention`), decode keeps a ring buffer of `window` kv —
so long_500k lowers with O(window + lru_width) state.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, RGLRUConfig
from repro.distributed.sharding import constrain
from repro.models import layers as L

RG_C = 8.0  # Griffin's fixed `c` exponent scale


def _cfg(cfg: ModelConfig) -> RGLRUConfig:
    return cfg.rglru or RGLRUConfig()


def _num_blocks(cfg):  # block-diagonal gate blocks
    return cfg.num_heads


# --------------------------------------------------------------------------
# Params
# --------------------------------------------------------------------------

def _block_specs(cfg: ModelConfig, kind: str):
    d, ff = cfg.d_model, cfg.d_ff
    rg = _cfg(cfg)
    W, nb = rg.lru_width, _num_blocks(cfg)
    bw = W // nb
    s = {
        "ln1": ((d,), (None,)),
        "ln2": ((d,), (None,)),
        "w_gate": ((d, ff), ("embed", "ff")),
        "w_up": ((d, ff), ("embed", "ff")),
        "w_down": ((ff, d), ("ff", "embed")),
    }
    if kind == "recurrent":
        s.update({
            "wx": ((d, W), ("embed", "lru")),
            "wg": ((d, W), ("embed", "lru")),
            "wout": ((W, d), ("lru", "embed")),
            "conv_w": ((rg.conv_width, W), (None, "lru")),
            "conv_b": ((W,), ("lru",)),
            "rg_a": ((nb, bw, bw), ("lru_blocks", None, None)),
            "rg_a_b": ((W,), ("lru",)),
            "rg_x": ((nb, bw, bw), ("lru_blocks", None, None)),
            "rg_x_b": ((W,), ("lru",)),
            "a_param": ((W,), ("lru",)),
        })
    else:  # attention
        H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        s.update({
            "wq": ((d, H, hd), ("embed", "heads", None)),
            "wk": ((d, K, hd), ("embed", "kv_heads", "head_dim")),
            "wv": ((d, K, hd), ("embed", "kv_heads", "head_dim")),
            "wo": ((H, hd, d), ("heads", None, "embed")),
        })
    return s


def layer_plan(cfg: ModelConfig) -> Tuple[int, Tuple[str, ...]]:
    """(num_superlayers, tail_kinds)."""
    pat = _cfg(cfg).block_pattern
    n_super = cfg.num_layers // len(pat)
    tail = tuple(pat[: cfg.num_layers % len(pat)])
    return n_super, tail


def param_specs(cfg: ModelConfig):
    from repro.configs.base import padded_vocab
    d, V = cfg.d_model, padded_vocab(cfg.vocab_size)
    pat = _cfg(cfg).block_pattern
    n_super, tail = layer_plan(cfg)
    s = {"embed": ((V, d), ("vocab", "embed")),
         "final_norm": ((d,), (None,))}
    if not cfg.tie_embeddings:
        s["head"] = ((V, d), ("vocab", "embed"))
    if n_super:
        for bi, kind in enumerate(pat):
            for name, (shape, axes) in _block_specs(cfg, kind).items():
                s[f"super/{bi}/{name}"] = ((n_super,) + shape,
                                           ("layers",) + axes)
    for ti, kind in enumerate(tail):
        for name, (shape, axes) in _block_specs(cfg, kind).items():
            s[f"tail/{ti}/{name}"] = (shape, axes)
    return s


def logical_axes(cfg: ModelConfig):
    return {k: v[1] for k, v in param_specs(cfg).items()}


def init_params(cfg: ModelConfig, key: jax.Array):
    dt = jnp.dtype(cfg.dtype)
    specs = param_specs(cfg)
    params = {}
    keys = jax.random.split(key, len(specs))
    for (name, (shape, _)), k in zip(sorted(specs.items()), keys):
        leaf = name.split("/")[-1]
        if leaf in ("ln1", "ln2", "final_norm"):
            params[name] = jnp.ones(shape, dt)
        elif leaf in ("conv_b", "rg_a_b", "rg_x_b"):
            params[name] = jnp.zeros(shape, dt)
        elif leaf == "a_param":
            # softplus(a_param) in ~(0.04, 0.6) -> per-channel decay spread
            params[name] = jnp.linspace(-3.0, 0.0, math.prod(shape),
                                        dtype=jnp.float32).reshape(shape).astype(jnp.float32)
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            params[name] = (jax.random.normal(k, shape, jnp.float32)
                            / math.sqrt(max(fan_in, 1))).astype(dt)
    return params


def abstract_params(cfg: ModelConfig):
    dt = jnp.dtype(cfg.dtype)
    out = {}
    for kname, (shape, _) in param_specs(cfg).items():
        leaf_dt = jnp.float32 if kname.endswith("a_param") else dt
        out[kname] = jax.ShapeDtypeStruct(shape, leaf_dt)
    return out


# --------------------------------------------------------------------------
# RG-LRU + conv
# --------------------------------------------------------------------------

def _block_diag(u: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """u: (B,S,W), w: (nb,bw,bw), b: (W,) -> (B,S,W)."""
    B, S, W = u.shape
    nb, bw, _ = w.shape
    ub = u.reshape(B, S, nb, bw)
    out = jnp.einsum("bsnw,nwv->bsnv", ub, w)
    return out.reshape(B, S, W) + b


def causal_conv1d(u: jax.Array, w: jax.Array, b: jax.Array,
                  state: jax.Array):
    """Depthwise causal conv. u: (B,S,W), w: (cw,W), state: (B,cw-1,W).
    Returns (out (B,S,W), new_state)."""
    cw = w.shape[0]
    full = jnp.concatenate([state.astype(u.dtype), u], axis=1)
    out = sum(full[:, i:i + u.shape[1]] * w[i] for i in range(cw))
    new_state = full[:, -(cw - 1):] if cw > 1 else state
    return out + b, new_state


def rg_lru(u: jax.Array, p: Dict[str, jax.Array], h0: jax.Array):
    """u: (B,S,W); h0: (B,W) f32. Returns (h_seq (B,S,W) f32, hT)."""
    r = jax.nn.sigmoid(_block_diag(u, p["rg_a"], p["rg_a_b"]).astype(jnp.float32))
    i = jax.nn.sigmoid(_block_diag(u, p["rg_x"], p["rg_x_b"]).astype(jnp.float32))
    log_a = -RG_C * r * jax.nn.softplus(p["a_param"].astype(jnp.float32))
    a = jnp.exp(log_a)
    gated = i * u.astype(jnp.float32)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = beta * gated

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    a_cum, b_cum = lax.associative_scan(combine, (a, b), axis=1)
    h = b_cum + a_cum * h0[:, None, :]
    return h, h[:, -1, :]


def rg_lru_step(u: jax.Array, p: Dict[str, jax.Array], h0: jax.Array):
    """Single-token RG-LRU update. u: (B,1,W)."""
    h, hT = rg_lru(u, p, h0)
    return h, hT


# --------------------------------------------------------------------------
# Blocks
# --------------------------------------------------------------------------

def recurrent_block(cfg, p, x, st, *, decode: bool):
    """st: {"h": (B,W) f32, "conv": (B,cw-1,W)}."""
    u = constrain(x @ p["wx"], ("batch", None, "lru"))
    u, conv_state = causal_conv1d(u, p["conv_w"], p["conv_b"], st["conv"])
    h, hT = rg_lru(u, p, st["h"])
    gate = jax.nn.gelu(x @ p["wg"], approximate=True)
    y = (gate * h.astype(x.dtype)) @ p["wout"]
    return y, {"h": hT, "conv": conv_state.astype(st["conv"].dtype)}


def _to_ring(k: jax.Array, window: int) -> jax.Array:
    """(B, S, K, hd) -> ring buffer (B, window, K, hd), slot = pos % window."""
    B, S = k.shape[:2]
    if S >= window:
        last = k[:, -window:]
    else:
        last = jnp.pad(k, ((0, 0), (window - S, 0), (0, 0), (0, 0)))
    return jnp.roll(last, S % window, axis=1)


def attention_block(cfg, p, x, st, *, decode: bool, pos=None):
    """st: {"k": (B,window,K,hd), "v": ..., } ring buffer (decode only)."""
    rg = _cfg(cfg)
    B, S, d = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if decode:
        positions = pos[None]
        q = L.rope_for_seq(q, positions, cfg.rope_theta)
        k = L.rope_for_seq(k, positions, cfg.rope_theta)
        slot = pos % rg.attention_window
        kc = lax.dynamic_update_slice_in_dim(st["k"], k.astype(st["k"].dtype),
                                             slot, 1)
        vc = lax.dynamic_update_slice_in_dim(st["v"], v.astype(st["v"].dtype),
                                             slot, 1)
        valid = jnp.minimum(pos + 1, rg.attention_window)
        out = L.decode_attention(q, L.expand_kv(kc, H), L.expand_kv(vc, H),
                                 valid)
        new_st = {"k": kc, "v": vc}
    else:
        positions = jnp.arange(S)
        q = L.rope_for_seq(q, positions, cfg.rope_theta)
        k = L.rope_for_seq(k, positions, cfg.rope_theta)
        out = L.local_chunked_attention(q, L.expand_kv(k, H),
                                        L.expand_kv(v, H),
                                        window=rg.attention_window)
        # stash the last `window` kv as a ring buffer (slot = pos % window)
        # so a subsequent decode phase can continue seamlessly
        w = rg.attention_window
        new_st = {"k": _to_ring(k, w).astype(st["k"].dtype),
                  "v": _to_ring(v, w).astype(st["v"].dtype)}
    out = jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), p["wo"])
    return out, new_st


def _block(cfg, kind, p, x, st, *, decode=False, pos=None):
    x = constrain(x, ("batch", None, None))
    h = L.rms_norm(x, p["ln1"], cfg.rms_eps)
    if kind == "recurrent":
        out, st = recurrent_block(cfg, p, h, st, decode=decode)
    else:
        out, st = attention_block(cfg, p, h, st, decode=decode, pos=pos)
    x = x + out
    h = L.rms_norm(x, p["ln2"], cfg.rms_eps)
    mlp = L.mlp_glu(h, p["w_gate"], p["w_up"], p["w_down"], cfg.act)
    return constrain(x + mlp, ("batch", None, None)), st


# --------------------------------------------------------------------------
# State
# --------------------------------------------------------------------------

def _block_state(cfg: ModelConfig, kind: str, batch: int, lead=()):
    rg = _cfg(cfg)
    dt = jnp.dtype(cfg.dtype)
    if kind == "recurrent":
        return {"h": jnp.zeros(lead + (batch, rg.lru_width), jnp.float32),
                "conv": jnp.zeros(lead + (batch, rg.conv_width - 1,
                                          rg.lru_width), dt)}
    K, hd = cfg.num_kv_heads, cfg.head_dim
    return {"k": jnp.zeros(lead + (batch, rg.attention_window, K, hd), dt),
            "v": jnp.zeros(lead + (batch, rg.attention_window, K, hd), dt)}


def init_state(cfg: ModelConfig, batch: int):
    pat = _cfg(cfg).block_pattern
    n_super, tail = layer_plan(cfg)
    st: Dict[str, Any] = {"len": jnp.zeros((), jnp.int32)}
    if n_super:
        for bi, kind in enumerate(pat):
            st[f"super/{bi}"] = _block_state(cfg, kind, batch, (n_super,))
    for ti, kind in enumerate(tail):
        st[f"tail/{ti}"] = _block_state(cfg, kind, batch)
    return st


def abstract_state(cfg: ModelConfig, batch: int):
    return jax.eval_shape(lambda: init_state(cfg, batch))


def state_logical_axes(cfg: ModelConfig):
    pat = _cfg(cfg).block_pattern
    n_super, tail = layer_plan(cfg)

    def ax(kind, lead):
        if kind == "recurrent":
            return {"h": lead + ("batch", "lru"),
                    "conv": lead + ("batch", None, "lru")}
        return {"k": lead + ("batch", None, "kv_heads", "head_dim"),
                "v": lead + ("batch", None, "kv_heads", "head_dim")}

    st: Dict[str, Any] = {"len": ()}
    if n_super:
        for bi, kind in enumerate(pat):
            st[f"super/{bi}"] = ax(kind, ("layers",))
    for ti, kind in enumerate(tail):
        st[f"tail/{ti}"] = ax(kind, ())
    return st


# --------------------------------------------------------------------------
# Forward passes
# --------------------------------------------------------------------------

def _split(params):
    top, sup, tail = {}, {}, {}
    for kname, v in params.items():
        if kname.startswith("super/"):
            _, bi, leaf = kname.split("/", 2)
            sup.setdefault(int(bi), {})[leaf] = v
        elif kname.startswith("tail/"):
            _, ti, leaf = kname.split("/", 2)
            tail.setdefault(int(ti), {})[leaf] = v
        else:
            top[kname] = v
    return top, sup, tail


def forward(cfg: ModelConfig, params, batch, *, state=None,
            remat: bool = True, return_state: bool = False,
            last_only: bool = False, decode: bool = False):
    pat = _cfg(cfg).block_pattern
    n_super, tail_kinds = layer_plan(cfg)
    top, sup, tail = _split(params)
    tok = batch["tokens"]
    x = constrain(jnp.take(top["embed"], tok, axis=0), ("batch", None, None))
    if cfg.scale_embed:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    B = x.shape[0]
    st = state if state is not None else init_state(cfg, B)
    pos = st["len"]

    if n_super:
        def body(x, xs):
            lp_by_block, s_by_block = xs
            new_s = {}
            for bi, kind in enumerate(pat):
                x, new_s[bi] = _block(cfg, kind, lp_by_block[bi], x,
                                      s_by_block[bi], decode=decode, pos=pos)
            return x, new_s

        body_fn = jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.nothing_saveable) if remat else body
        s_by_block = {bi: st[f"super/{bi}"] for bi in range(len(pat))}
        x, new_sup = lax.scan(body_fn, x, (sup, s_by_block))
    else:
        new_sup = {}
    new_tail = {}
    for ti, kind in enumerate(tail_kinds):
        x, new_tail[ti] = _block(cfg, kind, tail[ti], x, st[f"tail/{ti}"],
                                 decode=decode, pos=pos)
    x = L.rms_norm(x, top["final_norm"], cfg.rms_eps)
    if last_only:
        x = x[:, -1:]
    w = top["embed"] if cfg.tie_embeddings else top["head"]
    logits = constrain(jnp.einsum("bsd,vd->bsv", x, w),
                       ("batch", None, "vocab"))
    logits = L.soft_cap(logits, cfg.logit_softcap)
    logits = L.mask_pad_logits(logits, cfg.vocab_size)
    if return_state:
        new_state: Dict[str, Any] = {"len": pos + tok.shape[1]}
        for bi in new_sup:
            new_state[f"super/{bi}"] = new_sup[bi]
        for ti in new_tail:
            new_state[f"tail/{ti}"] = new_tail[ti]
        return logits, new_state
    return logits, 0.0


def loss_fn(cfg: ModelConfig, params, batch, **kw):
    logits, _ = forward(cfg, params, batch, **kw)
    loss = L.softmax_cross_entropy(logits, batch["labels"])
    return loss, {"ce": loss, "aux": 0.0}


def prefill(cfg: ModelConfig, params, batch, **kw):
    return forward(cfg, params, batch, return_state=True, last_only=True,
                   **kw)


def decode_step(cfg: ModelConfig, params, batch, state):
    return forward(cfg, params, {"tokens": batch["token"]}, state=state,
                   remat=False, return_state=True, decode=True)
