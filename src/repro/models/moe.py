"""Mixture-of-experts FFN with sort-based capacity dispatch.

Tokens pick top-k experts; (token, expert) pairs are sorted by expert id
and packed into a static (E, C, d) dispatch buffer (capacity
C = ceil(T*k/E * capacity_factor)); overflow tokens are dropped (their
residual path passes through unchanged, as in Switch/GShard). All shapes
are static, so the same code lowers for the dry-run and runs eagerly for
tests. `moe_ffn_dense` is the O(E)-FLOPs oracle used by property tests.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig


def router_probs(cfg: ModelConfig, p: Dict[str, jax.Array], xf: jax.Array):
    """xf: (T, d) -> (probs (T,E) f32, gate_vals (T,k), expert_ids (T,k))."""
    m = cfg.moe
    logits = (xf.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = lax.top_k(probs, m.top_k)
    if m.renorm_topk:
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9)
    return probs, gate_vals, expert_ids


def aux_load_balance(probs: jax.Array, expert_ids: jax.Array,
                     num_experts: int) -> jax.Array:
    """Switch-style load-balance loss: E * sum_e f_e * P_e."""
    T, k = expert_ids.shape
    counts = jnp.zeros((num_experts,), jnp.float32).at[
        expert_ids.reshape(-1)].add(1.0)
    f = counts / (T * k)
    P = probs.mean(axis=0)
    return num_experts * jnp.sum(f * P)


def capacity(cfg: ModelConfig, group_tokens: int) -> int:
    """Per-group expert capacity (groups = sequences; see moe_ffn)."""
    m = cfg.moe
    c = int(-(-group_tokens * m.top_k * m.capacity_factor // m.num_experts))
    return max(4, -(-c // 4) * 4)  # round up to a multiple of 4


def dispatch_indices(expert_ids: jax.Array, gate_vals: jax.Array,
                     num_experts: int, cap: int):
    """Sort (token, expert) pairs by expert and pack into (E*C,) slots.

    Returns (disp, gate_slot): disp[(e*C + c)] = token index (or T if the
    slot is empty / token dropped), gate_slot = the matching gate weight.
    """
    T, k = expert_ids.shape
    flat_e = expert_ids.reshape(-1)                       # (T*k,)
    sort_idx = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[sort_idx]
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos_in_e = jnp.arange(T * k) - first
    valid = pos_in_e < cap
    slot = jnp.where(valid, sorted_e * cap + pos_in_e,
                     num_experts * cap)                   # OOB -> dropped
    token_of = sort_idx // k
    disp = jnp.full((num_experts * cap,), T, jnp.int32)
    disp = disp.at[slot].set(token_of.astype(jnp.int32), mode="drop")
    gate_flat = gate_vals.reshape(-1)[sort_idx]
    gate_slot = jnp.zeros((num_experts * cap,), jnp.float32)
    gate_slot = gate_slot.at[slot].set(gate_flat, mode="drop")
    return disp, gate_slot


def moe_ffn(cfg: ModelConfig, p: Dict[str, jax.Array], x: jax.Array
            ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out (B,S,d), aux_loss scalar).

    GShard-style GROUP-LOCAL dispatch: each sequence is a dispatch group
    with its own capacity C = ceil(S*k*cf/E), so sort/gather/scatter all
    stay sharded over the batch axis. (A global sort produced an E*C =
    5.2M-slot replicated gather — 40 GiB/device on prefill_32k; see
    EXPERIMENTS.md §Perf.)
    """
    from repro.distributed.sharding import constrain
    from repro.models.layers import activate
    m = cfg.moe
    B, S, d = x.shape
    probs, gate_vals, expert_ids = router_probs(
        cfg, p, x.reshape(B * S, d))
    aux = aux_load_balance(probs, expert_ids, m.num_experts)
    cap = capacity(cfg, S)
    gate_g = gate_vals.reshape(B, S, m.top_k)
    ids_g = expert_ids.reshape(B, S, m.top_k)
    disp, gate_slot = jax.vmap(
        lambda ids, g: dispatch_indices(ids, g, m.num_experts, cap)
    )(ids_g, gate_g)                                     # (B, E*C) each
    xpad = jnp.concatenate([x, jnp.zeros((B, 1, d), x.dtype)], axis=1)
    xd = jnp.take_along_axis(xpad, disp[..., None], axis=1)
    xd = constrain(xd.reshape(B, m.num_experts, cap, d),
                   ("batch", "experts", None, None))     # (B, E, C, d)
    h = activate(jnp.einsum("becd,edf->becf", xd, p["we_gate"]), cfg.act)
    h = h * jnp.einsum("becd,edf->becf", xd, p["we_up"])
    y = jnp.einsum("becf,efd->becd", h, p["we_down"])    # (B, E, C, d)
    y = (y.astype(jnp.float32)
         * gate_slot.reshape(B, m.num_experts, cap, 1))
    out = jnp.zeros((B, S + 1, d), jnp.float32)
    out = out.at[jnp.arange(B)[:, None], disp].add(
        y.reshape(B, m.num_experts * cap, d))
    return constrain(out[:, :S].astype(x.dtype), ("batch", None, None)), aux


def moe_ffn_dense(cfg: ModelConfig, p: Dict[str, jax.Array], x: jax.Array
                  ) -> Tuple[jax.Array, jax.Array]:
    """O(E) oracle: every expert computed for every token, combined with
    the same top-k gates. No capacity, no drops — property tests compare
    `moe_ffn` against this wherever no token exceeds capacity."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    xf = x.reshape(T, d)
    probs, gate_vals, expert_ids = router_probs(cfg, p, xf)
    aux = aux_load_balance(probs, expert_ids, m.num_experts)
    from repro.models.layers import activate
    h = activate(jnp.einsum("td,edf->etf", xf, p["we_gate"]), cfg.act)
    h = h * jnp.einsum("td,edf->etf", xf, p["we_up"])
    y = jnp.einsum("etf,efd->etd", h, p["we_down"])       # (E, T, d)
    w = jnp.zeros((T, m.num_experts), jnp.float32)
    w = jax.vmap(lambda wr, ids, g: wr.at[ids].add(g))(w, expert_ids,
                                                       gate_vals)
    out = jnp.einsum("etd,te->td", y.astype(jnp.float32), w)
    return out.reshape(B, S, d).astype(x.dtype), aux
