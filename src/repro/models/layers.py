"""Shared model layers: norms, rotary embeddings, chunked (flash-style)
attention, and MLPs.

Everything here is pure JAX (`jnp`/`lax`) so it lowers on any backend; the
Pallas kernels in `repro.kernels` are drop-in TPU fast paths validated
against these implementations.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


# --------------------------------------------------------------------------
# Norms & activations
# --------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * lax.rsqrt(var + eps)
    return (y * scale).astype(dtype)


def group_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               num_groups: int, eps: float = 1e-5) -> jax.Array:
    """GroupNorm over the last dim (used by RWKV6's ln_x)."""
    dtype = x.dtype
    *lead, d = x.shape
    xg = x.astype(jnp.float32).reshape(*lead, num_groups, d // num_groups)
    mean = jnp.mean(xg, axis=-1, keepdims=True)
    var = jnp.var(xg, axis=-1, keepdims=True)
    xg = (xg - mean) * lax.rsqrt(var + eps)
    return (xg.reshape(*lead, d) * scale + bias).astype(dtype)


def activate(x: jax.Array, act: str) -> jax.Array:
    if act == "silu":
        return jax.nn.silu(x)
    if act == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if act == "relu2":
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(f"unknown activation {act!r}")


def soft_cap(x: jax.Array, cap: float) -> jax.Array:
    """Gemma-style logit soft cap: cap * tanh(x / cap)."""
    if not cap:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# --------------------------------------------------------------------------
# Position embeddings
# --------------------------------------------------------------------------

def rope_tables(positions: jax.Array, head_dim: int, theta: float):
    """positions: (...,) int32 -> (cos, sin) of shape (..., head_dim//2)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, N, D); cos/sin: (S, D//2) or broadcastable (B, S, D//2)."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    while cos.ndim < x1.ndim:  # (S, half) -> (1, S, 1, half)
        cos, sin = cos[None], sin[None]
    cos = jnp.moveaxis(cos, -2, 1) if False else cos  # keep simple: caller aligns
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    return jnp.concatenate([out1, out2], axis=-1).astype(dtype)


def rope_for_seq(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Apply RoPE to (B, S, N, D) given positions (S,) or (B, S)."""
    cos, sin = rope_tables(positions, x.shape[-1], theta)  # (S, half) / (B,S,half)
    if cos.ndim == 2:            # (S, half) -> (1, S, 1, half)
        cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    else:                        # (B, S, half) -> (B, S, 1, half)
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(dtype)


def sinusoidal_pos_embed(positions: jax.Array, dim: int) -> jax.Array:
    """(S,) -> (S, dim) classic transformer sinusoidal embedding."""
    half = dim // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --------------------------------------------------------------------------
# Attention
# --------------------------------------------------------------------------

def expand_kv(k: jax.Array, num_heads: int) -> jax.Array:
    """(B, S, K, D) -> (B, S, H, D) by repeating each kv head H/K times.

    A no-op reshape when K == H. When kv heads are replicated across the
    model axis (K % TP != 0, DESIGN.md §4) this repeat is a local gather.
    """
    B, S, K, D = k.shape
    if K == num_heads:
        return k
    G = num_heads // K
    return jnp.repeat(k, G, axis=2)


def _block_mask(qpos, kpos, *, causal: bool, window: Optional[int],
                kv_len: jax.Array | int):
    """qpos: (bq,), kpos: (bk,) -> bool (bq, bk). True = attend."""
    m = kpos[None, :] < kv_len
    if causal:
        m &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        m &= kpos[None, :] > qpos[:, None] - window
    return m


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: Optional[int] = None,
                      q_start=0, kv_len=None,
                      block_q: int = 512, block_k: int = 512,
                      impl: str = "masked") -> jax.Array:
    """Flash-style chunked attention with online softmax.

    q: (B, Sq, H, D); k, v: (B, Sk, H, D) (kv already expanded to H heads).
    Never materializes the (Sq, Sk) score matrix; peak score memory is
    (B, H, block_q, block_k).

    impl:
      "masked" — scan all (q-block, kv-block) pairs, mask invalid ones.
                 HLO FLOPs ≈ 2x the causal minimum (baseline).
      "tri"    — scan only lower-triangle block pairs (exact causal FLOPs;
                 beyond-paper optimization, see EXPERIMENTS.md §Perf).
    """
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    kv_len = Sk if kv_len is None else kv_len
    scale = 1.0 / math.sqrt(D)
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    nq, nk = -(-Sq // bq), -(-Sk // bk)
    qp, kp = nq * bq - Sq, nk * bk - Sk
    if qp:
        q = jnp.pad(q, ((0, 0), (0, qp), (0, 0), (0, 0)))
    if kp:
        k = jnp.pad(k, ((0, 0), (0, kp), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kp), (0, 0), (0, 0)))
    qb = q.reshape(B, nq, bq, H, D)
    kb = k.reshape(B, nk, bk, H, D)
    vb = v.reshape(B, nk, bk, H, D)

    q_start = jnp.asarray(q_start)

    def kv_step(i, carry, j):
        m, l, acc = carry
        kj = lax.dynamic_index_in_dim(kb, j, 1, keepdims=False)
        vj = lax.dynamic_index_in_dim(vb, j, 1, keepdims=False)
        qi = lax.dynamic_index_in_dim(qb, i, 1, keepdims=False)
        s = jnp.einsum("bqhd,bkhd->bhqk", qi, kj,
                       preferred_element_type=jnp.float32) * scale
        qpos = q_start + i * bq + jnp.arange(bq)
        kpos = j * bk + jnp.arange(bk)
        mask = _block_mask(qpos, kpos, causal=causal, window=window,
                           kv_len=kv_len)
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(vj.dtype), vj,
            preferred_element_type=jnp.float32)
        return (m_new, l, acc)

    def q_block(i):
        init = (jnp.full((B, H, bq), NEG_INF, jnp.float32),
                jnp.zeros((B, H, bq), jnp.float32),
                jnp.zeros((B, H, bq, D), jnp.float32))
        m, l, acc = lax.scan(lambda c, j: (kv_step(i, c, j), None),
                             init, jnp.arange(nk))[0]
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.astype(q.dtype)  # (B, H, bq, D)

    if impl == "tri" and causal and window is None:
        return _tri_attention(qb, kb, vb, B=B, H=H, D=D, bq=bq, bk=bk,
                              nq=nq, nk=nk, Sq=Sq, q_start=q_start,
                              kv_len=kv_len, scale=scale,
                              out_dtype=q.dtype)
    outs = lax.map(q_block, jnp.arange(nq))      # (nq, B, H, bq, D)
    out = jnp.moveaxis(outs, 0, 2).reshape(B, H, nq * bq, D)
    out = jnp.moveaxis(out, 1, 2)                # (B, Sq_pad, H, D)
    return out[:, :Sq]


def _tri_attention(qb, kb, vb, *, B, H, D, bq, bk, nq, nk, Sq, q_start,
                   kv_len, scale, out_dtype):
    """Lower-triangle-only causal flash attention.

    Scans exactly the T = sum_i (#kv blocks visible to q block i) valid
    block pairs, so HLO FLOPs match the causal minimum (vs 2x for the
    masked variant). Requires self-attention alignment (q_start maps q
    block i to kv diagonal block i + q_start//bk); block sizes must divide
    the diagonal offset.
    """
    # Build the static (i, j) schedule: for q block i, kv blocks 0..diag(i).
    import numpy as np
    off = int(q_start) // bk if isinstance(q_start, (int, np.integer)) else 0
    pairs = [(i, j) for i in range(nq) for j in range(min(nk, i * bq // bk + off + 1))]
    ii = jnp.array([p[0] for p in pairs], jnp.int32)
    jj = jnp.array([p[1] for p in pairs], jnp.int32)

    def step(carry, idx):
        m, l, acc = carry  # per-q-block accumulators: (B,H,nq,bq[,D])
        i, j = idx
        kj = lax.dynamic_index_in_dim(kb, j, 1, keepdims=False)
        vj = lax.dynamic_index_in_dim(vb, j, 1, keepdims=False)
        qi = lax.dynamic_index_in_dim(qb, i, 1, keepdims=False)
        s = jnp.einsum("bqhd,bkhd->bhqk", qi, kj,
                       preferred_element_type=jnp.float32) * scale
        qpos = q_start + i * bq + jnp.arange(bq)
        kpos = j * bk + jnp.arange(bk)
        mask = _block_mask(qpos, kpos, causal=True, window=None, kv_len=kv_len)
        s = jnp.where(mask[None, None], s, NEG_INF)
        mi = lax.dynamic_index_in_dim(m, i, 2, keepdims=False)
        li = lax.dynamic_index_in_dim(l, i, 2, keepdims=False)
        ai = lax.dynamic_index_in_dim(acc, i, 2, keepdims=False)
        m_new = jnp.maximum(mi, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(mi - m_new)
        li = li * corr + p.sum(axis=-1)
        ai = ai * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(vj.dtype), vj,
            preferred_element_type=jnp.float32)
        m = lax.dynamic_update_index_in_dim(m, m_new, i, 2)
        l = lax.dynamic_update_index_in_dim(l, li, i, 2)
        acc = lax.dynamic_update_index_in_dim(acc, ai, i, 2)
        return (m, l, acc), None

    init = (jnp.full((B, H, nq, bq), NEG_INF, jnp.float32),
            jnp.zeros((B, H, nq, bq), jnp.float32),
            jnp.zeros((B, H, nq, bq, D), jnp.float32))
    (m, l, acc), _ = lax.scan(step, init, (ii, jj))
    out = acc / jnp.maximum(l, 1e-30)[..., None]        # (B,H,nq,bq,D)
    out = out.reshape(B, H, nq * bq, D)
    out = jnp.moveaxis(out, 1, 2)[:, :Sq]
    return out.astype(out_dtype)


def local_chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                            window: int, q_start=0, kv_len=None,
                            block_q: int = 512) -> jax.Array:
    """Sliding-window attention that only touches the window.

    Unlike `chunked_attention(window=...)` (which scans every kv block and
    masks), this slices a static `window + block_q` span of kv per q block,
    so HLO FLOPs scale as O(Sq * window) — required for long-context
    hybrid archs (DESIGN.md §5).
    """
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    kv_len = Sk if kv_len is None else kv_len
    scale = 1.0 / math.sqrt(D)
    bq = min(block_q, Sq)
    nq = -(-Sq // bq)
    qp = nq * bq - Sq
    if qp:
        q = jnp.pad(q, ((0, 0), (0, qp), (0, 0), (0, 0)))
    qb = q.reshape(B, nq, bq, H, D)
    span = window + bq
    # pad kv in front so every slice start is valid
    kpad = jnp.pad(k, ((0, 0), (window, 0), (0, 0), (0, 0)))
    vpad = jnp.pad(v, ((0, 0), (window, 0), (0, 0), (0, 0)))
    q_start = jnp.asarray(q_start)

    def q_block(i):
        qi = lax.dynamic_index_in_dim(qb, i, 1, keepdims=False)
        p = q_start + i * bq                      # first q position
        start = jnp.clip(p, 0, Sk + window - span)
        kj = lax.dynamic_slice_in_dim(kpad, start, span, axis=1)
        vj = lax.dynamic_slice_in_dim(vpad, start, span, axis=1)
        kpos = start + jnp.arange(span) - window  # original coordinates
        qpos = p + jnp.arange(bq)
        s = jnp.einsum("bqhd,bkhd->bhqk", qi, kj,
                       preferred_element_type=jnp.float32) * scale
        mask = ((kpos[None, :] <= qpos[:, None])
                & (kpos[None, :] > qpos[:, None] - window)
                & (kpos[None, :] >= 0) & (kpos[None, :] < kv_len))
        s = jnp.where(mask[None, None], s, NEG_INF)
        pr = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhqk,bkhd->bqhd", pr.astype(vj.dtype), vj,
                          preferred_element_type=jnp.float32).astype(q.dtype)

    outs = lax.map(q_block, jnp.arange(nq))       # (nq, B, bq, H, D)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nq * bq, H, D)
    return out[:, :Sq]


def decode_attention_grouped(q: jax.Array, k_cache: jax.Array,
                             v_cache: jax.Array, cache_len, *,
                             window: Optional[int] = None) -> jax.Array:
    """GQA decode attention WITHOUT expanding kv to H heads.

    q: (B, 1, H, D); k_cache/v_cache: (B, S, K, D) with H % K == 0.
    Contracting directly against the K-headed cache avoids the
    (B, S, H, D) repeat copy — and, when the cache is sequence-sharded
    (flash-decoding), keeps all per-shard compute local with only tiny
    softmax-merge all-reduces. Returns (B, 1, H, D).
    """
    B, _, H, D = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    q5 = q.reshape(B, 1, K, G, D)
    scale = 1.0 / math.sqrt(D)
    s = jnp.einsum("bqkgd,btkd->bkgqt", q5, k_cache,
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(S)
    clen = jnp.asarray(cache_len).reshape(-1, 1)
    mask = pos[None, :] < clen
    if window is not None:
        mask = mask & (pos[None, :] > clen - 1 - window)
    s = jnp.where(mask[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqt,btkd->bqkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, D).astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len, *, window: Optional[int] = None) -> jax.Array:
    """Single-position attention against a cache.

    q: (B, 1, H, D); k_cache/v_cache: (B, S, H, D) (expanded heads).
    cache_len: number of valid cache positions (new token already written).
    """
    B, _, H, D = q.shape
    S = k_cache.shape[1]
    scale = 1.0 / math.sqrt(D)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k_cache,
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(S)
    clen = jnp.asarray(cache_len).reshape(-1, 1)       # (B|1, 1)
    mask = pos[None, :] < clen
    if window is not None:
        mask = mask & (pos[None, :] > clen - 1 - window)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------

def mlp_glu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
            w_down: jax.Array, act: str) -> jax.Array:
    h = activate(x @ w_gate, act) * (x @ w_up)
    return h @ w_down


def mlp_classic(x: jax.Array, w_up: jax.Array, w_down: jax.Array,
                act: str) -> jax.Array:
    return activate(x @ w_up, act) @ w_down


# --------------------------------------------------------------------------
# Loss
# --------------------------------------------------------------------------

def mask_pad_logits(logits: jax.Array, vocab_size: int) -> jax.Array:
    """Mask build-time vocab padding (configs.base.padded_vocab) to -inf
    so softmax/argmax semantics match the unpadded vocabulary."""
    if logits.shape[-1] == vocab_size:
        return logits
    idx = lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    return jnp.where(idx < vocab_size, logits,
                     jnp.asarray(NEG_INF, logits.dtype))


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          mask: Optional[jax.Array] = None,
                          z_loss: float = 0.0):
    """logits: (..., V), labels: (...).

    Vocab-sharding-safe: the gold logit is extracted with an iota-mask
    reduction (fuses; each model shard reduces its V slice + a tiny
    all-reduce) instead of take_along_axis (which would all-gather the
    full fp32 logits — measured at >100 GiB/device on qwen train_4k).
    """
    logits = logits.astype(jnp.float32)
    m = lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    lse = m[..., 0] + jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1))
    V = logits.shape[-1]
    idx = lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    gold = jnp.sum(jnp.where(idx == labels[..., None], logits, 0.0), axis=-1)
    loss = lse - gold
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    if mask is not None:
        loss = loss * mask
        return loss.sum() / jnp.maximum(mask.sum(), 1.0)
    return loss.mean()
