"""Decoder-only transformer covering the dense / moe / vlm / audio families.

Layers are stacked on a leading L axis and driven by `lax.scan` (+remat) so
the HLO stays O(1) in depth; the same layer function serves train, prefill,
and decode (with a paged or contiguous KV cache).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.models import moe as moe_lib

PyTree = Any


# --------------------------------------------------------------------------
# Parameter construction
# --------------------------------------------------------------------------

def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def param_specs(cfg: ModelConfig) -> Dict[str, Tuple[Tuple[int, ...], Tuple]]:
    """name -> (shape, logical_axes). Layer params carry a leading L dim.
    Vocab dims are padded (configs.base.padded_vocab); pad logits are
    masked in output_logits."""
    from repro.configs.base import padded_vocab
    d, H, K, hd, ff, V, nl = (cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                              cfg.head_dim, cfg.d_ff,
                              padded_vocab(cfg.vocab_size), cfg.num_layers)
    s: Dict[str, Tuple[Tuple[int, ...], Tuple]] = {}
    s["embed"] = ((V, d), ("vocab", "embed"))
    if not cfg.tie_embeddings:
        if cfg.frontend.kind == "audio" and cfg.frontend.num_codebooks > 1:
            s["head"] = ((cfg.frontend.num_codebooks, V, d),
                         (None, "vocab", "embed"))
        else:
            s["head"] = ((V, d), ("vocab", "embed"))
    s["final_norm"] = ((d,), (None,))
    if cfg.frontend.kind == "vlm":
        s["patch_proj"] = ((cfg.frontend.patch_embed_dim, d),
                           (None, "embed"))

    def lyr(name, shape, axes):
        s[f"layers/{name}"] = ((nl,) + shape, ("layers",) + axes)

    lyr("ln1", (d,), (None,))
    lyr("ln2", (d,), (None,))
    lyr("wq", (d, H, hd), ("embed", "heads", None))
    lyr("wk", (d, K, hd), ("embed", "kv_heads", "head_dim"))
    lyr("wv", (d, K, hd), ("embed", "kv_heads", "head_dim"))
    lyr("wo", (H, hd, d), ("heads", None, "embed"))
    if cfg.qkv_bias:
        lyr("bq", (H, hd), ("heads", None))
        lyr("bk", (K, hd), ("kv_heads", "head_dim"))
        lyr("bv", (K, hd), ("kv_heads", "head_dim"))
    if cfg.qk_norm:
        lyr("q_norm", (hd,), (None,))
        lyr("k_norm", (hd,), (None,))
    if cfg.moe is None:
        if cfg.mlp_glu:
            lyr("w_gate", (d, ff), ("embed", "ff"))
        lyr("w_up", (d, ff), ("embed", "ff"))
        lyr("w_down", (ff, d), ("ff", "embed"))
    else:
        m = cfg.moe
        lyr("router", (d, m.num_experts), ("embed", "experts"))
        lyr("we_gate", (m.num_experts, d, m.d_expert),
            ("experts", "embed", "expert_ff"))
        lyr("we_up", (m.num_experts, d, m.d_expert),
            ("experts", "embed", "expert_ff"))
        lyr("we_down", (m.num_experts, m.d_expert, d),
            ("experts", "expert_ff", "embed"))
        if m.num_shared_experts:
            lyr("ws_gate", (d, m.d_shared), ("embed", "ff"))
            lyr("ws_up", (d, m.d_shared), ("embed", "ff"))
            lyr("ws_down", (m.d_shared, d), ("ff", "embed"))
            lyr("shared_gate", (d,), ("embed",))
    return s


def logical_axes(cfg: ModelConfig) -> Dict[str, Tuple]:
    return {k: v[1] for k, v in param_specs(cfg).items()}


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict[str, jax.Array]:
    specs = param_specs(cfg)
    dt = _dtype(cfg)
    params = {}
    keys = jax.random.split(key, len(specs))
    for (name, (shape, _)), k in zip(sorted(specs.items()), keys):
        if "norm" in name or name.endswith(("ln1", "ln2")):
            params[name] = jnp.ones(shape, dt)
        elif name.endswith(("bq", "bk", "bv", "shared_gate")):
            params[name] = jnp.zeros(shape, dt)
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            std = 1.0 / math.sqrt(max(fan_in, 1))
            params[name] = (jax.random.normal(k, shape, jnp.float32)
                            * std).astype(dt)
    return params


def abstract_params(cfg: ModelConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct tree for the dry-run (no allocation)."""
    dt = _dtype(cfg)
    return {k: jax.ShapeDtypeStruct(shape, dt)
            for k, (shape, _) in param_specs(cfg).items()}


def param_count_tree(params: PyTree) -> int:
    return sum(int(jnp.size(p)) if isinstance(p, jax.Array)
               else int(math.prod(p.shape)) for p in jax.tree.leaves(params))


# --------------------------------------------------------------------------
# Layer
# --------------------------------------------------------------------------

def _attn(cfg: ModelConfig, p: Dict[str, jax.Array], x: jax.Array,
          positions: jax.Array, *, mode: str,
          kv_in: Optional[Tuple[jax.Array, jax.Array]] = None,
          cache_len=None, attn_impl: str = "masked",
          window: Optional[int] = None):
    """Self-attention. Returns (out, (k, v)) where k/v are this segment's
    keys/values (train/prefill) or None (decode uses kv_in as full cache)."""
    B, S, d = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        q = L.rms_norm(q, p["q_norm"], cfg.rms_eps)
        k = L.rms_norm(k, p["k_norm"], cfg.rms_eps)
    q = L.rope_for_seq(q, positions, cfg.rope_theta)
    k = L.rope_for_seq(k, positions, cfg.rope_theta)
    q = constrain(q, ("batch", None, "heads", None))
    k = constrain(k, ("batch", None, "kv_heads", "head_dim"))
    v = constrain(v, ("batch", None, "kv_heads", "head_dim"))

    if mode == "decode":
        k_cache, v_cache = kv_in              # (B, Smax, K, hd), new kv written
        ke = L.expand_kv(k_cache, H)
        ve = L.expand_kv(v_cache, H)
        out = L.decode_attention(q, ke, ve, cache_len, window=window)
        new_kv = (k, v)                       # single-position kv to store
    else:
        ke, ve = L.expand_kv(k, H), L.expand_kv(v, H)
        if window is not None:
            out = L.local_chunked_attention(q, ke, ve, window=window)
        else:
            out = L.chunked_attention(q, ke, ve, causal=True, impl=attn_impl)
        new_kv = (k, v)
    out = jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), p["wo"])
    return out, new_kv


def _ffn(cfg: ModelConfig, p: Dict[str, jax.Array], x: jax.Array):
    """Dense or MoE FFN. Returns (out, aux_loss)."""
    if cfg.moe is None:
        if cfg.mlp_glu:
            return L.mlp_glu(x, p["w_gate"], p["w_up"], p["w_down"],
                             cfg.act), 0.0
        return L.mlp_classic(x, p["w_up"], p["w_down"], cfg.act), 0.0
    out, aux = moe_lib.moe_ffn(cfg, p, x)
    if cfg.moe.num_shared_experts:
        shared = L.mlp_glu(x, p["ws_gate"], p["ws_up"], p["ws_down"], cfg.act)
        gate = jax.nn.sigmoid(
            jnp.einsum("bsd,d->bs", x.astype(jnp.float32),
                       p["shared_gate"].astype(jnp.float32)))[..., None]
        out = out + (gate * shared.astype(jnp.float32)).astype(out.dtype)
    return out, aux


def _layer(cfg: ModelConfig, p: Dict[str, jax.Array], x: jax.Array,
           positions, *, mode: str, kv_in=None, cache_len=None,
           attn_impl: str = "masked"):
    x = constrain(x, ("batch", None, None))
    h = L.rms_norm(x, p["ln1"], cfg.rms_eps)
    attn_out, kv = _attn(cfg, p, h, positions, mode=mode, kv_in=kv_in,
                         cache_len=cache_len, attn_impl=attn_impl)
    x = x + attn_out
    h = L.rms_norm(x, p["ln2"], cfg.rms_eps)
    ffn_out, aux = _ffn(cfg, p, h)
    return constrain(x + ffn_out, ("batch", None, None)), kv, aux


def _split_layers(params: Dict[str, jax.Array]):
    lyr = {k[len("layers/"):]: v for k, v in params.items()
           if k.startswith("layers/")}
    top = {k: v for k, v in params.items() if not k.startswith("layers/")}
    return top, lyr


# --------------------------------------------------------------------------
# Input embedding / output head (family hooks)
# --------------------------------------------------------------------------

def embed_inputs(cfg: ModelConfig, params, batch: Dict[str, jax.Array]):
    """Returns (x, positions, label_mask_prefix_len)."""
    top, _ = _split_layers(params)
    if cfg.frontend.kind == "audio":
        # stub frontend supplies precomputed frame embeddings (B, S, d)
        x = batch["frame_embeds"].astype(_dtype(cfg))
        Spos = x.shape[1]
        pos = jnp.arange(Spos)
        x = x + L.sinusoidal_pos_embed(pos, cfg.d_model).astype(x.dtype)[None]
        return constrain(x, ("batch", None, None)), pos, 0
    tok = batch["tokens"]
    x = jnp.take(top["embed"], tok, axis=0)
    prefix = 0
    if cfg.frontend.kind == "vlm":
        patches = batch["patch_embeds"].astype(_dtype(cfg))
        px = patches @ top["patch_proj"]
        x = jnp.concatenate([px, x], axis=1)
        prefix = px.shape[1]
    pos = jnp.arange(x.shape[1])
    return constrain(x, ("batch", None, None)), pos, prefix


def output_logits(cfg: ModelConfig, params, h: jax.Array) -> jax.Array:
    top, _ = _split_layers(params)
    w = top["embed"] if cfg.tie_embeddings else top["head"]
    if cfg.frontend.kind == "audio" and cfg.frontend.num_codebooks > 1:
        logits = constrain(jnp.einsum("bsd,cvd->bscv", h, w),
                           ("batch", None, None, "vocab"))
    else:
        logits = constrain(jnp.einsum("bsd,vd->bsv", h, w),
                           ("batch", None, "vocab"))
    return L.mask_pad_logits(logits, cfg.vocab_size)


# --------------------------------------------------------------------------
# Forward passes
# --------------------------------------------------------------------------

def forward(cfg: ModelConfig, params, batch, *, attn_impl: str = "masked",
            remat: bool = True):
    """Training/scoring forward: returns (logits, aux_loss)."""
    top, lyr = _split_layers(params)
    x, positions, prefix = embed_inputs(cfg, params, batch)

    def body(carry, lp):
        x, aux = carry
        x, _, a = _layer(cfg, lp, x, positions, mode="train",
                         attn_impl=attn_impl)
        return (x, aux + a), None

    body_fn = jax.checkpoint(
        body, policy=jax.checkpoint_policies.nothing_saveable) if remat else body
    (x, aux), _ = lax.scan(body_fn, (x, 0.0), lyr)
    x = L.rms_norm(x, top["final_norm"], cfg.rms_eps)
    logits = output_logits(cfg, params, x)
    if prefix:
        logits = logits[:, prefix:]
    return logits, aux


def loss_fn(cfg: ModelConfig, params, batch, *, attn_impl: str = "masked"):
    logits, aux = forward(cfg, params, batch, attn_impl=attn_impl)
    labels = batch["labels"]
    if cfg.frontend.kind == "audio" and cfg.frontend.num_codebooks > 1:
        loss = L.softmax_cross_entropy(logits, labels)   # (B,S,C) labels
    else:
        loss = L.softmax_cross_entropy(logits, labels)
    coef = cfg.moe.router_aux_coef if cfg.moe is not None else 0.0
    return loss + coef * aux, {"ce": loss, "aux": aux}


# ---- KV cache ------------------------------------------------------------

@dataclass(frozen=True)
class CacheSpec:
    layout: str            # "contiguous" | "paged"
    max_len: int
    page_size: int = 256

    @property
    def num_pages(self) -> int:
        return -(-self.max_len // self.page_size)


def init_cache(cfg: ModelConfig, batch: int, spec: CacheSpec):
    K, hd, nl = cfg.num_kv_heads, cfg.head_dim, cfg.num_layers
    dt = _dtype(cfg)
    if spec.layout == "contiguous":
        kv = jnp.zeros((nl, batch, spec.max_len, K, hd), dt)
        return {"k": kv, "v": kv, "len": jnp.zeros((), jnp.int32)}
    P, ps = spec.num_pages, spec.page_size
    kv = jnp.zeros((nl, batch, P, ps, K, hd), dt)
    table = jnp.tile(jnp.arange(P, dtype=jnp.int32)[None], (batch, 1))
    return {"k": kv, "v": kv, "block_table": table,
            "len": jnp.zeros((), jnp.int32)}


def abstract_cache(cfg: ModelConfig, batch: int, spec: CacheSpec):
    # eval_shape: NEVER materialize the cache here (a 32k-context cache is
    # hundreds of GB; the dry-run must stay allocation-free)
    return jax.eval_shape(lambda: init_cache(cfg, batch, spec))


def cache_logical_axes(cfg: ModelConfig, spec: CacheSpec):
    if spec.layout == "contiguous":
        kv = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
        return {"k": kv, "v": kv, "len": ()}
    kv = ("layers", "batch", "kv_seq", None, "kv_heads", "head_dim")
    return {"k": kv, "v": kv, "block_table": ("batch", None), "len": ()}


def _gather_pages(pool: jax.Array, table: jax.Array) -> jax.Array:
    """pool: (B, P, ps, K, hd); table: (B, P) logical->physical page ids.

    Returns the logically-ordered contiguous view (B, P*ps, K, hd). This is
    the XLA-level paged read; the Pallas `paged_attention` kernel performs
    the same access without materializing the copy (see kernels/).
    """
    B, P, ps, K, hd = pool.shape
    idx = table[:, :, None, None, None]
    g = jnp.take_along_axis(pool, idx, axis=1)
    return g.reshape(B, P * ps, K, hd)


def _scatter_token(pool: jax.Array, table: jax.Array, pos: jax.Array,
                   val: jax.Array) -> jax.Array:
    """Write val (B, K, hd) at logical position pos into the paged pool."""
    B, P, ps, K, hd = pool.shape
    page, off = pos // ps, pos % ps
    phys = table[jnp.arange(B), page]          # (B,)
    return pool.at[jnp.arange(B), phys, off].set(val.astype(pool.dtype))


def decode_step(cfg: ModelConfig, params, batch, cache, *,
                spec: CacheSpec):
    """One token of autoregressive decode against the KV cache.

    batch: {"token": (B,1) int} (or {"frame_embed": (B,1,d)} for audio).
    Returns (logits_last, new_cache).
    """
    top, lyr = _split_layers(params)
    pos = cache["len"]                          # scalar current length
    if cfg.frontend.kind == "audio":
        x = batch["frame_embed"].astype(_dtype(cfg))
        x = x + L.sinusoidal_pos_embed(pos[None], cfg.d_model).astype(x.dtype)[None]
    else:
        x = jnp.take(top["embed"], batch["token"], axis=0)
    positions = pos[None]                       # (1,)

    paged = spec.layout == "paged"

    x = constrain(x, ("batch", None, None))

    def layer_compute(lp, x, kc, vc):
        """One decode layer on per-layer cache slices (B, ...)."""
        h = L.rms_norm(x, lp["ln1"], cfg.rms_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, lp["wq"])
        k = jnp.einsum("bsd,dhk->bshk", h, lp["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, lp["wv"])
        if cfg.qkv_bias:
            q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
        if cfg.qk_norm:
            q = L.rms_norm(q, lp["q_norm"], cfg.rms_eps)
            k = L.rms_norm(k, lp["k_norm"], cfg.rms_eps)
        q = L.rope_for_seq(q, positions, cfg.rope_theta)
        k = L.rope_for_seq(k, positions, cfg.rope_theta)
        if paged:
            kc = _scatter_token(kc, cache["block_table"], pos, k[:, 0])
            vc = _scatter_token(vc, cache["block_table"], pos, v[:, 0])
            kfull = _gather_pages(kc, cache["block_table"])
            vfull = _gather_pages(vc, cache["block_table"])
        else:
            kc = lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), pos, 1)
            vc = lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), pos, 1)
            kfull, vfull = kc, vc
        kfull = constrain(kfull, ("batch", "kv_seq", "kv_heads", "head_dim"))
        vfull = constrain(vfull, ("batch", "kv_seq", "kv_heads", "head_dim"))
        from repro.distributed.sharding import get_global_rules
        rules = get_global_rules() or {}
        if rules.get("kv_seq"):
            # flash-decoding: per-S-shard scores need the (tiny) q on
            # every model shard; replicating q beats gathering the cache
            q = constrain(q, ("batch", None, None, None))
        # grouped GQA: no (B,S,H,D) kv expansion — works with hd- OR
        # sequence-sharded (flash-decoding) caches
        out = L.decode_attention_grouped(q, kfull, vfull, pos + 1)
        out = jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), lp["wo"])
        x = x + out
        h = L.rms_norm(x, lp["ln2"], cfg.rms_eps)
        ffn_out, _ = _ffn(cfg, lp, h)
        return constrain(x + ffn_out, ("batch", None, None)), kc, vc

    # fori_loop (NOT scan): the caches live in the loop CARRY and are
    # updated in place per layer, so XLA aliases one cache buffer end to
    # end (scan xs->ys would double-buffer the full cache; with donation
    # this path holds exactly one copy).
    def body(l, carry):
        x, kc_all, vc_all = carry
        lp = jax.tree.map(
            lambda a: lax.dynamic_index_in_dim(a, l, 0, keepdims=False), lyr)
        kc = lax.dynamic_index_in_dim(kc_all, l, 0, keepdims=False)
        vc = lax.dynamic_index_in_dim(vc_all, l, 0, keepdims=False)
        x, kc, vc = layer_compute(lp, x, kc, vc)
        kc_all = lax.dynamic_update_index_in_dim(kc_all, kc, l, 0)
        vc_all = lax.dynamic_update_index_in_dim(vc_all, vc, l, 0)
        return (x, kc_all, vc_all)

    x, k_new, v_new = lax.fori_loop(0, cfg.num_layers, body,
                                    (x, cache["k"], cache["v"]))
    x = L.rms_norm(x, top["final_norm"], cfg.rms_eps)
    logits = output_logits(cfg, params, x)
    new_cache = dict(cache, k=k_new, v=v_new, len=pos + 1)
    return logits, new_cache


def prefill(cfg: ModelConfig, params, batch, *, spec: CacheSpec,
            attn_impl: str = "masked"):
    """Prefill: run the full prompt, return (last_logits, cache)."""
    top, lyr = _split_layers(params)
    x, positions, prefix = embed_inputs(cfg, params, batch)
    B, S = x.shape[:2]

    def body(x, lp):
        x, (k, v), _ = _layer(cfg, lp, x, positions, mode="prefill",
                              attn_impl=attn_impl)
        return x, (k, v)

    x, (ks, vs) = lax.scan(body, x, lyr)        # ks: (L, B, S, K, hd)
    x = L.rms_norm(x, top["final_norm"], cfg.rms_eps)
    logits = output_logits(cfg, params, x[:, -1:])
    pad = spec.max_len - S if spec.layout == "contiguous" else \
        spec.num_pages * spec.page_size - S
    ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    if spec.layout == "paged":
        P, ps = spec.num_pages, spec.page_size
        K, hd = cfg.num_kv_heads, cfg.head_dim
        ks = ks.reshape(cfg.num_layers, B, P, ps, K, hd)
        vs = vs.reshape(cfg.num_layers, B, P, ps, K, hd)
        table = jnp.tile(jnp.arange(P, dtype=jnp.int32)[None], (B, 1))
        cache = {"k": ks, "v": vs, "block_table": table,
                 "len": jnp.asarray(S, jnp.int32)}
    else:
        cache = {"k": ks, "v": vs, "len": jnp.asarray(S, jnp.int32)}
    return logits, cache
