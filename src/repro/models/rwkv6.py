"""RWKV6 "Finch" (arXiv:2404.05892): attention-free LM with data-dependent
per-channel decay.

Two WKV implementations:
  * ``wkv_scan``    — sequential `lax.scan` over time. The correctness
                      oracle; O(S) steps, exact.
  * ``wkv_chunked`` — chunk-parallel linear-attention form (log-domain
                      stabilized). The production path for train/prefill:
                      matmul-dominated, remat-friendly; validated against
                      the oracle in tests/test_rwkv.py.

State per layer: wkv state (B, H, hs, hs) + token-shift registers. decode
is O(1) in context length — this is why rwkv6-3b runs the long_500k cell.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, RWKVConfig
from repro.distributed.sharding import constrain
from repro.models import layers as L

PyTree = Any


# --------------------------------------------------------------------------
# Params
# --------------------------------------------------------------------------

def param_specs(cfg: ModelConfig):
    from repro.configs.base import padded_vocab
    d, ff, V, nl = (cfg.d_model, cfg.d_ff, padded_vocab(cfg.vocab_size),
                    cfg.num_layers)
    rw = cfg.rwkv or RWKVConfig()
    H = d // rw.head_size
    s = {}
    s["embed"] = ((V, d), ("vocab", "embed"))
    s["embed_norm"] = ((d,), (None,))
    if not cfg.tie_embeddings:
        s["head"] = ((V, d), ("vocab", "embed"))
    s["final_norm"] = ((d,), (None,))

    def lyr(name, shape, axes):
        s[f"layers/{name}"] = ((nl,) + shape, ("layers",) + axes)

    lyr("ln1", (d,), (None,))
    lyr("ln2", (d,), (None,))
    # time-mix token-shift ddlerp
    lyr("mu_x", (d,), (None,))
    lyr("mu", (5, d), (None, None))                    # w,k,v,r,g bases
    lyr("w_mix1", (d, 5 * rw.mix_lora), ("embed", None))
    lyr("w_mix2", (5, rw.mix_lora, d), (None, None, "embed"))
    # projections
    for n in ("wr", "wk", "wv", "wg"):
        lyr(n, (d, d), ("embed", "heads_d"))
    lyr("wo", (d, d), ("heads_d", "embed"))
    # data-dependent decay
    lyr("w_base", (d,), (None,))
    lyr("wd1", (d, rw.decay_lora), ("embed", None))
    lyr("wd2", (rw.decay_lora, d), (None, "heads_d"))
    lyr("u", (H, rw.head_size), ("heads", None))       # bonus
    lyr("ln_x_scale", (d,), (None,))
    lyr("ln_x_bias", (d,), (None,))
    # channel-mix
    lyr("c_mu_k", (d,), (None,))
    lyr("c_mu_r", (d,), (None,))
    lyr("wck", (d, ff), ("embed", "ff"))
    lyr("wcv", (ff, d), ("ff", "embed"))
    lyr("wcr", (d, d), ("embed", "heads_d"))
    return s


def logical_axes(cfg: ModelConfig):
    return {k: v[1] for k, v in param_specs(cfg).items()}


def init_params(cfg: ModelConfig, key: jax.Array):
    dt = jnp.dtype(cfg.dtype)
    specs = param_specs(cfg)
    params = {}
    keys = jax.random.split(key, len(specs))
    for (name, (shape, _)), k in zip(sorted(specs.items()), keys):
        if "norm" in name or "ln" in name.split("/")[-1][:2] or name.endswith("ln_x_scale"):
            params[name] = jnp.ones(shape, dt)
        elif name.endswith(("mu_x", "mu", "c_mu_k", "c_mu_r", "ln_x_bias")):
            params[name] = (jax.random.uniform(k, shape, jnp.float32)
                            * 0.5).astype(dt)
        elif name.endswith("w_base"):
            # decay base: spread so w = exp(-exp(w_base)) covers (0, 1)
            params[name] = jnp.linspace(-6.0, 1.0, math.prod(shape),
                                        dtype=jnp.float32).reshape(shape).astype(dt)
        elif name.endswith("u"):
            params[name] = (jax.random.normal(k, shape, jnp.float32)
                            * 0.1).astype(dt)
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            params[name] = (jax.random.normal(k, shape, jnp.float32)
                            / math.sqrt(max(fan_in, 1))).astype(dt)
    return params


def abstract_params(cfg: ModelConfig):
    dt = jnp.dtype(cfg.dtype)
    return {k: jax.ShapeDtypeStruct(shape, dt)
            for k, (shape, _) in param_specs(cfg).items()}


# --------------------------------------------------------------------------
# WKV kernels (pure JAX)
# --------------------------------------------------------------------------

def wkv_scan(r, k, v, w, u, state0):
    """Oracle. r,k,v,w: (B, S, H, hs) (w = decay in (0,1), f32 math);
    u: (H, hs); state0: (B, H, hs, hs) [key, value]. Returns (y, stateT)."""
    B, S, H, hs = r.shape
    r, k, v, w = (t.astype(jnp.float32) for t in (r, k, v, w))
    u = u.astype(jnp.float32)

    def step(state, xs):
        rt, kt, vt, wt = xs                     # (B, H, hs)
        kv = kt[..., :, None] * vt[..., None, :]          # (B,H,hs,hs)
        y = jnp.einsum("bhi,bhij->bhj", rt, state + u[None, :, :, None] * kv)
        state = wt[..., :, None] * state + kv
        return state, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    stateT, ys = lax.scan(step, state0.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1), stateT       # (B,S,H,hs), (B,H,hs,hs)


def wkv_chunked(r, k, v, w, u, state0, *, chunk: int = 32):
    """Chunk-parallel WKV (log-domain linear attention).

    Within a chunk of length C:
      y_t = r~_t·S_0 + sum_{s<t} (r~_t·k~_s) v_s + (r_t·(u∘k_t)) v_t
      with r~_t = r_t∘P⁻_t, k~_s = k_s/P_s, P_t = prod_{s<=t} w_s.
    S_{chunk end} = diag(P_C) S_0 + sum_t diag(P_C/P_t) k_t^T v_t.

    All cross-chunk factors (P_C, P_C/P_t, P⁻_t) have exponents <= 0 and
    the intra-chunk matrix uses exact per-pair exponents (also <= 0), so
    the formulation is exact for arbitrarily heavy data-dependent decay.
    Matches `wkv_scan` to fp32 tolerance (tests/test_rwkv.py).
    """
    B, S, H, hs = r.shape
    C = min(chunk, S)
    n = -(-S // C)
    pad = n * C - S
    if pad:
        z = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = z(r), z(k), z(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)),
                    constant_values=1.0)
    f32 = jnp.float32
    rc = r.reshape(B, n, C, H, hs).astype(f32)
    kc = k.reshape(B, n, C, H, hs).astype(f32)
    vc = v.reshape(B, n, C, H, hs).astype(f32)
    wc = w.reshape(B, n, C, H, hs).astype(f32)
    u = u.astype(f32)

    def chunk_step(state, xs):
        rt, kt, vt, wt = xs                     # (B, C, H, hs)
        lw = jnp.log(jnp.clip(wt, 1e-12, 1.0))  # (B,C,H,hs) <= 0
        cum = jnp.cumsum(lw, axis=1)            # log P_t (inclusive)
        cum_ex = cum - lw                       # log P⁻_t (exclusive)
        total = cum[:, -1:]                     # log P_C
        # Intra-chunk: A_ij = sum_e r_ie k_je exp(cum_ex_ie - cum_je), j<i.
        # The exponent is <= 0 for every valid pair, so computing it
        # PER-PAIR is exact for arbitrarily heavy decay (a factored form
        # around a single reference overflows once the chunk spans >80
        # nats — see tests/test_rwkv.py). Cost: a (C, C, hs) elementwise
        # exp per chunk, same order as the matmul at C<=32.
        expo = cum_ex[:, :, None] - cum[:, None, :]     # (B, Ci, Cj, H, hs)
        ii = jnp.arange(C)
        causal = (ii[None, :] < ii[:, None])            # strict lower tri
        expo = jnp.where(causal[None, :, :, None, None], expo, -jnp.inf)
        A = jnp.einsum("bihe,bjhe,bijhe->bhij", rt, kt,
                       jnp.exp(expo), preferred_element_type=f32)
        intra = jnp.einsum("bhij,bjhe->bihe", A, vt)
        diag = jnp.einsum("bihe,bihe->bih", rt, u[None, None] * kt)
        intra = intra + diag[..., None] * vt
        inter = jnp.einsum("bihe,bhef->bihf", rt * jnp.exp(cum_ex), state)
        y = inter + intra
        decay_out = jnp.exp(total - cum)        # P_C / P_t  (<= 1)
        state = (jnp.exp(total)[:, 0, :, :, None] * state
                 + jnp.einsum("bihe,bihf->bhef", kt * decay_out, vt))
        return state, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (rc, kc, vc, wc))
    stateT, ys = lax.scan(chunk_step, state0.astype(f32), xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, n * C, H, hs)[:, :S]
    return y, stateT


# --------------------------------------------------------------------------
# Blocks
# --------------------------------------------------------------------------

def _token_shift(x: jax.Array, last: jax.Array) -> jax.Array:
    """x: (B,S,d); last: (B,d) = final token of the previous segment.
    Returns the 1-step-shifted sequence."""
    return jnp.concatenate([last[:, None, :], x[:, :-1, :]], axis=1)


def _ddlerp(p, x, xx):
    """Data-dependent lerp producing the 5 mixed inputs (w,k,v,r,g)."""
    B, S, d = x.shape
    mlora = p["w_mix1"].shape[1] // 5
    base = x + xx * p["mu_x"]
    s = jnp.tanh(base @ p["w_mix1"]).reshape(B, S, 5, mlora)
    offs = jnp.einsum("bsfm,fmd->bsfd", s, p["w_mix2"])   # (B,S,5,d)
    mix = p["mu"][None, None] + offs                      # (B,S,5,d)
    xi = x[:, :, None, :] + xx[:, :, None, :] * mix       # (B,S,5,d)
    return tuple(xi[:, :, i] for i in range(5))           # w,k,v,r,g


def time_mix(cfg: ModelConfig, p, x, tm_state, wkv_state, *,
             wkv_impl: str = "chunked"):
    """x: (B,S,d). tm_state: (B,d) shift register; wkv_state: (B,H,hs,hs).
    Returns (out, new_tm_state, new_wkv_state)."""
    rw = cfg.rwkv or RWKVConfig()
    B, S, d = x.shape
    H, hs = d // rw.head_size, rw.head_size
    xx = _token_shift(x, tm_state) - x
    xw, xk, xv, xr, xg = _ddlerp(p, x, xx)
    r = constrain((xr @ p["wr"]).reshape(B, S, H, hs),
                  ("batch", None, "heads", None))
    k = constrain((xk @ p["wk"]).reshape(B, S, H, hs),
                  ("batch", None, "heads", None))
    v = constrain((xv @ p["wv"]).reshape(B, S, H, hs),
                  ("batch", None, "heads", None))
    g = jax.nn.silu(xg @ p["wg"])
    dlog = (p["w_base"].astype(jnp.float32)
            + (jnp.tanh(xw @ p["wd1"]) @ p["wd2"]).astype(jnp.float32))
    w = jnp.exp(-jnp.exp(dlog)).reshape(B, S, H, hs)      # decay in (0,1)
    fn = wkv_chunked if wkv_impl == "chunked" else wkv_scan
    y, wkv_state = fn(r, k, v, w, p["u"], wkv_state)
    y = y.reshape(B, S, d)
    y = L.group_norm(y, p["ln_x_scale"], p["ln_x_bias"], num_groups=H)
    out = (y * g).astype(x.dtype) @ p["wo"]
    return out, x[:, -1, :], wkv_state


def channel_mix(cfg: ModelConfig, p, x, cm_state):
    xx = _token_shift(x, cm_state) - x
    xk = x + xx * p["c_mu_k"]
    xr = x + xx * p["c_mu_r"]
    kk = jax.nn.relu(xk @ p["wck"])
    kk = kk * kk
    out = jax.nn.sigmoid(xr @ p["wcr"]) * (kk @ p["wcv"])
    return out, x[:, -1, :]


def _layer(cfg, lp, x, st, *, wkv_impl):
    """st = {"tm": (B,d), "cm": (B,d), "wkv": (B,H,hs,hs)}."""
    x = constrain(x, ("batch", None, None))
    h = L.rms_norm(x, lp["ln1"], cfg.rms_eps)
    out, tm, wkv = time_mix(cfg, lp, h, st["tm"], st["wkv"],
                            wkv_impl=wkv_impl)
    x = x + out
    h = L.rms_norm(x, lp["ln2"], cfg.rms_eps)
    out, cm = channel_mix(cfg, lp, h, st["cm"])
    return constrain(x + out, ("batch", None, None)), \
        {"tm": tm, "cm": cm, "wkv": wkv}


def _split(params):
    lyr = {k[len("layers/"):]: v for k, v in params.items()
           if k.startswith("layers/")}
    top = {k: v for k, v in params.items() if not k.startswith("layers/")}
    return top, lyr


def init_state(cfg: ModelConfig, batch: int):
    rw = cfg.rwkv or RWKVConfig()
    d, nl = cfg.d_model, cfg.num_layers
    H, hs = d // rw.head_size, rw.head_size
    dt = jnp.dtype(cfg.dtype)
    return {"tm": jnp.zeros((nl, batch, d), dt),
            "cm": jnp.zeros((nl, batch, d), dt),
            "wkv": jnp.zeros((nl, batch, H, hs, hs), jnp.float32),
            "len": jnp.zeros((), jnp.int32)}


def abstract_state(cfg: ModelConfig, batch: int):
    return jax.eval_shape(lambda: init_state(cfg, batch))


def state_logical_axes(cfg: ModelConfig):
    return {"tm": ("layers", "batch", None),
            "cm": ("layers", "batch", None),
            "wkv": ("layers", "batch", "heads", None, None),
            "len": ()}


def forward(cfg: ModelConfig, params, batch, *, state=None,
            wkv_impl: str = "chunked", remat: bool = True,
            return_state: bool = False, last_only: bool = False):
    """Training/scoring/prefill forward. batch: {"tokens": (B,S)}."""
    top, lyr = _split(params)
    tok = batch["tokens"]
    x = jnp.take(top["embed"], tok, axis=0)
    x = constrain(x, ("batch", None, None))
    x = L.rms_norm(x, top["embed_norm"], cfg.rms_eps)
    B = x.shape[0]
    st = state if state is not None else init_state(cfg, B)

    def body(x, xs):
        lp, s = xs
        x, s_new = _layer(cfg, lp, x, s, wkv_impl=wkv_impl)
        return x, s_new

    body_fn = jax.checkpoint(
        body, policy=jax.checkpoint_policies.nothing_saveable) if remat else body
    layer_state = {k: st[k] for k in ("tm", "cm", "wkv")}
    x, new_state = lax.scan(body_fn, x, (lyr, layer_state))
    x = L.rms_norm(x, top["final_norm"], cfg.rms_eps)
    if last_only:
        x = x[:, -1:]
    w = top["embed"] if cfg.tie_embeddings else top["head"]
    logits = constrain(jnp.einsum("bsd,vd->bsv", x, w),
                       ("batch", None, "vocab"))
    logits = L.mask_pad_logits(logits, cfg.vocab_size)
    if return_state:
        new_state["len"] = st["len"] + tok.shape[1]
        return logits, new_state
    return logits, 0.0


def loss_fn(cfg: ModelConfig, params, batch, *, wkv_impl: str = "chunked"):
    logits, _ = forward(cfg, params, batch, wkv_impl=wkv_impl)
    loss = L.softmax_cross_entropy(logits, batch["labels"])
    return loss, {"ce": loss, "aux": 0.0}


def prefill(cfg: ModelConfig, params, batch, **kw):
    logits, state = forward(cfg, params, batch, return_state=True,
                            last_only=True, **kw)
    return logits, state


def decode_step(cfg: ModelConfig, params, batch, state):
    """One-token decode: O(1) in context length."""
    logits, new_state = forward(cfg, params, {"tokens": batch["token"]},
                                state=state, wkv_impl="scan",
                                remat=False, return_state=True)
    return logits, new_state

