"""Uniform model API over the four implementation families.

`build_model(cfg)` returns a `Model` whose methods take/return plain
pytrees, so the launch/serving/checkpoint layers never branch on family.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import rglru, rwkv6, transformer
from repro.models.transformer import CacheSpec

PyTree = Any


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init_params: Callable[[jax.Array], PyTree]
    abstract_params: Callable[[], PyTree]
    logical_axes: Callable[[], PyTree]
    loss_fn: Callable[..., Any]          # (params, batch) -> (loss, metrics)
    forward: Callable[..., Any]          # (params, batch) -> (logits, aux)
    prefill: Callable[..., Any]          # (params, batch) -> (logits, cache)
    decode_step: Callable[..., Any]      # (params, batch, cache) -> (logits, cache)
    init_cache: Callable[..., PyTree]    # (batch_size, max_len) -> cache
    abstract_cache: Callable[..., PyTree]
    cache_logical_axes: Callable[..., PyTree]

    @property
    def name(self) -> str:
        return self.cfg.name

    def param_count(self, params: Optional[PyTree] = None) -> int:
        tree = params if params is not None else self.abstract_params()
        return sum(int(jnp.size(p)) if isinstance(p, jax.Array)
                   else int(_prod(p.shape)) for p in jax.tree.leaves(tree))


def _prod(shape):
    out = 1
    for s in shape:
        out *= int(s)
    return out


def build_model(cfg: ModelConfig, *, kv_layout: str = "paged",
                page_size: int = 256, attn_impl: str = "masked",
                wkv_impl: str = "chunked") -> Model:
    if cfg.family == "ssm":
        return Model(
            cfg=cfg,
            init_params=lambda key: rwkv6.init_params(cfg, key),
            abstract_params=lambda: rwkv6.abstract_params(cfg),
            logical_axes=lambda: rwkv6.logical_axes(cfg),
            loss_fn=lambda p, b: rwkv6.loss_fn(cfg, p, b, wkv_impl=wkv_impl),
            forward=lambda p, b: rwkv6.forward(cfg, p, b, wkv_impl=wkv_impl),
            prefill=lambda p, b, max_len=None: rwkv6.prefill(
                cfg, p, b, wkv_impl=wkv_impl),
            decode_step=lambda p, b, c: rwkv6.decode_step(cfg, p, b, c),
            init_cache=lambda bs, max_len: rwkv6.init_state(cfg, bs),
            abstract_cache=lambda bs, max_len: rwkv6.abstract_state(cfg, bs),
            cache_logical_axes=lambda max_len=0: rwkv6.state_logical_axes(cfg),
        )
    if cfg.family == "hybrid":
        return Model(
            cfg=cfg,
            init_params=lambda key: rglru.init_params(cfg, key),
            abstract_params=lambda: rglru.abstract_params(cfg),
            logical_axes=lambda: rglru.logical_axes(cfg),
            loss_fn=lambda p, b: rglru.loss_fn(cfg, p, b),
            forward=lambda p, b: rglru.forward(cfg, p, b),
            prefill=lambda p, b, max_len=None: rglru.prefill(cfg, p, b),
            decode_step=lambda p, b, c: rglru.decode_step(cfg, p, b, c),
            init_cache=lambda bs, max_len: rglru.init_state(cfg, bs),
            abstract_cache=lambda bs, max_len: rglru.abstract_state(cfg, bs),
            cache_logical_axes=lambda max_len=0: rglru.state_logical_axes(cfg),
        )
    # dense / moe / vlm / audio -> transformer

    def spec(max_len):
        return CacheSpec(layout=kv_layout, max_len=max_len,
                         page_size=min(page_size, max_len))

    return Model(
        cfg=cfg,
        init_params=lambda key: transformer.init_params(cfg, key),
        abstract_params=lambda: transformer.abstract_params(cfg),
        logical_axes=lambda: transformer.logical_axes(cfg),
        loss_fn=lambda p, b: transformer.loss_fn(cfg, p, b,
                                                 attn_impl=attn_impl),
        forward=lambda p, b: transformer.forward(cfg, p, b,
                                                 attn_impl=attn_impl),
        prefill=lambda p, b, max_len=None: transformer.prefill(
            cfg, p, b, spec=spec(max_len if max_len else b["tokens"].shape[1]),
            attn_impl=attn_impl),
        decode_step=lambda p, b, c: transformer.decode_step(
            cfg, p, b, c, spec=_infer_spec(cfg, c, kv_layout)),
        init_cache=lambda bs, max_len: transformer.init_cache(
            cfg, bs, spec(max_len)),
        abstract_cache=lambda bs, max_len: transformer.abstract_cache(
            cfg, bs, spec(max_len)),
        cache_logical_axes=lambda max_len: transformer.cache_logical_axes(
            cfg, spec(max_len)),
    )


def _infer_spec(cfg: ModelConfig, cache: PyTree, kv_layout: str) -> CacheSpec:
    k = cache["k"]
    if "block_table" in cache:
        _, _, P, ps, _, _ = k.shape
        return CacheSpec(layout="paged", max_len=P * ps, page_size=ps)
    return CacheSpec(layout="contiguous", max_len=k.shape[2])
