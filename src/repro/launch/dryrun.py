import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
# Only the dry-run sees 512 placeholder devices; tests/benches see 1.

# Multi-pod dry-run: lower + compile every (architecture × input shape)
# cell on the production meshes and record memory / cost / collective
# analysis for EXPERIMENTS.md §Dry-run and §Roofline.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --all
#   PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b \
#       --shape train_4k --mesh both

import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.analysis.hlo import analyze_hlo, bf16_upcast_f32_bytes
from repro.configs import (ARCH_NAMES, SHAPES_BY_NAME, get_config,
                           shapes_for)
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell

DEFAULT_OUT = Path("experiments/dryrun.jsonl")


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             kv_layout: str = "paged", attn_impl: str = "masked",
             wkv_impl: str = "chunked", save_hlo: bool = False,
             extra_tag: str = "", expert_sharding: str = "",
             microbatches: int = 0, grad_compress: bool = False,
             flash_decode: bool = False) -> dict:
    import dataclasses
    cfg = get_config(arch)
    if expert_sharding and cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, expert_sharding=expert_sharding))
    shape = SHAPES_BY_NAME[shape_name]
    if microbatches and shape.kind == "train":
        from repro.launch import specs as specs_lib
        specs_lib.TRAIN_MICROBATCHES[arch] = microbatches
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": int(mesh.size), "kind": shape.kind,
        "kv_layout": kv_layout, "attn_impl": attn_impl,
        "wkv_impl": wkv_impl, "tag": extra_tag,
    }
    t0 = time.time()
    try:
        cell = build_cell(cfg, shape, mesh, kv_layout=kv_layout,
                          attn_impl=attn_impl, wkv_impl=wkv_impl,
                          grad_compress=grad_compress,
                          flash_decode=flash_decode)
        with jax.set_mesh(mesh):
            jitted = jax.jit(cell["fn"],
                             in_shardings=cell["in_shardings"],
                             out_shardings=cell["out_shardings"],
                             donate_argnums=cell["donate_argnums"])
            lowered = jitted.lower(*cell["args"])
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "code_bytes": int(ma.generated_code_size_in_bytes),
        }
        rec["memory"]["total_bytes"] = (
            rec["memory"]["argument_bytes"] + rec["memory"]["output_bytes"]
            + rec["memory"]["temp_bytes"] - rec["memory"]["alias_bytes"])
        txt = compiled.as_text()
        upcast = bf16_upcast_f32_bytes(txt)
        rec["memory"]["f32_upcast_bytes"] = upcast
        rec["memory"]["tpu_corrected_bytes"] = max(
            rec["memory"]["total_bytes"] - upcast,
            rec["memory"]["argument_bytes"] + rec["memory"]["output_bytes"]
            - rec["memory"]["alias_bytes"])
        ca = compiled.cost_analysis() or {}
        rec["xla_cost"] = {k: float(v) for k, v in ca.items()
                           if isinstance(v, (int, float))
                           and k in ("flops", "bytes accessed",
                                     "transcendentals")}
        rec["hlo_chars"] = len(txt)
        analysis = analyze_hlo(txt, pod_stride=256 if multi_pod else 10**9)
        rec["analysis"] = analysis.summary()
        rec["collectives_by_op"] = {}
        for c in analysis.collectives:
            key = f"{c.opcode}{'_dcn' if c.dcn else ''}"
            d = rec["collectives_by_op"].setdefault(
                key, {"count": 0.0, "result_bytes": 0.0, "ring_bytes": 0.0})
            d["count"] += c.count
            d["result_bytes"] += c.result_bytes
            d["ring_bytes"] += c.ring_bytes
        rec["while_trips"] = analysis.while_trips[:50]
        rec["param_count"] = int(cell["model"].param_count())
        rec["lower_s"] = round(t1 - t0, 2)
        rec["compile_s"] = round(t2 - t1, 2)
        rec["ok"] = True
        if save_hlo:
            p = Path("experiments/hlo")
            p.mkdir(parents=True, exist_ok=True)
            (p / f"{arch}_{shape_name}_{rec['mesh']}{extra_tag}.txt"
             ).write_text(txt)
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        rec["elapsed_s"] = round(time.time() - t0, 2)
    return rec


def cells(arch_filter=None, shape_filter=None):
    for arch in ARCH_NAMES:
        if arch_filter and arch != arch_filter:
            continue
        cfg = get_config(arch)
        for shape in shapes_for(cfg):
            if shape_filter and shape.name != shape_filter:
                continue
            yield arch, shape.name


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=str(DEFAULT_OUT))
    ap.add_argument("--kv-layout", default="paged",
                    choices=["paged", "contiguous"])
    ap.add_argument("--attn-impl", default="masked", choices=["masked", "tri"])
    ap.add_argument("--wkv-impl", default="chunked",
                    choices=["chunked", "scan"])
    ap.add_argument("--expert-sharding", default="",
                    choices=["", "expert", "ffn"])
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--grad-compress", action="store_true",
                    help="int8 error-feedback grad exchange over the pod "
                         "(DCN) axis")
    ap.add_argument("--flash-decode", action="store_true",
                    help="shard the KV cache over sequence/pages when "
                         "kv_heads < TP (flash-decoding style)")
    ap.add_argument("--tag", default="")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    done = set()
    if args.skip_existing and out.exists():
        for line in out.read_text().splitlines():
            try:
                r = json.loads(line)
                if r.get("ok"):
                    done.add((r["arch"], r["shape"], r["mesh"], r.get("tag", "")))
            except json.JSONDecodeError:
                pass

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    todo = list(cells(args.arch, args.shape))
    if not todo:
        raise SystemExit(f"no cells match arch={args.arch} shape={args.shape}")
    n_ok = n_fail = 0
    with out.open("a") as f:
        for arch, shape_name in todo:
            for multi in meshes:
                mesh_name = "2x16x16" if multi else "16x16"
                if (arch, shape_name, mesh_name, args.tag) in done:
                    print(f"[skip] {arch} {shape_name} {mesh_name}")
                    continue
                print(f"[run ] {arch} {shape_name} {mesh_name} ...",
                      flush=True)
                rec = run_cell(arch, shape_name, multi,
                               kv_layout=args.kv_layout,
                               attn_impl=args.attn_impl,
                               wkv_impl=args.wkv_impl,
                               expert_sharding=args.expert_sharding,
                               microbatches=args.microbatches,
                               grad_compress=args.grad_compress,
                               flash_decode=args.flash_decode,
                               save_hlo=args.save_hlo, extra_tag=args.tag)
                f.write(json.dumps(rec) + "\n")
                f.flush()
                if rec["ok"]:
                    n_ok += 1
                    m = rec["memory"]["total_bytes"] / 2**30
                    print(f"   ok: {m:.2f} GiB/dev, "
                          f"flops/dev={rec['analysis']['flops']:.3e}, "
                          f"compile={rec['compile_s']}s", flush=True)
                else:
                    n_fail += 1
                    print(f"   FAIL: {rec['error'][:200]}", flush=True)
    print(f"done: {n_ok} ok, {n_fail} failed -> {out}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
