"""Serving launcher: batched generation over the SMS-paged KV cache.

`--evict-resume` additionally exercises the paper's on-demand migration
on device payloads: finished sequences' KV pages are evicted to COS
(zero-copy uint8 views via the Payload protocol, no intermediate
`bytes`) and restored before a second generation round.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.configs import get_config, reduced
from repro.serving import ServeConfig, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--evict-resume", action="store_true",
                    help="evict seq0's pages to COS and resume it "
                         "(device-payload on-demand migration)")
    args = ap.parse_args()
    cfg = reduced(get_config(args.arch))
    eng = ServeEngine(cfg, ServeConfig(batch_slots=args.batch,
                                       max_len=args.prompt_len
                                       + args.max_new_tokens + 8,
                                       page_size=args.page_size))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    out = eng.generate(prompts, args.max_new_tokens)
    print("generated tokens:\n", out)
    if args.evict_resume:
        # push seq0's live pages out to COS, then bring them back — the
        # whole round-trip stays on uint8 array views
        keys = [k for k, v in list(eng.kv.pages.items()) if v[0] == 0]
        for key in keys:
            eng.kv.evict_page_to_cos(key)
        restored = eng.resume("seq0", 0)
        print(f"evicted {len(keys)} pages to COS, restored {restored}")
    print("kv stats:", eng.kv.stats)
    print("serve stats:", eng.stats)


if __name__ == "__main__":
    main()
