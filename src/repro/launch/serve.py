"""Serving launcher: batched generation over the SMS-paged KV cache."""
from __future__ import annotations

import argparse

import numpy as np

from repro.configs import get_config, reduced
from repro.serving import ServeConfig, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--page-size", type=int, default=8)
    args = ap.parse_args()
    cfg = reduced(get_config(args.arch))
    eng = ServeEngine(cfg, ServeConfig(batch_slots=args.batch,
                                       max_len=args.prompt_len
                                       + args.max_new_tokens + 8,
                                       page_size=args.page_size))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    out = eng.generate(prompts, args.max_new_tokens)
    print("generated tokens:\n", out)
    print("kv stats:", eng.kv.stats)
    print("serve stats:", eng.stats)


if __name__ == "__main__":
    main()
