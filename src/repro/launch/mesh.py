"""Production mesh construction.

`make_production_mesh` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import (see launch/dryrun.py); tests and benchmarks see 1 device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_test_mesh(data: int = 1, model: int = 1, *, pod: int = 0):
    """Small mesh for CPU tests (fits in however many devices exist)."""
    if pod:
        return jax.make_mesh(
            (pod, data, model), ("pod", "data", "model"),
            axis_types=(jax.sharding.AxisType.Auto,) * 3)
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


# TPU v5e hardware model used by the roofline analysis (per chip).
HW = {
    "peak_flops_bf16": 197e12,     # FLOP/s
    "hbm_bw": 819e9,               # B/s
    "ici_bw": 50e9,                # B/s per link
    "dcn_bw": 6.25e9,              # B/s per chip across pods (assumption)
    "hbm_bytes": 16e9,
}
