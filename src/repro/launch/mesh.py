"""Production mesh construction.

`make_production_mesh` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import (see launch/dryrun.py); tests and benchmarks see 1 device.
"""
from __future__ import annotations

import jax


def compat_make_mesh(shape, axes):
    """jax.make_mesh across jax versions: pass axis_types=Auto where the
    API exists (jax >= 0.5); older jax has no AxisType and treats every
    axis as Auto already."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(
            shape, axes, axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def compat_shard_map(f, *, mesh, in_specs, out_specs, axis_names):
    """shard_map across jax versions: `jax.shard_map(..., axis_names=...,
    check_vma=False)` on new jax; on old jax, the experimental shard_map
    with `auto=` carrying the non-manual axes so only `axis_names` go
    manual (same partial-manual semantics as the new API). axis_names is
    required — a default would mean opposite things in the two branches
    (new jax: all axes manual; old jax: none)."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  axis_names=axis_names, check_vma=False)
    from jax.experimental.shard_map import shard_map as sm_old
    auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    return sm_old(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False, auto=auto)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_test_mesh(data: int = 1, model: int = 1, *, pod: int = 0):
    """Small mesh for CPU tests (fits in however many devices exist)."""
    if pod:
        return compat_make_mesh((pod, data, model),
                                ("pod", "data", "model"))
    return compat_make_mesh((data, model), ("data", "model"))


# TPU v5e hardware model used by the roofline analysis (per chip).
HW = {
    "peak_flops_bf16": 197e12,     # FLOP/s
    "hbm_bw": 819e9,               # B/s
    "ici_bw": 50e9,                # B/s per link
    "dcn_bw": 6.25e9,              # B/s per chip across pods (assumption)
    "hbm_bytes": 16e9,
}
