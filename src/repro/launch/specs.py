"""Input specs (ShapeDtypeStruct stand-ins) for every (arch × shape) cell.

Shardable, weak-type-correct, zero allocation — the dry-run lowers against
these. Each spec comes with a logical-axis tree so launch code can derive
in_shardings from the same rules as the params.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig

PyTree = Any

# Per-arch gradient-accumulation microbatch counts for train_4k, sized so
# one microbatch's activations fit HBM next to the ZeRO-sharded state
# (DESIGN.md §4; derivation in EXPERIMENTS.md §Dry-run).
TRAIN_MICROBATCHES: Dict[str, int] = {
    "qwen1.5-0.5b": 1,
    "qwen3-1.7b": 2,
    "qwen3-14b": 8,
    "qwen1.5-110b": 16,
    "internvl2-1b": 1,
    "rwkv6-3b": 4,
    "recurrentgemma-2b": 4,
    "qwen2-moe-a2.7b": 4,
    "granite-moe-1b-a400m": 2,
    "musicgen-large": 4,
}


def num_microbatches(cfg: ModelConfig, shape: ShapeConfig,
                     dp: int = 1) -> int:
    """Gradient-accumulation depth, clamped so each microbatch's batch dim
    stays divisible by the data-parallel degree."""
    if shape.kind != "train":
        return 1
    n = TRAIN_MICROBATCHES.get(cfg.name, shape.num_microbatches)
    n = max(1, min(n, shape.global_batch // max(dp, 1)))
    while n > 1 and (shape.global_batch % n
                     or (shape.global_batch // n) % max(dp, 1)):
        n -= 1
    return n


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig, dp: int = 1
                      ) -> Tuple[PyTree, PyTree]:
    """Returns (specs, logical_axes). Leading dim = microbatches (scanned),
    second dim = per-microbatch global batch (sharded over dp)."""
    n = num_microbatches(cfg, shape, dp)
    B = shape.global_batch // n
    S = shape.seq_len
    i32, bf16 = jnp.int32, jnp.dtype(cfg.dtype)
    if cfg.frontend.kind == "audio":
        C = cfg.frontend.num_codebooks
        specs = {"frame_embeds": _sds((n, B, S, cfg.d_model), bf16),
                 "labels": _sds((n, B, S, C), i32)}
        axes = {"frame_embeds": (None, "batch", None, None),
                "labels": (None, "batch", None, None)}
    elif cfg.frontend.kind == "vlm":
        Pn = cfg.frontend.num_prefix_embeds
        St = S - Pn
        specs = {"tokens": _sds((n, B, St), i32),
                 "patch_embeds": _sds((n, B, Pn, cfg.frontend.patch_embed_dim),
                                      bf16),
                 "labels": _sds((n, B, St), i32)}
        axes = {"tokens": (None, "batch", None),
                "patch_embeds": (None, "batch", None, None),
                "labels": (None, "batch", None)}
    else:
        specs = {"tokens": _sds((n, B, S), i32),
                 "labels": _sds((n, B, S), i32)}
        axes = {"tokens": (None, "batch", None),
                "labels": (None, "batch", None)}
    return specs, axes


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeConfig
                        ) -> Tuple[PyTree, PyTree]:
    B, S = shape.global_batch, shape.seq_len
    i32, bf16 = jnp.int32, jnp.dtype(cfg.dtype)
    if cfg.frontend.kind == "audio":
        return ({"frame_embeds": _sds((B, S, cfg.d_model), bf16)},
                {"frame_embeds": ("batch", None, None)})
    if cfg.frontend.kind == "vlm":
        Pn = cfg.frontend.num_prefix_embeds
        return ({"tokens": _sds((B, S - Pn), i32),
                 "patch_embeds": _sds((B, Pn, cfg.frontend.patch_embed_dim),
                                      bf16)},
                {"tokens": ("batch", None),
                 "patch_embeds": ("batch", None, None)})
    return ({"tokens": _sds((B, S), i32)}, {"tokens": ("batch", None)})


def decode_batch_specs(cfg: ModelConfig, shape: ShapeConfig
                       ) -> Tuple[PyTree, PyTree]:
    B = shape.global_batch
    i32, bf16 = jnp.int32, jnp.dtype(cfg.dtype)
    if cfg.frontend.kind == "audio":
        return ({"frame_embed": _sds((B, 1, cfg.d_model), bf16)},
                {"frame_embed": ("batch", None, None)})
    return ({"token": _sds((B, 1), i32)}, {"token": ("batch", None)})


def input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """The dry-run entry point: ShapeDtypeStruct stand-ins for every model
    input of this cell (training batch, prefill prompt, or decode batch)."""
    if shape.kind == "train":
        return train_batch_specs(cfg, shape)
    if shape.kind == "prefill":
        return prefill_batch_specs(cfg, shape)
    return decode_batch_specs(cfg, shape)
