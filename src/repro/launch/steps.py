"""Step builders: train_step (grad-accumulation + ZeRO AdamW) and
serve_step (prefill / decode), with their in/out shardings.

These are the functions the multi-pod dry-run lowers and the examples run.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import (make_rules, set_global_rules,
                                        sharding_for, tree_shardings)
from repro.launch import specs as specs_lib
from repro.models.registry import Model, build_model
from repro.optim import adamw

PyTree = Any


def _axes_is_leaf(x):
    return isinstance(x, tuple) and all(a is None or isinstance(a, str)
                                        for a in x)


# --------------------------------------------------------------------------
# Train
# --------------------------------------------------------------------------

def make_train_step(model: Model, opt_cfg: adamw.AdamWConfig):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    batch leaves have a leading num_microbatches dim; gradients are
    accumulated in fp32 across a `lax.scan` so activation memory stays
    one-microbatch-deep.
    """
    def train_step(params, opt_state, batch):
        def loss_of(p, mb):
            loss, metrics = model.loss_fn(p, mb)
            return loss, metrics

        grad_fn = jax.value_and_grad(loss_of, has_aux=True)

        def micro(carry, mb):
            g_acc, loss_acc = carry
            (loss, _metrics), grads = grad_fn(params, mb)
            g_acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), g_acc, grads)
            return (g_acc, loss_acc + loss), None

        n = jax.tree.leaves(batch)[0].shape[0]
        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (g_sum, loss_sum), _ = lax.scan(micro, (g0, 0.0), batch)
        grads = jax.tree.map(lambda g: g / n, g_sum)
        loss = loss_sum / n
        new_params, new_opt, om = adamw.adamw_update(
            opt_cfg, grads, opt_state, params)
        metrics = {"loss": loss, **om}
        return new_params, new_opt, metrics

    return train_step


def dp_size(mesh: Mesh) -> int:
    return mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)


def make_train_step_compressed(model: Model, opt_cfg: adamw.AdamWConfig,
                               mesh: Mesh):
    """Multi-pod train step with int8 error-feedback gradient exchange
    over the pod (DCN) axis — see optim/compression.py. The opt state
    carries the quantization-error tree under "err"; intra-pod (ICI)
    reductions stay full precision."""
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import (get_global_rules,
                                            set_global_rules)
    from repro.optim import compression

    def train_step(params, opt_state, batch):
        err = opt_state["err"]

        def per_pod(params, batch, err):
            def loss_of(p, mb):
                loss, metrics = model.loss_fn(p, mb)
                return loss, metrics

            grad_fn = jax.value_and_grad(loss_of, has_aux=True)

            def micro(carry, mb):
                g_acc, loss_acc = carry
                (loss, _m), grads = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), g_acc, grads)
                return (g_acc, loss_acc + loss), None

            n = jax.tree.leaves(batch)[0].shape[0]
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (g_sum, loss_sum), _ = lax.scan(micro, (g0, 0.0), batch)
            grads = jax.tree.map(lambda g: g / n, g_sum)
            loss = loss_sum / n
            # compressed cross-pod exchange (int8 on the DCN)
            grads, new_err = compression.psum_compressed(grads, "pod", err)
            loss = jax.lax.pmean(loss, "pod")
            return grads, new_err, loss

        b_spec = jax.tree.map(
            lambda x: P(None, "pod") if x.ndim >= 2 else P(), batch)
        g_spec = jax.tree.map(lambda _: P(), params)
        # inside the manual-pod region, activation constraints must not
        # mention the (now Manual) pod axis — swap the rules for tracing
        outer_rules = get_global_rules()
        if outer_rules is not None:
            inner = dict(outer_rules)
            inner["batch"] = "data"
            set_global_rules(inner)
        try:
            from repro.launch.mesh import compat_shard_map
            grads, new_err, loss = compat_shard_map(
                per_pod, mesh=mesh, axis_names={"pod"},
                in_specs=(g_spec, b_spec, g_spec),
                out_specs=(g_spec, g_spec, P()),
            )(params, batch, err)
        finally:
            set_global_rules(outer_rules)
        new_params, new_opt, om = adamw.adamw_update(
            opt_cfg, grads, {k: v for k, v in opt_state.items()
                             if k != "err"}, params)
        new_opt["err"] = new_err
        return new_params, new_opt, {"loss": loss, **om}

    return train_step


def train_shardings(model: Model, mesh: Mesh, shape: ShapeConfig,
                    with_err: bool = False):
    """(in_shardings, out_shardings) trees for make_train_step's fn."""
    rules = make_rules(model.cfg, mesh)
    p_axes = model.logical_axes()
    ap = model.abstract_params()
    p_sh = tree_shardings(p_axes, mesh, rules, ap)
    o_axes = adamw.opt_logical_axes(p_axes)
    o_abs = adamw.abstract_opt_state(ap)
    if with_err:
        o_axes["err"] = o_axes["master"]
        o_abs["err"] = o_abs["master"]
    opt_sh = tree_shardings(o_axes, mesh, rules, o_abs)
    b_specs, b_axes = specs_lib.train_batch_specs(model.cfg, shape,
                                                  dp=dp_size(mesh))
    b_sh = tree_shardings(b_axes, mesh, rules, b_specs)
    metric_sh = NamedSharding(mesh, P())
    in_sh = (p_sh, opt_sh, b_sh)
    out_sh = (p_sh, opt_sh,
              {"loss": metric_sh, "grad_norm": metric_sh, "lr": metric_sh})
    return in_sh, out_sh


def abstract_train_state(model: Model):
    ap = model.abstract_params()
    return ap, adamw.abstract_opt_state(ap)


# --------------------------------------------------------------------------
# Serve
# --------------------------------------------------------------------------

def make_prefill_step(model: Model, max_len: Optional[int] = None):
    def prefill_step(params, batch):
        return model.prefill(params, batch, max_len=max_len)
    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, batch, cache):
        logits, new_cache = model.decode_step(params, batch, cache)
        # greedy sampling keeps the lowered graph self-contained;
        # (B,1,V) -> (B,1), audio (B,1,C,V) -> (B,1,C)
        next_tok = jnp.argmax(logits, axis=-1)
        return next_tok.astype(jnp.int32), new_cache
    return decode_step


def serve_shardings(model: Model, mesh: Mesh, shape: ShapeConfig, *,
                    mode: str, max_len: Optional[int] = None,
                    flash_decode: bool = False):
    """Shardings for prefill ("prefill") or decode ("decode") steps."""
    cfg = model.cfg
    from repro.configs.base import padded_vocab
    rules = make_rules(cfg, mesh, flash_decode=flash_decode)
    p_sh = tree_shardings(model.logical_axes(), mesh, rules,
                          model.abstract_params())
    b_specs, b_axes = (specs_lib.prefill_batch_specs(cfg, shape)
                       if mode == "prefill"
                       else specs_lib.decode_batch_specs(cfg, shape))
    b_sh = tree_shardings(b_axes, mesh, rules, b_specs)
    c_axes = model.cache_logical_axes(max_len or shape.seq_len)
    c_abs = model.abstract_cache(shape.global_batch,
                                 max_len or shape.seq_len)
    c_sh = tree_shardings(c_axes, mesh, rules, c_abs)
    B, Vp = shape.global_batch, padded_vocab(cfg.vocab_size)
    audio = (cfg.frontend.kind == "audio"
             and cfg.frontend.num_codebooks > 1)
    C = cfg.frontend.num_codebooks
    logits_sh = sharding_for(
        ("batch", None, None, "vocab") if audio else ("batch", None, "vocab"),
        mesh, rules, shape=(B, 1, C, Vp) if audio else (B, 1, Vp))
    tok_sh = sharding_for(
        ("batch", None, None) if audio else ("batch", None), mesh, rules,
        shape=(B, 1, C) if audio else (B, 1))
    if mode == "prefill":
        return (p_sh, b_sh), (logits_sh, c_sh)
    return (p_sh, b_sh, c_sh), (tok_sh, c_sh)


# --------------------------------------------------------------------------
# Cell assembly (arch × shape -> step fn + specs + shardings)
# --------------------------------------------------------------------------

def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, *,
               kv_layout: str = "paged", attn_impl: str = "masked",
               wkv_impl: str = "chunked", grad_compress: bool = False,
               flash_decode: bool = False,
               opt_cfg: Optional[adamw.AdamWConfig] = None):
    """Everything needed to lower one (arch × shape) cell on a mesh.

    Returns dict with: fn, example_args (ShapeDtypeStructs), in_shardings,
    out_shardings, model.
    """
    model = build_model(cfg, kv_layout=kv_layout, attn_impl=attn_impl,
                        wkv_impl=wkv_impl)
    # install activation-sharding rules for tracing (see sharding.constrain)
    set_global_rules(make_rules(cfg, mesh, flash_decode=flash_decode))
    if shape.kind == "train":
        compress = grad_compress and "pod" in mesh.axis_names
        ocfg = opt_cfg or adamw.AdamWConfig()
        fn = (make_train_step_compressed(model, ocfg, mesh) if compress
              else make_train_step(model, ocfg))
        in_sh, out_sh = train_shardings(model, mesh, shape,
                                        with_err=compress)
        b_specs, _ = specs_lib.train_batch_specs(cfg, shape,
                                                 dp=dp_size(mesh))
        ap, aopt = abstract_train_state(model)
        if compress:
            aopt["err"] = jax.tree.map(
                lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), ap)
        args = (ap, aopt, b_specs)
        donate = (0, 1)          # params + opt state update in place
    elif shape.kind == "prefill":
        fn = make_prefill_step(model, max_len=shape.seq_len)
        in_sh, out_sh = serve_shardings(model, mesh, shape, mode="prefill",
                                        max_len=shape.seq_len)
        b_specs, _ = specs_lib.prefill_batch_specs(cfg, shape)
        args = (model.abstract_params(), b_specs)
        donate = ()
    else:  # decode
        fn = make_decode_step(model)
        in_sh, out_sh = serve_shardings(model, mesh, shape, mode="decode",
                                        max_len=shape.seq_len,
                                        flash_decode=flash_decode)
        b_specs, _ = specs_lib.decode_batch_specs(cfg, shape)
        cache = model.abstract_cache(shape.global_batch, shape.seq_len)
        args = (model.abstract_params(), b_specs, cache)
        donate = (2,)            # KV cache / recurrent state in place
    return {"fn": fn, "args": args, "in_shardings": in_sh,
            "out_shardings": out_sh, "model": model,
            "donate_argnums": donate}
