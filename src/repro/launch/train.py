"""Training launcher: real training loop with InfiniStore checkpointing.

On the CPU container this drives reduced configs end-to-end (the examples
use it); on a pod the same loop runs the full configs under
make_production_mesh(). Fault tolerance: periodic EC-coded checkpoints
through InfiniStore; on restart (or simulated failure) the loop resumes
from the latest recoverable step, and the deterministic data pipeline
replays the exact stream.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from dataclasses import dataclass
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer, CheckpointConfig
from repro.configs import SHAPES_BY_NAME, ShapeConfig, get_config, reduced
from repro.configs.base import ModelConfig
from repro.core import Clock, InfiniStore, StoreConfig
from repro.core.ec import ECConfig
from repro.core.gc_window import GCConfig
from repro.data.pipeline import TokenPipeline
from repro.distributed.sharding import make_rules, set_global_rules
from repro.launch.mesh import make_test_mesh
from repro.launch.steps import make_train_step
from repro.models import build_model
from repro.optim import adamw


@dataclass
class TrainResult:
    steps: int
    final_loss: float
    losses: list
    wall_s: float
    restored_from: Optional[int] = None


def make_store_for_checkpoints(tmpdir: Optional[str] = None) -> InfiniStore:
    cfg = StoreConfig(
        ec=ECConfig(k=4, p=2),
        function_capacity=64 * 1024 * 1024,
        fragment_bytes=8 * 1024 * 1024,
        gc=GCConfig(gc_interval=3600.0),
    )
    return InfiniStore(cfg, clock=Clock(), cos_root=tmpdir)


def train(cfg: ModelConfig, shape: ShapeConfig, *, steps: int,
          seed: int = 0, num_microbatches: int = 1,
          checkpointer: Optional[Checkpointer] = None,
          checkpoint_every: int = 0, resume: bool = False,
          opt_cfg: Optional[adamw.AdamWConfig] = None,
          mesh=None) -> TrainResult:
    t0 = time.monotonic()
    model = build_model(cfg)
    if mesh is not None:
        set_global_rules(make_rules(cfg, mesh))
    opt_cfg = opt_cfg or adamw.AdamWConfig(lr=1e-3, warmup_steps=10)
    step_fn = jax.jit(make_train_step(model, opt_cfg),
                      donate_argnums=(0, 1))
    params = model.init_params(jax.random.PRNGKey(seed))
    opt_state = adamw.adamw_init(params)
    start = 0
    restored_from = None
    if resume and checkpointer is not None:
        latest = checkpointer.latest_step()
        if latest is not None:
            state = checkpointer.restore(latest,
                                         like={"params": params,
                                               "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            params = jax.tree.map(jnp.asarray, params)
            opt_state = jax.tree.map(jnp.asarray, opt_state)
            start = latest
            restored_from = latest
    pipe = TokenPipeline(cfg, shape, num_microbatches=num_microbatches,
                         seed=seed, start_step=start)
    losses = []
    for step in range(start, steps):
        batch = jax.tree.map(jnp.asarray, next(pipe))
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if checkpointer is not None and checkpoint_every \
                and (step + 1) % checkpoint_every == 0:
            checkpointer.save(step + 1,
                              {"params": params, "opt": opt_state})
    return TrainResult(steps=steps, final_loss=losses[-1] if losses else 0.0,
                       losses=losses, wall_s=time.monotonic() - t0,
                       restored_from=restored_from)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--checkpoint-every", type=int, default=0)
    args = ap.parse_args()
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    shape = ShapeConfig("cli", seq_len=args.seq_len,
                        global_batch=args.batch, kind="train")
    ckpt = None
    if args.checkpoint_every:
        ckpt = Checkpointer(make_store_for_checkpoints())
    res = train(cfg, shape, steps=args.steps, checkpointer=ckpt,
                checkpoint_every=args.checkpoint_every)
    print(f"trained {res.steps} steps in {res.wall_s:.1f}s; "
          f"loss {res.losses[0]:.3f} -> {res.final_loss:.3f}")


if __name__ == "__main__":
    main()
