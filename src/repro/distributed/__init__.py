from repro.distributed.sharding import (  # noqa: F401
    make_rules, sharding_for, tree_shardings)
