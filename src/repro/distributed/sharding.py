"""Logical-axis sharding rules (t5x-style), specialized per architecture.

Model code annotates every param/cache leaf with logical axis names
("embed", "heads", "vocab", ...). `make_rules(cfg, mesh)` maps those to
mesh axes:

  * embed        -> data   (FSDP/ZeRO: params, grads, optimizer state all
                            sharded over the data axis; GSPMD inserts the
                            per-layer all-gather / reduce-scatter)
  * vocab/ff/heads/lru -> model  (tensor parallel)
  * kv_heads     -> model only when num_kv_heads % tp == 0, else the kv
                    heads are replicated and head_dim is sharded instead
                    (DESIGN.md §4: GQA with K < TP)
  * experts      -> model for "expert" sharding (EP), expert_ff for "ffn"
  * batch        -> (pod, data) on the multi-pod mesh

Uneven head counts (e.g. 40 q heads over tp=16) are allowed: GSPMD pads.
The padding waste is measured, not hidden — see EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

Axis = Union[None, str, Tuple[str, ...]]


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def tp_size(mesh: Mesh) -> int:
    return mesh.shape.get("model", 1)


def make_rules(cfg: ModelConfig, mesh: Mesh, *,
               flash_decode: bool = False) -> Dict[str, Axis]:
    """flash_decode: for GQA archs with K < TP, shard the KV cache over
    the SEQUENCE/pages dim instead of head_dim (flash-decoding style) —
    attention scores are computed per S-shard and merged with tiny
    all-reduces instead of all-gathering the cache every layer."""
    tp = tp_size(mesh)
    kv_even = cfg.num_kv_heads % tp == 0
    rules: Dict[str, Axis] = {
        "batch": dp_axes(mesh),
        "vocab": "model",
        "embed": "data" if "data" in mesh.axis_names else None,
        "ff": "model",
        "heads": "model",
        "heads_d": "model",          # rwkv fused (H*hs) output dim
        "kv_heads": "model" if kv_even else None,
        "head_dim": (None if kv_even or flash_decode else "model"),
        "kv_seq": ("model" if flash_decode and not kv_even else None),
        "lru": "model",
        "lru_blocks": None,          # block-diag gate blocks stay replicated
        "layers": None,
        "experts": None,
        "expert_ff": None,
    }
    if cfg.moe is not None:
        if cfg.moe.expert_sharding == "expert":
            rules["experts"] = "model"
        else:
            rules["expert_ff"] = "model"
    return rules


def spec_for(axes: Tuple, rules: Dict[str, Axis],
             shape: Optional[Tuple[int, ...]] = None,
             mesh: Optional[Mesh] = None) -> P:
    """Logical axes -> PartitionSpec. If `shape` (+mesh) is given, mesh
    axes that do not evenly divide the dim are dropped (replicated): jit
    ARGUMENT shardings must divide evenly; intermediates may stay uneven
    (GSPMD pads — the waste shows up in the roofline, by design)."""
    parts = []
    for i, ax in enumerate(axes):
        r = None if ax is None else rules.get(ax, None)
        if r is not None and shape is not None and mesh is not None:
            names = (r,) if isinstance(r, str) else tuple(r)
            total = 1
            for nm in names:
                total *= mesh.shape.get(nm, 1)
            if total == 0 or shape[i] % total != 0:
                r = None
        parts.append(r)
    return P(*parts)


def sharding_for(axes: Tuple, mesh: Mesh, rules: Dict[str, Axis],
                 shape: Optional[Tuple[int, ...]] = None) -> NamedSharding:
    return NamedSharding(mesh, spec_for(axes, rules, shape, mesh))


def _axes_leaf(x) -> bool:
    return isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)


def tree_shardings(axes_tree: Any, mesh: Mesh, rules: Dict[str, Axis],
                   shapes_tree: Any = None):
    """Map a pytree of logical-axis tuples to NamedShardings. When
    `shapes_tree` (matching pytree of ShapeDtypeStructs/arrays) is given,
    non-dividing mesh axes are dropped per-leaf."""
    if shapes_tree is None:
        return jax.tree.map(lambda axes: sharding_for(axes, mesh, rules),
                            axes_tree, is_leaf=_axes_leaf)
    flat_axes, treedef = jax.tree.flatten(axes_tree, is_leaf=_axes_leaf)
    flat_shapes = jax.tree.flatten(shapes_tree)[0]
    if len(flat_axes) != len(flat_shapes):
        raise ValueError(
            f"axes tree ({len(flat_axes)} leaves) does not match shapes "
            f"tree ({len(flat_shapes)} leaves)")
    out = [sharding_for(a, mesh, rules, tuple(s.shape))
           for a, s in zip(flat_axes, flat_shapes)]
    return jax.tree.unflatten(treedef, out)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


# --------------------------------------------------------------------------
# Activation sharding constraints
# --------------------------------------------------------------------------
# GSPMD alone resolves the embedding-gather conflict (batch over data vs
# d_model over data) by REPLICATING the batch — measured 117 GiB/device on
# qwen1.5-0.5b train_4k. Model code therefore pins activation shardings via
# `constrain(x, logical_axes)`; the rules are installed process-globally by
# build_cell()/the launchers before tracing, and `constrain` is a no-op when
# no rules are installed (eager unit tests, single-device smoke runs).

_RULES: Optional[Dict[str, Axis]] = None


def set_global_rules(rules: Optional[Dict[str, Axis]]) -> None:
    global _RULES
    _RULES = rules


def get_global_rules() -> Optional[Dict[str, Axis]]:
    return _RULES


def constrain(x, axes: Tuple):
    if _RULES is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec_for(axes, _RULES))
