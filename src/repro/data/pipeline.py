"""Deterministic synthetic token pipeline, family-aware.

Produces batches shaped exactly like launch/specs.py's train specs
((num_microbatches, B, S) leading dims) so the examples drive the same
train_step the dry-run lowers. Deterministic in (seed, step) — restart at
step k reproduces the same stream, which the checkpoint/restart example
asserts.
"""
from __future__ import annotations

from typing import Any, Dict, Iterator

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


def make_batch(cfg: ModelConfig, shape: ShapeConfig, *, step: int,
               num_microbatches: int = 1, seed: int = 0
               ) -> Dict[str, np.ndarray]:
    """One global training batch for `step` (numpy; caller device_puts)."""
    n = num_microbatches
    B = shape.global_batch // n
    S = shape.seq_len
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    if cfg.frontend.kind == "audio":
        C = cfg.frontend.num_codebooks
        return {
            "frame_embeds": rng.standard_normal(
                (n, B, S, cfg.d_model)).astype(np.float32) * 0.02,
            "labels": rng.integers(0, cfg.vocab_size, (n, B, S, C),
                                   dtype=np.int32),
        }
    if cfg.frontend.kind == "vlm":
        Pn = cfg.frontend.num_prefix_embeds
        St = S - Pn
        return {
            "tokens": rng.integers(0, cfg.vocab_size, (n, B, St),
                                   dtype=np.int32),
            "patch_embeds": rng.standard_normal(
                (n, B, Pn, cfg.frontend.patch_embed_dim)
            ).astype(np.float32) * 0.02,
            "labels": rng.integers(0, cfg.vocab_size, (n, B, St),
                                   dtype=np.int32),
        }
    toks = rng.integers(0, cfg.vocab_size, (n, B, S + 1), dtype=np.int32)
    return {"tokens": toks[..., :-1], "labels": toks[..., 1:]}


class TokenPipeline:
    """Iterator over training batches; stateless given (seed, step)."""

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, *,
                 num_microbatches: int = 1, seed: int = 0,
                 start_step: int = 0):
        self.cfg = cfg
        self.shape = shape
        self.n = num_microbatches
        self.seed = seed
        self.step = start_step

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        b = make_batch(self.cfg, self.shape, step=self.step,
                       num_microbatches=self.n, seed=self.seed)
        self.step += 1
        return b
