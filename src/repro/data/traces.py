"""Synthetic workload traces matching the paper's §2 characterization.

Two generators, scaled-down but statistically faithful:

* `ibm_registry_trace` — IBM container-registry-like: log-normal object
  sizes with a heavy tail (~31% of objects > `large_threshold`), strong
  temporal reuse (~80% of re-accesses within `reuse_p80`), shifting
  working set (epoch-wise key-population drift, WSS max/min > 100x), and
  bursty arrivals (CoV > 1 via Pareto inter-arrival times).
* `azure_blob_trace` — Azure-Functions-blob-like: shorter reuse
  intervals (~98% within one interval), heavier burstiness, ~45% large
  objects.

Each event is (time, op, key, size); benchmarks replay them against
InfiniStore and the baselines (Table 2, Figs. 9-11, 15).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

import numpy as np


@dataclass(frozen=True)
class TraceEvent:
    t: float
    op: str           # "get" | "put"
    key: str
    size: int


def _sizes(rng, n, *, large_frac: float, large_threshold: int,
           small_mu: float, small_sigma: float) -> np.ndarray:
    """Log-normal body + heavy tail so `large_frac` of objects exceed
    `large_threshold`."""
    small = rng.lognormal(small_mu, small_sigma, n)
    large = large_threshold * (1.0 + rng.pareto(1.5, n))
    is_large = rng.random(n) < large_frac
    return np.where(is_large, large, np.minimum(small, large_threshold - 1)
                    ).astype(np.int64)


def _bursty_gaps(rng, n, mean_gap: float, cov: float) -> np.ndarray:
    """Pareto-mixture inter-arrival times with coefficient of variation
    > 1 (paper Fig. 1d: ~80% of reused objects have CoV > 1)."""
    shape = 1.0 + 1.0 / max(cov, 1.01)
    gaps = rng.pareto(shape, n) * mean_gap * (shape - 1)
    return gaps


def _trace(rng, *, num_objects: int, num_requests: int, duration: float,
           large_frac: float, large_threshold: int, reuse_interval: float,
           reuse_frac: float, wss_epochs: int, put_frac: float,
           cov: float) -> List[TraceEvent]:
    sizes = _sizes(rng, num_objects, large_frac=large_frac,
                   large_threshold=large_threshold, small_mu=11.0,
                   small_sigma=1.6)
    keys = [f"obj{i:06d}" for i in range(num_objects)]
    gaps = _bursty_gaps(rng, num_requests, duration / num_requests, cov)
    times = np.cumsum(gaps)
    times = times / times[-1] * duration
    # epoch-wise working-set drift: each epoch draws from a sliding window
    # of the key population (drives the WSS shifts of Fig. 1a)
    events: List[TraceEvent] = []
    last_access: dict = {}
    epoch_len = duration / wss_epochs
    for t in times:
        epoch = min(int(t / epoch_len), wss_epochs - 1)
        # working set of this epoch: a window over the population whose
        # width itself varies (max/min WSS ratio >> 1)
        width = max(4, int(num_objects / wss_epochs
                           * (0.1 + 2.0 * abs(np.sin(epoch)))))
        base = int(epoch * num_objects / (wss_epochs + 1))
        if rng.random() < reuse_frac and last_access:
            # temporal reuse: revisit something touched recently
            recent = [k for k, lt in last_access.items()
                      if t - lt <= reuse_interval]
            key = (recent[int(rng.random() * len(recent))]
                   if recent else keys[base + int(rng.random() * width)])
        else:
            key = keys[min(base + int(rng.random() * width),
                           num_objects - 1)]
        op = "put" if (key not in last_access
                       or rng.random() < put_frac) else "get"
        idx = int(key[3:])
        events.append(TraceEvent(float(t), op, key, int(sizes[idx])))
        last_access[key] = t
    return events


def ibm_registry_trace(*, num_objects: int = 400, num_requests: int = 4000,
                       duration: float = 3600.0, scale_bytes: float = 1.0,
                       seed: int = 0) -> List[TraceEvent]:
    rng = np.random.default_rng(seed)
    ev = _trace(rng, num_objects=num_objects, num_requests=num_requests,
                duration=duration, large_frac=0.31,
                large_threshold=int(10 * 1024 * 1024 * scale_bytes),
                reuse_interval=600.0, reuse_frac=0.8, wss_epochs=12,
                put_frac=0.05, cov=4.0)
    return ev


def azure_blob_trace(*, num_objects: int = 300, num_requests: int = 5000,
                     duration: float = 1800.0, scale_bytes: float = 1.0,
                     seed: int = 1) -> List[TraceEvent]:
    rng = np.random.default_rng(seed)
    ev = _trace(rng, num_objects=num_objects, num_requests=num_requests,
                duration=duration, large_frac=0.45,
                large_threshold=int(10 * 1024 * 1024 * scale_bytes),
                reuse_interval=60.0, reuse_frac=0.98, wss_epochs=20,
                put_frac=0.30, cov=3.0)
    return ev


def trace_stats(events: List[TraceEvent]) -> dict:
    """Reuse-interval and IAT-CoV statistics (validates Fig. 1 shape)."""
    last: dict = {}
    reuse: List[float] = []
    arrivals: dict = {}
    for e in events:
        if e.key in last:
            reuse.append(e.t - last[e.key])
        last[e.key] = e.t
        arrivals.setdefault(e.key, []).append(e.t)
    covs = []
    for ts in arrivals.values():
        if len(ts) >= 10:
            gaps = np.diff(ts)
            m = gaps.mean()
            if m > 0:
                covs.append(gaps.std() / m)
    sizes = np.array([e.size for e in events])
    return {
        "num_events": len(events),
        "reuse_p50": float(np.percentile(reuse, 50)) if reuse else 0.0,
        "reuse_p80": float(np.percentile(reuse, 80)) if reuse else 0.0,
        "cov_median": float(np.median(covs)) if covs else 0.0,
        "frac_cov_gt1": float(np.mean([c > 1 for c in covs])) if covs else 0.0,
        "frac_large": float(np.mean(sizes > 10 * 1024 * 1024)),
    }
