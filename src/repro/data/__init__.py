from repro.data.pipeline import TokenPipeline, make_batch  # noqa: F401
from repro.data.traces import (azure_blob_trace, ibm_registry_trace,  # noqa: F401
                               TraceEvent)
