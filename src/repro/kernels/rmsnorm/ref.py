"""Pure-jnp oracle for the fused RMSNorm kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm_ref(x: jax.Array, scale: jax.Array,
                 eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(dt)
