"""Public op: fused RMSNorm with backend dispatch."""
from __future__ import annotations

import jax

from repro.kernels.rmsnorm.kernel import rms_norm_pallas
from repro.kernels.rmsnorm.ref import rms_norm_ref


def rms_norm_op(x, scale, eps: float = 1e-6, *, backend: str = "auto"):
    on_tpu = jax.default_backend() == "tpu"
    if backend == "pallas" or (backend == "auto" and on_tpu):
        return rms_norm_pallas(x, scale, eps, interpret=not on_tpu)
    if backend == "interpret":
        return rms_norm_pallas(x, scale, eps, interpret=True)
    return rms_norm_ref(x, scale, eps)
