from repro.kernels.rmsnorm.ops import rms_norm_op  # noqa: F401
from repro.kernels.rmsnorm.ref import rms_norm_ref  # noqa: F401
