"""Pallas TPU kernel: fused RMSNorm over (rows, d) tiles.

One (block_rows, d) stripe per grid step stays resident in VMEM; the
reduction, rsqrt, and scale apply in one pass (XLA emits separate
reduce + broadcast-multiply HBM round trips at d >= 8k model widths).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 256


def _kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * s_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def rms_norm_pallas(x: jax.Array, scale: jax.Array, eps: float = 1e-6, *,
                    interpret: bool = True) -> jax.Array:
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    br = min(BLOCK_ROWS, rows)
    pad = (-rows) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=((rows + pad) // br,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows + pad, d), x.dtype),
        interpret=interpret,
    )(x2, scale)
    return out[:rows].reshape(orig_shape)
