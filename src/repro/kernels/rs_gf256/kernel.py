"""Pallas TPU kernel: GF(256) matrix multiply for Reed-Solomon coding.

Computes OUT = G ∘ X over GF(2^8): OUT[i, :] = XOR_j gfmul(G[i,j], X[j, :]).
Used for both EC encode (G = Cauchy parity rows) and decode (G = inverted
reconstruction matrix).

TPU adaptation (DESIGN.md §8): GPU RS codecs use shared-memory log/exp
tables; TPU VMEM has no efficient gather, so the per-coefficient multiply
is a branch-free 8-step xtime ladder over int32 lanes — pure VPU ops
(shift/and/xor/select), one (k, TILE) stripe per grid step resident in
VMEM. Validated in interpret mode on CPU; compiled path targets TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 1024          # lane-aligned (8 sublanes x 128 lanes) byte tile


def _gf_mul_const(vec: jax.Array, coeff: jax.Array) -> jax.Array:
    """vec: int32 array of bytes; coeff: int32 scalar byte. GF(256) product
    via the xtime ladder (poly 0x11D), branch-free."""
    res = jnp.zeros_like(vec)
    a = vec
    for bit in range(8):
        take = (coeff >> bit) & 1
        res = jnp.where(take == 1, res ^ a, res)
        hi = (a >> 7) & 1
        a = ((a << 1) & 0xFF) ^ jnp.where(hi == 1, 0x1D, 0)
    return res


def _rs_kernel(g_ref, x_ref, o_ref, *, m: int, k: int):
    x = x_ref[...].astype(jnp.int32)             # (k, TILE)
    for i in range(m):
        acc = jnp.zeros((x.shape[1],), jnp.int32)
        for j in range(k):
            coeff = g_ref[i, j].astype(jnp.int32)
            acc = acc ^ _gf_mul_const(x[j], coeff)
        o_ref[i, :] = acc.astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _call(G: jax.Array, X: jax.Array, *, interpret: bool = True):
    m, k = G.shape
    k2, L = X.shape
    assert k == k2 and L % TILE == 0
    grid = (L // TILE,)
    return pl.pallas_call(
        functools.partial(_rs_kernel, m=m, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, k), lambda i: (0, 0)),       # coefficients
            pl.BlockSpec((k, TILE), lambda i: (0, i)),    # data stripe
        ],
        out_specs=pl.BlockSpec((m, TILE), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, L), jnp.uint8),
        interpret=interpret,
    )(G, X)


def gf256_matmul_pallas(G, X, *, interpret: bool = True):
    """G: (m,k) uint8 coefficients; X: (k, L) uint8 data. Pads L to TILE."""
    G = jnp.asarray(G, jnp.uint8)
    X = jnp.asarray(X, jnp.uint8)
    L = X.shape[1]
    pad = (-L) % TILE
    if pad:
        X = jnp.pad(X, ((0, 0), (0, pad)))
    out = _call(G, X, interpret=interpret)
    return out[:, :L]
