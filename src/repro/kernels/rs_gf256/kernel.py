"""Pallas TPU kernels: GF(256) matrix multiply for Reed-Solomon coding.

Computes OUT = G ∘ X over GF(2^8): OUT[i, :] = XOR_j gfmul(G[i,j], X[j, :]).
Used for both EC encode (G = Cauchy parity rows) and decode (G = inverted
reconstruction matrix).

DESIGN (bit-sliced kernel, the production path)
-----------------------------------------------
GPU RS codecs use shared-memory log/exp tables; TPU VMEM has no efficient
gather, so the multiply must decompose into vector ALU ops. Multiplication
by a *constant* c is GF(2)-linear in the bits of x, i.e. an 8x8 bit matrix
(the companion-matrix representation of c). We exploit that in three ways:

1. **Host-side bit-plane expansion** — each coefficient G[i,j] expands to
   8 bytes ``plane[b] = gfmul(G[i,j], 2^b)`` (`gf_coeff_planes` in ref.py):
   the image of input bit b. The inner loop is then pure mask/XOR
   accumulation:  ``out ^= spread(bit_b(x)) & plane[b]``  with NO per-bit
   selects and no data-dependent control flow — unlike the xtime ladder,
   which needs a `where` per coefficient bit *and* a carry-fixup `where`
   per shift.
2. **4 bytes per int32 lane** — X is bitcast to uint32 so every VPU lane
   carries 4 payload bytes. ``bits = (x >> b) & 0x01010101`` grabs bit b
   of all four bytes at once and ``(bits << 8) - bits`` spreads each 0/1
   byte to 0x00/0xFF (byte-local borrow, no cross-byte carries), giving
   4x the per-op throughput of the byte-per-lane ladder.
3. **2-D grid (stripe, output row)** — the ladder kernel unrolled a
   Python loop over output rows inside one grid step; here rows are a
   grid dimension, so large (m, L) problems tile instead of unrolling,
   and the X stripe stays resident in VMEM across the row sweep (stripe
   is the slow-moving grid axis).

The legacy per-coefficient xtime-ladder kernel is kept as
`gf256_matmul_pallas_ladder` for A/B benchmarking (benchmarks/kernels.py).
Both are validated bit-identical to the numpy/jnp oracles in interpret
mode on CPU; the compiled path targets TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.rs_gf256.ref import gf_coeff_planes

TILE = 1024          # ladder kernel: byte tile (8 sublanes x 128 lanes)
TILE_W = 1024        # bit-sliced kernel: uint32 words per stripe (4 KB)

_LOW_BITS = 0x01010101   # bit 0 of each packed byte


# ---------------------------------------------------------------------------
# bit-sliced kernel (production path)
# ---------------------------------------------------------------------------

def _rs_bitsliced_kernel(g_ref, x_ref, o_ref, *, k: int):
    """One output-row stripe: g_ref (1, k, 8) uint32 coefficient planes
    (each plane byte replicated into all 4 byte lanes), x_ref (k, TILE_W)
    uint32 packed data, o_ref (1, TILE_W) uint32."""
    x = x_ref[...]
    acc = jnp.zeros((x.shape[1],), jnp.uint32)
    low = jnp.uint32(_LOW_BITS)
    for j in range(k):
        xj = x[j]
        for b in range(8):
            bits = (xj >> b) & low
            mask = (bits << 8) - bits          # 0x00/0xFF per payload byte
            acc = acc ^ (mask & g_ref[0, j, b])
    o_ref[0, :] = acc


@functools.partial(jax.jit, static_argnames=("interpret",))
def _call_bitsliced(GW: jax.Array, Xp: jax.Array, *, interpret: bool = True):
    m = GW.shape[0]
    k, W = Xp.shape
    assert W % TILE_W == 0
    grid = (W // TILE_W, m)                   # stripe slow, row fast
    return pl.pallas_call(
        functools.partial(_rs_bitsliced_kernel, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, k, 8), lambda w, i: (i, 0, 0)),   # planes
            pl.BlockSpec((k, TILE_W), lambda w, i: (0, w)),        # stripe
        ],
        out_specs=pl.BlockSpec((1, TILE_W), lambda w, i: (i, w)),
        out_shape=jax.ShapeDtypeStruct((m, W), jnp.uint32),
        interpret=interpret,
    )(GW, Xp)


def gf256_matmul_bitsliced(G, X, *, interpret: bool = True):
    """Bit-sliced GF(256) matmul. G: (m,k) uint8, X: (k,L) uint8.

    Expands G host-side into companion-matrix bit-planes, packs X 4 bytes
    per uint32 lane (padding L to 4*TILE_W), and XOR-accumulates on the
    VPU. Bit-identical to `gf_matmul_np` / `gf256_matmul_ref`."""
    Gh = np.asarray(G, np.uint8)
    m, k = Gh.shape
    planes = gf_coeff_planes(Gh).astype(np.uint32)          # (m, k, 8)
    GW = jnp.asarray(planes * np.uint32(_LOW_BITS))         # byte-replicated
    X = jnp.asarray(X, jnp.uint8)
    L = X.shape[1]
    pad = (-L) % (4 * TILE_W)
    if pad:
        X = jnp.pad(X, ((0, 0), (0, pad)))
    Xp = jax.lax.bitcast_convert_type(X.reshape(k, -1, 4), jnp.uint32)
    out = _call_bitsliced(GW, Xp, interpret=interpret)      # (m, W) uint32
    out8 = jax.lax.bitcast_convert_type(out, jnp.uint8).reshape(m, -1)
    return out8[:, :L]


# ---------------------------------------------------------------------------
# legacy xtime-ladder kernel (kept for A/B benchmarks)
# ---------------------------------------------------------------------------

def _gf_mul_const(vec: jax.Array, coeff: jax.Array) -> jax.Array:
    """vec: int32 array of bytes; coeff: int32 scalar byte. GF(256) product
    via the xtime ladder (poly 0x11D), branch-free."""
    res = jnp.zeros_like(vec)
    a = vec
    for bit in range(8):
        take = (coeff >> bit) & 1
        res = jnp.where(take == 1, res ^ a, res)
        hi = (a >> 7) & 1
        a = ((a << 1) & 0xFF) ^ jnp.where(hi == 1, 0x1D, 0)
    return res


def _rs_ladder_kernel(g_ref, x_ref, o_ref, *, m: int, k: int):
    x = x_ref[...].astype(jnp.int32)             # (k, TILE)
    for i in range(m):
        acc = jnp.zeros((x.shape[1],), jnp.int32)
        for j in range(k):
            coeff = g_ref[i, j].astype(jnp.int32)
            acc = acc ^ _gf_mul_const(x[j], coeff)
        o_ref[i, :] = acc.astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _call_ladder(G: jax.Array, X: jax.Array, *, interpret: bool = True):
    m, k = G.shape
    k2, L = X.shape
    assert k == k2 and L % TILE == 0
    grid = (L // TILE,)
    return pl.pallas_call(
        functools.partial(_rs_ladder_kernel, m=m, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, k), lambda i: (0, 0)),       # coefficients
            pl.BlockSpec((k, TILE), lambda i: (0, i)),    # data stripe
        ],
        out_specs=pl.BlockSpec((m, TILE), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, L), jnp.uint8),
        interpret=interpret,
    )(G, X)


def gf256_matmul_pallas_ladder(G, X, *, interpret: bool = True):
    """Legacy ladder kernel. G: (m,k) uint8; X: (k, L) uint8. Pads L."""
    G = jnp.asarray(G, jnp.uint8)
    X = jnp.asarray(X, jnp.uint8)
    L = X.shape[1]
    pad = (-L) % TILE
    if pad:
        X = jnp.pad(X, ((0, 0), (0, pad)))
    out = _call_ladder(G, X, interpret=interpret)
    return out[:, :L]


def gf256_matmul_pallas(G, X, *, interpret: bool = True):
    """G: (m,k) uint8 coefficients; X: (k, L) uint8 data. Bit-sliced
    production kernel (see module docstring)."""
    return gf256_matmul_bitsliced(G, X, interpret=interpret)
