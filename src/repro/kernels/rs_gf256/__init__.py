from repro.kernels.rs_gf256.ops import gf256_matmul  # noqa: F401
from repro.kernels.rs_gf256.ref import (  # noqa: F401
    EXP_TABLE, LOG_TABLE, gf256_matmul_ref, gf_inv_matrix_np,
    gf_matmul_np, gf_mul_np)
