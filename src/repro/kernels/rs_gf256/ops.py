"""Public op: gf256_matmul with backend dispatch.

On TPU the bit-sliced Pallas kernel runs compiled; everywhere else it
runs in interpret mode (exercised by tests) or falls back to the jnp
oracle. The legacy xtime-ladder kernel stays reachable as
backend="ladder" for A/B benchmarking.
"""
from __future__ import annotations

import jax

from repro.kernels.rs_gf256.kernel import (gf256_matmul_bitsliced,
                                           gf256_matmul_pallas_ladder)
from repro.kernels.rs_gf256.ref import gf256_matmul_ref


def gf256_matmul(G, X, *, backend: str = "auto"):
    """OUT = G @ X over GF(256). G: (m,k) uint8, X: (k,L) uint8.

    backend: "pallas" (bit-sliced; compiled on TPU, interpret elsewhere),
             "interpret" (bit-sliced, forced interpret mode),
             "ladder" (legacy xtime-ladder kernel, interpret off-TPU),
             "ref" (jnp oracle), "auto" (pallas on TPU else ref).
    """
    on_tpu = jax.default_backend() == "tpu"
    if backend == "pallas" or (backend == "auto" and on_tpu):
        return gf256_matmul_bitsliced(G, X, interpret=not on_tpu)
    if backend == "interpret":
        return gf256_matmul_bitsliced(G, X, interpret=True)
    if backend == "ladder":
        return gf256_matmul_pallas_ladder(G, X, interpret=not on_tpu)
    return gf256_matmul_ref(G, X)
