"""GF(256) arithmetic + pure-jnp oracle for the RS erasure-coding kernel.

Field: GF(2^8) with the AES/RS polynomial x^8+x^4+x^3+x^2+1 (0x11D),
generator 2. Host-side codec math (encode matrices, Gauss-Jordan
inversion) uses numpy tables; `gf256_matmul_ref` is the jnp oracle the
Pallas kernel is validated against.
"""
from __future__ import annotations

import numpy as np

try:
    import jax.numpy as jnp
except Exception:                                    # pragma: no cover
    jnp = None

POLY = 0x11D


def _build_tables():
    exp = np.zeros(512, np.int32)
    log = np.zeros(256, np.int32)
    x = 1
    for i in range(255):
        exp[i] = x
        log[x] = i
        x <<= 1
        if x & 0x100:
            x ^= POLY
    exp[255:510] = exp[:255]
    return exp, log


EXP_TABLE, LOG_TABLE = _build_tables()


def gf_mul_np(a, b):
    """Element-wise GF(256) multiply (numpy, table-based)."""
    a = np.asarray(a, np.int32)
    b = np.asarray(b, np.int32)
    out = EXP_TABLE[(LOG_TABLE[a] + LOG_TABLE[b]) % 255]
    return np.where((a == 0) | (b == 0), 0, out).astype(np.uint8)


def gf_inv_np(a):
    a = np.asarray(a, np.int32)
    if np.any(a == 0):
        raise ZeroDivisionError("GF(256) inverse of 0")
    return EXP_TABLE[255 - LOG_TABLE[a]].astype(np.uint8)


def gf_matmul_np(A: np.ndarray, X: np.ndarray) -> np.ndarray:
    """(m,k) @ (k,L) over GF(256): XOR-accumulated products."""
    A = np.asarray(A, np.uint8)
    X = np.asarray(X, np.uint8)
    m, k = A.shape
    out = np.zeros((m, X.shape[1]), np.uint8)
    for j in range(k):
        out ^= gf_mul_np(A[:, j:j + 1], X[j:j + 1, :])
    return out


def _build_mul_table() -> np.ndarray:
    """Full 256x256 GF(256) product table (64 KB): MUL[a, b] = a*b."""
    a = np.arange(256, dtype=np.uint8)
    return gf_mul_np(a[:, None], a[None, :])


GF_MUL_TABLE = _build_mul_table()


def gf_matmul_table(A: np.ndarray, X: np.ndarray) -> np.ndarray:
    """Fast-path (m,k) @ (k,L) over GF(256): one gather + one XOR per
    coefficient via the full product table, instead of the exp/log path's
    two gathers + add + mod + exp gather + zero masking. The codec's hot
    host matmul; `gf_matmul_np` stays as the independent oracle."""
    A = np.asarray(A, np.uint8)
    X = np.asarray(X, np.uint8)
    m, k = A.shape
    out = np.zeros((m, X.shape[1]), np.uint8)
    for i in range(m):
        row = out[i]
        for j in range(k):
            c = A[i, j]
            if c:
                row ^= GF_MUL_TABLE[c, X[j]]
    return out


def gf_coeff_planes(A: np.ndarray) -> np.ndarray:
    """(m,k) uint8 -> (m,k,8) uint8 companion-matrix bit-planes.

    plane[..., b] = A * 2^b over GF(256) — the image of input bit b under
    multiplication by each coefficient (column b of the coefficient's 8x8
    GF(2) companion matrix, packed as a byte). With these, a GF(256)
    constant multiply is 8 mask-and-XOR steps with no per-bit selects:
    out = XOR_b spread(bit_b(x)) & plane[b]."""
    planes = [np.asarray(A, np.uint8)]
    for _ in range(7):
        planes.append(gf_mul_np(planes[-1], np.uint8(2)))
    return np.stack(planes, axis=-1)


def gf_inv_matrix_np(M: np.ndarray) -> np.ndarray:
    """Gauss-Jordan inversion over GF(256)."""
    M = np.asarray(M, np.uint8)
    n = M.shape[0]
    aug = np.concatenate([M, np.eye(n, dtype=np.uint8)], axis=1)
    for col in range(n):
        piv = next((r for r in range(col, n) if aug[r, col] != 0), None)
        if piv is None:
            raise ValueError("singular GF(256) matrix")
        if piv != col:
            aug[[col, piv]] = aug[[piv, col]]
        aug[col] = gf_mul_np(aug[col], gf_inv_np(aug[col, col]))
        for r in range(n):
            if r != col and aug[r, col]:
                aug[r] ^= gf_mul_np(aug[r, col], aug[col])
    return aug[:, n:]


def cauchy_parity_matrix(k: int, p: int) -> np.ndarray:
    """Parity rows of a systematic RS code: Cauchy matrix
    C[i,j] = 1/(x_i ^ y_j) with x_i = k+i, y_j = j — every square
    submatrix of [I; C] is invertible, so any k of the k+p chunks
    reconstruct the data."""
    if k + p > 256:
        raise ValueError("k+p must be <= 256 for GF(256)")
    x = np.arange(k, k + p, dtype=np.int32)
    y = np.arange(k, dtype=np.int32)
    return gf_inv_np(x[:, None] ^ y[None, :])


# ---- jnp oracle ------------------------------------------------------------

def gf256_matmul_ref(G, X):
    """jnp oracle for the Pallas kernel: (m,k) @ (k,L) over GF(256),
    table-based."""
    exp = jnp.asarray(EXP_TABLE)
    log = jnp.asarray(LOG_TABLE)
    G = jnp.asarray(G, jnp.int32)
    X = jnp.asarray(X, jnp.int32)
    lg = log[G]                                  # (m,k)
    lx = log[X]                                  # (k,L)
    prod = exp[(lg[:, :, None] + lx[None, :, :]) % 255]
    prod = jnp.where((G[:, :, None] == 0) | (X[None, :, :] == 0), 0, prod)
    # XOR-reduce over k
    def xor_reduce(c, row):
        return c ^ row, None
    import jax
    out, _ = jax.lax.scan(lambda c, r: (c ^ r, None),
                          jnp.zeros((G.shape[0], X.shape[1]), jnp.int32),
                          jnp.moveaxis(prod, 1, 0))
    return out.astype(jnp.uint8)
