"""Pallas TPU kernel: decode attention over an SMS-paged KV pool.

The XLA fallback (`ref.py`) must `take_along_axis` the entire pool into
logical order — a full extra cache copy per step (dominates the decode
memory roofline term; see EXPERIMENTS.md §Perf). This kernel instead
walks the block table with scalar-prefetched indices: page i's physical
slot is known before the grid step, so the pipeline DMAs exactly one
(ps, K, hd) page per step from HBM to VMEM and accumulates online
softmax in VMEM scratch. Cache reads become one pass, no copy.

TPU adaptation notes (DESIGN.md §2): this is the ServerlessMemory
"chunk" read path — pages are chunks, the block table is the daemon's
chunk->slab mapping, and PlaceChunk-compacted pages stay contiguous in
the pool so the DMA stream stays dense.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
            acc_ref, m_ref, l_ref, *, ps: int, num_pages: int):
    b = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                 # (K, G, hd)
    k = k_ref[0, 0].astype(jnp.float32)              # (ps, K, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    K, G, hd = q.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    s = jnp.einsum("kgd,pkd->kgp", q, k,
                   preferred_element_type=jnp.float32) * scale
    pos = i * ps + jax.lax.broadcasted_iota(jnp.int32, (1, 1, ps), 2)
    valid = pos < len_ref[b]
    s = jnp.where(valid, s, -1e30)

    m_prev = m_ref[...]                              # (K, G)
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
    acc_ref[...] = (acc_ref[...] * corr[..., None]
                    + jnp.einsum("kgp,pkd->kgd", p, v,
                                 preferred_element_type=jnp.float32))
    m_ref[...] = m_new

    @pl.when(i == num_pages - 1)
    def _finalize():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[..., None]
        o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention_pallas(q, k_pool, v_pool, block_table, lens, *,
                                  interpret: bool = True):
    """q: (B, H, hd); pools: (B, P, ps, K, hd); block_table: (B, P) int32;
    lens: (B,) int32. Returns (B, H, hd) in q.dtype."""
    B, H, hd = q.shape
    _, P, ps, K, hd2 = k_pool.shape
    assert hd == hd2 and H % K == 0
    G = H // K
    q5 = q.reshape(B, K, G, hd)

    grid = (B, P)
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, K, G, hd), lambda b, i, tbl, ln: (b, 0, 0, 0)),
            pl.BlockSpec((1, 1, ps, K, hd),
                         lambda b, i, tbl, ln: (b, tbl[b, i], 0, 0, 0)),
            pl.BlockSpec((1, 1, ps, K, hd),
                         lambda b, i, tbl, ln: (b, tbl[b, i], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, K, G, hd),
                               lambda b, i, tbl, ln: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((K, G, hd), jnp.float32),
            pltpu.VMEM((K, G), jnp.float32),
            pltpu.VMEM((K, G), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, ps=ps, num_pages=P),
        grid_spec=spec,
        out_shape=jax.ShapeDtypeStruct((B, K, G, hd), q.dtype),
        interpret=interpret,
    )(block_table, lens, q5, k_pool, v_pool)
    return out.reshape(B, H, hd)
