"""Public op: paged decode attention with backend dispatch."""
from __future__ import annotations

import jax

from repro.kernels.paged_attention.kernel import paged_decode_attention_pallas
from repro.kernels.paged_attention.ref import paged_decode_attention_ref


def paged_decode_attention(q, k_pool, v_pool, block_table, lens, *,
                           backend: str = "auto"):
    """Decode attention over an SMS-paged KV pool.

    backend: "pallas" (compiled on TPU / interpret on CPU),
             "interpret" (force interpret), "ref" (XLA gather fallback),
             "auto" (pallas on TPU else ref).
    """
    on_tpu = jax.default_backend() == "tpu"
    if backend == "pallas" or (backend == "auto" and on_tpu):
        return paged_decode_attention_pallas(q, k_pool, v_pool, block_table,
                                             lens, interpret=not on_tpu)
    if backend == "interpret":
        return paged_decode_attention_pallas(q, k_pool, v_pool, block_table,
                                             lens, interpret=True)
    return paged_decode_attention_ref(q, k_pool, v_pool, block_table,
                                      lens).astype(q.dtype)
