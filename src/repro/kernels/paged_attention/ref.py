"""Pure-jnp oracle for paged decode attention.

Gathers the paged pool into logical order (the XLA fallback path the
dry-run measures — it materializes a full cache copy) and runs masked
decode attention. The Pallas kernel must match this bit-for-bit at f32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def paged_decode_attention_ref(q, k_pool, v_pool, block_table, lens):
    """q: (B, H, hd); k_pool/v_pool: (B, P, ps, K, hd);
    block_table: (B, P) int32 logical->physical; lens: (B,) int32 number
    of valid tokens. Returns (B, H, hd) f32."""
    B, H, hd = q.shape
    _, P, ps, K, hd2 = k_pool.shape
    assert hd == hd2 and H % K == 0
    idx = block_table[:, :, None, None, None]
    k = jnp.take_along_axis(k_pool, idx, axis=1).reshape(B, P * ps, K, hd)
    v = jnp.take_along_axis(v_pool, idx, axis=1).reshape(B, P * ps, K, hd)
    G = H // K
    qk = q.reshape(B, K, G, hd).astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    s = jnp.einsum("bkgd,btkd->bkgt", qk, k.astype(jnp.float32)) * scale
    pos = jnp.arange(P * ps)
    mask = pos[None, :] < lens[:, None]                  # (B, T)
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p, v.astype(jnp.float32))
    return out.reshape(B, H, hd)
