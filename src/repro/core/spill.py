"""Crash-consistent writeback spill journal (paper §5.3.2).

PR 2's ack contract — "an instance failure between ack and persistence
loses nothing" — only covered *instance* failures: the WritebackQueue
pending map is process memory, so a client-daemon crash silently lost
every acked-but-unpersisted write. The paper's persistent buffer is a
durability structure, so the buffer itself must survive the daemon.
`SpillJournal` is that durable half: an append-only, checksummed,
segment-rotated local journal the writeback path appends to BEFORE a
PUT acknowledges, replayed on daemon restart to re-enqueue every
surviving write.

On-disk format (all little-endian), one record frame per append:

    magic  u32   0x53504C31 ("SPL1")
    rtype  u8    1 = APPEND (key + payload), 2 = PERSIST (logical
                 truncation: `seq` names the APPEND now persisted)
    seq    u64   monotonically increasing enqueue sequence
    klen   u32   key length in bytes
    plen   u64   payload length in bytes
    crc    u32   CRC-32 over (rtype..plen) + key + payload digest
    key    klen bytes
    payload plen bytes

The payload enters the CRC through a 128-bit vectorized digest (u64
word sum + word xor, plus the sub-word tail bytes verbatim) rather than
byte-by-byte: full-payload corruption coverage at memory bandwidth
instead of zlib's ~1 GB/s, which is what keeps journaling inside the
PUT ack-latency budget. The digest is alignment-independent, so writer
(ndarray) and replayer (bytes) always agree.

A torn tail record (partial frame, bad magic, or CRC mismatch — the
crash-mid-append case) is detected during replay and dropped along with
anything after it in that segment; earlier complete records survive.

Segments (`seg-<id>.wal`) rotate at `segment_bytes`. Records are
*logically* truncated by appending a PERSIST record as COS persists
them; a sealed segment whose records are all persisted is deleted, and
a sealed segment pinned by only a few live bytes (small surviving
records, e.g. metadata entries) is compacted — its live frames are
re-appended verbatim to the active segment and the file reclaimed. When
nothing at all is live the active segment is truncated in place, so a
drained journal occupies no disk.

Two write disciplines:

- `sync_each=True` (default): every append is built, written, and
  flushed on the caller's thread before returning — the simple durable
  mode.
- `sync_each=False` (group commit): appends stay in the writer buffer
  until the caller's `sync()` durability barrier — one flush per ack
  batch instead of one per record. This is the store's mode: it syncs
  once at the PUT ack point. With `async_writer=True` the frame
  builds, CRCs, and file I/O additionally run in FIFO order on an
  internal `spill-journal` thread and `sync()` drains it; that only
  pays off on runtimes where the journal thread is not GIL-convoyed
  behind the caller's pure-Python phases, so it is off by default.

Flushes reach the OS (durable across a process crash — the scenario the
persistent-buffer contract names); pass `fsync=True` for machine-crash
durability at ack-latency cost. Thread-safe; same-key appends supersede
(latest seq wins), mirroring the WritebackQueue pending-map semantics.
"""
from __future__ import annotations

import os
import struct
import threading
import zlib

try:
    import fcntl                         # POSIX advisory locks
except ImportError:                      # pragma: no cover - non-POSIX
    fcntl = None
from collections import deque
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.locks import make_rlock
from repro.core.payload import as_u8, payload_nbytes
from repro.obs import NOOP_CM

_MAGIC = 0x53504C31                      # "SPL1"
_MAGIC_S = struct.Struct("<I")
_META_S = struct.Struct("<BQIQ")         # rtype, seq, klen, plen
_CRC_S = struct.Struct("<I")
_HDR_LEN = _MAGIC_S.size + _META_S.size + _CRC_S.size   # 29 bytes
_APPEND, _PERSIST = 1, 2
_MAX_KLEN = 64 * 1024


@dataclass
class SpillStats:
    appends: int = 0
    persists: int = 0                 # logical truncations written
    appended_bytes: int = 0           # payload bytes journaled
    replayed_records: int = 0         # live records found at open
    replayed_bytes: int = 0
    torn_records: int = 0             # frames rejected by framing/CRC
    segments_created: int = 0
    segments_reclaimed: int = 0       # deleted (fully persisted)
    segments_compacted: int = 0       # rewritten into the active segment


@dataclass
class _Rec:
    key: str
    seg: int
    offset: int                       # frame start within segment file
    frame_len: int
    payload_len: int


_SIG_WEIGHTS: Dict[int, np.ndarray] = {}   # odd-weight cache by word count


def _sig_weights(nwords: int) -> np.ndarray:
    w = _SIG_WEIGHTS.get(nwords)
    if w is None:
        if len(_SIG_WEIGHTS) > 64:          # few distinct payload sizes
            _SIG_WEIGHTS.clear()
        # odd weights (2i+1) are units mod 2^64: a swap of unequal words
        # i!=j changes the weighted sum by (2i-2j)(w_j - w_i) != 0
        w = (np.arange(nwords, dtype=np.uint64) << np.uint64(1)) \
            + np.uint64(1)
        _SIG_WEIGHTS[nwords] = w
    return w


def _payload_sig(payload) -> bytes:
    """192-bit vectorized payload digest + raw tail: u64 word sum,
    position-weighted word sum (catches word reordering, which the
    plain sum/xor alone would miss), and word xor over the 8-aligned
    prefix, then the <8 trailing bytes verbatim. Runs at memory
    bandwidth and is independent of the buffer's alignment/type, so the
    write side (ndarray views) and the replay side (bytes slices)
    always produce identical signatures. Not cryptographic — it targets
    torn/garbled frames from crashes and bit rot, not an adversary."""
    n = payload_nbytes(payload)
    if n == 0:
        return b""
    u8 = payload if isinstance(payload, np.ndarray) \
        else np.frombuffer(payload, np.uint8)
    m = n & ~7
    h_sum = h_pos = h_xor = 0
    if m:
        try:
            u64 = u8[:m].view(np.uint64)
        except ValueError:                 # unaligned base: one memcpy
            u64 = np.ascontiguousarray(u8[:m]).view(np.uint64)
        h_sum = int(u64.sum(dtype=np.uint64))
        with np.errstate(over="ignore"):   # mod-2^64 wrap is the point
            h_pos = int(np.dot(u64, _sig_weights(u64.size)))
        h_xor = int(np.bitwise_xor.reduce(u64, dtype=np.uint64))
    return struct.pack("<QQQ", (h_sum + n) & 0xFFFFFFFFFFFFFFFF,
                       h_pos & 0xFFFFFFFFFFFFFFFF,
                       h_xor) + bytes(u8[m:])


def _frame_crc(meta: bytes, key: bytes, payload) -> int:
    crc = zlib.crc32(meta)
    crc = zlib.crc32(key, crc)
    return zlib.crc32(_payload_sig(payload), crc) & 0xFFFFFFFF


class SpillJournal:
    """Durable spill for the writeback pending map. `append` before ack
    (+ `sync()` in group-commit mode), `mark_persisted` as COS confirms,
    `take_pending` after a restart."""

    def __init__(self, path, *, segment_bytes: int = 64 * 1024 * 1024,
                 fsync: bool = False, compact_below: int = 256 * 1024,
                 sync_each: bool = True, async_writer: bool = False,
                 faults=None):
        # optional FaultPlan (repro.core.faults): "spill.append" /
        # "spill.sync" raise on the ack path, "spill.io" raises inside
        # the (possibly async) frame writer, "spill.torn_close" tears
        # the unsynced tail on a hard close.
        self.faults = faults
        # optional ObsPlane (repro.obs), attached by the owning store
        # after construction: "journal.append" / "journal.sync" spans
        # around the ack-path journal work
        self.obs = None
        self.dir = Path(path)
        self.dir.mkdir(parents=True, exist_ok=True)
        # inter-process exclusivity: two journals on the same directory
        # (a restart racing a not-yet-dead daemon) would both replay and
        # rewrite/unlink each other's segments. Fail fast instead. A
        # real crash releases the flock with the process, so restart
        # always succeeds; close() releases it explicitly.
        self._lockf = None
        if fcntl is not None:
            lockf = open(self.dir / ".lock", "wb")
            try:
                fcntl.flock(lockf.fileno(),
                            fcntl.LOCK_EX | fcntl.LOCK_NB)
            except OSError as e:
                lockf.close()
                raise RuntimeError(
                    f"spill journal directory {self.dir} is locked by "
                    "another live journal (concurrent daemon?)") from e
            self._lockf = lockf
        self.segment_bytes = segment_bytes
        self.fsync = fsync
        self.compact_below = compact_below
        self.sync_each = sync_each
        self.stats = SpillStats()
        self._lock = make_rlock("spill.SpillJournal._lock")
        self._closed = False
        # live (unpersisted) records by seq; _by_key for supersession
        self._records: Dict[int, _Rec] = {}
        self._by_key: Dict[str, int] = {}
        self._seg_live: Dict[int, int] = {}        # seg -> live record count
        self._seg_live_bytes: Dict[int, int] = {}  # seg -> live frame bytes
        self._next_seq = 1
        self._replayed: List[Tuple[int, str, bytes]] = []
        try:
            max_seg = self._replay()
            self._active_id = max_seg + 1
            self._active_size = 0
            self._f = open(self._seg_path(self._active_id), "wb",
                           buffering=64 * 1024)
        except BaseException:
            self._release_dir_lock()
            raise
        # executor-side counters for the ACTIVE file: bytes written vs
        # bytes known flushed (hard close truncates to the latter)
        self._written = self._synced = 0
        self.stats.segments_created += 1
        self._seg_live.setdefault(self._active_id, 0)
        self._seg_live_bytes.setdefault(self._active_id, 0)
        # group-commit writer: FIFO of file ops executed off the caller
        # thread; `sync()` barriers on it. In sync_each mode ops run
        # inline and the queue machinery is idle.
        self._wq: deque = deque()
        self._wcond = threading.Condition(self._lock)
        self._winflight = False
        self._wstop = False
        self._werr: Optional[BaseException] = None
        self._wthread: Optional[threading.Thread] = None
        if async_writer and not sync_each:
            self._wthread = threading.Thread(target=self._writer_loop,
                                             name="spill-journal",
                                             daemon=True)
            self._wthread.start()

    # ---- paths ------------------------------------------------------------

    def _seg_path(self, seg_id: int) -> Path:
        return self.dir / f"seg-{seg_id:08d}.wal"

    def _segment_ids(self) -> List[int]:
        out = []
        for p in self.dir.glob("seg-*.wal"):
            try:
                out.append(int(p.stem.split("-", 1)[1]))
            except ValueError:
                continue
        return sorted(out)

    # ---- replay (construction) --------------------------------------------

    def _replay(self) -> int:
        """Scan surviving segments in order, building the live set: an
        APPEND enters it (superseding an older same-key APPEND), a
        PERSIST removes its target, a torn frame ends its segment.
        Returns the highest segment id seen."""
        payloads: Dict[int, bytes] = {}
        seg_ids = self._segment_ids()
        for seg_id in seg_ids:
            data = self._seg_path(seg_id).read_bytes()
            off = 0
            while off < len(data):
                frame = self._parse_frame(data, off)
                if frame is None:
                    self.stats.torn_records += 1
                    break
                rtype, seq, key, payload, frame_len = frame
                self._next_seq = max(self._next_seq, seq + 1)
                if rtype == _APPEND:
                    self._drop_live(seq)              # re-appended frame
                    old = self._by_key.get(key)
                    if old is not None:               # newer same-key wins
                        self._drop_live(old)
                        payloads.pop(old, None)
                    self._records[seq] = _Rec(key, seg_id, off, frame_len,
                                              len(payload))
                    self._by_key[key] = seq
                    payloads[seq] = payload
                else:                                  # _PERSIST
                    self._drop_live(seq)
                    payloads.pop(seq, None)
                off += frame_len
        # per-segment live accounting; fully-persisted segments reclaim now
        for rec in self._records.values():
            self._seg_live[rec.seg] = self._seg_live.get(rec.seg, 0) + 1
            self._seg_live_bytes[rec.seg] = \
                self._seg_live_bytes.get(rec.seg, 0) + rec.frame_len
        for seg_id in seg_ids:
            if self._seg_live.get(seg_id, 0) == 0:
                self._seg_path(seg_id).unlink(missing_ok=True)
                self._seg_live.pop(seg_id, None)
                self._seg_live_bytes.pop(seg_id, None)
                self.stats.segments_reclaimed += 1
        self._replayed = [(seq, self._records[seq].key, payloads[seq])
                          for seq in sorted(self._records)]
        self.stats.replayed_records = len(self._replayed)
        self.stats.replayed_bytes = sum(len(p) for _, _, p in self._replayed)
        return seg_ids[-1] if seg_ids else 0

    @staticmethod
    def _parse_frame(data: bytes, off: int):
        """One frame at `off`, or None if torn/corrupt."""
        if off + _HDR_LEN > len(data):
            return None
        (magic,) = _MAGIC_S.unpack_from(data, off)
        if magic != _MAGIC:
            return None
        meta = data[off + _MAGIC_S.size:off + _MAGIC_S.size + _META_S.size]
        rtype, seq, klen, plen = _META_S.unpack(meta)
        if rtype not in (_APPEND, _PERSIST) or klen > _MAX_KLEN:
            return None
        frame_len = _HDR_LEN + klen + plen
        if off + frame_len > len(data):
            return None                                # torn tail
        (crc,) = _CRC_S.unpack_from(data, off + _MAGIC_S.size + _META_S.size)
        body_off = off + _HDR_LEN
        key = data[body_off:body_off + klen]
        payload = data[body_off + klen:body_off + klen + plen]
        if _frame_crc(meta, key, payload) != crc:
            return None
        return rtype, seq, key.decode(), payload, frame_len

    def _drop_live(self, seq: int) -> None:
        rec = self._records.pop(seq, None)
        if rec is not None and self._by_key.get(rec.key) == seq:
            del self._by_key[rec.key]

    def take_pending(self) -> List[Tuple[int, str, bytes]]:
        """The surviving (unpersisted) records, in enqueue-seq order.
        Payload buffers are handed over — callers re-enqueue them; the
        journal keeps only on-disk locations afterwards."""
        with self._lock:
            out, self._replayed = self._replayed, []
            return out

    # ---- writes (bookkeeping on the caller, file ops via _submit) ---------

    def append(self, key: str, data) -> int:
        """Journal one pending write BEFORE it is acknowledged. Returns
        the record's seq (handed back via `mark_persisted`). In group-
        commit mode the frame is durable only after the next `sync()`."""
        obs = self.obs
        with (obs.span("journal.append")
              if obs is not None else NOOP_CM):
            with self._lock:
                return self._append_locked(key, data)

    def append_many(self, items) -> List[int]:
        """Batch append (one lock round for a PUT's whole chunk set —
        the per-record overhead matters on the ack path). items:
        iterable of (key, payload). Returns the seqs in order."""
        items = list(items)
        obs = self.obs
        with (obs.span("journal.append", n=len(items))
              if obs is not None else NOOP_CM):
            with self._lock:
                return [self._append_locked(k, d) for k, d in items]

    def _append_locked(self, key: str, data) -> int:
        if self.faults is not None:
            self.faults.fire("spill.append", key)   # pre-bookkeeping
        kb = key.encode()
        body = data if isinstance(data, (bytes, bytearray, memoryview)) \
            else as_u8(data)                           # zero-copy u8 view
        nbytes = payload_nbytes(body)
        frame_len = _HDR_LEN + len(kb) + nbytes
        if self._closed:
            raise RuntimeError("spill journal is closed")
        self._raise_pending_error()
        seq = self._next_seq
        self._next_seq += 1
        offset = self._active_size
        self._submit(("frame", _APPEND, seq, kb, body))
        self._active_size += frame_len
        old = self._by_key.get(key)
        old_rec = self._records.pop(old) if old is not None else None
        self._records[seq] = _Rec(key, self._active_id, offset,
                                  frame_len, nbytes)
        self._by_key[key] = seq
        self._seg_live[self._active_id] += 1
        self._seg_live_bytes[self._active_id] += frame_len
        self.stats.appends += 1
        self.stats.appended_bytes += nbytes
        if old_rec is not None:         # superseded: dead AFTER the new
            self._note_dead(old_rec)    # frame is registered live
        self._maybe_rotate()
        return seq

    def mark_persisted(self, seq: int) -> bool:
        """Logical truncation: the write behind `seq` reached COS (or was
        superseded). Appends a PERSIST record and reclaims/compacts the
        segment once its live bytes drain. Unknown/already-dead seqs are
        no-ops (replay supersession may have dropped them)."""
        with self._lock:
            rec = self._records.pop(seq, None)
            if rec is None or self._closed:
                return False
            if self._by_key.get(rec.key) == seq:
                del self._by_key[rec.key]
            self._submit(("frame", _PERSIST, seq, b"", b""))
            self._active_size += _HDR_LEN
            self.stats.persists += 1
            self._note_dead(rec)
            self._maybe_rotate()
            return True

    def sync(self) -> None:
        """Durability barrier: every record appended so far is on disk
        when this returns. Group-commit callers MUST invoke it before
        acknowledging the writes those records cover."""
        if self.faults is not None:
            self.faults.fire("spill.sync")
        obs = self.obs
        with (obs.span("journal.sync")
              if obs is not None else NOOP_CM):
            with self._lock:
                if self._closed:
                    return
                self._submit(("flush",))
            self._drain()

    # ---- internal writer --------------------------------------------------

    def _submit(self, op: tuple) -> None:
        """Run a file op inline (sync_each) or queue it FIFO for the
        writer thread (group commit). Callers hold the lock; bookkeeping
        they did under it describes exactly the state the op will see,
        because ops execute in submission order."""
        if self._wthread is None:
            self._exec_op(op)
        else:
            self._wq.append(op)
            self._wcond.notify_all()

    def _writer_loop(self) -> None:
        while True:
            with self._lock:
                self._winflight = False
                self._wcond.notify_all()          # wake sync() barriers
                while not self._wq and not self._wstop:
                    self._wcond.wait()
                if not self._wq:                  # stopping, fully drained
                    return
                op = self._wq.popleft()
                self._winflight = True
            try:
                self._exec_op(op)                 # I/O outside the lock
            except BaseException as e:            # noqa: BLE001
                with self._lock:
                    self._werr = e
                    # wake blocked sync()/drain barriers NOW — without
                    # this, the ack path only discovered a writer-side
                    # failure on its next poll tick
                    self._wcond.notify_all()

    def _drain(self) -> None:
        """Wait until every queued file op has executed; surface any
        writer failure (original exception type) to the caller — the
        ack path. Writer-side failures notify the condition variable,
        so this blocks without polling and wakes immediately."""
        if self._wthread is None:
            self._raise_pending_error()
            return
        with self._lock:
            while (self._wq or self._winflight) and self._werr is None:
                self._wcond.wait()
            self._raise_pending_error()

    def _raise_pending_error(self) -> None:
        if self._werr is not None:
            err, self._werr = self._werr, None
            raise err

    def _exec_op(self, op: tuple) -> None:
        kind = op[0]
        if kind == "frame":
            if self.faults is not None:
                self.faults.fire("spill.io")     # writer-side I/O error
            _, rtype, seq, kb, body = op
            nbytes = payload_nbytes(body)
            meta = _META_S.pack(rtype, seq, len(kb), nbytes)
            # one small coalesced write (header + key), then the payload
            # as its own write so large bodies bypass the buffer copy
            self._f.write(_MAGIC_S.pack(_MAGIC) + meta
                          + _CRC_S.pack(_frame_crc(meta, kb, body)) + kb)
            if nbytes:
                self._f.write(body)
            self._written += _HDR_LEN + len(kb) + nbytes
            if self.sync_each:
                self._do_flush()             # survives a process crash
        elif kind == "flush":
            self._do_flush()
        elif kind == "rotate":
            _, old_id, delete_old, new_id = op
            self._do_flush()                 # seal durably: fsync=True
            self._f.close()                  # must cover sealed frames
            if delete_old:
                self._seg_path(old_id).unlink(missing_ok=True)
            self._f = open(self._seg_path(new_id), "wb",
                           buffering=64 * 1024)
            self._written = self._synced = 0
        elif kind == "truncate":
            self._f.seek(0)                  # implicit buffer flush
            self._f.truncate()
            self._written = self._synced = 0
        elif kind == "unlink":
            self._seg_path(op[1]).unlink(missing_ok=True)
        elif kind == "compact":
            _, src, entries = op
            try:
                data = src.read_bytes()
            except FileNotFoundError:
                return
            for off, ln in entries:
                self._f.write(data[off:off + ln])
                self._written += ln
            # The copies are about to become the ONLY durable frames for
            # these records: flush them (honoring fsync) before the
            # sealed source is destroyed, else a crash in between loses
            # acked data. _do_flush also advances _synced so a hard
            # close cannot truncate the compacted frames away.
            self._do_flush()
            src.unlink(missing_ok=True)

    def _do_flush(self) -> None:
        self._f.flush()
        if self.fsync:
            # lint: allow(blocking-under-lock): journal I/O is inline under _lock by design (crash-order atomicity); waiver covers all callers
            os.fsync(self._f.fileno())       # machine-crash durability
        self._synced = self._written

    # ---- segment lifecycle (bookkeeping under the lock) -------------------

    def _note_dead(self, rec: _Rec) -> None:
        self._seg_live[rec.seg] -= 1
        self._seg_live_bytes[rec.seg] -= rec.frame_len
        if rec.seg == self._active_id:
            if not self._records:
                # nothing live anywhere: the whole journal is garbage —
                # truncate the active segment in place (bounded disk)
                self._submit(("truncate",))
                self._active_size = 0
                self._seg_live[self._active_id] = 0
                self._seg_live_bytes[self._active_id] = 0
            return
        if self._seg_live[rec.seg] == 0:
            self._submit(("unlink", rec.seg))
            self._seg_live.pop(rec.seg)
            self._seg_live_bytes.pop(rec.seg)
            self.stats.segments_reclaimed += 1
        elif self._seg_live_bytes[rec.seg] <= self.compact_below:
            self._compact_segment(rec.seg)

    def _compact_segment(self, seg_id: int) -> None:
        """A sealed segment pinned by a few small live records (metadata
        entries, typically) re-appends those frames verbatim — same seqs
        — to the active segment and reclaims the file. Offsets are
        re-assigned synchronously; the copy executes in queue order, so
        it sees the sealed file complete and precedes any later op."""
        entries = []
        for seq in sorted(s for s, r in self._records.items()
                          if r.seg == seg_id):
            rec = self._records[seq]
            entries.append((rec.offset, rec.frame_len))
            rec.seg = self._active_id
            rec.offset = self._active_size
            self._active_size += rec.frame_len
            self._seg_live[self._active_id] += 1
            self._seg_live_bytes[self._active_id] += rec.frame_len
        self._seg_live.pop(seg_id, None)
        self._seg_live_bytes.pop(seg_id, None)
        self._submit(("compact", self._seg_path(seg_id), entries))
        self.stats.segments_compacted += 1

    def rotate(self) -> int:
        """Force-seal the active segment and open a new one — the
        journal-GENERATION boundary the metadata-snapshot scheme uses:
        the snapshot becomes the first record of the fresh generation,
        and everything it supersedes sits in sealed segments that
        reclaim or compact away on their own. Returns the new active
        segment id (== `generation`). No-op on an empty active segment
        or a closed journal."""
        with self._lock:
            if self._closed or self._active_size == 0:
                return self._active_id
            self._rotate_locked()
            return self._active_id

    @property
    def generation(self) -> int:
        """The active segment id — advances on every rotation (size-
        triggered or a forced `rotate()` generation boundary)."""
        with self._lock:
            return self._active_id

    def _maybe_rotate(self) -> None:
        if self._active_size < self.segment_bytes:
            return
        self._rotate_locked()

    def _rotate_locked(self) -> None:
        old = self._active_id
        delete_old = self._seg_live.get(old, 0) == 0
        if delete_old:
            self._seg_live.pop(old, None)
            self._seg_live_bytes.pop(old, None)
            self.stats.segments_reclaimed += 1
        self._active_id += 1
        self._active_size = 0
        self._seg_live.setdefault(self._active_id, 0)
        self._seg_live_bytes.setdefault(self._active_id, 0)
        self._submit(("rotate", old, delete_old, self._active_id))
        self.stats.segments_created += 1

    # ---- lifecycle / introspection ----------------------------------------

    def _release_dir_lock(self) -> None:
        lockf, self._lockf = self._lockf, None
        if lockf is not None:
            try:
                fcntl.flock(lockf.fileno(), fcntl.LOCK_UN)
            except OSError:
                pass
            lockf.close()

    def close(self, *, reclaim: bool = True, hard: bool = False) -> None:
        """Drain, flush, and close. With `reclaim` (graceful shutdown), a
        journal with zero live records deletes its files. `hard=True` is
        the crash-simulation path: after closing, the active segment is
        truncated back to its last flushed offset, discarding the
        unsynced buffer tail exactly as a SIGKILL would (only frames a
        `sync()` barrier covered — i.e. acked data — survive)."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            self._wstop = True
            self._wcond.notify_all()
        if self._wthread is not None:
            self._wthread.join(timeout=10.0)      # drains the queue first
        if hard:
            synced = self._synced
            self._f.close()                       # flushes the tail ...
            cut = synced
            if self.faults is not None and \
                    self.faults.fire("spill.torn_close") == "torn":
                # leave a PARTIAL unsynced frame behind the synced
                # boundary — the crash-mid-append case replay must
                # detect (bad framing) and drop; synced (acked) frames
                # are never torn, the contract says they survive
                p = self._seg_path(self._active_id)
                try:
                    tail = os.path.getsize(p) - synced
                except OSError:
                    tail = 0
                if tail > 0:
                    cut = synced + min(_HDR_LEN - 12, tail)
            try:                                  # ... which a real crash
                os.truncate(self._seg_path(self._active_id), cut)
            except OSError:                       # would have lost
                pass
            self._release_dir_lock()              # as process death would
            return
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())
        self._f.close()
        with self._lock:
            if reclaim and not self._records:
                for seg_id in self._segment_ids():
                    self._seg_path(seg_id).unlink(missing_ok=True)
        self._release_dir_lock()

    @property
    def pending_count(self) -> int:
        with self._lock:
            return len(self._records)

    @property
    def pending_bytes(self) -> int:
        with self._lock:
            return sum(r.payload_len for r in self._records.values())

    def pending_keys(self) -> List[str]:
        with self._lock:
            return sorted(r.key for r in self._records.values())

    def snapshot(self) -> Dict:
        with self._lock:
            return {"dir": str(self.dir),
                    "pending_records": len(self._records),
                    "pending_bytes": sum(r.payload_len
                                         for r in self._records.values()),
                    "segments": len(self._seg_live),
                    "appends": self.stats.appends,
                    "persists": self.stats.persists,
                    "replayed_records": self.stats.replayed_records,
                    "replayed_bytes": self.stats.replayed_bytes,
                    "torn_records": self.stats.torn_records,
                    "segments_reclaimed": self.stats.segments_reclaimed,
                    "segments_compacted": self.stats.segments_compacted}
