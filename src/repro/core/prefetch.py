"""Sequential-scan detection + readahead accounting for the GET pipeline.

Checkpoint shard restore (`ckpt/<step>/<leaf>/s0, s1, ...`) and KV page
restore (`kv/<seq>/p0, p1, ...`) both issue ordered `get_many_arrays`
batches — exactly the access pattern a serverless cache can get ahead
of (Faa$T-style prefetching, PAPERS.md). This module is the policy half:
it watches the object-key stream, detects per-stem runs of consecutive
trailing indices, and predicts the next `depth` keys once a run reaches
`min_run`. The mechanics half lives in `InfiniStore`: predicted objects'
non-resident chunks are fetched from COS on the GET I/O executor and
warmed into bucket cache space (`Slab.cache_put`) while decode of the
current batch is still running.

A key that breaks its stem's sequence cancels the run immediately
(random access must not keep speculating), and every warmed chunk is
accounted: consumed by a later GET -> `hits`; dropped by a cancelled
run, a failed fetch, or the outstanding-cap prune -> `wasted`.
"""
from __future__ import annotations

import re
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

# trailing decimal index: "ckpt/8/w/s12" -> ("ckpt/8/w/s", 12, width 2)
_TRAILING_IDX = re.compile(r"^(?P<stem>.*?)(?P<idx>\d+)$")


@dataclass(frozen=True)
class PrefetchConfig:
    enabled: bool = True
    min_run: int = 3        # consecutive keys before a stem is "sequential"
    depth: int = 2          # objects predicted ahead of the scan head
    max_stems: int = 32     # LRU bound on tracked stems
    max_outstanding: int = 256   # warmed-but-unconsumed chunk cap


@dataclass
class PrefetchStats:
    runs_detected: int = 0
    runs_cancelled: int = 0
    predicted: int = 0      # object keys predicted
    issued: int = 0         # chunk warms issued by the store
    hits: int = 0           # warmed chunks later consumed by a GET
    wasted: int = 0         # warmed chunks dropped unconsumed


@dataclass
class _Run:
    last_idx: int
    length: int = 1
    width: int = 0          # zero-padding width of the index ("s007" -> 3)


def split_key(key: str) -> Optional[Tuple[str, int, int]]:
    """(stem, index, pad-width) for keys ending in a decimal index."""
    m = _TRAILING_IDX.match(key)
    if m is None:
        return None
    digits = m.group("idx")
    width = len(digits) if digits.startswith("0") and len(digits) > 1 else 0
    return m.group("stem"), int(digits), width


class SequentialPrefetcher:
    """Per-stem run tracking + warmed-chunk accounting.

    NOT thread-safe by design: the store calls it only from its
    client-daemon thread (the I/O executor touches futures, never this).
    """

    def __init__(self, cfg: PrefetchConfig = PrefetchConfig()):
        self.cfg = cfg
        self.stats = PrefetchStats()
        self._runs: "OrderedDict[str, _Run]" = OrderedDict()
        # warmed, not-yet-consumed chunk keys -> owning stem (insertion
        # order doubles as the prune order)
        self._outstanding: "OrderedDict[str, str]" = OrderedDict()
        # chunk keys dropped by run cancellation / pruning since the last
        # take_dropped() — the store cancels their in-flight fetches
        self._dropped: List[str] = []

    # ---- detection ---------------------------------------------------------

    def observe(self, keys) -> List[Tuple[str, str]]:
        """Feed the next GET's object keys (in request order). Returns
        [(predicted_key, stem)] for every run at/over min_run — the keys
        the store should warm next."""
        if not self.cfg.enabled:
            return []
        predicted: List[Tuple[str, str]] = []
        seen: Dict[str, None] = {}
        for key in keys:
            parts = split_key(key)
            if parts is None:
                continue
            stem, idx, width = parts
            run = self._runs.get(stem)
            if run is not None and idx == run.last_idx + 1:
                run.last_idx = idx
                run.length += 1
                run.width = max(run.width, width)
                if run.length == self.cfg.min_run:
                    self.stats.runs_detected += 1
            elif run is not None and idx == run.last_idx:
                pass                           # re-read of the head: keep
            else:
                if run is not None:
                    self._cancel(stem, run)
                self._runs[stem] = run = _Run(last_idx=idx, width=width)
            self._runs.move_to_end(stem)
            if run.length >= self.cfg.min_run:
                for d in range(1, self.cfg.depth + 1):
                    nxt = self._format(stem, run.last_idx + d, run.width)
                    if nxt not in seen:
                        seen[nxt] = None
                        predicted.append((nxt, stem))
        while len(self._runs) > self.cfg.max_stems:
            stem, run = self._runs.popitem(last=False)
            self._cancel(stem, run, evicted=True)
        self.stats.predicted += len(predicted)
        return predicted

    @staticmethod
    def _format(stem: str, idx: int, width: int) -> str:
        return f"{stem}{idx:0{width}d}" if width else f"{stem}{idx}"

    def _cancel(self, stem: str, run: _Run, *, evicted: bool = False) -> None:
        """Run broken (random access) or evicted: its unconsumed warmed
        chunks are wasted speculation."""
        if run.length >= self.cfg.min_run and not evicted:
            self.stats.runs_cancelled += 1
        stale = [ck for ck, s in self._outstanding.items() if s == stem]
        for ck in stale:
            del self._outstanding[ck]
        self._dropped.extend(stale)
        self.stats.wasted += len(stale)

    def take_dropped(self) -> List[str]:
        """Chunk keys whose warms were abandoned since the last call —
        the store should cancel their queued fetches so stale
        speculation never delays demand reads."""
        out, self._dropped = self._dropped, []
        return out

    # ---- warmed-chunk accounting ------------------------------------------

    def record_issued(self, ckey: str, stem: str) -> None:
        """The store issued a warm fetch for chunk `ckey` of a predicted
        object belonging to `stem`."""
        self._outstanding[ckey] = stem
        self._outstanding.move_to_end(ckey)
        self.stats.issued += 1
        while len(self._outstanding) > self.cfg.max_outstanding:
            old, _ = self._outstanding.popitem(last=False)
            self._dropped.append(old)
            self.stats.wasted += 1

    def consume(self, ckey: str) -> bool:
        """A GET read chunk `ckey`; True (and a hit) iff it was warmed by
        prefetch and not consumed before."""
        if self._outstanding.pop(ckey, None) is None:
            return False
        self.stats.hits += 1
        return True

    def discard(self, ckey: str) -> None:
        """Warm fetch came back empty / got dropped: wasted speculation."""
        if self._outstanding.pop(ckey, None) is not None:
            self.stats.wasted += 1

    @property
    def outstanding(self) -> int:
        return len(self._outstanding)

    def snapshot(self) -> Dict[str, int]:
        return {"runs_detected": self.stats.runs_detected,
                "runs_cancelled": self.stats.runs_cancelled,
                "predicted": self.stats.predicted,
                "issued": self.stats.issued,
                "hits": self.stats.hits,
                "wasted": self.stats.wasted,
                "outstanding": self.outstanding}
