"""ServerlessMemory Store: the slab pool (paper §4, §5.4).

A Slab is the TPU-world analogue of a Lambda instance's function-memory
(DESIGN.md §2): a fixed-capacity memory unit that can be reclaimed at any
time by the platform. Each slab's memory is split into a *storage
partition* (regular object chunks, counted against HARDCAP) and a *cache
space* (demand-cached chunks, evictable, NOT counted against HARDCAP —
paper §5.4).

Payloads are bytes (numpy-backed); the serving integration keeps the hot
data path on device and uses these slabs as the control-plane ledger.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, Iterable, Optional, Tuple

from repro.core.clock import Clock
from repro.core.locks import make_rlock

# Lambda runtime overhead the paper excludes from HARDCAP (~100 MB of a
# 1536 MB function) and the fraction reserved for recovery buffers §5.5.2.
RUNTIME_OVERHEAD_FRACTION = 100 / 1536
RECOVERY_RESERVE_FRACTION = 0.10


def hardcap(capacity: int) -> int:
    return int(capacity * (1 - RUNTIME_OVERHEAD_FRACTION
                           - RECOVERY_RESERVE_FRACTION))


@dataclass
class SlabStats:
    invocations: int = 0
    busy_seconds: float = 0.0        # billed execution time
    stored_bytes: int = 0
    cached_bytes: int = 0


class Ref:
    """Size-only entry for device-resident chunks (e.g. KV pages): SMS
    tracks placement/accounting while the payload stays in HBM."""
    __slots__ = ("size",)

    def __init__(self, size: int):
        self.size = size


def _nbytes(v) -> int:
    return v.size if isinstance(v, Ref) else len(v)


class Slab:
    """One function instance's memory."""

    def __init__(self, fid: int, capacity: int, clock: Clock):
        self.fid = fid
        self.capacity = capacity
        self.hardcap = hardcap(capacity)
        self.clock = clock
        # optional FaultPlan (set by SMS.add); a "reclaim" advisory at
        # sms.store / sms.load reclaims this slab mid-operation — the
        # FaaS provider killing the instance under us.
        self.faults = None
        self.storage: Dict[str, bytes] = {}
        self.cache: "OrderedDict[str, bytes]" = OrderedDict()
        # incremental byte accounting: `used`/cache totals used to be
        # recomputed by summing every entry on EVERY store/trim — O(n)
        # per chunk write on the PUT hot path, pure-Python and
        # GIL-bound, which throttled multi-daemon scale-out long before
        # the encode did. Maintained on each insert/delete instead.
        self._used = 0
        self._cached = 0
        self.alive = True                  # False = reclaimed by provider
        self.term = 0                      # insertion-log term (§5.5.1)
        self.log_hash = ""
        self.diff_rank = 0
        self.last_invoked = clock.now()
        self.stats = SlabStats()
        self._lock = make_rlock("sms.Slab._lock")

    # ---- billing / liveness -------------------------------------------------

    def invoke(self, busy_s: float = 0.0) -> None:
        with self._lock:
            if not self.alive:   # cold start: fresh instance, empty memory
                self.alive = True
            self.last_invoked = self.clock.now()
            self.stats.invocations += 1
            self.stats.busy_seconds += busy_s

    def reclaim(self) -> None:
        """Provider reclaims the instance: memory is lost. The insertion
        log (in COS) survives; term/hash mismatch on the next invocation
        triggers failure detection (§5.5.1)."""
        with self._lock:
            self.alive = False
            self.storage.clear()
            self.cache.clear()
            self._used = 0
            self._cached = 0
            self.stats.stored_bytes = 0
            self.stats.cached_bytes = 0
            self.term = 0
            self.log_hash = ""
            self.diff_rank = 0

    # ---- storage partition ---------------------------------------------------

    @property
    def used(self) -> int:
        return self._used

    def store(self, key: str, data) -> bool:
        """data: bytes payload, or a `Ref` for device-resident chunks.
        Accepts writes while under HARDCAP (the crossing write goes
        through — the placement layer then seals the FG, §5.3.1); the raw
        capacity is the absolute bound, with cache-space eviction first."""
        if self.faults is not None:
            if self.faults.fire("sms.store", key) == "reclaim":
                self.reclaim()            # instance died mid-store
                return False
        with self._lock:
            if not self.alive:
                return False
            needed = _nbytes(data)
            if self._used >= self.hardcap:
                return False
            if self._used + needed > self.capacity:
                self._evict_cache(needed)                # paper §5.4
                if self._used + needed > self.capacity:
                    return False
            old = self.storage.get(key)
            if old is not None:                # same-key overwrite
                self._used -= _nbytes(old)
            self.storage[key] = data
            self._used += needed
            self.stats.stored_bytes = self._used
            return True

    def load(self, key: str) -> Optional[bytes]:
        if self.faults is not None:
            if self.faults.fire("sms.load", key) == "reclaim":
                self.reclaim()            # instance died mid-gather
                return None
        with self._lock:
            if not self.alive:
                return None
            if key in self.storage:
                return self.storage[key]
            if key in self.cache:
                self.cache.move_to_end(key)
                return self.cache[key]
            return None

    def delete(self, key: str) -> bool:
        with self._lock:
            v = self.storage.pop(key, None)
            if v is not None:
                self._used = max(0, self._used - _nbytes(v))
                self.stats.stored_bytes = self._used
                return True
            v = self.cache.pop(key, None)
            if v is None:
                return False
            self._cached = max(0, self._cached - _nbytes(v))
            self.stats.cached_bytes = self._cached
            return True

    # ---- cache space (demand-cached chunks, §5.3.3/§5.4) --------------------

    def cache_put(self, key: str, data: bytes) -> None:
        with self._lock:
            if not self.alive:
                return
            old = self.cache.get(key)
            if old is not None:
                self._cached -= _nbytes(old)
            self.cache[key] = data
            self.cache.move_to_end(key)
            self._cached += _nbytes(data)
            budget = self.capacity - self.hardcap
            self._trim_cache(budget)

    def _trim_cache(self, budget: int) -> None:
        while self.cache and self._cached > budget:
            _, v = self.cache.popitem(last=False)
            self._cached -= _nbytes(v)
        self._cached = max(0, self._cached)
        self.stats.cached_bytes = self._cached

    def cache_delete(self, key: str) -> bool:
        """Drop a cache-space entry WITHOUT touching the storage
        partition (expired temporary recovery placements, §5.5.2)."""
        with self._lock:
            v = self.cache.pop(key, None)
            if v is None:
                return False
            self._cached = max(0, self._cached - _nbytes(v))
            self.stats.cached_bytes = self._cached
            return True

    def _evict_cache(self, needed: int) -> None:
        freed = 0
        while self.cache and freed < needed:
            _, v = self.cache.popitem(last=False)
            freed += _nbytes(v)
        self._cached = max(0, self._cached - freed)
        self.stats.cached_bytes = self._cached

    def keys(self) -> Iterable[str]:
        with self._lock:
            return list(self.storage.keys())


class SMS:
    """The collective function-memory pool."""

    def __init__(self, clock: Clock):
        self.clock = clock
        self.slabs: Dict[int, Slab] = {}
        self.faults = None               # propagated to new slabs
        self._lock = make_rlock("sms.SMS._lock")

    def add(self, fid: int, capacity: int) -> Slab:
        with self._lock:
            slab = Slab(fid, capacity, self.clock)
            slab.faults = self.faults
            self.slabs[fid] = slab
            return slab

    def get(self, fid: int) -> Slab:
        return self.slabs[fid]

    def remove(self, fid: int) -> None:
        with self._lock:
            self.slabs.pop(fid, None)

    def reclaim_idle(self, idle_threshold: float) -> list:
        """Provider-side reclamation of instances idle beyond threshold —
        the FaaS behaviour InfiniStore's warmups fight against."""
        now = self.clock.now()
        out = []
        for slab in self.slabs.values():
            if slab.alive and now - slab.last_invoked > idle_threshold:
                slab.reclaim()
                out.append(slab.fid)
        return out

    @property
    def total_stored(self) -> int:
        return sum(s.used for s in self.slabs.values())

    def alive_count(self) -> int:
        return sum(1 for s in self.slabs.values() if s.alive)
