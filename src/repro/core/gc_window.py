"""Sliding-window GC-bucket management (paper §5.3, Fig. 4).

The ServerlessMemory space is organized as GC-buckets of function groups.
Buckets age ACTIVE (M intervals) -> DEGRADED (N intervals) -> RELEASED;
data re-accessed within H = (M+N)*interval is *marked* and compacted into
the latest bucket, so a released bucket only holds cold data. Function
management policy (FMP) differs per state: active buckets get frequent
warmup ticks, degraded buckets get infrequent ones, released buckets none
(the provider reclaims them).
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set

from repro.core.clock import Clock


class BucketState(enum.Enum):
    ACTIVE = "active"
    DEGRADED = "degraded"
    RELEASED = "released"


@dataclass
class GCConfig:
    gc_interval: float = 600.0        # seconds (paper IBM config: 10 min)
    active_intervals: int = 6         # M
    degraded_intervals: int = 12      # N
    active_warmup: float = 60.0       # warmup period for active FMP
    degraded_warmup: float = 300.0    # reduced warmup for degraded FMP
    compaction_fraction: float = 0.5  # random subset migrated per round
    compaction_max_interval: float = 30.0

    @property
    def horizon(self) -> float:       # H
        return (self.active_intervals + self.degraded_intervals) \
            * self.gc_interval


@dataclass
class GCBucket:
    index: int
    created_at: float
    state: BucketState = BucketState.ACTIVE
    fg_ids: List[int] = field(default_factory=list)
    function_ids: Set[int] = field(default_factory=set)

    def add_function(self, fid: int, fg_id: int) -> None:
        self.function_ids.add(fid)
        if fg_id not in self.fg_ids:
            self.fg_ids.append(fg_id)


@dataclass
class WindowEvent:
    """Result of one GC execution."""
    demoted_buckets: List[GCBucket] = field(default_factory=list)
    released_buckets: List[GCBucket] = field(default_factory=list)
    released_functions: Set[int] = field(default_factory=set)
    new_bucket: Optional[GCBucket] = None


class SlidingWindow:
    """Owns bucket lifecycle; placement/compaction layers consult it."""

    def __init__(self, cfg: GCConfig, clock: Clock):
        self.cfg = cfg
        self.clock = clock
        self._buckets: List[GCBucket] = []
        self._next_index = 0
        self._last_gc = clock.now()
        self._marked: Set[str] = set()        # chunks re-accessed within H
        self._new_bucket()

    # ---- bucket access ---------------------------------------------------

    def _new_bucket(self) -> GCBucket:
        b = GCBucket(index=self._next_index, created_at=self.clock.now())
        self._next_index += 1
        self._buckets.append(b)
        return b

    @property
    def latest(self) -> GCBucket:
        return self._buckets[-1]

    def buckets(self, state: Optional[BucketState] = None) -> List[GCBucket]:
        return [b for b in self._buckets
                if state is None or b.state == state]

    def bucket_of_function(self, fid: int) -> Optional[GCBucket]:
        for b in reversed(self._buckets):
            if fid in b.function_ids:
                return b
        return None

    def state_of_function(self, fid: int) -> Optional[BucketState]:
        b = self.bucket_of_function(fid)
        return b.state if b else None

    def warmup_period(self, fid: int) -> Optional[float]:
        st = self.state_of_function(fid)
        if st == BucketState.ACTIVE:
            return self.cfg.active_warmup
        if st == BucketState.DEGRADED:
            return self.cfg.degraded_warmup
        return None

    # ---- marking / compaction -------------------------------------------

    def mark(self, chunk_key: str) -> None:
        """Chunk re-accessed within H: candidate for compaction."""
        self._marked.add(chunk_key)

    def unmark(self, chunk_key: str) -> None:
        self._marked.discard(chunk_key)

    def marked(self) -> Set[str]:
        return set(self._marked)

    def take_compaction_round(self, rng) -> List[str]:
        """Random `compaction_fraction` subset of marked chunks (paper
        §5.3.3: the daemon migrates marked chunks in bounded rounds)."""
        marked = sorted(self._marked)
        if not marked:
            return []
        n = max(1, int(len(marked) * self.cfg.compaction_fraction))
        idx = rng.permutation(len(marked))[:n]
        picked = [marked[i] for i in idx]
        for c in picked:
            self._marked.discard(c)
        return picked

    # ---- GC execution -----------------------------------------------------

    def due(self) -> bool:
        return self.clock.now() - self._last_gc >= self.cfg.gc_interval

    def run_gc(self, *, carry_open_fgs: Callable[[GCBucket, GCBucket], None]
               = lambda old, new: None) -> WindowEvent:
        """Execute the GC procedure (paper Fig. 4):
        1. active buckets older than M intervals become degraded,
        2. degraded buckets older than M+N intervals are released,
        3. a fresh latest bucket is opened; open FGs are carried over."""
        now = self.clock.now()
        self._last_gc = now
        ev = WindowEvent()
        M = self.cfg.active_intervals * self.cfg.gc_interval
        H = self.cfg.horizon
        for b in self._buckets:
            age = now - b.created_at
            if b.state == BucketState.ACTIVE and age >= M:
                b.state = BucketState.DEGRADED
                ev.demoted_buckets.append(b)
            if b.state == BucketState.DEGRADED and age >= H:
                b.state = BucketState.RELEASED
                ev.released_buckets.append(b)
                ev.released_functions |= b.function_ids
        old_latest = self.latest
        ev.new_bucket = self._new_bucket()
        carry_open_fgs(old_latest, ev.new_bucket)
        # drop fully-released buckets from the window front; the new
        # bucket was appended by _new_bucket and is ACTIVE, so it always
        # survives this filter — no re-append, which would duplicate it
        self._buckets = [b for b in self._buckets
                         if b.state != BucketState.RELEASED]
        return ev

    def release_function(self, fid: int) -> None:
        """Remove a (failed degraded) function from the memory space
        immediately (paper §5.3: degraded + failure => removal)."""
        for b in self._buckets:
            b.function_ids.discard(fid)
