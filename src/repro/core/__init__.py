"""InfiniStore core: the paper's contribution as a composable library.

ServerlessMemory (sliding-window GC-bucket management + PlaceChunk
placement + slab pool) coupled with a persistent COS layer, RS erasure
coding, insertion-log failure detection, and parallel recovery.
"""
from repro.core.clock import Clock  # noqa: F401
from repro.core.cos import COS  # noqa: F401
from repro.core.costmodel import CostLedger  # noqa: F401
from repro.core.ec import ECConfig, RSCodec  # noqa: F401
from repro.core.faults import (COSThrottleError, FaultPlan,  # noqa: F401
                               FaultPoint, InjectedCrash, InjectedFault,
                               OpDeadlineExceeded, RetryPolicy,
                               TransientCOSError)
from repro.core.gc_window import (BucketState, GCConfig,  # noqa: F401
                                  SlidingWindow)
from repro.core.host import (ProcessShardedStore,  # noqa: F401
                             ShardWorkerDied)
from repro.core.ipc import ArenaBroken, ShmArena  # noqa: F401
from repro.core.insertion_log import InsertionLog, PutRecord  # noqa: F401
from repro.core.payload import (Payload, as_u8,  # noqa: F401
                                payload_nbytes, to_bytes)
from repro.core.placement import PlacementManager  # noqa: F401
from repro.core.prefetch import (PrefetchConfig,  # noqa: F401
                                 SequentialPrefetcher)
from repro.core.recovery import RecoveryManager  # noqa: F401
from repro.core.shard import (HashRouter, RangeRouter,  # noqa: F401
                              ShardedStore)
from repro.core.sms import SMS, Slab  # noqa: F401
from repro.core.spill import SpillJournal, SpillStats  # noqa: F401
from repro.core.store import (AtomicCounter,  # noqa: F401
                              ConcurrentPutError, InfiniStore,
                              StoreConfig, StoreFrontend, StoreStats)
from repro.core.transport import (HeartbeatConfig,  # noqa: F401
                                  LocalTransport, ShardTransport,
                                  TcpTransport)
from repro.core.versioning import (MetadataTable, Meta,  # noqa: F401
                                   PersistentBuffer)
from repro.core.writeback import (StoreFuture,  # noqa: F401
                                  WritebackQueue)
