"""Reed-Solomon erasure-coding codec facade (paper: RS(10+2) by default).

Splits a byte payload into k data chunks + p parity chunks; any k of the
k+p chunks reconstruct the payload. Host math is numpy (table-based);
`backend="pallas"` routes the GF(256) matmul through the TPU kernel
(interpret mode on CPU) — bit-identical by tests/test_kernels_rs.py.
"""
from __future__ import annotations

import struct
from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from repro.kernels.rs_gf256.ref import (cauchy_parity_matrix,
                                        gf_inv_matrix_np, gf_matmul_np)

_HEADER = struct.Struct("<I")    # original length prefix


@dataclass(frozen=True)
class ECConfig:
    k: int = 10
    p: int = 2

    @property
    def n(self) -> int:
        return self.k + self.p


class RSCodec:
    def __init__(self, cfg: ECConfig = ECConfig(), *, backend: str = "numpy"):
        self.cfg = cfg
        self.backend = backend
        self._parity = cauchy_parity_matrix(cfg.k, cfg.p)
        self._gen = np.concatenate(
            [np.eye(cfg.k, dtype=np.uint8), self._parity], axis=0)

    def _matmul(self, G: np.ndarray, X: np.ndarray) -> np.ndarray:
        if self.backend == "pallas":
            from repro.kernels.rs_gf256.ops import gf256_matmul
            return np.asarray(gf256_matmul(G, X, backend="interpret"))
        return gf_matmul_np(G, X)

    # ---- encode -------------------------------------------------------------

    def encode(self, payload: bytes) -> List[bytes]:
        """payload -> k+p chunk payloads (equal length)."""
        k, p = self.cfg.k, self.cfg.p
        framed = _HEADER.pack(len(payload)) + payload
        clen = -(-len(framed) // k)
        buf = np.zeros((k, clen), np.uint8)
        flat = np.frombuffer(framed, np.uint8)
        buf.reshape(-1)[:len(flat)] = flat
        parity = self._matmul(self._parity, buf)
        return [buf[i].tobytes() for i in range(k)] + \
               [parity[i].tobytes() for i in range(p)]

    # ---- decode -------------------------------------------------------------

    def decode(self, chunks: Dict[int, bytes]) -> bytes:
        """chunks: {chunk_index: payload} with >= k entries. Returns the
        original payload (any k of the k+p indices suffice)."""
        k = self.cfg.k
        if len(chunks) < k:
            raise ValueError(
                f"need >= {k} chunks to decode, got {len(chunks)}")
        idx = sorted(chunks)[:k]
        clen = len(chunks[idx[0]])
        data_rows = np.zeros((k, clen), np.uint8)
        if all(i < k for i in idx) and idx == list(range(k)):
            for i in idx:
                data_rows[i] = np.frombuffer(chunks[i], np.uint8)
        else:
            sub = self._gen[idx]                         # (k, k)
            surv = np.stack([np.frombuffer(chunks[i], np.uint8)
                             for i in idx])
            data_rows = self._matmul(gf_inv_matrix_np(sub), surv)
        framed = data_rows.reshape(-1).tobytes()
        (orig_len,) = _HEADER.unpack(framed[:_HEADER.size])
        return framed[_HEADER.size:_HEADER.size + orig_len]

    def chunk_len(self, payload_len: int) -> int:
        return -(-(payload_len + _HEADER.size) // self.cfg.k)
