"""Reed-Solomon erasure-coding codec facade (paper: RS(10+2) by default).

Splits a byte payload into k data chunks + p parity chunks; any k of the
k+p chunks reconstruct the payload. Host math is numpy via the full
256x256 product table (one gather + one XOR per coefficient);
`backend="pallas"` routes the GF(256) matmul through the bit-sliced TPU
kernel (compiled on TPU, interpret mode on CPU) — bit-identical by
tests/test_kernels_rs.py.

Batched data path: `encode_many` / `decode_many` stack every fragment of
a request column-wise into ONE (k, sum L) GF(256) matmul instead of one
dispatch per fragment, and decode matrices are LRU-cached by survivor
index tuple so repeated degraded reads with the same survivor set pay
for exactly one O(k^3) Gauss-Jordan inversion (`cache_info()` exposes
hit accounting). Encode writes the framed payload straight into one
preallocated stacked buffer — no intermediate `header + payload` concat.
"""
from __future__ import annotations

import struct
import threading
from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.payload import as_u8, payload_nbytes
from repro.kernels.rs_gf256.ref import (cauchy_parity_matrix,
                                        gf_inv_matrix_np, gf_matmul_table)

_HEADER = struct.Struct("<I")    # original length prefix


@dataclass(frozen=True)
class ECConfig:
    k: int = 10
    p: int = 2

    @property
    def n(self) -> int:
        return self.k + self.p


class RSCodec:
    def __init__(self, cfg: ECConfig = ECConfig(), *, backend: str = "numpy",
                 inv_cache_size: int = 64):
        self.cfg = cfg
        self.backend = backend
        self._parity = cauchy_parity_matrix(cfg.k, cfg.p)
        self._gen = np.concatenate(
            [np.eye(cfg.k, dtype=np.uint8), self._parity], axis=0)
        # decode-matrix LRU: survivor index tuple -> inverted (k, k) matrix
        self._inv_cache: "OrderedDict[Tuple[int, ...], np.ndarray]" = \
            OrderedDict()
        self._inv_cache_size = inv_cache_size
        self._inv_lock = threading.Lock()    # store serves concurrent GETs
        self._cache_hits = 0
        self._cache_misses = 0
        self._inversions = 0

    def _matmul(self, G: np.ndarray, X: np.ndarray) -> np.ndarray:
        if self.backend == "pallas":
            from repro.kernels.rs_gf256.ops import gf256_matmul
            # compiled on TPU, interpret elsewhere (ops.py dispatch)
            return np.asarray(gf256_matmul(G, X, backend="pallas"))
        return gf_matmul_table(G, X)

    # ---- encode -------------------------------------------------------------

    def encode(self, payload: bytes) -> List[bytes]:
        """payload -> k+p chunk payloads (equal length)."""
        return self.encode_many([payload])[0]

    def encode_many(self, payloads: Sequence, *,
                    as_arrays: bool = False) -> List[List[bytes]]:
        """Batch encode: all payloads' data blocks are stacked column-wise
        into one (k, sum clen) buffer and the parity rows come from a
        single GF(256) matmul.

        Payloads may be bytes OR array-like (numpy / jax uint8 views via
        the Payload protocol) — device-backed fragments reach the kernel
        without an intermediate `bytes` copy. With `as_arrays=True`
        chunks come back as uint8 views into the stacked encode buffer
        (zero-copy) instead of materialized `bytes`."""
        if not payloads:
            return []
        k, p = self.cfg.k, self.cfg.p
        clens = [self.chunk_len(payload_nbytes(pl)) for pl in payloads]
        data = np.zeros((k, int(sum(clens))), np.uint8)
        off = 0
        for pl, clen in zip(payloads, clens):
            self._fill_framed(data[:, off:off + clen], as_u8(pl))
            off += clen
        parity = self._matmul(self._parity, data)
        out: List[List[bytes]] = []
        off = 0
        for clen in clens:
            sl = slice(off, off + clen)
            if as_arrays:
                out.append([data[i, sl] for i in range(k)] +
                           [parity[i, sl] for i in range(p)])
            else:
                out.append([data[i, sl].tobytes() for i in range(k)] +
                           [parity[i, sl].tobytes() for i in range(p)])
            off += clen
        return out

    @staticmethod
    def _fill_framed(block: np.ndarray, flat: np.ndarray) -> None:
        """Write the framed payload (length header + flat uint8 payload)
        row-major into `block` — a (k, clen) column-slice view of the
        stacked buffer — via direct per-row memcpys."""
        k, clen = block.shape
        hdr = np.frombuffer(_HEADER.pack(flat.size), np.uint8)
        H, end = hdr.size, hdr.size + flat.size
        for i in range(k):
            s = i * clen
            if s >= end:
                break
            e = min(s + clen, end)
            dst = block[i]
            if s < H:                          # row overlaps the header
                hn = min(H, e) - s
                dst[:hn] = hdr[s:s + hn]
                if e > H:
                    dst[hn:e - s] = flat[:e - H]
            else:
                dst[:e - s] = flat[s - H:e - H]

    # ---- decode -------------------------------------------------------------

    def decode(self, chunks: Dict[int, bytes]) -> bytes:
        """chunks: {chunk_index: payload} with >= k entries. Returns the
        original payload (any k of the k+p indices suffice)."""
        return self.decode_many([chunks])[0]

    def decode_many(self, chunk_maps: Sequence[Dict[int, bytes]], *,
                    as_arrays: bool = False) -> List[bytes]:
        """Batch decode: fragments sharing a survivor set are stacked
        column-wise and reconstructed by one cached-inverse matmul.

        Chunks may be bytes or uint8 arrays (slab-resident views). With
        `as_arrays=True` results are flat uint8 arrays — the GET-side
        zero-copy path (no `bytes` materialization per fragment)."""
        k = self.cfg.k
        ident = tuple(range(k))
        results: List = [b""] * len(chunk_maps)
        groups: Dict[Tuple[int, ...], List[int]] = {}
        for pos, chunks in enumerate(chunk_maps):
            if len(chunks) < k:
                raise ValueError(
                    f"need >= {k} chunks to decode, got {len(chunks)}")
            idx = tuple(sorted(chunks)[:k])
            if idx == ident:                   # all data rows survive
                if not as_arrays and all(isinstance(chunks[i], bytes)
                                         for i in ident):
                    results[pos] = self._unframe(
                        b"".join(chunks[i] for i in ident))
                else:
                    flat = np.concatenate([as_u8(chunks[i]) for i in ident])
                    results[pos] = self._unframe_np(flat, as_arrays)
            else:
                groups.setdefault(idx, []).append(pos)
        for idx, positions in groups.items():
            inv = self._decode_matrix(idx)
            clens = [payload_nbytes(chunk_maps[pos][idx[0]])
                     for pos in positions]
            surv = np.empty((k, int(sum(clens))), np.uint8)
            off = 0
            for pos, clen in zip(positions, clens):
                cm = chunk_maps[pos]
                for r, i in enumerate(idx):
                    surv[r, off:off + clen] = as_u8(cm[i])
                off += clen
            dec = self._matmul(inv, surv)
            off = 0
            for pos, clen in zip(positions, clens):
                flat = dec[:, off:off + clen].reshape(-1)
                results[pos] = self._unframe_np(flat, as_arrays)
                off += clen
        return results

    def _decode_matrix(self, idx: Tuple[int, ...]) -> np.ndarray:
        with self._inv_lock:
            inv = self._inv_cache.get(idx)
            if inv is not None:
                self._inv_cache.move_to_end(idx)
                self._cache_hits += 1
                return inv
            self._cache_misses += 1
            self._inversions += 1
        inv = gf_inv_matrix_np(self._gen[list(idx)])   # outside the lock
        with self._inv_lock:
            self._inv_cache[idx] = inv
            if len(self._inv_cache) > self._inv_cache_size:
                self._inv_cache.popitem(last=False)
        return inv

    @staticmethod
    def _unframe(framed: bytes) -> bytes:
        (orig_len,) = _HEADER.unpack_from(framed)
        return framed[_HEADER.size:_HEADER.size + orig_len]

    @staticmethod
    def _unframe_np(flat: np.ndarray, as_arrays: bool):
        """Unframe a flat uint8 buffer; returns a view (as_arrays) or
        bytes."""
        (orig_len,) = _HEADER.unpack_from(flat[:_HEADER.size].tobytes())
        body = flat[_HEADER.size:_HEADER.size + orig_len]
        return body if as_arrays else body.tobytes()

    def cache_info(self) -> Dict[str, int]:
        """Decode-matrix LRU accounting (hits/misses/inversions/size)."""
        return {"hits": self._cache_hits, "misses": self._cache_misses,
                "inversions": self._inversions,
                "size": len(self._inv_cache)}

    def chunk_len(self, payload_len: int) -> int:
        return -(-(payload_len + _HEADER.size) // self.cfg.k)
