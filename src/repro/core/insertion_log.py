"""Insertion logs, snapshots, and failure-detection metadata (§5.5.1, Fig 6).

Each function's log is a chain of *insertion nodes* persisted to COS; a
node consolidates the PUT records of one invocation window and carries a
monotonically increasing *term* plus a chained hash. `diff_rank` counts
all PUT records since term 1 (including deletes) — the daemon-vs-instance
diff_rank difference decides local vs parallel recovery. A *snapshot*
(chunk list at some term) bounds replay length; the *operation manifest*
= snapshot + subsequent nodes.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional, Set

from repro.core.cos import COS


@dataclass(frozen=True)
class PutRecord:
    key: str              # chunk key ("objkey|ver#chunkidx")
    size: int
    version: int
    delete: bool = False


@dataclass
class InsertionNode:
    term: int
    records: List[PutRecord]
    prev_hash: str

    @property
    def hash(self) -> str:
        h = hashlib.sha256(self.prev_hash.encode())
        for r in self.records:
            h.update(f"{r.key}|{r.size}|{r.version}|{r.delete}".encode())
        return h.hexdigest()

    def to_bytes(self) -> bytes:
        return json.dumps({"term": self.term, "prev": self.prev_hash,
                           "records": [asdict(r) for r in self.records]}
                          ).encode()

    @classmethod
    def from_bytes(cls, b: bytes) -> "InsertionNode":
        d = json.loads(b.decode())
        return cls(term=d["term"],
                   records=[PutRecord(**r) for r in d["records"]],
                   prev_hash=d["prev"])


@dataclass
class Snapshot:
    term: int
    chunk_keys: List[str]
    hash: str

    def to_bytes(self) -> bytes:
        return json.dumps(asdict(self)).encode()

    @classmethod
    def from_bytes(cls, b: bytes) -> "Snapshot":
        return cls(**json.loads(b.decode()))


@dataclass
class Piggyback:
    """Insertion info piggybacked on GET/PUT responses (§5.5.1): the
    daemon's view of a function's latest durable state."""
    term: int = 0
    hash: str = ""
    diff_rank: int = 0
    last_node_size: int = 0
    snapshot_term: int = 0


class InsertionLog:
    """Per-function log; nodes and snapshots are persisted in COS.

    With a `writeback` queue attached, node/snapshot persistence rides
    the background writer (§5.5.1: the *instance* persists the node as
    the invocation returns — it is not on the client's ack path) and
    reads check the pending map first, so recovery sees nodes that are
    acked but not yet in COS."""

    def __init__(self, fid: int, cos: COS, *, snapshot_every: int = 8,
                 writeback=None):
        self.fid = fid
        self.cos = cos
        self.writeback = writeback
        self.snapshot_every = snapshot_every
        self.term = 0
        self.last_hash = ""
        self.diff_rank = 0
        self.snapshot_term = 0
        self._live: Set[str] = set()     # chunk keys currently stored
        self._last_node_size = 0

    # ---- key helpers ------------------------------------------------------

    def node_key(self, term: int) -> str:
        return f"ilog/{self.fid}/{term:08d}"

    @property
    def snap_key(self) -> str:
        return f"isnap/{self.fid}"

    # ---- writes -----------------------------------------------------------

    def append(self, records: List[PutRecord]) -> InsertionNode:
        """Consolidate one invocation window's PUTs into a sealed node and
        persist it to COS before the invocation returns (§5.5.1)."""
        self.term += 1
        node = InsertionNode(term=self.term, records=records,
                             prev_hash=self.last_hash)
        data = node.to_bytes()
        self._persist(self.node_key(self.term), data)
        self.last_hash = node.hash
        self.diff_rank += len(records)
        self._last_node_size = len(data)
        for r in records:
            if r.delete:
                self._live.discard(r.key)
            else:
                self._live.add(r.key)
        if self.term - self.snapshot_term >= self.snapshot_every:
            self.snapshot()
        return node

    def snapshot(self) -> Snapshot:
        """Persist the full chunk list (§5.5.1: 'On returning, the function
        instance creates a snapshot ... to speed up recovery')."""
        snap = Snapshot(term=self.term, chunk_keys=sorted(self._live),
                        hash=self.last_hash)
        self._persist(self.snap_key, snap.to_bytes())
        self.snapshot_term = self.term
        return snap

    def _persist(self, key: str, data: bytes) -> None:
        if self.writeback is not None:
            self.writeback.enqueue(key, data)
        else:
            self.cos.put(key, data)

    # ---- reads ------------------------------------------------------------

    def _read(self, key: str) -> Optional[bytes]:
        if self.writeback is not None:
            return self.writeback.read_through(key)
        return self.cos.get(key)

    def piggyback(self) -> Piggyback:
        return Piggyback(term=self.term, hash=self.last_hash,
                         diff_rank=self.diff_rank,
                         last_node_size=self._last_node_size,
                         snapshot_term=self.snapshot_term)

    def manifest(self) -> List[str]:
        """Operation manifest from COS: last snapshot's chunk list replayed
        with the insertion nodes after it. This is what a recovering
        instance downloads first (§5.5.1)."""
        live: Set[str] = set()
        start_term = 1
        snap_b = self._read(self.snap_key)
        if snap_b is not None:
            snap = Snapshot.from_bytes(snap_b)
            live = set(snap.chunk_keys)
            start_term = snap.term + 1
        t = start_term
        while True:
            b = self._read(self.node_key(t))
            if b is None:
                break
            node = InsertionNode.from_bytes(b)
            for r in node.records:
                if r.delete:
                    live.discard(r.key)
                else:
                    live.add(r.key)
            t += 1
        return sorted(live)

    def live_keys(self) -> Set[str]:
        return set(self._live)
