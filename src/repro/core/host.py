"""Multi-process shard host: per-shard worker processes behind the
`ShardedStore` surface, with a zero-copy shared-memory data plane.

`BENCH_shard.json` showed the in-process `ShardedStore` scaling 4.06x
to 4 shards and collapsing past that: every shard daemon (EC encode,
journal digests, framing) shares ONE interpreter, so aggregate daemon
CPU is GIL-capped.  `ProcessShardedStore` keeps the exact same router
+ 2PC leader machinery (it IS a `ShardedStore`; `_make_shard` is the
only construction hook it overrides) but each shard becomes a worker
PROCESS owning a full `InfiniStore` — its own interpreter, client
daemon, writeback writer, and `SpillJournal` under
`<spill_dir>/shard-<i>/` — over one shared disk-backed COS root.  The
real InfiniStore runs its client<->proxy split as separate processes
over sockets (ports 6378/6379); this is that architecture with the
sockets replaced by something faster.

Data plane (`repro.core.ipc.ShmArena`): each worker gets a request
ring and a response ring in `multiprocessing.shared_memory`.  A PUT
payload is bulk-copied once into the request ring by the caller; the
worker maps a *writable* numpy view over the slot and submits it —
`InfiniStore._snapshot_value` copies writable buffers synchronously at
submission, so the store owns a private copy the moment the RPC is
dispatched and the slot is released immediately (watermarks ride the
control pipe).  No per-chunk pickling, no payload on the pipe.  GET
results travel the response ring the same way, packed by the worker's
daemon callbacks in send order.

Control plane: one duplex `Pipe` per worker carries framed tuples
`(op, rid, payload)` / `("ok"|"val"|"err"|"rel", rid, value)` — invokes,
2PC prepare/commit/abort rounds (prepared batches are held worker-side
and named by their prepare rid), flush barriers, stats snapshots, and
health.  A per-worker reader thread multiplexes the pipe with the
process sentinel (`multiprocessing.connection.wait`), so a SIGKILLed
worker fails its in-flight futures with `ShardWorkerDied` instead of
hanging them, and the survivors keep serving.

Both planes live behind `repro.core.transport.ShardTransport`: the
pipe+arena path above is `LocalTransport` (the default, fastest on one
box), and `transport="tcp"` swaps in `TcpTransport` + the
`repro.core.netshard` worker — framed sockets with heartbeat failure
detection, per-RPC deadlines, epoch-fenced reconnect, and
deterministic `net.*` fault injection (the real InfiniStore's
client<->proxy socket split, made partition-tolerant).

Crash semantics become REAL here: `simulate_crash(shard=i)` sends
SIGKILL, `restart_shard(i)` spawns a fresh worker whose `InfiniStore`
constructor replays the shard's spill journal, and the inherited
`resolve_indoubt` sweep settles any 2PC ticket the kill stranded.
Fault plans serialize into workers (each process owns an independent
deterministic copy; leader sites keep firing in the parent).

Lifecycle hygiene: `close()` runs the close RPC on every worker in
parallel under one shared deadline, then joins each process and
escalates join -> terminate -> kill; a `weakref.finalize` + module
`atexit` hook reaps abandoned stores so no worker process or /dev/shm
segment outlives the parent.  Workers are daemonic besides — the
interpreter will not exit leaving them behind.
"""
from __future__ import annotations

import atexit
import dataclasses
import logging
import os
import shutil
import signal
import tempfile
import threading
import time
import weakref
import itertools
import multiprocessing as mp
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional

import numpy as np

from repro.obs import FlightRecorder

from .clock import Clock
from .ipc import ArenaBroken, ShmArena, desc_watermark, pack_payload, \
    unpack_payload
from .locks import make_lock
from .shard import ShardedStore
from .store import InfiniStore, StoreStats
from .transport import (HeartbeatConfig, LocalTransport, ShardTransport,
                        ShardWorkerDied, TcpTransport)
from .writeback import StoreFuture

__all__ = ["ProcessShardedStore", "ShardWorkerDied",
           "DEFAULT_ARENA_BYTES"]

_LOG = logging.getLogger("repro.host")

MB = 1024 * 1024
DEFAULT_ARENA_BYTES = 64 * MB


# ---------------------------------------------------------------------------
# worker process side
# ---------------------------------------------------------------------------

def _swallow(fn, *args):
    try:
        return fn(*args)
    except Exception:                                 # noqa: BLE001
        return None


def _portable_exc(e: BaseException) -> BaseException:
    """Best-effort picklable form of a worker-side exception."""
    import pickle
    try:
        pickle.loads(pickle.dumps(e))
        return e
    except Exception:                                 # noqa: BLE001
        return RuntimeError(f"{type(e).__name__}: {e}")


def _worker_main(spec: dict) -> None:
    """Entry point of one shard worker process."""
    # the parent handles ^C; an interactive SIGINT must not tear the
    # worker down mid-journal-write before the parent's close sequence
    try:
        signal.signal(signal.SIGINT, signal.SIG_IGN)
    except (ValueError, OSError):                     # pragma: no cover
        pass
    conn = spec["conn"]
    send_lock = make_lock("host._worker_main.send_lock")

    def send(msg) -> None:
        with send_lock:
            try:
                # lint: allow(blocking-under-lock): send_lock exists to serialize exactly this pipe write
                conn.send(msg)
            except (OSError, ValueError, BrokenPipeError):
                pass                 # parent gone: nothing left to tell

    req = resp = None
    try:
        req = ShmArena.attach(spec["req_name"], spec["arena_bytes"])
        resp = ShmArena.attach(spec["resp_name"], spec["arena_bytes"])
        store = InfiniStore(spec["cfg"], clock=Clock(),
                            cos_root=spec["cos_root"],
                            seed=spec["seed"], name=spec["name"])
        # benchmarks model COS latency with attributes on the COS
        # object; each worker owns its own COS, so the model is shipped
        # in the spec and applied before "ready"
        for attr, val in spec.get("cos_latency", {}).items():
            setattr(store.cos, attr, val)
        if store.obs is not None:
            # shm workers have no reconnect epochs; pin epoch 1 so
            # flight records are attributable like the TCP worker's
            store.obs.set_epoch(1)
    except BaseException as e:                        # noqa: BLE001
        send(("err", -1, _portable_exc(e)))
        return
    # "ready" only after construction: journal replay is included, so
    # the parent's restart_shard timing covers the real recovery cost
    send(("ok", -1, os.getpid()))
    loop = _WorkerLoop(store, conn, req, resp, send)
    try:
        loop.run()
    finally:
        loop.shutdown()
        for a in (req, resp):
            try:
                a.close()
            except Exception:                         # noqa: BLE001
                pass


class _WorkerLoop:
    """The worker's dispatch loop: recv ops from the pipe, submit them
    to the store's async surface, reply from future callbacks. The loop
    thread NEVER blocks on a store future — a GET callback waiting for
    response-ring space needs the loop alive to process release
    watermarks."""

    def __init__(self, store: InfiniStore, conn, req: ShmArena,
                 resp: ShmArena, send) -> None:
        self.store = store
        self.conn = conn
        self.req = req
        self.resp = resp
        self.send = send
        # blocking ops (flush barriers, gc ticks, close) leave the loop
        self.aux = ThreadPoolExecutor(max_workers=2,
                                      thread_name_prefix="shard-host-aux")
        self.preps: Dict[int, object] = {}   # prepare rid -> prepared
        self.resp_lock = make_lock("host._WorkerLoop.resp_lock")    # resp pack+send = one unit
        self._last_rel = 0

    def run(self) -> None:
        # shutdown must not depend on pipe EOF: the parent sends an
        # explicit "bye" from reap(), and a ppid watchdog catches a
        # parent that died without one (SIGKILLed host) — EOF delivery
        # on the control socket has proven unreliable once the full
        # store (arenas + forkserver) is attached
        ppid = os.getppid()
        while True:
            try:
                if not self.conn.poll(1.0):
                    if os.getppid() != ppid:
                        return       # parent died: exit
                    continue
                msg = self.conn.recv()
            except (EOFError, OSError):
                return               # parent closed (or died): exit
            op, rid, p = msg
            if op == "bye":
                return               # parent is reaping us: exit now
            if op == "release":
                self.resp.release_to(p)
                continue
            try:
                self.dispatch(op, rid, p)
            except BaseException as e:                # noqa: BLE001
                self.send(("err", rid, _portable_exc(e)))

    def shutdown(self) -> None:
        self.aux.shutdown(wait=False)

    # -- request-ring bookkeeping ------------------------------------------

    def _consumed(self, wm: int) -> None:
        """Ack request-ring bytes: by the time an *_async call returned,
        the store snapshot-copied every writable arena view, so the
        parent may reuse the slot. Alloc order == pipe order == dispatch
        order, so the watermark is monotonic."""
        if wm > self._last_rel:
            self._last_rel = wm
            self.send(("rel", 0, wm))

    def _unpack(self, desc):
        """Materialize one request payload descriptor. The shm loop
        maps arena slots; `netshard._NetWorkerLoop` overrides this to
        map frame-offset descriptors instead — dispatch is shared."""
        return unpack_payload(self.req, desc)

    def _unpack_items(self, items_desc):
        return [(k, self._unpack(d)) for k, d in items_desc]

    # -- replies -----------------------------------------------------------

    def _reply_done(self, rid: int, fut: StoreFuture) -> None:
        def cb(f):
            try:
                v = f.result()
            except BaseException as e:                # noqa: BLE001
                self.send(("err", rid, _portable_exc(e)))
                return
            self.send(("ok", rid, v))
        fut.add_done_callback(cb)

    def _pack_result(self, v):
        if v is None:
            return ("n",)
        return pack_payload(self.resp, v)

    def _reply_value(self, rid: int, fut: StoreFuture) -> None:
        """GET reply: pack the payload into the response ring and send,
        as ONE unit under resp_lock — ring order must equal send order,
        or the parent's monotonic release watermark could free a slot
        whose reply is still in flight."""
        def cb(f):
            try:
                v = f.result()
            except BaseException as e:                # noqa: BLE001
                self.send(("err", rid, _portable_exc(e)))
                return
            try:
                with self.resp_lock:
                    d = self._pack_result(v)
                    self.send(("val", rid, d))
            except BaseException as e:                # noqa: BLE001
                self.send(("err", rid, _portable_exc(e)))
        fut.add_done_callback(cb)

    def _reply_map(self, rid: int, fut: StoreFuture) -> None:
        def cb(f):
            try:
                out = f.result()
            except BaseException as e:                # noqa: BLE001
                self.send(("err", rid, _portable_exc(e)))
                return
            try:
                with self.resp_lock:
                    d = {k: self._pack_result(v) for k, v in out.items()}
                    self.send(("val", rid, d))
            except BaseException as e:                # noqa: BLE001
                self.send(("err", rid, _portable_exc(e)))
        fut.add_done_callback(cb)

    def _reply_sync(self, rid: int, fn) -> None:
        try:
            self.send(("ok", rid, fn()))
        except BaseException as e:                    # noqa: BLE001
            self.send(("err", rid, _portable_exc(e)))

    # -- dispatch ----------------------------------------------------------

    def dispatch(self, op: str, rid: int, p) -> None:
        # trace envelope: _ShardProxy._rpc wraps the payload when the
        # parent has an ambient span; adopting it here means every span
        # the store opens below stitches into the parent's trace
        if type(p) is tuple and len(p) == 3 and p[0] == "_tctx":
            _, tctx, p = p
            obs = self.store.obs
            if obs is not None:
                with obs.adopt(tctx):
                    self._dispatch(op, rid, p)
                return
        self._dispatch(op, rid, p)

    def _dispatch(self, op: str, rid: int, p) -> None:  # noqa: C901
        store = self.store
        if op == "put":
            key, desc = p
            fut = store.put_async(key, self._unpack(desc))
            self._consumed(desc_watermark([desc]))
            self._reply_done(rid, fut)
        elif op == "put_many":
            items_desc, roc = p
            fut = store.put_many_async(self._unpack_items(items_desc),
                                       raise_on_conflict=roc)
            self._consumed(desc_watermark([d for _, d in items_desc]))
            self._reply_done(rid, fut)
        elif op == "prepare":
            items_desc, roc, ticket = p
            fut = store.prepare_put_many_async(
                self._unpack_items(items_desc), raise_on_conflict=roc,
                ticket=ticket)
            self._consumed(desc_watermark([d for _, d in items_desc]))

            def on_prep(f):
                try:
                    prep = f.result()
                except BaseException as e:            # noqa: BLE001
                    self.send(("err", rid, _portable_exc(e)))
                    return
                self.preps[rid] = prep
                self.send(("ok", rid, rid))   # the handle IS the rid
            fut.add_done_callback(on_prep)
        elif op == "commit2pc":
            prep_rid, ticket = p
            prep = self.preps.pop(prep_rid)   # KeyError -> err -> sweep
            self._reply_done(rid, store.commit_put_many_async(
                prep, ticket=ticket))
        elif op == "abort2pc":
            prep = self.preps.pop(p)
            self._reply_done(rid, store.abort_put_many_async(prep))
        elif op == "get":
            self._reply_value(rid, store.get_async(p))
        elif op == "get_many":
            keys, as_arrays = p
            fut = store.get_many_arrays_async(keys) if as_arrays \
                else store.get_many_async(keys)
            self._reply_map(rid, fut)
        elif op == "flush":
            self.aux.submit(self._reply_sync, rid,
                            lambda: store.flush_writeback(timeout=p))
        elif op == "gc":
            self.aux.submit(self._reply_sync, rid, store.gc_tick)
        elif op == "close":
            self.aux.submit(self._reply_sync, rid,
                            lambda: store.close(flush=p))
        elif op == "indoubt":
            self._reply_done(rid, store.indoubt_tickets_async())
        elif op == "resolve":
            ticket, commit = p
            self._reply_done(rid, store.resolve_indoubt(ticket,
                                                        commit=commit))
        elif op == "stats":
            self._reply_sync(rid, lambda: store.stats.as_dict())
        elif op == "obs":
            self._reply_sync(rid, store.snapshot_metrics)
        elif op == "snapshot":
            self._reply_sync(rid, store.snapshot_metadata)
        elif op == "cos_keys":
            self._reply_sync(rid, lambda: store.cos_keys(p))
        elif op == "balance":
            self._reply_sync(rid, store.balance_count)
        elif op == "ledger":
            self._reply_sync(rid, store.ledger_dollars)
        elif op == "nfuncs":
            self._reply_sync(rid, lambda: store.num_functions(p))
        elif op == "pause_wb":
            self._reply_sync(rid, store.pause_writeback)
        elif op == "resume_wb":
            self._reply_sync(rid, store.resume_writeback)
        else:
            raise ValueError(f"unknown host op {op!r}")


# ---------------------------------------------------------------------------
# parent side: per-worker proxy with the InfiniStore shard surface
# ---------------------------------------------------------------------------

_USE_DEFAULT = object()              # _rpc deadline sentinel


class _ShardProxy:
    """Parent-side handle for one worker, implementing the slice of
    the `InfiniStore` surface that `ShardedStore` (and the conformance
    suite) drives — every call becomes an RPC over a `ShardTransport`
    (pipe + shared-memory rings, or framed TCP with heartbeats and
    epoch fencing; see `repro.core.transport`).

    Locking: `_order_lock` makes (pack payload -> assign rid -> send)
    atomic, which pins staging order == wire order (the shm worker's
    release watermark and the TCP frame offsets both depend on it).
    The transport delivers replies on its reader thread via
    `_on_message`, failure via `_on_down`, recovery via
    `_on_reconnect`, and a periodic `_on_tick` that expires per-RPC
    deadlines."""

    def __init__(self, *, ctx, shard_id: int, cfg, cos_root: str,
                 seed: int, name: str, arena_bytes: int,
                 resources: "_HostResources",
                 boot_timeout_s: float,
                 cos_latency: Optional[dict] = None,
                 transport: str = "shm",
                 heartbeat: Optional[HeartbeatConfig] = None,
                 faults=None,
                 obs=None,
                 on_reconnect=None) -> None:
        self.shard_id = shard_id
        self.name = name
        self._obs = obs              # parent-side plane (may be None)
        self.spill_dir = cfg.spill_dir
        self._order_lock = make_lock("host._ShardProxy._order_lock")
        self._state_lock = make_lock("host._ShardProxy._state_lock")
        self._rids = itertools.count(1)
        self._inflight: Dict[int, tuple] = {}
        self._alive = False
        self._closing = False
        self._expected_death = False
        self._stats_cache = StoreStats()
        self._resources = resources
        # WEAK ref: proxies are pinned by the module-global orphan
        # registry; a bound-method callback would pin the whole store
        # and defeat the abandoned-store finalizer
        self._reconnect_cb = None if on_reconnect is None \
            else weakref.WeakMethod(on_reconnect)
        self.pid: Optional[int] = None
        spec = {"cfg": cfg, "cos_root": cos_root, "seed": seed,
                "name": name, "cos_latency": dict(cos_latency or {})}
        if transport == "tcp":
            self._t: ShardTransport = TcpTransport(
                shard_id=shard_id, ctx=ctx, spec=spec,
                hb=heartbeat or HeartbeatConfig(),
                boot_timeout_s=boot_timeout_s, faults=faults,
                seed=seed + shard_id)
        elif transport == "shm":
            self._t = LocalTransport(
                ctx=ctx, shard_id=shard_id, spec=spec,
                arena_bytes=arena_bytes, boot_timeout_s=boot_timeout_s)
        else:
            raise ValueError(f"unknown shard transport {transport!r}")
        self._t.obs = obs            # heartbeat/reconnect instrumentation
        resources.register(self)
        try:
            self.pid = self._t.start(on_message=self._on_message,
                                     on_down=self._on_down,
                                     on_reconnect=self._on_reconnect,
                                     on_tick=self._on_tick)
        except BaseException:
            self.reap()
            raise
        self._alive = True

    # -- transport callbacks -----------------------------------------------

    def _on_message(self, msg) -> None:
        kind, rid, val = msg
        with self._state_lock:
            ent = self._inflight.pop(rid, None)
        if ent is None:
            return                   # deadline-expired / failed at down
        fut, post, _op, _dl = ent
        if kind == "err":
            fut.set_exception(val if isinstance(val, BaseException)
                              else RuntimeError(str(val)))
            return
        if kind == "val":
            try:
                v, wm = post(val)
            except BaseException as e:                # noqa: BLE001
                fut.set_exception(e)
                return
            if wm:
                self._t.ack_reply(wm)
            fut._resolve(v)
            return
        fut._resolve(post(val) if post is not None else val)

    def _on_down(self, exc: BaseException) -> None:
        with self._state_lock:
            was_alive = self._alive
            self._alive = False
            pending = list(self._inflight.values())
            self._inflight.clear()
            quiet = self._closing or self._expected_death
        for fut, _post, _op, _dl in pending:
            if not fut.done():
                fut.set_exception(exc)
        if was_alive and not quiet:
            _LOG.warning("shard %d worker (pid %s) unreachable with "
                         "%d RPCs in flight: %s", self.shard_id,
                         self.pid, len(pending), exc)

    def _on_reconnect(self, epoch: int) -> None:
        with self._state_lock:
            if self._closing:
                return
            self._alive = True
        cb = None if self._reconnect_cb is None \
            else self._reconnect_cb()
        if cb is not None:
            cb(self.shard_id, epoch)

    def _on_tick(self) -> None:
        """Expire per-RPC deadlines: a reply lost to a drop or a silent
        partition fails fast instead of waiting for the detector."""
        now = time.monotonic()
        expired = []
        with self._state_lock:
            for rid, (fut, _post, op, dl) in list(self._inflight.items()):
                if dl is not None and now > dl:
                    expired.append((fut, op))
                    del self._inflight[rid]
        for fut, op in expired:
            if not fut.done():
                fut.set_exception(ShardWorkerDied(
                    f"shard {self.shard_id} rpc {op!r} missed its "
                    "reply deadline", shard_id=self.shard_id,
                    epoch=self._t.epoch, op=op))

    # -- RPC plumbing ------------------------------------------------------

    def _rpc(self, op: str, payload=None, *, pack=None, post=None,
             deadline_s=_USE_DEFAULT) -> StoreFuture:
        fut = StoreFuture()
        obs = self._obs
        tctx = obs.ctx() if obs is not None else None
        t0 = time.perf_counter() if obs is not None else 0.0
        with self._order_lock:
            rid = None
            try:
                if pack is not None:
                    payload = pack()
                if tctx is not None:
                    # trace envelope: the worker loop unwraps + adopts
                    # it, stitching worker spans into the parent trace
                    payload = ("_tctx", tctx, payload)
                with self._state_lock:
                    if not self._alive:
                        raise ShardWorkerDied(
                            f"shard {self.shard_id} worker is down",
                            shard_id=self.shard_id,
                            epoch=self._t.epoch, op=op)
                    rid = next(self._rids)
                    dls = self._t.default_rpc_deadline() \
                        if deadline_s is _USE_DEFAULT else deadline_s
                    dl = None if dls is None \
                        else time.monotonic() + dls
                    self._inflight[rid] = (fut, post, op, dl)
                # lint: allow(blocking-under-lock): _order_lock must span staging and send so ring order equals wire order
                self._t.send((op, rid, payload))
            except BaseException as e:
                # failed before the frame left: unstage its payloads
                # (next frame's offsets must start clean) and unregister
                self._t.discard_staged()
                if rid is not None:
                    with self._state_lock:
                        self._inflight.pop(rid, None)
                if isinstance(e, ArenaBroken):
                    raise ShardWorkerDied(
                        str(e), shard_id=self.shard_id,
                        epoch=self._t.epoch, op=op) from e
                raise
        if obs is not None:
            def _timed(_f, obs=obs, t0=t0):
                obs.record("rpc.roundtrip_us",
                           (time.perf_counter() - t0) * 1e6)
            fut.add_done_callback(_timed)
        return fut

    def _pack_items(self, items) -> List[tuple]:
        items = list(items.items()) if isinstance(items, dict) \
            else list(items)
        return [(k, self._t.pack(v)) for k, v in items]

    def _post_value(self, as_array: bool):
        def post(desc):
            if desc[0] == "n":
                return None, 0
            if desc[0] == "i":
                raw = desc[1]
                if as_array:
                    v = np.frombuffer(raw, dtype=np.uint8)
                    return v, 0
                return raw, 0
            _, pos, n = desc
            view = self._t.reply_view(pos, n)
            if as_array:
                v = view.copy()
                v.flags.writeable = False
            else:
                v = bytes(view)
            return v, pos + n
        return post

    def _post_map(self, as_arrays: bool):
        one = self._post_value(as_arrays)

        def post(dmap):
            out, wm = {}, 0
            for k, d in dmap.items():
                v, w = one(d)
                out[k] = v
                wm = max(wm, w)
            return out, wm
        return post

    # -- the shard surface -------------------------------------------------

    def put_async(self, key: str, value) -> StoreFuture:
        return self._rpc(
            "put", pack=lambda: (key, self._t.pack(value)))

    def put(self, key: str, value) -> int:
        return self.put_async(key, value).result()

    def put_many_async(self, items, *,
                       raise_on_conflict: bool = False) -> StoreFuture:
        return self._rpc(
            "put_many",
            pack=lambda: (self._pack_items(items), raise_on_conflict))

    def put_many(self, items, *,
                 raise_on_conflict: bool = False) -> Dict[str, int]:
        return self.put_many_async(
            items, raise_on_conflict=raise_on_conflict).result()

    def prepare_put_many_async(self, items, *,
                               raise_on_conflict: bool = False,
                               ticket: Optional[int] = None
                               ) -> StoreFuture:
        return self._rpc(
            "prepare",
            pack=lambda: (self._pack_items(items), raise_on_conflict,
                          ticket))

    def commit_put_many_async(self, prep, *,
                              ticket: Optional[int] = None) -> StoreFuture:
        return self._rpc("commit2pc", (prep, ticket))

    def abort_put_many_async(self, prep) -> StoreFuture:
        return self._rpc("abort2pc", prep)

    def get_async(self, key: str) -> StoreFuture:
        return self._rpc("get", key, post=self._post_value(False))

    def get(self, key: str) -> Optional[bytes]:
        return self.get_async(key).result()

    def get_array(self, key: str) -> Optional[np.ndarray]:
        return self._rpc("get", key,
                         post=self._post_value(True)).result()

    def get_many_async(self, keys) -> StoreFuture:
        return self._rpc("get_many", (list(keys), False),
                         post=self._post_map(False))

    def get_many(self, keys) -> Dict[str, Optional[bytes]]:
        return self.get_many_async(keys).result()

    def get_many_arrays_async(self, keys) -> StoreFuture:
        return self._rpc("get_many", (list(keys), True),
                         post=self._post_map(True))

    def get_many_arrays(self, keys) -> Dict[str, Optional[np.ndarray]]:
        return self.get_many_arrays_async(keys).result()

    def flush_async(self, timeout: Optional[float] = None) -> StoreFuture:
        # barrier op: legitimately outlives any per-RPC deadline
        return self._rpc("flush", timeout, deadline_s=None)

    def flush_writeback(self, timeout: Optional[float] = None) -> bool:
        try:
            return self.flush_async(timeout).result()
        except ConnectionError:
            return False             # dead worker: writes NOT persisted

    def gc_tick(self) -> None:
        try:
            self._rpc("gc", deadline_s=None).result()
        except ConnectionError:
            pass                     # dead shard: restart_shard re-GCs

    def indoubt_tickets(self) -> List[int]:
        return self._rpc("indoubt").result()

    def resolve_indoubt(self, ticket: int, *, commit: bool) -> StoreFuture:
        return self._rpc("resolve", (ticket, commit))

    def cos_keys(self, prefix: str = "") -> List[str]:
        try:
            return self._rpc("cos_keys", prefix).result()
        except ConnectionError:
            return []

    def balance_count(self) -> int:
        try:
            return self._rpc("balance").result()
        except ConnectionError:
            return 0

    def ledger_dollars(self) -> Dict[str, float]:
        try:
            return self._rpc("ledger").result()
        except ConnectionError:
            return {}

    def num_functions(self, state=None) -> int:
        try:
            return self._rpc("nfuncs", state).result()
        except ConnectionError:
            return 0

    def pause_writeback(self) -> None:
        self._rpc("pause_wb").result()

    def resume_writeback(self) -> None:
        self._rpc("resume_wb").result()

    @property
    def stats(self) -> StoreStats:
        try:
            snap = StoreStats(**self._rpc("stats").result())
        except (ConnectionError, TypeError):
            return self._stats_cache  # dead: last known counters
        self._stats_cache = snap
        return snap

    def snapshot_metadata(self):
        try:
            snap = self._rpc("snapshot").result()
        except ConnectionError:
            # DOWN here covers heartbeat timeout and partition, not
            # only process death: the transport refuses the RPC the
            # moment the detector declares the worker unreachable
            return {"mt": {}, "chunk_map": {},
                    "health": {"state": "SHARD_DOWN",
                               "indoubt_tickets": [],
                               "writeback": None, "spill_pending": 0,
                               "transport": self._t.health()},
                    "shard_down": True}
        snap["health"]["transport"] = self._t.health()
        return snap

    def transport_health(self) -> dict:
        """Per-shard transport state: CONNECTED/SUSPECT/DOWN/
        RECONNECTING, current epoch, last-heartbeat age."""
        return self._t.health()

    def transport_stats(self) -> dict:
        """Worker-side fencing counters (TCP only): fenced connects,
        stale acks suppressed, duplicate frames dropped."""
        if self._t.kind != "tcp":
            return {}
        return self._rpc("xstats").result()

    def snapshot_metrics(self) -> dict:
        """The worker's ObsPlane snapshot ({} when the worker is down
        or was built without a plane)."""
        try:
            return self._rpc("obs").result() or {}
        except ConnectionError:
            return {}

    # -- lifecycle ---------------------------------------------------------

    def is_alive(self) -> bool:
        with self._state_lock:
            return self._alive

    def simulate_crash(self) -> Optional[str]:
        """REAL kill: SIGKILL the worker mid-flight. Journal segments
        (and the shared COS root) survive on disk for restart_shard.
        Reconnect is suppressed FIRST — a TCP transport must not burn
        its attempt budget dialing a corpse."""
        with self._state_lock:
            self._expected_death = True
        self._t.suppress_reconnect()
        if self.pid is not None:
            try:
                os.kill(self.pid, signal.SIGKILL)
            except (ProcessLookupError, PermissionError):
                pass
        self._t.join(timeout=30.0)
        return self.spill_dir

    def request_close(self, flush: bool) -> Optional[StoreFuture]:
        with self._state_lock:
            self._closing = True
        self._t.suppress_reconnect()
        try:
            return self._rpc("close", flush, deadline_s=None)
        except ShardWorkerDied:
            return None

    def finish_close(self, fut: Optional[StoreFuture],
                     deadline: float) -> bool:
        ok = False
        if fut is not None:
            try:
                ok = fut.result(
                    timeout=max(0.1, deadline - time.monotonic()))
            except Exception:                         # noqa: BLE001
                ok = False
        self.reap(deadline=deadline)
        return ok

    def close(self, *, flush: bool = True) -> bool:
        deadline = time.monotonic() + 120.0
        return self.finish_close(self.request_close(flush), deadline)

    def reap(self, deadline: Optional[float] = None) -> None:
        """Tear down the worker and every parent-side transport
        resource (pipe + /dev/shm segments, or socket + heartbeat
        threads): escalating join -> terminate -> kill inside the
        transport. Idempotent; safe from finalizers and atexit."""
        with self._state_lock:
            self._closing = True
        self._t.reap(deadline=deadline)
        # fail any straggler futures (idempotent if _on_down already ran)
        self._on_down(ShardWorkerDied(
            f"shard {self.shard_id} worker reaped",
            shard_id=self.shard_id, epoch=self._t.epoch, op="reap"))
        self._resources.unregister(self)


# ---------------------------------------------------------------------------
# orphan reaping: finalizers + atexit
# ---------------------------------------------------------------------------

class _HostResources:
    """The set of live worker proxies of ONE store, shared with its
    `weakref.finalize` callback and the module atexit sweep — neither
    holds a reference back to the store, so an abandoned store is
    collectable and its workers/segments still get reaped."""

    def __init__(self) -> None:
        self._lock = make_lock("host._HostResources._lock")
        self._proxies: List[_ShardProxy] = []

    def register(self, p: _ShardProxy) -> None:
        with self._lock:
            self._proxies.append(p)
        with _REGISTRY_LOCK:
            if self not in _LIVE_RESOURCES:
                _LIVE_RESOURCES.append(self)

    def unregister(self, p: _ShardProxy) -> None:
        with self._lock:
            if p in self._proxies:
                self._proxies.remove(p)
            empty = not self._proxies
        if empty:
            with _REGISTRY_LOCK:
                if self in _LIVE_RESOURCES:
                    _LIVE_RESOURCES.remove(self)

    def reap_all(self) -> None:
        with self._lock:
            proxies = list(self._proxies)
        for p in proxies:
            try:
                p.reap()
            except Exception:                         # noqa: BLE001
                pass


_REGISTRY_LOCK = make_lock("host._REGISTRY_LOCK")
_LIVE_RESOURCES: List[_HostResources] = []


@atexit.register
def _reap_orphans() -> None:         # pragma: no cover - exit path
    with _REGISTRY_LOCK:
        resources = list(_LIVE_RESOURCES)
    for r in resources:
        r.reap_all()


# ---------------------------------------------------------------------------
# spawn context
# ---------------------------------------------------------------------------

_CTX_LOCK = make_lock("host._CTX_LOCK")
_CTX = None


def _host_context(method: Optional[str] = None):
    """Process-wide spawn context. Default: forkserver with this module
    preloaded — workers fork from a clean template that already
    imported numpy + the store stack (fast respawn, no inherited locks
    or threads), falling back to spawn where forkserver is unavailable."""
    global _CTX
    if method is not None:
        return mp.get_context(method)
    with _CTX_LOCK:
        if _CTX is None:
            try:
                ctx = mp.get_context("forkserver")
                ctx.set_forkserver_preload(["repro.core.host",
                                            "repro.core.netshard"])
            except ValueError:                        # pragma: no cover
                ctx = mp.get_context("spawn")
            _CTX = ctx
        return _CTX


# ---------------------------------------------------------------------------
# the store front-end
# ---------------------------------------------------------------------------

class ProcessShardedStore(ShardedStore):
    """`ShardedStore` whose shards are worker PROCESSES (module
    docstring). Same router, same 2PC leader, same `StoreFrontend`
    conformance — `_make_shard` swaps the in-process `InfiniStore` for
    a `_ShardProxy` over pipe + shared-memory rings.

    The COS root is forced onto disk (a private tempdir when the caller
    gave none): memory-backed COS cannot be shared across processes.
    The parent keeps its own `COS` over the same root for the 2PC
    leader's journal-less decision stubs, so every durable artifact the
    thread-mode store writes lands in the same places here."""

    def __init__(self, cfg=None, *, num_shards: int = 4,
                 router="hash", range_boundaries=None,
                 clock: Optional[Clock] = None,
                 cos_root: Optional[str] = None, seed: int = 0,
                 arena_bytes: int = DEFAULT_ARENA_BYTES,
                 start_method: Optional[str] = None,
                 boot_timeout_s: float = 120.0,
                 cos_latency: Optional[dict] = None,
                 transport: str = "shm",
                 heartbeat: Optional[HeartbeatConfig] = None):
        self._arena_bytes = int(arena_bytes)
        self._cos_latency = dict(cos_latency or {})
        self._boot_timeout_s = float(boot_timeout_s)
        self._transport_kind = transport
        self._heartbeat = heartbeat
        self._ctx = _host_context(start_method)
        self._cos_root_auto = cos_root is None
        if cos_root is None:
            cos_root = tempfile.mkdtemp(prefix="infinistore-cos-")
        self._cos_root_path = cos_root
        self._host_resources = _HostResources()
        self._finalizer = weakref.finalize(
            self, _HostResources.reap_all, self._host_resources)
        try:
            super().__init__(cfg, num_shards=num_shards, router=router,
                             range_boundaries=range_boundaries,
                             clock=clock, cos_root=cos_root, seed=seed)
        except BaseException:
            self._host_resources.reap_all()
            if self._cos_root_auto:
                shutil.rmtree(cos_root, ignore_errors=True)
            raise
        # the parent's COS view (leader decision stubs, direct reads)
        # follows the same latency model the workers were given
        for attr, val in self._cos_latency.items():
            setattr(self.cos, attr, val)

    # -- construction / restart hooks --------------------------------------

    def _make_shard(self, i: int) -> _ShardProxy:
        scfg = dataclasses.replace(self.cfg,
                                   spill_dir=self._shard_spill_dir(i))
        return _ShardProxy(ctx=self._ctx, shard_id=i, cfg=scfg,
                           cos_root=str(self.cos.root),
                           seed=self._seed + i, name=f"s{i}",
                           arena_bytes=self._arena_bytes,
                           resources=self._host_resources,
                           boot_timeout_s=self._boot_timeout_s,
                           cos_latency=self._cos_latency,
                           transport=self._transport_kind,
                           heartbeat=self._heartbeat,
                           faults=getattr(self.cfg, "faults", None),
                           obs=self.obs,
                           on_reconnect=self._shard_reconnected)

    def _shard_reconnected(self, shard_id: int, epoch: int) -> None:
        """Transport reconnected at a new epoch: any 2PC ticket the
        partition stranded is settled by the inherited sweep. Runs off
        the heartbeat thread — the sweep issues RPCs of its own."""
        if getattr(self, "_closed", False):
            return
        threading.Thread(
            target=lambda: _swallow(self.resolve_indoubt),
            name=f"reconnect-sweep-{shard_id}", daemon=True).start()

    def shard_transport_health(self) -> List[dict]:
        """Per-shard transport state (CONNECTED/SUSPECT/DOWN/
        RECONNECTING), current epoch, last-heartbeat age."""
        return [s.transport_health() for s in self.shards]

    # -- observability fan-in -----------------------------------------------

    def _shard_metric_snapshots(self) -> List[dict]:
        """Each live worker's ObsPlane snapshot (per-process histograms,
        spans, flight events) for `snapshot_metrics()` to merge."""
        return [snap for snap in
                (s.snapshot_metrics() for s in self.shards) if snap]

    def transport_metrics(self) -> dict:
        """Per-shard transport health + worker fencing counters, with
        store-wide totals (stale frames are counted on BOTH ends:
        parent reader and worker server)."""
        per: List[dict] = []
        totals = {"reconnects": 0, "fenced_connects": 0,
                  "stale_acks_suppressed": 0, "dup_frames_dropped": 0,
                  "stale_frames_dropped_client": 0,
                  "stale_frames_dropped_server": 0}
        for s in self.shards:
            h = s.transport_health()
            try:
                x = s.transport_stats()
            except ConnectionError:
                x = {}
            per.append({"shard": s.shard_id, "health": h, "xstats": x})
            totals["reconnects"] += h.get("reconnects") or 0
            totals["stale_frames_dropped_client"] += \
                h.get("stale_frames_dropped") or 0
            totals["fenced_connects"] += x.get("fenced_connects", 0)
            totals["stale_acks_suppressed"] += \
                x.get("stale_acks_suppressed", 0)
            totals["dup_frames_dropped"] += x.get("dup_frames_dropped", 0)
            totals["stale_frames_dropped_server"] += \
                x.get("stale_frames_dropped", 0)
        return {"per_shard": per, "totals": totals}

    def restart_shard(self, i: int) -> _ShardProxy:
        """Respawn shard i's worker: the old process (usually already
        SIGKILLed) is reaped — pipe closed, rings unlinked — and the
        fresh worker's `InfiniStore` replays `<spill>/shard-<i>/`
        before reporting ready; the inherited sweep then settles any
        ticket the kill left in doubt."""
        obs = self.obs
        if obs is not None:
            # recover the dead worker's flight file BEFORE the respawn
            # truncates it: its pre-kill events/spans become forensics
            path = os.path.join(self._shard_spill_dir(i), "flight.bin")
            records = FlightRecorder.read_file(path)
            if records:
                obs.add_forensics(f"shard-{i}", records, shard=i)
        self.shards[i].reap()
        return super().restart_shard(i)

    # -- crash / close -----------------------------------------------------

    def simulate_crash(self, shard: Optional[int] = None):
        out = super().simulate_crash(shard)
        if shard is None:
            # transports are parent-side state, not durable state: a
            # "crashed" store's rings and pipes have no replay value
            for s in self.shards:
                s.reap()
        return out

    def close(self, *, flush: bool = True,
              deadline_s: float = 120.0) -> bool:
        """Parallel close: every worker runs its close RPC (drain
        daemon, flush writeback) concurrently under ONE shared
        deadline, then each process is joined with what remains of it,
        escalating to terminate/kill — one stuck shard cannot hold the
        host hostage."""
        if self._closed:
            return True
        self._closed = True
        deadline = time.monotonic() + deadline_s
        self._leader.shutdown(wait=True)
        # Best-effort in-doubt sweep, BOUNDED: the sweep's RPCs have no
        # deadline of their own, so a wedged worker (stopped, livelocked)
        # must not park close() before the reaping even starts. Run it in
        # a side thread with a slice of the budget — once reap() marks a
        # dead shard, the thread's blocked future fails and it exits.
        sweeper = threading.Thread(
            target=lambda: _swallow(self.resolve_indoubt),
            name="host-close-sweep", daemon=True)
        sweeper.start()
        sweeper.join(timeout=min(30.0, max(0.2, deadline_s / 4.0)))
        reqs = [(s, s.request_close(flush)) for s in self.shards]
        oks = [s.finish_close(f, deadline) for s, f in reqs]
        if self._leader_spill is not None:
            self._leader_spill.close()
        self.cos.shutdown()
        if self._spill_auto:
            shutil.rmtree(self._spill_root, ignore_errors=True)
        if self._cos_root_auto:
            shutil.rmtree(self._cos_root_path, ignore_errors=True)
        self._finalizer.detach()
        return all(oks)

    # -- fan-out overrides tuned for cross-process latency ------------------

    def flush_writeback(self, timeout: Optional[float] = None) -> bool:
        """Parallel barrier: one flush RPC per worker, all draining
        concurrently against the caller's single shared deadline."""
        futs = []
        for s in self.shards:
            try:
                futs.append(s.flush_async(timeout))
            except ShardWorkerDied:
                futs.append(None)
        ok = True
        for f in futs:
            if f is None:
                ok = False
                continue
            try:
                ok = f.result() and ok
            except Exception:                         # noqa: BLE001
                ok = False
        return ok

    def cos_keys(self, prefix: str = "") -> List[str]:
        # a disk COS only lists keys the listing process has touched;
        # the union must include the parent's view (leader decision
        # stubs, pre-existing root contents)
        keys = set(super().cos_keys(prefix))
        keys.update(self.cos.list_keys(prefix))
        return sorted(keys)

    # -- introspection ------------------------------------------------------

    def worker_pids(self) -> List[Optional[int]]:
        return [s.pid for s in self.shards]

    def workers_alive(self) -> List[bool]:
        return [s.is_alive() for s in self.shards]
