"""Pay-per-access cost accounting (paper §3.1, §6.1.1, Figs. 10/11/22).

There is no per-invocation bill on a TPU pod, but the paper's cost model
is kept as an accounting model so the cost experiments reproduce: slab
invocations + busy GB-seconds map to Lambda pricing, COS ops/storage map
to S3 pricing. Categories follow Fig. 10: request (GET/PUT service),
warmup, recovery, COS.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

# AWS prices used by the paper (us-east-1, 2022)
LAMBDA_GBS = 0.0000166667          # $ per GB-second
LAMBDA_INVOKE = 0.02 / 1e6         # $ per invocation
S3_PUT = 0.005 / 1e3               # $ per PUT
S3_GET = 0.0004 / 1e3              # $ per GET
S3_GB_MONTH = 0.023                # $ per GB-month
SECONDS_PER_MONTH = 30 * 24 * 3600


@dataclass
class CostLedger:
    """Accumulates billable events by category."""
    gb_seconds: Dict[str, float] = field(
        default_factory=lambda: {"request": 0.0, "warmup": 0.0,
                                 "recovery": 0.0})
    invocations: Dict[str, int] = field(
        default_factory=lambda: {"request": 0, "warmup": 0, "recovery": 0})
    cos_puts: int = 0
    cos_gets: int = 0
    cos_gb_seconds: float = 0.0    # integrated storage (GB * seconds)
    _hourly: List[Dict[str, float]] = field(default_factory=list)

    # ---- event hooks ------------------------------------------------------

    def invoke(self, category: str, *, gb: float, seconds: float) -> None:
        self.invocations[category] = self.invocations.get(category, 0) + 1
        self.gb_seconds[category] = (self.gb_seconds.get(category, 0.0)
                                     + gb * seconds)

    def cos_op(self, op: str, n: int = 1) -> None:
        if op == "put":
            self.cos_puts += n
        else:
            self.cos_gets += n

    def cos_storage(self, gb: float, seconds: float) -> None:
        self.cos_gb_seconds += gb * seconds

    # ---- dollars ------------------------------------------------------------

    def dollars(self) -> Dict[str, float]:
        out = {}
        for cat in self.gb_seconds:
            out[cat] = (self.gb_seconds[cat] * LAMBDA_GBS
                        + self.invocations.get(cat, 0) * LAMBDA_INVOKE)
        out["cos"] = (self.cos_puts * S3_PUT + self.cos_gets * S3_GET
                      + self.cos_gb_seconds / SECONDS_PER_MONTH * S3_GB_MONTH)
        out["total"] = sum(out.values())
        return out

    def pay_per_access_overhead(self) -> float:
        """Paper's metric: (recovery + warmup) / (request + COS) — the cost
        of durability maintenance relative to access+storage cost
        (26.00% for InfiniStore vs 106.51% for InfiniCache)."""
        d = self.dollars()
        denom = d["request"] + d["cos"]
        if denom <= 0:
            return 0.0
        return (d["recovery"] + d["warmup"]) / denom

    def checkpoint_hour(self) -> None:
        self._hourly.append(self.dollars())

    @property
    def hourly(self) -> List[Dict[str, float]]:
        return list(self._hourly)


def elasticache_cost(instance_hourly: float, n_instances: int,
                     hours: float) -> float:
    """Statically-provisioned baseline cost (Fig. 11)."""
    return instance_hourly * n_instances * hours


# Paper's comparison clusters (§6.1.1)
ELASTICACHE_R6G_2XLARGE_HOURLY = 0.821   # cache.r6g.2xlarge
ELASTICACHE_M6G_LARGE_HOURLY = 0.147     # cache.m6g.large
