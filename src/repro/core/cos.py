"""Cloud Object Store (COS): the persistence layer (paper §5.2, §5.5).

Backends: in-memory dict (tests/benchmarks) or a directory on disk
(checkpointing). Eventual consistency is SIMULATED via a configurable
visibility lag: a newly PUT object/version only becomes readable after
`visibility_lag` clock time, which is exactly the behaviour the
SCFS-style consistency-increasing GET loop (Appendix A) must mask.

Concurrency: `self._lock` guards ONLY metadata (visibility map, the
in-memory dict, stats) — file I/O happens outside it, so one slow disk
write no longer serializes every other COS operation. Disk writes go to
a uniquely-named temp file and `os.replace` in atomically; visibility is
flipped only after the write lands, so readers never observe a visible
key with a half-written object.

Payloads may be `bytes` or flat uint8 `ndarray` views (the zero-copy
writeback path); the mem backend stores whatever it is handed.

`put_delay_base_s` / `put_delay_per_byte_s` optionally model real
object-store PUT latency (S3-like: ~tens of ms + bandwidth) for
benchmarks that compare sync-ack vs async-writeback PUT paths;
`get_delay_base_s` / `get_delay_per_byte_s` are the GET-side mirror
(first-byte latency + per-connection bandwidth) for benchmarks that
compare serial vs fanned-out demand reads. The sleeps happen outside
the metadata lock, so concurrent GETs overlap — exactly the property
the pipelined read path exploits.
"""
from __future__ import annotations

import hashlib
import os
import threading
import time
import uuid
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Optional, Tuple

from repro.core.clock import Clock
from repro.core.payload import payload_nbytes

# Negative-lookup cache bounds for the daemon-restart disk-adoption
# probe: absent keys are re-stat'd at most once per TTL (wall time), so
# an external writer sharing cos_root is seen within a TTL; the map is
# capped so miss-heavy scans cannot grow it without bound.
NEG_PROBE_TTL_S = 1.0
NEG_PROBE_CAP = 65536


@dataclass
class COSStats:
    puts: int = 0
    gets: int = 0
    get_misses: int = 0
    bytes_in: int = 0
    bytes_out: int = 0

    @property
    def stored_ops(self) -> Tuple[int, int]:
        return self.puts, self.gets


class COS:
    def __init__(self, clock: Clock, *, visibility_lag: float = 0.0,
                 root: Optional[str] = None, workers: int = 8,
                 put_delay_base_s: float = 0.0,
                 put_delay_per_byte_s: float = 0.0,
                 get_delay_base_s: float = 0.0,
                 get_delay_per_byte_s: float = 0.0):
        self.clock = clock
        self.visibility_lag = visibility_lag
        self.root = Path(root) if root else None
        if self.root:
            self.root.mkdir(parents=True, exist_ok=True)
        self._mem: Dict[str, bytes] = {}
        self._visible_at: Dict[str, float] = {}
        # key -> clock time of a daemon-restart disk probe that found it
        # absent: the adoption check stats the filesystem at most once
        # per key per NEG_PROBE_TTL_S, so hot miss loops (consistency-
        # increasing GET retries, visibility-lag polls) don't hit the
        # disk under the lock on every poll. Entries expire (another
        # process may share cos_root and write the key later) and the
        # map is capped (miss-heavy scans must not leak); this process's
        # own put() clears its entry immediately.
        self._probed_absent: Dict[str, float] = {}
        self._lock = threading.RLock()
        self.stats = COSStats()
        # optional FaultPlan (repro.core.faults); None = zero-cost no-op.
        # Injected faults fire BEFORE any state change, modelling a
        # request that never reached the service.
        self.faults = None
        self.put_delay_base_s = put_delay_base_s
        self.put_delay_per_byte_s = put_delay_per_byte_s
        self.get_delay_base_s = get_delay_base_s
        self.get_delay_per_byte_s = get_delay_per_byte_s
        self._pool = ThreadPoolExecutor(max_workers=workers,
                                        thread_name_prefix="cos")

    # ---- sync API -------------------------------------------------------

    def _path(self, key: str) -> Path:
        h = hashlib.sha1(key.encode()).hexdigest()
        return self.root / h[:2] / h[2:]

    def _adopt_locked(self, key: str) -> Optional[float]:
        """Daemon-restart path (caller holds the lock): an object this
        process never put may still exist on disk, persisted by a
        previous process — its put predates this one, so any visibility
        lag has long elapsed; adopt it as visible. The disk probe runs
        at most once per absent key per TTL (see `_probed_absent`)."""
        if self.root is None:
            return None
        # TTL on wall time, NOT self.clock: the logical clock only moves
        # when a test advances it, which would freeze the TTL and hide
        # an external writer's key forever.
        now = time.monotonic()
        probed = self._probed_absent.get(key)
        if probed is not None and now - probed < NEG_PROBE_TTL_S:
            return None
        if self._path(key).exists():
            self._probed_absent.pop(key, None)
            vis = self.clock.now()
            self._visible_at[key] = vis
            return vis
        if len(self._probed_absent) >= NEG_PROBE_CAP:
            self._probed_absent.clear()
        self._probed_absent[key] = now
        return None

    def put(self, key: str, data) -> None:
        if self.faults is not None:
            self.faults.fire("cos.put", key)
        n = payload_nbytes(data)
        if self.put_delay_base_s or self.put_delay_per_byte_s:
            time.sleep(self.put_delay_base_s + n * self.put_delay_per_byte_s)
        if self.root:
            # write outside the lock; unique temp name so concurrent puts
            # of the same key can't clobber each other's staging file
            p = self._path(key)
            p.parent.mkdir(parents=True, exist_ok=True)
            tmp = p.with_name(f"{p.name}.{uuid.uuid4().hex}.tmp")
            with open(tmp, "wb") as f:
                f.write(data)
            os.replace(tmp, p)
        with self._lock:
            self.stats.puts += 1
            self.stats.bytes_in += n
            if not self.root:
                self._mem[key] = data
            self._probed_absent.pop(key, None)
            self._visible_at[key] = self.clock.now() + self.visibility_lag

    def get(self, key: str):
        if self.faults is not None:
            self.faults.fire("cos.get", key)
        if self.get_delay_base_s:
            time.sleep(self.get_delay_base_s)     # first-byte latency
        with self._lock:
            self.stats.gets += 1
            vis = self._visible_at.get(key)
            if vis is None:
                vis = self._adopt_locked(key)
            if vis is None or self.clock.now() < vis:
                self.stats.get_misses += 1
                return None
            data = None if self.root else self._mem.get(key)
        if self.root:
            # disk read outside the lock; a concurrent delete makes this
            # a miss, same as observing the delete first
            try:
                data = self._path(key).read_bytes()
            except FileNotFoundError:
                data = None
        if data is None:
            with self._lock:
                self.stats.get_misses += 1
            return None
        if self.get_delay_per_byte_s:             # per-connection bandwidth
            time.sleep(payload_nbytes(data) * self.get_delay_per_byte_s)
        with self._lock:
            self.stats.bytes_out += payload_nbytes(data)
        return data

    def exists(self, key: str) -> bool:
        with self._lock:
            vis = self._visible_at.get(key)
            if vis is None:
                vis = self._adopt_locked(key)
            return vis is not None and self.clock.now() >= vis

    def delete(self, key: str) -> None:
        with self._lock:
            self._visible_at.pop(key, None)
            if not self.root:
                self._mem.pop(key, None)
        if self.root:
            p = self._path(key)
            if p.exists():
                p.unlink()

    def list_keys(self, prefix: str = "") -> list:
        """Keys this process has seen (put, or adopted by get()/exists()
        after a daemon restart). NOTE: the disk layout stores objects
        under hashed paths, so keys persisted by a PREVIOUS process are
        listable only once touched by key — by-key reads (GET data path,
        recovery manifests, journal replay) work regardless."""
        with self._lock:
            return sorted(k for k in self._visible_at if k.startswith(prefix))

    @property
    def stored_bytes(self) -> int:
        with self._lock:
            keys = list(self._visible_at)
            if not self.root:
                return sum(payload_nbytes(self._mem.get(k, b"")) for k in keys)
        return sum(self._path(k).stat().st_size
                   for k in keys if self._path(k).exists())

    # ---- async API (persistent-buffer path, §5.3.2) ----------------------

    def put_async(self, key: str, data) -> Future:
        return self._pool.submit(self.put, key, data)

    def get_async(self, key: str) -> Future:
        """Fan-out read on the COS worker pool (batched page restore /
        demand-read callers)."""
        return self._pool.submit(self.get, key)

    def shutdown(self) -> None:
        self._pool.shutdown(wait=True)
