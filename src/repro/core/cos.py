"""Cloud Object Store (COS): the persistence layer (paper §5.2, §5.5).

Backends: in-memory dict (tests/benchmarks) or a directory on disk
(checkpointing). Eventual consistency is SIMULATED via a configurable
visibility lag: a newly PUT object/version only becomes readable after
`visibility_lag` clock time, which is exactly the behaviour the
SCFS-style consistency-increasing GET loop (Appendix A) must mask.
"""
from __future__ import annotations

import hashlib
import os
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Optional, Tuple

from repro.core.clock import Clock


@dataclass
class COSStats:
    puts: int = 0
    gets: int = 0
    get_misses: int = 0
    bytes_in: int = 0
    bytes_out: int = 0

    @property
    def stored_ops(self) -> Tuple[int, int]:
        return self.puts, self.gets


class COS:
    def __init__(self, clock: Clock, *, visibility_lag: float = 0.0,
                 root: Optional[str] = None, workers: int = 8):
        self.clock = clock
        self.visibility_lag = visibility_lag
        self.root = Path(root) if root else None
        if self.root:
            self.root.mkdir(parents=True, exist_ok=True)
        self._mem: Dict[str, bytes] = {}
        self._visible_at: Dict[str, float] = {}
        self._lock = threading.RLock()
        self.stats = COSStats()
        self._pool = ThreadPoolExecutor(max_workers=workers,
                                        thread_name_prefix="cos")

    # ---- sync API -------------------------------------------------------

    def _path(self, key: str) -> Path:
        h = hashlib.sha1(key.encode()).hexdigest()
        return self.root / h[:2] / h[2:]

    def put(self, key: str, data: bytes) -> None:
        with self._lock:
            self.stats.puts += 1
            self.stats.bytes_in += len(data)
            self._visible_at[key] = self.clock.now() + self.visibility_lag
            if self.root:
                p = self._path(key)
                p.parent.mkdir(parents=True, exist_ok=True)
                tmp = p.with_suffix(".tmp")
                tmp.write_bytes(data)
                os.replace(tmp, p)
            else:
                self._mem[key] = bytes(data)

    def get(self, key: str) -> Optional[bytes]:
        with self._lock:
            self.stats.gets += 1
            vis = self._visible_at.get(key)
            if vis is None or self.clock.now() < vis:
                self.stats.get_misses += 1
                return None
            if self.root:
                p = self._path(key)
                if not p.exists():
                    self.stats.get_misses += 1
                    return None
                data = p.read_bytes()
            else:
                data = self._mem.get(key)
                if data is None:
                    self.stats.get_misses += 1
                    return None
            self.stats.bytes_out += len(data)
            return data

    def exists(self, key: str) -> bool:
        with self._lock:
            vis = self._visible_at.get(key)
            return vis is not None and self.clock.now() >= vis

    def delete(self, key: str) -> None:
        with self._lock:
            self._visible_at.pop(key, None)
            if self.root:
                p = self._path(key)
                if p.exists():
                    p.unlink()
            else:
                self._mem.pop(key, None)

    def list_keys(self, prefix: str = "") -> list:
        with self._lock:
            return sorted(k for k in self._visible_at if k.startswith(prefix))

    @property
    def stored_bytes(self) -> int:
        with self._lock:
            if self.root:
                return sum(self._path(k).stat().st_size
                           for k in self._visible_at
                           if self._path(k).exists())
            return sum(len(self._mem.get(k, b"")) for k in self._visible_at)

    # ---- async API (persistent-buffer path, §5.3.2) ----------------------

    def put_async(self, key: str, data: bytes) -> Future:
        return self._pool.submit(self.put, key, data)

    def shutdown(self) -> None:
        self._pool.shutdown(wait=True)
