"""Sharded multi-daemon scale-out: a keyspace-partitioned front-end
over N independent `InfiniStore` shards (ROADMAP "Multi-daemon
scale-out").

One `InfiniStore` funnels every mutation through a single client-daemon
thread — the right model for the paper's single-client sections, but a
throughput ceiling long before the function pool saturates. This is the
contrast InfiniStore draws against shared-nothing partitioned designs
(Anna's hash-partitioned actors, Faa$T's per-application hash-
distributed cache): partition the METADATA TABLE and CHUNK MAP by key
so independent daemons serve disjoint keyspaces.

`ShardedStore` implements exactly that while preserving the whole
`StoreFrontend` contract at the sharded surface:

- **Partitioning**: a deterministic, pluggable `ShardRouter` (stable
  CRC-32 `HashRouter` by default, contiguous `RangeRouter` for ordered
  keyspaces) maps every object key to one shard. Each shard is a full
  `InfiniStore` — its own client daemon, `WritebackQueue` writer,
  `SpillJournal` under `<spill_dir>/shard-<i>/`, placement state, GC
  window, and recovery manager — all sharing ONE `COS` backend (the
  cloud object store is the global layer in the paper; everything
  daemon-local is per-shard). Chunk keys derive from object keys, so
  disjoint object keyspaces imply disjoint chunk/metadata/journal
  keyspaces: shards never coordinate on the data path.
- **Scatter/gather**: the batched APIs (`put_many_async`,
  `get_many_async`, `get_many_arrays_async`) split a batch into
  per-shard sub-batches, pipeline them on the shard daemons
  concurrently, and join the sub-results into one `StoreFuture`.
- **Cross-shard atomic `put_many`**: a multi-key batch spanning shards
  commits via a leader-sequenced two-round protocol. The protocol
  provides failure atomicity, not read isolation: while round 2 lands
  shard by shard, a concurrent reader may observe some shards' new
  versions before the others commit. Round 1 (prepare) runs each
  shard's sub-batch through the shard's one multi-key CAS + fragment +
  slab/journal path but stops BEFORE the ack point — the new versions
  stay PENDING, invisible to readers and blocking same-key writers —
  and journals a durable `prepared/<ticket>` record in the shard's
  spill journal. The leader then records a durable COMMIT DECISION
  (`decision/<ticket>` in its own journal under `<spill_root>/leader/`,
  or a `2pc/decision/<ticket>` COS stub when running journal-less) and
  round 2 finalizes every sub-batch (ack + metadata journal, ticket
  stamped into each shard's journal record); if ANY shard fails to
  prepare, every prepared shard aborts and readers keep seeing the
  previous versions everywhere. Single-shard batches skip the protocol
  entirely (the common, fast case).

  **The in-doubt window is CLOSED** (presumed abort): a shard that
  crashes — or whose commit submission fails — between prepare and
  commit restarts with the batch withheld as in-doubt (its journal
  replay finds `prepared/<ticket>` with no resolution). The
  `resolve_indoubt()` sweep — run at construction, on every
  `restart_shard`, on every `gc_tick`, or explicitly — queries the
  leader's durable decision for each in-doubt ticket and rolls the
  sub-batch FORWARD (decision record found: the versions become
  readable heads exactly as if round 2 had run) or BACK (no record:
  the leader never decided, so the batch aborts everywhere). The
  invariant: once the decision record is durable the batch can only
  ever commit; before it, only ever abort — no key stays PENDING
  across a crash, and no batch is ever half-visible after resolution.
  Decision records are retired once every participant has resolved.
- **Failure domains**: `simulate_crash(shard=i)` kills one daemon; the
  surviving shards keep serving their keyspaces and `restart_shard(i)`
  rebuilds the dead one from its own spill journal (per-shard recovery
  session) with zero acked loss — the PR-4 kill/restart contract per
  failure domain. `flush_writeback` / `close` / `gc_tick` fan out.
- **Observability**: `stats` aggregates every shard's `StoreStats`
  (per-counter atomic reads — see the StoreStats consistency model;
  the aggregate is not a consistent cut), `stats_per_shard` keeps the
  breakdown, and `snapshot_metadata()` adds a shard-balance histogram
  (distinct object keys per shard) plus the router description.
"""
from __future__ import annotations

import bisect
import dataclasses
import itertools
import os
import shutil
import tempfile
import threading
import time
import zlib
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core.clock import Clock
from repro.core.cos import COS
from repro.core.faults import RetryPolicy
from repro.core.locks import make_lock
from repro.core.spill import SpillJournal
from repro.core.store import (_STAT_FIELDS, InfiniStore, StoreConfig,
                              StoreStats)
from repro.core.writeback import StoreFuture
from repro.obs import NOOP_CM, ObsPlane, merge_metric_snapshots


class HashRouter:
    """Stable hash partitioning: CRC-32 of the key modulo the shard
    count. Deterministic across processes and restarts (never Python's
    salted `hash`), uniform for generic key populations."""

    def __init__(self, num_shards: int):
        if num_shards < 1:
            raise ValueError("num_shards must be >= 1")
        self.num_shards = num_shards

    def shard_of(self, key: str) -> int:
        return zlib.crc32(key.encode()) % self.num_shards

    def snapshot(self) -> Dict:
        return {"kind": "hash", "num_shards": self.num_shards}


class RangeRouter:
    """Contiguous key-range partitioning: `boundaries` are the N-1
    split points of an N-shard keyspace; shard i serves
    [boundaries[i-1], boundaries[i]). Ordered keyspaces (checkpoint
    shards, KV pages) stay shard-local per scan run — at the cost of
    skew when the workload concentrates on one range."""

    def __init__(self, boundaries: Sequence[str]):
        self.boundaries = sorted(boundaries)
        self.num_shards = len(self.boundaries) + 1

    def shard_of(self, key: str) -> int:
        return bisect.bisect_right(self.boundaries, key)

    def snapshot(self) -> Dict:
        return {"kind": "range", "num_shards": self.num_shards,
                "boundaries": list(self.boundaries)}


ShardRouter = Union[HashRouter, RangeRouter]


class ShardedStore:
    """Keyspace-partitioned `StoreFrontend` over N `InfiniStore` shards
    (see the module docstring for the design)."""

    def __init__(self, cfg: Optional[StoreConfig] = None, *,
                 num_shards: int = 4,
                 router: Union[str, ShardRouter] = "hash",
                 range_boundaries: Optional[Sequence[str]] = None,
                 clock: Optional[Clock] = None,
                 cos_root: Optional[str] = None, seed: int = 0):
        self.cfg = cfg = cfg if cfg is not None else StoreConfig()
        self.clock = clock or Clock()
        # ONE shared COS backend: the global persistence layer. Shards
        # receive it pre-built and never shut it down (_owns_cos=False).
        self.cos = COS(self.clock, visibility_lag=cfg.cos_visibility_lag,
                       root=cos_root)
        if isinstance(router, str):
            if router == "hash":
                router = HashRouter(num_shards)
            elif router == "range":
                if range_boundaries is None:
                    raise ValueError("router='range' needs range_boundaries")
                router = RangeRouter(range_boundaries)
            else:
                raise ValueError(f"unknown router {router!r}")
        self.router = router
        self.num_shards = router.num_shards
        # per-shard spill layout: <root>/shard-<i>/ — each journal is a
        # private failure domain. "auto" makes one private temp root
        # (reclaimed on graceful close, like the single-store auto mode).
        self._spill_auto = False
        self._spill_root = cfg.spill_dir
        if cfg.async_writeback and cfg.spill_dir == "auto":
            self._spill_root = tempfile.mkdtemp(
                prefix="infinistore-shards-")
            self._spill_auto = True
        self._seed = seed
        # deterministic fault plan (repro.core.faults): shared COS gets
        # it here (shards never overwrite a COS they don't own); the
        # per-shard layers get it through cfg
        self.faults = cfg.faults
        if cfg.faults is not None:
            self.cos.faults = cfg.faults
        # observability plane (repro.obs), resolved BEFORE the shards
        # are built: the front-end binds the root flight file first, so
        # in-process shards' own binds no-op (one file per crash
        # domain) while worker processes bind their shard directories.
        # ISTORE_METRICS_DUMP auto-attaches a plane like InfiniStore.
        if cfg.obs is None and os.environ.get("ISTORE_METRICS_DUMP"):
            cfg.obs = ObsPlane(name="frontend")
        self.obs = cfg.obs
        if self.obs is not None:
            if self._spill_root is not None:
                self.obs.bind_flight(
                    os.path.join(self._spill_root, "flight.bin"))
            if cfg.faults is not None:
                # leader-side fires mirror on the front-end's copy
                cfg.faults.obs = self.obs
        self.shards: List[InfiniStore] = [
            self._make_shard(i) for i in range(self.num_shards)]
        # leader decision journal (2PC in-doubt closure): the durable
        # commit decisions, one `decision/<ticket>` record per
        # cross-shard batch, retired once every participant resolved.
        # Journal-less deployments fall back to COS decision stubs.
        # NOT fault-instrumented: the dedicated "shard.decision" site
        # models decision loss without entangling shard spill schedules.
        self._tlock = make_lock("shard.ShardedStore._tlock")
        self._decisions: Dict[int, int] = {}     # ticket -> record seq
        self._inflight_tickets: set = set()
        self._decision_retry = RetryPolicy(
            max_attempts=6, backoff_base_s=0.005, backoff_cap_s=0.1,
            seed=seed)
        self._leader_spill: Optional[SpillJournal] = None
        if cfg.async_writeback and self._spill_root is not None:
            self._leader_spill = SpillJournal(
                os.path.join(self._spill_root, "leader"),
                fsync=cfg.spill_fsync, sync_each=False)
            for seq, key, _data in self._leader_spill.take_pending():
                if key.startswith("decision/"):
                    try:
                        self._decisions[int(key[len("decision/"):])] = seq
                        continue
                    except ValueError:
                        pass
                self._leader_spill.mark_persisted(seq)
        # leader side: commit tickets are one monotonic sequence across
        # the whole store (itertools.count: atomic under the GIL), and
        # cross-shard batches coordinate on a small leader pool so
        # put_many_async stays non-blocking for the caller. A rebuilt
        # store reseeds the sequence past every replayed decision and
        # in-doubt ticket — reusing a live ticket would supersede its
        # `prepared/<t>` journal record mid-doubt.
        maxt = max([0, *self._decisions,
                    *(t for s in self.shards
                      for t in self._shard_indoubt(s))])
        self._tickets = itertools.count(maxt + 1)
        self._leader = ThreadPoolExecutor(
            max_workers=max(2, min(8, self.num_shards)),
            thread_name_prefix="shard-leader")
        self._closed = False
        # restart-time sweep: no key may stay PENDING across a crash
        self.resolve_indoubt()

    # ------------------------------------------------------------------
    # shard lifecycle
    # ------------------------------------------------------------------

    def _shard_spill_dir(self, i: int) -> Optional[str]:
        if self._spill_root is None:
            return None
        return os.path.join(self._spill_root, f"shard-{i}")

    def _make_shard(self, i: int) -> InfiniStore:
        scfg = dataclasses.replace(self.cfg,
                                   spill_dir=self._shard_spill_dir(i))
        return InfiniStore(scfg, clock=self.clock, cos=self.cos,
                           seed=self._seed + i, name=f"s{i}")

    def restart_shard(self, i: int) -> InfiniStore:
        """Rebuild a (crashed) shard on its own spill journal: replays
        surviving metadata + pending writes exactly like a single-store
        daemon restart, while the other shards keep serving. Any 2PC
        batch the replay found in doubt is resolved against the
        leader's decisions before this returns."""
        if self.obs is not None:
            self.obs.event("shard.restart", shard=i)
        self.shards[i] = self._make_shard(i)
        self.resolve_indoubt()
        return self.shards[i]

    # ------------------------------------------------------------------
    # 2PC decision plane + in-doubt resolution
    # ------------------------------------------------------------------

    @staticmethod
    def _shard_indoubt(s: InfiniStore) -> List[int]:
        try:
            return s.indoubt_tickets()
        except Exception:                             # noqa: BLE001
            return []            # daemon dead: restart_shard re-sweeps

    def _record_decision(self, ticket: int) -> None:
        """DECISION DURABILITY POINT: once this returns, the batch can
        only ever commit — a restart-time resolver finding the record
        rolls every in-doubt participant forward. Registered before the
        sync so a failed sync can still retire the (possibly-landed)
        record before the batch aborts."""
        if self._leader_spill is not None:
            seq = self._leader_spill.append(f"decision/{ticket}",
                                            b"commit")
            with self._tlock:
                self._decisions[ticket] = seq
            self._leader_spill.sync()
            return
        # journal-less fallback: a COS stub. Weaker — subject to the
        # backend's visibility lag and injected faults like any PUT.
        self.cos.put(f"2pc/decision/{ticket}", b"commit")
        with self._tlock:
            self._decisions[ticket] = -1

    def _retire_decision(self, ticket: int) -> None:
        """Truncate a decision record every participant has resolved
        (or one being withdrawn because the batch aborts before any
        commit was submitted)."""
        with self._tlock:
            seq = self._decisions.pop(ticket, None)
        if seq is None:
            return
        if self._leader_spill is not None:
            self._leader_spill.mark_persisted(seq)
            try:
                self._leader_spill.sync()
            except Exception:                         # noqa: BLE001
                pass             # truncation retries on the next sync
        else:
            try:
                self.cos.delete(f"2pc/decision/{ticket}")
            except Exception:                         # noqa: BLE001
                pass             # stale stub: harmless, re-swept later

    def _decision(self, ticket: int) -> bool:
        """The leader's verdict for an in-doubt ticket: True = a durable
        commit decision exists (roll forward), False = none was ever
        recorded (presumed abort). Raises only on the stub path when COS
        stays unreadable through the retry budget — the sweep then skips
        the ticket and retries next round rather than mis-aborting."""
        with self._tlock:
            if ticket in self._decisions:
                return True
        if self._leader_spill is None:
            return self._decision_retry.run(
                lambda: self.cos.get(f"2pc/decision/{ticket}")) is not None
        return False

    def resolve_indoubt(self) -> Dict[int, str]:
        """Sweep every shard's in-doubt tickets (journal-replayed AND
        live prepared batches whose round 2 never arrived — leader
        death, commit-submission failure) and resolve each against the
        leader's durable decision. Returns {ticket: "commit"|"abort"}
        for everything resolved this round. Idempotent and safe to run
        any time: tickets of batches still in flight are skipped, and a
        shard whose daemon is down is picked up by `restart_shard`'s
        sweep. Decision records no participant still reports are
        retired at the end."""
        out: Dict[int, str] = {}
        all_answered = True
        for s in self.shards:
            try:
                tickets = s.indoubt_tickets()
            except Exception:                         # noqa: BLE001
                all_answered = False
                continue
            for t in tickets:
                with self._tlock:
                    if t in self._inflight_tickets:
                        continue
                try:
                    commit = self._decision(t)
                except Exception:                     # noqa: BLE001
                    all_answered = False
                    continue     # decision unreadable: retry next sweep
                try:
                    s.resolve_indoubt(t, commit=commit).result()
                except Exception:                     # noqa: BLE001
                    all_answered = False
                    continue
                out[t] = "commit" if commit else "abort"
                if self.obs is not None:
                    self.obs.event("2pc.indoubt_resolved",
                                   ticket=t, decision=out[t])
        with self._tlock:
            candidates = [t for t in self._decisions
                          if t not in self._inflight_tickets]
        if candidates and all_answered:
            remaining: set = set()
            for s in self.shards:
                remaining.update(self._shard_indoubt(s))
            for t in candidates:
                if t not in remaining:
                    self._retire_decision(t)
        return out

    def indoubt_tickets(self) -> List[int]:
        """Union of every shard's unresolved prepared tickets."""
        out: set = set()
        for s in self.shards:
            out.update(self._shard_indoubt(s))
        return sorted(out)

    def simulate_crash(self, shard: Optional[int] = None):
        """Kill one shard's daemon mid-flight (`shard=i`) — its journal
        segments survive for `restart_shard(i)`, every other shard keeps
        serving — or the whole store (`shard=None`), returning the spill
        root a rebuilt `ShardedStore` would replay from."""
        if shard is not None:
            return self.shards[shard].simulate_crash()
        for s in self.shards:
            s.simulate_crash()
        self._leader.shutdown(wait=False, cancel_futures=True)
        if self._leader_spill is not None:
            # hard close: only synced decision records survive — the
            # same SIGKILL contract as the shard journals
            self._leader_spill.close(reclaim=False, hard=True)
        self.cos.shutdown()
        self._closed = True
        return self._spill_root

    def close(self, *, flush: bool = True) -> bool:
        """Close every shard (drain daemons, flush writebacks), then the
        leader pool and the shared COS. False if any shard left writes
        unpersisted."""
        if self._closed:
            return True
        self._closed = True
        self._leader.shutdown(wait=True)      # in-flight batches first
        self.resolve_indoubt()                # no ticket left PENDING
        oks = [s.close(flush=flush) for s in self.shards]
        if self._leader_spill is not None:
            self._leader_spill.close()
        self.cos.shutdown()
        if self._spill_auto:
            shutil.rmtree(self._spill_root, ignore_errors=True)
        return all(oks)

    # ------------------------------------------------------------------
    # routing + scatter/join plumbing
    # ------------------------------------------------------------------

    def _shard(self, key: str) -> InfiniStore:
        return self.shards[self.router.shard_of(key)]

    def _scatter(self, keys) -> Dict[int, List[str]]:
        groups: Dict[int, List[str]] = {}
        for k in keys:
            groups.setdefault(self.router.shard_of(k), []).append(k)
        return groups

    @staticmethod
    def _join(futs: List[StoreFuture]) -> StoreFuture:
        """Join per-shard dict futures into one: merge results, first
        exception wins. Callbacks run on the shard daemons; the merge
        is locked, the resolve happens exactly once."""
        out = StoreFuture()
        if not futs:
            out._resolve({})
            return out
        merged: Dict = {}
        lock = make_lock("shard.ShardedStore._join.lock")
        remaining = [len(futs)]

        def on_done(f):
            with lock:
                if out.done():
                    return
                err = f.exception()
                if err is not None:
                    out.set_exception(err)
                    return
                # lint: allow(blocking-under-lock): future is already done inside its own done-callback; result() cannot block
                merged.update(f.result())
                remaining[0] -= 1
                if remaining[0] == 0:
                    out._resolve(merged)

        for f in futs:
            f.add_done_callback(on_done)
        return out

    # ------------------------------------------------------------------
    # single-key API (pure delegation)
    # ------------------------------------------------------------------

    def put(self, key: str, value) -> int:
        return self._shard(key).put(key, value)

    def put_async(self, key: str, value) -> StoreFuture:
        return self._shard(key).put_async(key, value)

    def get(self, key: str):
        return self._shard(key).get(key)

    def get_async(self, key: str) -> StoreFuture:
        return self._shard(key).get_async(key)

    def get_array(self, key: str) -> Optional[np.ndarray]:
        return self._shard(key).get_array(key)

    # ------------------------------------------------------------------
    # batched GET (scatter / join)
    # ------------------------------------------------------------------

    def get_many_async(self, keys) -> StoreFuture:
        groups = self._scatter(dict.fromkeys(keys))
        obs = self.obs
        with (obs.span("client.get_many", shards=len(groups))
              if obs is not None else NOOP_CM):
            return self._join([self.shards[sid].get_many_async(sub)
                               for sid, sub in groups.items()])

    def get_many(self, keys) -> Dict[str, Optional[bytes]]:
        return self.get_many_async(keys).result()

    def get_many_arrays_async(self, keys) -> StoreFuture:
        groups = self._scatter(dict.fromkeys(keys))
        return self._join([self.shards[sid].get_many_arrays_async(sub)
                           for sid, sub in groups.items()])

    def get_many_arrays(self, keys) -> Dict[str, Optional[np.ndarray]]:
        return self.get_many_arrays_async(keys).result()

    # ------------------------------------------------------------------
    # batched PUT (leader-sequenced two-round cross-shard commit)
    # ------------------------------------------------------------------

    def put_many(self, items, *, raise_on_conflict: bool = False
                 ) -> Dict[str, int]:
        return self.put_many_async(
            items, raise_on_conflict=raise_on_conflict).result()

    def put_many_async(self, items, *, raise_on_conflict: bool = False
                       ) -> StoreFuture:
        """Batch PUT across shards. A single-shard batch delegates to
        that shard's one-CAS-round fast path; a cross-shard batch runs
        the two-round protocol: per-shard CAS prepare (versions stay
        PENDING/invisible), then a leader commit ticket finalizes every
        shard — or, if any shard failed to prepare, every prepared
        shard aborts. A prepare-stage failure is therefore never
        half-visible: readers observe either no key or every key of
        the batch (per-key CAS conflicts keep the single-store
        contract: -1 for just that key, or `ConcurrentPutError`
        aborting the whole batch when raise_on_conflict). A failure
        inside the COMMIT round — after the leader's decision became
        durable — leaves the affected shards IN DOUBT, never
        half-aborted: the error propagates (the batch is un-acked),
        and the `resolve_indoubt` sweep rolls every in-doubt shard
        forward per the durable decision, so the batch converges to
        fully-committed (see the module docstring's in-doubt
        contract)."""
        items = list(items.items()) if isinstance(items, dict) \
            else list(items)
        if len({k for k, _ in items}) != len(items):
            raise ValueError("duplicate keys in put_many batch")
        groups: Dict[int, List] = {}
        for k, v in items:
            groups.setdefault(self.router.shard_of(k), []).append((k, v))
        obs = self.obs
        with (obs.span("client.put_many", n=len(items),
                       shards=len(groups))
              if obs is not None else NOOP_CM):
            if len(groups) == 1:
                # single-shard fast path: the shard's own put_many_async
                # captures payloads at submission (snapshot copy
                # in-process, arena copy over IPC) — snapshotting here
                # too would be a second full memcpy of the batch
                sid = next(iter(groups))
                return self.shards[sid].put_many_async(
                    groups[sid], raise_on_conflict=raise_on_conflict)
            # cross-shard: the leader thread touches payloads AFTER this
            # returns, so mutable buffers must be snapshotted NOW — the
            # caller may reuse them the moment this returns
            groups = {sid: [(k, InfiniStore._snapshot_value(v))
                            for k, v in sub]
                      for sid, sub in groups.items()}
            fut = StoreFuture()
            # executor hop: carry the client span's context onto the
            # leader thread so the 2PC span stitches under it
            self._leader.submit(
                obs.bind_current(self._cross_shard_put)
                if obs is not None else self._cross_shard_put,
                groups, raise_on_conflict, fut)
            return fut

    def _cross_shard_put(self, groups: Dict[int, List],
                         raise_on_conflict: bool, fut: StoreFuture) -> None:
        try:
            fut._resolve(self._cross_shard_put_impl(groups,
                                                    raise_on_conflict))
        except BaseException as e:                    # noqa: BLE001
            fut.set_exception(e)

    def _cross_shard_put_impl(self, groups: Dict[int, List],
                              raise_on_conflict: bool) -> Dict[str, int]:
        # the leader ticket is issued FIRST: round 1 journals it into
        # each shard's durable `prepared/<ticket>` record, which is what
        # a crashed shard replays to know the batch was in doubt
        ticket = next(self._tickets)
        with self._tlock:
            self._inflight_tickets.add(ticket)
        obs = self.obs
        try:
            with (obs.span("leader.2pc", ticket=ticket,
                           shards=len(groups))
                  if obs is not None else NOOP_CM):
                return self._cross_shard_rounds(ticket, groups,
                                                raise_on_conflict)
        finally:
            with self._tlock:
                self._inflight_tickets.discard(ticket)

    def _cross_shard_rounds(self, ticket: int, groups: Dict[int, List],
                            raise_on_conflict: bool) -> Dict[str, int]:
        # round 1: prepare on every touched shard, in parallel on the
        # shard daemons. A shard that cannot prepare (daemon dead, CAS
        # conflict under raise_on_conflict, encode/placement failure)
        # fails the whole batch.
        prep_futs: Dict[int, StoreFuture] = {}
        errors: List[BaseException] = []
        for sid, sub in groups.items():
            try:
                prep_futs[sid] = self.shards[sid].prepare_put_many_async(
                    sub, raise_on_conflict=raise_on_conflict,
                    ticket=ticket)
            except BaseException as e:                # noqa: BLE001
                errors.append(e)                      # dead daemon
        preps: Dict[int, object] = {}
        for sid, pf in prep_futs.items():
            try:
                preps[sid] = pf.result()
            except BaseException as e:                # noqa: BLE001
                errors.append(e)
        if errors:
            # round 2 (abort): no shard may expose its sub-batch. No
            # decision was recorded, so a shard that dies before its
            # abort lands resolves by presumed abort at restart.
            for sid, prep in preps.items():
                try:
                    self.shards[sid].abort_put_many_async(prep).result()
                except BaseException:                 # noqa: BLE001
                    pass         # aborting a shard that died meanwhile
            raise errors[0]
        # decision point: make the commit decision durable BEFORE any
        # shard is told to commit. Fails closed — a leader death (or
        # journal failure) here aborts the still-PENDING batch
        # everywhere, matching what a restart-time resolver would
        # presume for a ticket with no decision record.
        try:
            if self.faults is not None:
                self.faults.fire("shard.decision", str(ticket))
            self._record_decision(ticket)
        except BaseException:
            self._retire_decision(ticket)
            for sid, prep in preps.items():
                try:
                    self.shards[sid].abort_put_many_async(prep).result()
                except BaseException:                 # noqa: BLE001
                    pass
            raise
        # the decision is durable: from here the batch can ONLY commit.
        # An injected leader death leaves every prepared shard in doubt
        # — the resolve_indoubt sweep rolls them all forward.
        if self.faults is not None:
            self.faults.fire("shard.leader_death", str(ticket))
        # round 2 (commit): shards stamp the ticket into their journaled
        # metadata records. Commit is submitted to EVERY prepared shard
        # even if one submission fails — skipping a live shard would
        # strand its prepared heads; a shard whose submission failed (or
        # that died mid-commit) stays in doubt and is rolled forward by
        # the sweep against the durable decision.
        out: Dict[str, int] = {}
        commit_errs: List[BaseException] = []
        commits = []
        for sid, prep in preps.items():
            try:
                if self.faults is not None:
                    self.faults.fire("shard.commit_submit", str(sid))
                commits.append(self.shards[sid].commit_put_many_async(
                    prep, ticket=ticket))
            except BaseException as e:                # noqa: BLE001
                commit_errs.append(e)                 # in doubt: swept
        for cf in commits:
            try:
                out.update(cf.result())
            except BaseException as e:                # noqa: BLE001
                # ticketed commits never abort on failure — the shard
                # stays registered in doubt and the sweep retries the
                # idempotent commit, converging forward
                commit_errs.append(e)
        if commit_errs:
            raise commit_errs[0]
        # every participant committed: the decision has no readers left
        self._retire_decision(ticket)
        return out

    # ------------------------------------------------------------------
    # maintenance fan-out
    # ------------------------------------------------------------------

    def flush_writeback(self, timeout: Optional[float] = None) -> bool:
        """Barrier across every shard's writeback queue. The timeout is
        a SHARED deadline — each shard gets what remains of it, so the
        call honors the caller's bound instead of num_shards x timeout."""
        deadline = None if timeout is None \
            else time.monotonic() + timeout
        ok = True
        for s in self.shards:
            remaining = None if deadline is None \
                else max(0.0, deadline - time.monotonic())
            ok = s.flush_writeback(timeout=remaining) and ok
        return ok

    def gc_tick(self) -> None:
        # the maintenance tick doubles as the in-doubt retry point:
        # tickets stranded by a leader death or a failed commit
        # submission converge here without waiting for a restart
        self.resolve_indoubt()
        for s in self.shards:
            s.gc_tick()

    def pause_writeback(self) -> None:
        """Hold every shard's COS writes in-queue (tests/benchmarks)."""
        for s in self.shards:
            s.pause_writeback()

    def resume_writeback(self) -> None:
        for s in self.shards:
            s.resume_writeback()

    def cos_keys(self, prefix: str = "") -> List[str]:
        keys = set()
        for s in self.shards:
            keys.update(s.cos_keys(prefix))
        return sorted(keys)

    def num_functions(self, state=None) -> int:
        return sum(s.num_functions(state) for s in self.shards)

    # ------------------------------------------------------------------
    # observability
    # ------------------------------------------------------------------

    @property
    def stats(self) -> StoreStats:
        """Aggregate of every shard's counters. Each underlying read is
        atomic; the aggregate is NOT a consistent cut across shards or
        counters (see StoreStats). The sums are seeded directly — the
        aggregate is a fresh snapshot object, not a live multi-writer
        counter, so no atomic increments are needed."""
        snaps = self.stats_per_shard()      # ONE snapshot per shard
        return StoreStats(**{
            f: sum(snap[f] for snap in snaps) for f in _STAT_FIELDS})

    def stats_per_shard(self) -> List[Dict[str, int]]:
        return [s.stats.as_dict() for s in self.shards]

    def shard_balance(self) -> List[int]:
        """Distinct object keys (metadata heads) per shard — the
        router-quality histogram."""
        return [s.balance_count() for s in self.shards]

    def tickets_issued(self) -> int:
        """Cross-shard commit tickets handed out so far."""
        return self._tickets.__reduce__()[1][0] - 1

    def ledger_dollars(self) -> Dict[str, float]:
        """Summed cost breakdown across shards."""
        out: Dict[str, float] = {}
        for s in self.shards:
            for k, v in s.ledger_dollars().items():
                out[k] = out.get(k, 0.0) + v
        return out

    def snapshot_metadata(self):
        """Aggregated snapshot: router + balance histogram + per-shard
        breakdowns. Same consistency model as the per-shard snapshot —
        atomic counter reads, no global cut."""
        shards = [s.snapshot_metadata() for s in self.shards]
        states = {s["health"]["state"] for s in shards}
        with self._tlock:
            decisions = sorted(self._decisions)
        # ONE aggregated counter snapshot: every derived ratio below
        # comes from this dict, not from fresh per-ratio counter reads
        # (see StoreStats.derived)
        stats = self.stats.as_dict()
        return {"router": self.router.snapshot(),
                "num_shards": self.num_shards,
                "balance": self.shard_balance(),
                "commit_tickets_issued": self.tickets_issued(),
                "health": {
                    # a dead shard dominates; else degraded if ANY
                    # shard's writeback is degraded
                    "state": "SHARD_DOWN" if "SHARD_DOWN" in states
                    else "DEGRADED_WRITEBACK"
                    if "DEGRADED_WRITEBACK" in states else "OK",
                    "shard_states": sorted(states),
                    "indoubt_tickets": self.indoubt_tickets(),
                    "decisions_held": decisions,
                    # process/tcp frontends overlay per-shard transport
                    # health (state/epoch/heartbeat age); None for
                    # in-process shards
                    "shard_transports": [
                        s["health"].get("transport") for s in shards]},
                "stats": stats,
                "derived": StoreStats.derived(stats),
                "shards": shards}

    # ------------------------------------------------------------------
    # unified metrics export (repro.obs)
    # ------------------------------------------------------------------

    def _shard_metric_snapshots(self) -> List[Dict]:
        """Plane snapshots beyond the front-end's own. In-process
        shards SHARE the front-end plane (their spans and histogram
        samples are already in its snapshot), so there is nothing extra
        here; the process host overrides this with one RPC-collected
        snapshot per worker."""
        return []

    def transport_metrics(self) -> Dict:
        """Per-shard transport counters + summed totals. In-process
        shards have no transport; the process host overlays heartbeat
        health and the PR-8 fencing counters (stale_acks_suppressed,
        dup_frames_dropped, fenced_connects, stale_frames_dropped,
        reconnects)."""
        return {"per_shard": [], "totals": {}}

    def snapshot_metrics(self) -> Dict:
        """Store-wide unified observability export: the front-end
        plane's snapshot merged with every worker process's (histograms
        sum bucket-wise; spans stitch by trace id; flight events and
        forensics concatenate), plus the aggregated store counters and
        the transport section."""
        snaps = []
        if self.obs is not None:
            snaps.append(self.obs.snapshot())
        snaps.extend(self._shard_metric_snapshots())
        merged = merge_metric_snapshots(snaps)
        merged["counters"] = self.stats.as_dict()
        merged["transport"] = self.transport_metrics()
        return merged

    def dump_metrics(self, path: str) -> str:
        """Write `snapshot_metrics()` to `path` — Prometheus text, or
        JSON when the path ends in `.json`. Returns the path."""
        from repro.obs.metrics import dump_json, to_prometheus
        snap = self.snapshot_metrics()
        if path.endswith(".json"):
            dump_json(snap, path)
        else:
            with open(path, "w") as f:
                f.write(to_prometheus(snap))
        return path
