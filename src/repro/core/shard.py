"""Sharded multi-daemon scale-out: a keyspace-partitioned front-end
over N independent `InfiniStore` shards (ROADMAP "Multi-daemon
scale-out").

One `InfiniStore` funnels every mutation through a single client-daemon
thread — the right model for the paper's single-client sections, but a
throughput ceiling long before the function pool saturates. This is the
contrast InfiniStore draws against shared-nothing partitioned designs
(Anna's hash-partitioned actors, Faa$T's per-application hash-
distributed cache): partition the METADATA TABLE and CHUNK MAP by key
so independent daemons serve disjoint keyspaces.

`ShardedStore` implements exactly that while preserving the whole
`StoreFrontend` contract at the sharded surface:

- **Partitioning**: a deterministic, pluggable `ShardRouter` (stable
  CRC-32 `HashRouter` by default, contiguous `RangeRouter` for ordered
  keyspaces) maps every object key to one shard. Each shard is a full
  `InfiniStore` — its own client daemon, `WritebackQueue` writer,
  `SpillJournal` under `<spill_dir>/shard-<i>/`, placement state, GC
  window, and recovery manager — all sharing ONE `COS` backend (the
  cloud object store is the global layer in the paper; everything
  daemon-local is per-shard). Chunk keys derive from object keys, so
  disjoint object keyspaces imply disjoint chunk/metadata/journal
  keyspaces: shards never coordinate on the data path.
- **Scatter/gather**: the batched APIs (`put_many_async`,
  `get_many_async`, `get_many_arrays_async`) split a batch into
  per-shard sub-batches, pipeline them on the shard daemons
  concurrently, and join the sub-results into one `StoreFuture`.
- **Cross-shard atomic `put_many`**: a multi-key batch spanning shards
  commits via a leader-sequenced two-round protocol so a PREPARE-stage
  failure is never half-visible (a failure inside round 2, after the
  ticket issued, is the classic 2PC in-doubt window — see
  `put_many_async`). The protocol provides failure atomicity, not read
  isolation: while round 2 lands shard by shard, a concurrent reader
  may observe some shards' new versions before the others commit.
  Round 1 (prepare) runs each shard's sub-batch through
  the shard's one multi-key CAS + fragment + slab/journal path but
  stops BEFORE the ack point — the new versions stay PENDING,
  invisible to readers and blocking same-key writers. The leader then
  issues a commit ticket (one monotonic sequence across the store) and
  round 2 finalizes every sub-batch (ack + metadata journal, ticket
  stamped into each shard's journal record); if ANY shard fails to
  prepare, every prepared shard aborts and readers keep seeing the
  previous versions everywhere. Single-shard batches skip the protocol
  entirely (the common, fast case).
- **Failure domains**: `simulate_crash(shard=i)` kills one daemon; the
  surviving shards keep serving their keyspaces and `restart_shard(i)`
  rebuilds the dead one from its own spill journal (per-shard recovery
  session) with zero acked loss — the PR-4 kill/restart contract per
  failure domain. `flush_writeback` / `close` / `gc_tick` fan out.
- **Observability**: `stats` aggregates every shard's `StoreStats`
  (per-counter atomic reads — see the StoreStats consistency model;
  the aggregate is not a consistent cut), `stats_per_shard` keeps the
  breakdown, and `snapshot_metadata()` adds a shard-balance histogram
  (distinct object keys per shard) plus the router description.
"""
from __future__ import annotations

import bisect
import dataclasses
import itertools
import os
import shutil
import tempfile
import threading
import time
import zlib
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core.clock import Clock
from repro.core.cos import COS
from repro.core.store import (_STAT_FIELDS, InfiniStore, StoreConfig,
                              StoreStats)
from repro.core.writeback import StoreFuture


class HashRouter:
    """Stable hash partitioning: CRC-32 of the key modulo the shard
    count. Deterministic across processes and restarts (never Python's
    salted `hash`), uniform for generic key populations."""

    def __init__(self, num_shards: int):
        if num_shards < 1:
            raise ValueError("num_shards must be >= 1")
        self.num_shards = num_shards

    def shard_of(self, key: str) -> int:
        return zlib.crc32(key.encode()) % self.num_shards

    def snapshot(self) -> Dict:
        return {"kind": "hash", "num_shards": self.num_shards}


class RangeRouter:
    """Contiguous key-range partitioning: `boundaries` are the N-1
    split points of an N-shard keyspace; shard i serves
    [boundaries[i-1], boundaries[i]). Ordered keyspaces (checkpoint
    shards, KV pages) stay shard-local per scan run — at the cost of
    skew when the workload concentrates on one range."""

    def __init__(self, boundaries: Sequence[str]):
        self.boundaries = sorted(boundaries)
        self.num_shards = len(self.boundaries) + 1

    def shard_of(self, key: str) -> int:
        return bisect.bisect_right(self.boundaries, key)

    def snapshot(self) -> Dict:
        return {"kind": "range", "num_shards": self.num_shards,
                "boundaries": list(self.boundaries)}


ShardRouter = Union[HashRouter, RangeRouter]


class ShardedStore:
    """Keyspace-partitioned `StoreFrontend` over N `InfiniStore` shards
    (see the module docstring for the design)."""

    def __init__(self, cfg: Optional[StoreConfig] = None, *,
                 num_shards: int = 4,
                 router: Union[str, ShardRouter] = "hash",
                 range_boundaries: Optional[Sequence[str]] = None,
                 clock: Optional[Clock] = None,
                 cos_root: Optional[str] = None, seed: int = 0):
        self.cfg = cfg = cfg if cfg is not None else StoreConfig()
        self.clock = clock or Clock()
        # ONE shared COS backend: the global persistence layer. Shards
        # receive it pre-built and never shut it down (_owns_cos=False).
        self.cos = COS(self.clock, visibility_lag=cfg.cos_visibility_lag,
                       root=cos_root)
        if isinstance(router, str):
            if router == "hash":
                router = HashRouter(num_shards)
            elif router == "range":
                if range_boundaries is None:
                    raise ValueError("router='range' needs range_boundaries")
                router = RangeRouter(range_boundaries)
            else:
                raise ValueError(f"unknown router {router!r}")
        self.router = router
        self.num_shards = router.num_shards
        # per-shard spill layout: <root>/shard-<i>/ — each journal is a
        # private failure domain. "auto" makes one private temp root
        # (reclaimed on graceful close, like the single-store auto mode).
        self._spill_auto = False
        self._spill_root = cfg.spill_dir
        if cfg.async_writeback and cfg.spill_dir == "auto":
            self._spill_root = tempfile.mkdtemp(
                prefix="infinistore-shards-")
            self._spill_auto = True
        self._seed = seed
        self.shards: List[InfiniStore] = [
            self._make_shard(i) for i in range(self.num_shards)]
        # leader side: commit tickets are one monotonic sequence across
        # the whole store (itertools.count: atomic under the GIL), and
        # cross-shard batches coordinate on a small leader pool so
        # put_many_async stays non-blocking for the caller
        self._tickets = itertools.count(1)
        self._leader = ThreadPoolExecutor(
            max_workers=max(2, min(8, self.num_shards)),
            thread_name_prefix="shard-leader")
        self._closed = False

    # ------------------------------------------------------------------
    # shard lifecycle
    # ------------------------------------------------------------------

    def _shard_spill_dir(self, i: int) -> Optional[str]:
        if self._spill_root is None:
            return None
        return os.path.join(self._spill_root, f"shard-{i}")

    def _make_shard(self, i: int) -> InfiniStore:
        scfg = dataclasses.replace(self.cfg,
                                   spill_dir=self._shard_spill_dir(i))
        return InfiniStore(scfg, clock=self.clock, cos=self.cos,
                           seed=self._seed + i, name=f"s{i}")

    def restart_shard(self, i: int) -> InfiniStore:
        """Rebuild a (crashed) shard on its own spill journal: replays
        surviving metadata + pending writes exactly like a single-store
        daemon restart, while the other shards keep serving."""
        self.shards[i] = self._make_shard(i)
        return self.shards[i]

    def simulate_crash(self, shard: Optional[int] = None):
        """Kill one shard's daemon mid-flight (`shard=i`) — its journal
        segments survive for `restart_shard(i)`, every other shard keeps
        serving — or the whole store (`shard=None`), returning the spill
        root a rebuilt `ShardedStore` would replay from."""
        if shard is not None:
            return self.shards[shard].simulate_crash()
        for s in self.shards:
            s.simulate_crash()
        self._leader.shutdown(wait=False, cancel_futures=True)
        self.cos.shutdown()
        self._closed = True
        return self._spill_root

    def close(self, *, flush: bool = True) -> bool:
        """Close every shard (drain daemons, flush writebacks), then the
        leader pool and the shared COS. False if any shard left writes
        unpersisted."""
        if self._closed:
            return True
        self._closed = True
        oks = [s.close(flush=flush) for s in self.shards]
        self._leader.shutdown(wait=True)
        self.cos.shutdown()
        if self._spill_auto:
            shutil.rmtree(self._spill_root, ignore_errors=True)
        return all(oks)

    # ------------------------------------------------------------------
    # routing + scatter/join plumbing
    # ------------------------------------------------------------------

    def _shard(self, key: str) -> InfiniStore:
        return self.shards[self.router.shard_of(key)]

    def _scatter(self, keys) -> Dict[int, List[str]]:
        groups: Dict[int, List[str]] = {}
        for k in keys:
            groups.setdefault(self.router.shard_of(k), []).append(k)
        return groups

    @staticmethod
    def _join(futs: List[StoreFuture]) -> StoreFuture:
        """Join per-shard dict futures into one: merge results, first
        exception wins. Callbacks run on the shard daemons; the merge
        is locked, the resolve happens exactly once."""
        out = StoreFuture()
        if not futs:
            out._resolve({})
            return out
        merged: Dict = {}
        lock = threading.Lock()
        remaining = [len(futs)]

        def on_done(f):
            with lock:
                if out.done():
                    return
                err = f.exception()
                if err is not None:
                    out.set_exception(err)
                    return
                merged.update(f.result())
                remaining[0] -= 1
                if remaining[0] == 0:
                    out._resolve(merged)

        for f in futs:
            f.add_done_callback(on_done)
        return out

    # ------------------------------------------------------------------
    # single-key API (pure delegation)
    # ------------------------------------------------------------------

    def put(self, key: str, value) -> int:
        return self._shard(key).put(key, value)

    def put_async(self, key: str, value) -> StoreFuture:
        return self._shard(key).put_async(key, value)

    def get(self, key: str):
        return self._shard(key).get(key)

    def get_async(self, key: str) -> StoreFuture:
        return self._shard(key).get_async(key)

    def get_array(self, key: str) -> Optional[np.ndarray]:
        return self._shard(key).get_array(key)

    # ------------------------------------------------------------------
    # batched GET (scatter / join)
    # ------------------------------------------------------------------

    def get_many_async(self, keys) -> StoreFuture:
        groups = self._scatter(dict.fromkeys(keys))
        return self._join([self.shards[sid].get_many_async(sub)
                           for sid, sub in groups.items()])

    def get_many(self, keys) -> Dict[str, Optional[bytes]]:
        return self.get_many_async(keys).result()

    def get_many_arrays_async(self, keys) -> StoreFuture:
        groups = self._scatter(dict.fromkeys(keys))
        return self._join([self.shards[sid].get_many_arrays_async(sub)
                           for sid, sub in groups.items()])

    def get_many_arrays(self, keys) -> Dict[str, Optional[np.ndarray]]:
        return self.get_many_arrays_async(keys).result()

    # ------------------------------------------------------------------
    # batched PUT (leader-sequenced two-round cross-shard commit)
    # ------------------------------------------------------------------

    def put_many(self, items, *, raise_on_conflict: bool = False
                 ) -> Dict[str, int]:
        return self.put_many_async(
            items, raise_on_conflict=raise_on_conflict).result()

    def put_many_async(self, items, *, raise_on_conflict: bool = False
                       ) -> StoreFuture:
        """Batch PUT across shards. A single-shard batch delegates to
        that shard's one-CAS-round fast path; a cross-shard batch runs
        the two-round protocol: per-shard CAS prepare (versions stay
        PENDING/invisible), then a leader commit ticket finalizes every
        shard — or, if any shard failed to prepare, every prepared
        shard aborts. A prepare-stage failure is therefore never
        half-visible: readers observe either no key or every key of
        the batch (per-key CAS conflicts keep the single-store
        contract: -1 for just that key, or `ConcurrentPutError`
        aborting the whole batch when raise_on_conflict). A failure
        inside the COMMIT round — after the ticket was issued — is the
        classic 2PC in-doubt window: shards whose commit already ran
        serve the new versions, the failing shard aborts its heads
        back to the previous ones, and the error propagates so the
        caller can retry the batch."""
        items = list(items.items()) if isinstance(items, dict) \
            else list(items)
        if len({k for k, _ in items}) != len(items):
            raise ValueError("duplicate keys in put_many batch")
        # snapshot mutable payloads NOW (the caller may reuse buffers
        # the moment this returns) — shards then see stable copies
        items = [(k, InfiniStore._snapshot_value(v)) for k, v in items]
        groups: Dict[int, List] = {}
        for k, v in items:
            groups.setdefault(self.router.shard_of(k), []).append((k, v))
        if len(groups) == 1:
            sid = next(iter(groups))
            return self.shards[sid].put_many_async(
                groups[sid], raise_on_conflict=raise_on_conflict)
        fut = StoreFuture()
        self._leader.submit(self._cross_shard_put, groups,
                            raise_on_conflict, fut)
        return fut

    def _cross_shard_put(self, groups: Dict[int, List],
                         raise_on_conflict: bool, fut: StoreFuture) -> None:
        try:
            fut._resolve(self._cross_shard_put_impl(groups,
                                                    raise_on_conflict))
        except BaseException as e:                    # noqa: BLE001
            fut.set_exception(e)

    def _cross_shard_put_impl(self, groups: Dict[int, List],
                              raise_on_conflict: bool) -> Dict[str, int]:
        # round 1: prepare on every touched shard, in parallel on the
        # shard daemons. A shard that cannot prepare (daemon dead, CAS
        # conflict under raise_on_conflict, encode/placement failure)
        # fails the whole batch.
        prep_futs: Dict[int, StoreFuture] = {}
        errors: List[BaseException] = []
        for sid, sub in groups.items():
            try:
                prep_futs[sid] = self.shards[sid].prepare_put_many_async(
                    sub, raise_on_conflict=raise_on_conflict)
            except BaseException as e:                # noqa: BLE001
                errors.append(e)                      # dead daemon
        preps: Dict[int, object] = {}
        for sid, pf in prep_futs.items():
            try:
                preps[sid] = pf.result()
            except BaseException as e:                # noqa: BLE001
                errors.append(e)
        if errors:
            # round 2 (abort): no shard may expose its sub-batch
            for sid, prep in preps.items():
                try:
                    self.shards[sid].abort_put_many_async(prep).result()
                except BaseException:                 # noqa: BLE001
                    pass         # aborting a shard that died meanwhile
            raise errors[0]
        # round 2 (commit): one leader ticket sequences this batch
        # against every other cross-shard batch; shards stamp it into
        # their journaled metadata records. Commit is submitted to
        # EVERY prepared shard even if one submission/commit fails —
        # skipping a live shard would strand its prepared heads, and a
        # shard that died between prepare and commit is the classic
        # in-doubt 2PC window: its in-memory heads die with it (no
        # metadata was journaled at prepare), so a restart simply never
        # shows the batch there.
        ticket = next(self._tickets)
        out: Dict[str, int] = {}
        commit_errs: List[BaseException] = []
        commits = []
        for sid, prep in preps.items():
            try:
                commits.append(self.shards[sid].commit_put_many_async(
                    prep, ticket=ticket))
            except BaseException as e:                # noqa: BLE001
                commit_errs.append(e)                 # daemon died
        for cf in commits:
            try:
                out.update(cf.result())
            except BaseException as e:                # noqa: BLE001
                # the shard's commit path aborted its unfinalized heads
                # before raising (commit_put_many_async guard)
                commit_errs.append(e)
        if commit_errs:
            raise commit_errs[0]
        return out

    # ------------------------------------------------------------------
    # maintenance fan-out
    # ------------------------------------------------------------------

    def flush_writeback(self, timeout: Optional[float] = None) -> bool:
        """Barrier across every shard's writeback queue. The timeout is
        a SHARED deadline — each shard gets what remains of it, so the
        call honors the caller's bound instead of num_shards x timeout."""
        deadline = None if timeout is None \
            else time.monotonic() + timeout
        ok = True
        for s in self.shards:
            remaining = None if deadline is None \
                else max(0.0, deadline - time.monotonic())
            ok = s.flush_writeback(timeout=remaining) and ok
        return ok

    def gc_tick(self) -> None:
        for s in self.shards:
            s.gc_tick()

    def pause_writeback(self) -> None:
        """Hold every shard's COS writes in-queue (tests/benchmarks)."""
        for s in self.shards:
            s.writeback.pause()

    def resume_writeback(self) -> None:
        for s in self.shards:
            s.writeback.resume()

    def cos_keys(self, prefix: str = "") -> List[str]:
        keys = set()
        for s in self.shards:
            keys.update(s.cos_keys(prefix))
        return sorted(keys)

    def num_functions(self, state=None) -> int:
        return sum(s.num_functions(state) for s in self.shards)

    # ------------------------------------------------------------------
    # observability
    # ------------------------------------------------------------------

    @property
    def stats(self) -> StoreStats:
        """Aggregate of every shard's counters. Each underlying read is
        atomic; the aggregate is NOT a consistent cut across shards or
        counters (see StoreStats). The sums are seeded directly — the
        aggregate is a fresh snapshot object, not a live multi-writer
        counter, so no atomic increments are needed."""
        return StoreStats(**{
            f: sum(getattr(s.stats, f) for s in self.shards)
            for f in _STAT_FIELDS})

    def stats_per_shard(self) -> List[Dict[str, int]]:
        return [s.stats.as_dict() for s in self.shards]

    def shard_balance(self) -> List[int]:
        """Distinct object keys (metadata heads) per shard — the
        router-quality histogram."""
        out = []
        for s in self.shards:
            snap = s.mt.snapshot()
            out.append(sum(1 for k in snap if "|" not in k))
        return out

    def tickets_issued(self) -> int:
        """Cross-shard commit tickets handed out so far."""
        return self._tickets.__reduce__()[1][0] - 1

    def ledger_dollars(self) -> Dict[str, float]:
        """Summed cost breakdown across shards."""
        out: Dict[str, float] = {}
        for s in self.shards:
            for k, v in s.ledger.dollars().items():
                out[k] = out.get(k, 0.0) + v
        return out

    def snapshot_metadata(self):
        """Aggregated snapshot: router + balance histogram + per-shard
        breakdowns. Same consistency model as the per-shard snapshot —
        atomic counter reads, no global cut."""
        return {"router": self.router.snapshot(),
                "num_shards": self.num_shards,
                "balance": self.shard_balance(),
                "commit_tickets_issued": self.tickets_issued(),
                "stats": self.stats.as_dict(),
                "shards": [s.snapshot_metadata() for s in self.shards]}
