"""Networked shard worker: the TCP peer of `transport.TcpTransport`.

One process owning a full `InfiniStore`, serving the host's RPCs over
framed loopback/LAN sockets instead of pipe + shm rings (the real
InfiniStore's client<->proxy split over ports 6378/6379).  The dispatch
surface is EXACTLY `host._WorkerLoop` — this module only swaps the
byte plane:

- requests arrive as frames whose out-of-band payload section carries
  the bulk bytes; descriptors `("o", off, n)` map to read-only numpy
  views over the frame blob (bytes are immutable, so
  `InfiniStore._snapshot_value` retains them zero-copy — the frame IS
  the private capture);
- replies stage `("o", off, n)` payloads per callback thread and flush
  them as one frame under `resp_lock` (pack+send = one unit, exactly
  the ordering contract of the shm response ring).

Robustness contracts served here:

- **Epoch fencing**: a `hello` whose epoch is not strictly newer than
  the adopted one is refused (`fenced` reply, counted) — a stale
  parent socket reappearing after a partition cannot take the shard
  over. Adopting a NEWER epoch closes the previous socket and drops
  its prepared-batch handles: the store-side prepared state stays
  in-doubt and the leader's `resolve_indoubt` sweep settles it.
- **Stale-ack suppression**: every data rid records its arrival epoch;
  a reply whose rid predates the current epoch is swallowed (counted),
  so an RPC issued before a partition can never be acked after it.
- **Rid dedupe**: rids are strictly monotonic per parent, so a frame
  whose rid is <= the highest seen is a duplicate (`net.dup`
  injection, or a retransmitting relay) and is dropped, not re-run.
- A broken connection does NOT exit the process: the worker keeps its
  store hot and waits for the parent to reconnect at a newer epoch.
  Shutdown is the explicit "bye" on the bootstrap pipe (or parent
  death, caught by the ppid watchdog) — same contract as the shm
  worker.

`xstats` (an op the server answers itself) exposes the fencing
counters to tests and the chaos soak.
"""
from __future__ import annotations

import logging
import os
import signal
import socket
import threading
from typing import Dict, Optional

import numpy as np

from .clock import Clock
from .host import _WorkerLoop, _portable_exc, _swallow
from .locks import make_lock
from .payload import as_u8
from .store import InfiniStore
from .transport import FrameError, recv_frame, send_frame

__all__ = ["_net_worker_main"]

_LOG = logging.getLogger("repro.netshard")


def _net_worker_main(spec: dict) -> None:
    """Entry point of one networked shard worker process."""
    try:
        signal.signal(signal.SIGINT, signal.SIG_IGN)
    except (ValueError, OSError):                     # pragma: no cover
        pass
    conn = spec["conn"]

    def boot_send(msg) -> None:
        try:
            conn.send(msg)
        except (OSError, ValueError, BrokenPipeError):
            pass                     # parent gone: nothing left to tell

    lsock = None
    try:
        store = InfiniStore(spec["cfg"], clock=Clock(),
                            cos_root=spec["cos_root"],
                            seed=spec["seed"], name=spec["name"])
        for attr, val in spec.get("cos_latency", {}).items():
            setattr(store.cos, attr, val)
        lsock = socket.create_server(("127.0.0.1", 0), backlog=4)
    except BaseException as e:                        # noqa: BLE001
        boot_send(("err", -1, _portable_exc(e)))
        return
    # "ready" only after construction AND bind: journal replay is
    # included, and the reported port is accept()able immediately
    boot_send(("ok", -1, (os.getpid(), lsock.getsockname()[1])))
    server = _NetShardServer(store, lsock, conn)
    try:
        server.run()
    finally:
        server.shutdown()


class _NetWorkerLoop(_WorkerLoop):
    """`_WorkerLoop` over frame descriptors instead of arena slots.
    `run()` is never called — the server's per-connection readers feed
    `dispatch` directly."""

    def __init__(self, store: InfiniStore,
                 server: "_NetShardServer") -> None:
        super().__init__(store, None, None, None, server.reply)
        self.server = server

    def _unpack(self, desc):
        if desc[0] == "o":
            _, off, n = desc
            # read-only view over the immutable frame blob: the store
            # retains it zero-copy (needs_snapshot is False for bytes)
            return np.frombuffer(self.server.tls.frame, np.uint8,
                                 count=n, offset=off)
        if desc[0] == "i":
            return desc[1]
        raise ValueError(f"unknown net payload descriptor {desc!r}")

    def _pack_result(self, v):
        if v is None:
            return ("n",)
        return self.server.stage(as_u8(v).tobytes())


class _NetShardServer:
    """Accept loop + per-connection frame readers for one worker."""

    def __init__(self, store: InfiniStore, lsock: socket.socket,
                 boot_conn) -> None:
        self.store = store
        self.lsock = lsock
        self.boot = boot_conn
        self.loop = _NetWorkerLoop(store, self)
        self.tls = threading.local()     # .frame / .staged / .off
        self.epoch = 0
        self._sock: Optional[socket.socket] = None
        self._lock = make_lock("netshard._NetShardServer._lock")    # sock/epoch/rid bookkeeping
        self._send_lock = make_lock("netshard._NetShardServer._send_lock")
        self._rid_epoch: Dict[int, int] = {}
        self._last_rid = 0
        self.fenced_connects = 0
        self.stale_frames_dropped = 0
        self.stale_acks_suppressed = 0
        self.dup_frames_dropped = 0
        self._stop = False

    # -- accept loop ---------------------------------------------------------

    def run(self) -> None:
        self.lsock.settimeout(0.5)
        ppid = os.getppid()
        while not self._stop:
            try:
                if self.boot.poll(0):
                    op, _rid, _p = self.boot.recv()
                    if op == "bye":
                        return       # parent is reaping us: exit now
            except (EOFError, OSError):
                return               # parent closed (or died): exit
            if os.getppid() != ppid:
                return               # parent died without a bye
            try:
                c, _addr = self.lsock.accept()
            except socket.timeout:
                continue
            except OSError:
                return
            self._handshake(c)

    def _handshake(self, c: socket.socket) -> None:
        try:
            c.settimeout(5.0)
            ctrl, _ = recv_frame(c)
            ep, kind, _rid, _val = ctrl
            if kind != "hello":
                raise FrameError(f"expected hello, got {kind!r}")
        except Exception:                             # noqa: BLE001
            _swallow(c.close)
            return
        with self._lock:
            if ep <= self.epoch:
                self.fenced_connects += 1
                fenced = True
            else:
                fenced = False
                old, self._sock = self._sock, c
                self.epoch = ep
        if fenced:
            # a stale incarnation of the parent (or a zombie socket):
            # refuse — it may not take the shard over
            try:
                send_frame(c, (ep, "fenced", 0, None))
            except OSError:
                pass
            _swallow(c.close)
            return
        if old is not None:
            _swallow(old.close)      # fence the superseded connection
        # prepared handles of earlier epochs are unreachable now; the
        # store-side prepared state stays journaled in-doubt and the
        # leader sweep rolls it per the durable decision
        self.loop.preps.clear()
        obs = self.store.obs
        if obs is not None:
            # spans/events recorded from here on belong to this epoch;
            # post-SIGKILL forensics can attribute them across restarts
            obs.set_epoch(ep)
            obs.event("epoch.bump", epoch=ep)
        try:
            c.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            c.settimeout(None)
            send_frame(c, (ep, "welcome", 0, os.getpid()))
        except OSError:
            _swallow(c.close)
            return
        threading.Thread(target=self._conn_loop, args=(c, ep),
                         daemon=True,
                         name=f"netshard-rx-e{ep}").start()

    # -- per-connection reader ----------------------------------------------

    def _conn_loop(self, c: socket.socket, ep: int) -> None:
        while True:
            try:
                ctrl, payload = recv_frame(c)
            except Exception:                         # noqa: BLE001
                break                # parent gone: await a reconnect
            with self._lock:
                if ep != self.epoch:
                    break            # fenced while reading
                fep, kind, rid, val = ctrl
                if fep != ep:
                    self.stale_frames_dropped += 1
                    continue
                if kind == "ping":
                    pass             # not a data rid: no dedupe entry
                elif rid <= self._last_rid:
                    self.dup_frames_dropped += 1
                    continue
                else:
                    self._last_rid = rid
                    self._rid_epoch[rid] = ep
            if kind == "ping":
                self._send_frame("pong", rid, None, ())
                continue
            if kind == "xstats":
                self.reply(("ok", rid, self.xstats()))
                continue
            self.tls.frame = payload
            try:
                self.loop.dispatch(kind, rid, val)
            except BaseException as e:                # noqa: BLE001
                self.reply(("err", rid, _portable_exc(e)))

    # -- reply plane ---------------------------------------------------------

    def stage(self, raw: bytes):
        """Stage one reply payload on THIS callback thread; offsets
        reset per frame (the send pops the staging)."""
        tls = self.tls
        staged = getattr(tls, "staged", None)
        if staged is None:
            staged = tls.staged = []
            tls.off = 0
        off = tls.off
        staged.append(raw)
        tls.off += len(raw)
        return ("o", off, len(raw))

    def _pop_staged(self):
        tls = self.tls
        staged = getattr(tls, "staged", None) or []
        tls.staged = []
        tls.off = 0
        return staged

    def reply(self, msg) -> None:
        """The loop's send callable: epoch-fence the ack, then frame it.
        A reply for a rid that arrived under an older epoch is
        SWALLOWED — the parent already failed that RPC when it declared
        the epoch dead, and a late ack must not resurrect it."""
        kind, rid, val = msg
        staged = self._pop_staged()
        if kind != "val":
            staged = []              # discard a failed pack's leftovers
        with self._lock:
            ep = self._rid_epoch.pop(rid, None)
            if ep is not None and ep != self.epoch:
                self.stale_acks_suppressed += 1
                return
        self._send_frame(kind, rid, val, tuple(staged))

    def _send_frame(self, kind: str, rid: int, val, bufs) -> None:
        with self._lock:
            c, ep = self._sock, self.epoch
        if c is None:
            return
        try:
            with self._send_lock:
                # lint: allow(blocking-under-lock): _send_lock's critical section IS the frame pack+send
                send_frame(c, (ep, kind, rid, val), bufs)
        except OSError:
            pass                     # conn broke: parent reconnects

    def xstats(self) -> dict:
        with self._lock:
            return {"epoch": self.epoch,
                    "fenced_connects": self.fenced_connects,
                    "stale_frames_dropped": self.stale_frames_dropped,
                    "stale_acks_suppressed": self.stale_acks_suppressed,
                    "dup_frames_dropped": self.dup_frames_dropped,
                    "preps_held": len(self.loop.preps),
                    "rids_tracked": len(self._rid_epoch)}

    # -- shutdown ------------------------------------------------------------

    def shutdown(self) -> None:
        self._stop = True
        self.loop.shutdown()
        with self._lock:
            c, self._sock = self._sock, None
        if c is not None:
            _swallow(c.close)
        _swallow(self.lsock.close)
