"""Store futures + background COS writeback (paper §5.3.2).

`StoreFuture` is the handle the async client API returns: `result()`,
`exception()`, `done()`, `add_done_callback()` — a thin veneer over
`concurrent.futures.Future` so callers can pipeline PUT/GET without
blocking on the slowest layer.

`WritebackQueue` moves COS persistence off the PUT critical path: a PUT
acknowledges once its chunks sit in SMS slabs + the persistent buffer,
and the queue persists them to COS in the background — drained by a
dedicated writer thread and opportunistically by `gc_tick`. Durability
before persistence completes is covered by the pending map: recovery and
consistent reads consult `peek()` for anything enqueued-but-not-yet-in-
COS, which is exactly the paper's "retry persistence asynchronously from
the persistent buffer" contract at chunk granularity.

Bounded depth gives backpressure (enqueue blocks when the queue is
full), failures retry under the unified `RetryPolicy` (capped
exponential backoff + jitter; transient/throttle/permanent
classification — see `repro.core.faults`), and `flush()` is the barrier
checkpoint/shutdown paths use.

COS outages degrade, they don't destroy: `degraded_after` consecutive
transient failures flip the queue into the documented
`DEGRADED_WRITEBACK` state — retry budgets freeze (an outage is not the
write's fault, so nothing accumulates permanent failures), tasks probe
COS at the backoff cap, bounded depth keeps applying backpressure to
producers, and reads keep flowing from the pending map / spill journal
/ SMS. The first successful write heals the state automatically and the
queue drains. Only errors classified PERMANENT (or retry exhaustion
OUTSIDE an outage) fail a write for good; those are counted, their keys
recorded, and both surfaced through `health()` so callers can tell a
timed-out flush from data-at-risk.

With a `SpillJournal` attached, every enqueue is appended to the
durable journal BEFORE it enters the queue (so before any ack), and the
record is logically truncated when the write persists or is superseded
— a write that exhausts its retries stays journaled so a daemon restart
retries it. That closes the crash hole in the pure in-memory pending
map: a client-daemon crash no longer loses acked-but-unpersisted
writes; the constructing store replays the journal and re-enqueues them
(passing the original `seq` so nothing is double-journaled).
"""
from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.core.faults import RetryPolicy
from repro.core.locks import make_lock
from repro.obs import NOOP_CM


class StoreFuture(Future):
    """Async PUT/GET handle. PUT futures resolve to the committed version
    (and carry it as `.version`); GET futures resolve to the payload."""

    def __init__(self):
        super().__init__()
        self.version: Optional[int] = None

    def _resolve(self, value) -> None:
        if isinstance(value, int):
            self.version = value
        self.set_result(value)


@dataclass
class WritebackStats:
    enqueued: int = 0
    persisted: int = 0
    retries: int = 0
    failures: int = 0                 # permanently-failed writes
    superseded: int = 0               # dropped: a newer same-key write won
    peak_depth: int = 0
    flushes: int = 0
    throttled: int = 0                # SlowDown-classified retries
    degraded_entries: int = 0         # OK -> DEGRADED_WRITEBACK flips
    degraded_exits: int = 0           # outages healed


@dataclass
class _Task:
    key: str
    data: object                      # bytes or uint8 ndarray
    on_done: Optional[Callable[[str, bool], None]] = None
    attempts: int = 0
    not_before: float = 0.0           # wall time; retry backoff gate
    seq: Optional[int] = None         # spill-journal record to truncate
    ctx: Optional[tuple] = None       # trace context of the causing PUT


class WritebackQueue:
    """Bounded background COS writer with retry/backoff and flush/drain
    barriers. All public methods are thread-safe."""

    def __init__(self, cos, *, max_depth: int = 256, max_retries: int = 8,
                 backoff_base_s: float = 0.005, backoff_cap_s: float = 0.5,
                 start_thread: bool = True, spill=None,
                 name: str = "cos-writeback",
                 retry: Optional[RetryPolicy] = None,
                 degraded_after: int = 12, faults=None, obs=None):
        self.cos = cos
        # optional ObsPlane (repro.obs): "wb.persist" spans adopt the
        # causing PUT's trace context; degraded enter/heal transitions
        # land in the flight recorder
        self.obs = obs
        # optional SpillJournal: enqueues are journaled before ack and
        # truncated on persistence (crash-consistent pending map)
        self.spill = spill
        self.faults = faults
        self.max_depth = max_depth
        self.max_retries = max_retries
        self.backoff_base_s = backoff_base_s
        self.backoff_cap_s = backoff_cap_s
        # unified retry policy: classification + backoff shape; the
        # task-based writer keeps its own attempt counters, so only
        # classify()/delay() are used here (max_attempts comes from
        # max_retries for backward compatibility)
        self.retry = retry or RetryPolicy(max_attempts=max_retries + 1,
                                          backoff_base_s=backoff_base_s,
                                          backoff_cap_s=backoff_cap_s)
        # consecutive transient failures before declaring a COS outage
        self.degraded_after = max(1, degraded_after)
        self._consec_errors = 0
        self._degraded_since: Optional[float] = None
        self._failed_keys: List[str] = []
        self.stats = WritebackStats()
        self._q: deque = deque()
        # cos key -> payload for every write not yet persisted (including
        # in-flight and retrying) — the durability read path
        self._pending: Dict[str, object] = {}
        self._inflight = 0
        self._paused = False
        self._stop = False
        self._lock = make_lock("writeback.WritebackQueue._lock")
        self._not_empty = threading.Condition(self._lock)
        self._not_full = threading.Condition(self._lock)
        self._idle = threading.Condition(self._lock)    # empty + no inflight
        self._errors: List[str] = []
        self._thread: Optional[threading.Thread] = None
        if start_thread:
            # `name` tags the writer thread per store instance (shard)
            self._thread = threading.Thread(target=self._writer_loop,
                                            name=name,
                                            daemon=True)
            self._thread.start()

    # ---- producer side ----------------------------------------------------

    def enqueue(self, key: str, data, *,
                on_done: Optional[Callable[[str, bool], None]] = None,
                seq: Optional[int] = None) -> None:
        """Queue one COS write. Blocks while the queue is at max_depth
        (backpressure); the pending map serves reads immediately. With a
        spill journal the write is made durable-on-disk FIRST (so before
        the caller can ack); `seq` is passed by the restart replay path
        for records already journaled."""
        if self.spill is not None and seq is None:
            seq = self.spill.append(key, data)
        obs = self.obs
        # capture the enqueuing PUT's ambient trace context so the
        # writer thread's persist span stitches into the same trace
        ctx = obs.ctx() if obs is not None else None
        with self._lock:
            while len(self._q) >= self.max_depth and not self._stop:
                self._not_full.wait(timeout=0.1)
            self._q.append(_Task(key, data, on_done, seq=seq, ctx=ctx))
            self._pending[key] = data
            self.stats.enqueued += 1
            self.stats.peak_depth = max(self.stats.peak_depth,
                                        len(self._q) + self._inflight)
            self._not_empty.notify()

    # ---- read-your-writes / durability ------------------------------------

    def peek(self, key: str):
        """Payload of a not-yet-persisted write, or None."""
        with self._lock:
            return self._pending.get(key)

    def pending_keys(self, prefix: str = "") -> List[str]:
        with self._lock:
            return sorted(k for k in self._pending if k.startswith(prefix))

    @property
    def depth(self) -> int:
        with self._lock:
            return len(self._q) + self._inflight

    # ---- barriers ---------------------------------------------------------

    def flush(self, timeout: Optional[float] = None) -> bool:
        """Block until every enqueued write has been persisted or given
        up after max_retries. Returns True ONLY if everything actually
        persisted — False on timeout or if any write failed out during
        the barrier (check `errors()` / `stats.failures`)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        self.stats.flushes += 1
        with self._lock:
            failures_at_entry = self.stats.failures
            while self._q or self._inflight:
                if self._paused or self._thread is None:
                    # no writer will make progress: drain from this thread
                    self._lock.release()
                    try:
                        self._drain_some(16, ignore_backoff=True)
                    finally:
                        self._lock.acquire()
                    continue
                remaining = None if deadline is None \
                    else deadline - time.monotonic()
                if remaining is not None and remaining <= 0:
                    return False
                self._idle.wait(timeout=0.05 if remaining is None
                                else min(0.05, remaining))
            return self.stats.failures == failures_at_entry

    def drain(self, max_items: int = 32) -> int:
        """Synchronously persist up to max_items queued writes on the
        caller's thread (the gc_tick hook). Returns writes persisted."""
        return self._drain_some(max_items, ignore_backoff=False)

    # ---- test / lifecycle hooks -------------------------------------------

    def pause(self) -> None:
        """Stop background draining (tests use this to hold writes
        in-queue deterministically)."""
        with self._lock:
            self._paused = True

    def resume(self) -> None:
        with self._lock:
            self._paused = False
            self._not_empty.notify_all()

    def close(self, *, flush: bool = True,
              flush_timeout: Optional[float] = 30.0) -> bool:
        """Stop the writer. Returns the flush outcome: False means
        writes were left unpersisted (timeout or permanent failures) —
        callers that need durability must check it."""
        ok = True
        if flush:
            ok = self.flush(timeout=flush_timeout)
        with self._lock:
            self._stop = True
            self._not_empty.notify_all()
            self._not_full.notify_all()
        if self._thread is not None:
            self._thread.join(timeout=1.0)
        return ok

    def read_through(self, key: str):
        """Durability read path: the pending map first (acked, not yet
        persisted), then COS."""
        data = self.peek(key)
        if data is not None:
            return data
        return self.cos.get(key)

    def errors(self) -> List[str]:
        with self._lock:
            return list(self._errors)

    def health(self) -> dict:
        """Degradation/failure surface for `snapshot_metadata()["health"]`:
        distinguishes a queue that is merely deep (backpressure working)
        from one riding out a COS outage (DEGRADED_WRITEBACK) from one
        that has permanently failed writes (data-at-risk)."""
        with self._lock:
            degraded = self._degraded_since is not None
            return {
                "state": "DEGRADED_WRITEBACK" if degraded else "OK",
                "depth": len(self._q) + self._inflight,
                "consecutive_errors": self._consec_errors,
                "permanent_failures": self.stats.failures,
                "failed_keys": list(self._failed_keys),
                "degraded_since": self._degraded_since,
                "degraded_entries": self.stats.degraded_entries,
                "recoveries": self.stats.degraded_exits,
            }

    # ---- internals --------------------------------------------------------

    def _pop_task(self, ignore_backoff: bool) -> Optional[_Task]:
        """Pop the next runnable task under the lock; respects backoff
        gates by rotating not-yet-due tasks to the back."""
        if self._paused and not ignore_backoff:
            return None
        now = time.monotonic()
        for _ in range(len(self._q)):
            task = self._q.popleft()
            if ignore_backoff or task.not_before <= now:
                self._inflight += 1
                self._not_full.notify()
                return task
            self._q.append(task)                 # still backing off
        return None

    def _finalize(self, task: _Task, ok: bool,
                  exc: Optional[BaseException] = None) -> None:
        truncate = None
        with self._lock:
            self._inflight -= 1
            kind = None if ok else self.retry.classify(exc)
            degraded = self._degraded_since is not None
            # permanent = unretryable error class, or retry exhaustion
            # OUTSIDE an outage; during DEGRADED_WRITEBACK transient
            # failures never burn the budget (the outage is not this
            # write's fault)
            permanent = (not ok) and (
                kind == RetryPolicy.PERMANENT
                or (not degraded and task.attempts > self.max_retries))
            if ok or permanent:
                if ok:
                    self.stats.persisted += 1
                    # journal truncation on persistence; a PERMANENT
                    # failure keeps its record so a restart retries it
                    truncate = task.seq
                    self._consec_errors = 0
                    if degraded:                  # COS healed: auto-exit
                        self._degraded_since = None
                        self.stats.degraded_exits += 1
                        if self.obs is not None:
                            self.obs.event("wb.degraded_heal",
                                           key=task.key)
                else:
                    self.stats.failures += 1
                    self._errors.append(f"{task.key}: {exc!r}")
                    if len(self._errors) > 64:
                        del self._errors[:-64]
                    self._failed_keys.append(task.key)
                    if len(self._failed_keys) > 64:
                        del self._failed_keys[:-64]
                # drop from pending only if no NEWER write superseded it
                if self._pending.get(task.key) is task.data:
                    self._pending.pop(task.key, None)
                done = task.on_done
            else:
                self.stats.retries += 1
                if kind == RetryPolicy.THROTTLE:
                    self.stats.throttled += 1
                self._consec_errors += 1
                if not degraded \
                        and self._consec_errors >= self.degraded_after:
                    self._degraded_since = time.monotonic()
                    self.stats.degraded_entries += 1
                    degraded = True
                    if self.obs is not None:
                        self.obs.event("wb.degraded_enter",
                                       consecutive=self._consec_errors,
                                       key=task.key)
                if degraded:
                    # ride out the outage: reset the retry budget and
                    # probe COS at the backoff cap
                    task.attempts = 0
                    task.not_before = time.monotonic() + self.backoff_cap_s
                else:
                    task.not_before = time.monotonic() \
                        + self.retry.delay(task.attempts, kind)
                self._q.append(task)
                # wake the writer: it may be in an untimed wait (empty
                # queue) while this retry was produced by a drain() on
                # another thread — without the notify it never retries
                self._not_empty.notify()
                done = None
            if not self._q and not self._inflight:
                self._idle.notify_all()
        if truncate is not None and self.spill is not None:
            self.spill.mark_persisted(truncate)
        if done is not None:
            done(task.key, ok)

    def _run_one(self, task: _Task) -> None:
        with self._lock:
            # a newer write for the same key supersedes this one (e.g.
            # insertion-log snapshots reuse their key): persisting the
            # stale payload could overwrite the newer one in COS after a
            # retry reordering — drop it and let the newer task win
            superseded = self._pending.get(task.key) is not task.data
            if superseded:
                self._inflight -= 1
                self.stats.superseded += 1
                if not self._q and not self._inflight:
                    self._idle.notify_all()
        if superseded:
            if task.seq is not None and self.spill is not None:
                self.spill.mark_persisted(task.seq)   # logically dead
            if task.on_done is not None:
                task.on_done(task.key, True)
            return
        task.attempts += 1
        obs = self.obs
        t0 = time.perf_counter() if obs is not None else 0.0
        try:
            if self.faults is not None:
                self.faults.fire("writeback.persist", task.key)
            with (obs.adopt(task.ctx) if obs is not None else NOOP_CM):
                with (obs.span("wb.persist", key=task.key)
                      if obs is not None else NOOP_CM):
                    self.cos.put(task.key, task.data)
            if obs is not None:
                obs.record("wb.persist_us",
                           (time.perf_counter() - t0) * 1e6)
            self._finalize(task, True)
        except Exception as e:                   # noqa: BLE001
            self._finalize(task, False, e)

    def _drain_some(self, max_items: int, ignore_backoff: bool) -> int:
        n = 0
        while max_items is None or n < max_items:
            with self._lock:
                task = self._pop_task(ignore_backoff)
            if task is None:
                break
            self._run_one(task)
            n += 1
        return n

    def _writer_loop(self) -> None:
        while True:
            with self._lock:
                if self._stop:
                    return
                task = self._pop_task(ignore_backoff=False)
                if task is None:
                    # empty or paused: sleep until notified (enqueue /
                    # resume / close); tasks backing off: short timeout
                    # so their retry gate is re-checked
                    timeout = 0.02 if (self._q and not self._paused) \
                        else None
                    self._not_empty.wait(timeout=timeout)
                    continue
            self._run_one(task)
