"""Shard transport plane: the host's control/data plane as an interface.

`ProcessShardedStore` (host.py) drives each shard worker through a
`ShardTransport`.  Two implementations:

- `LocalTransport` — the PR-7 path: a duplex `Pipe` control plane plus
  two `ShmArena` shared-memory rings (request/response) for bulk
  payloads, a worker spawned from `host._worker_main`, and the process
  sentinel as the failure detector.  Epoch is fixed at 1 (a pipe cannot
  reconnect; worker death is final until `restart_shard`).

- `TcpTransport` — the networked path (the real InfiniStore runs its
  client<->proxy split over sockets, ports 6378/6379): RPCs and bulk
  payloads ride length-prefixed frames over a TCP connection to a
  `repro.core.netshard` worker.  The process sentinel is replaced by a
  heartbeat failure detector (`HeartbeatConfig`): pings every
  `interval_s`, CONNECTED -> SUSPECT after `suspect_after_s` without a
  pong, -> DOWN after `dead_after_s`.  A DOWN transport fails every
  in-flight RPC with `ShardWorkerDied` and starts a reconnect loop
  (capped exponential backoff, `RetryPolicy.delay` schedule).  Every
  (re)connection carries a monotonically increasing EPOCH: the worker
  fences connections whose epoch is not newer than its current one, and
  suppresses acks for RPCs that arrived under a previous epoch — a
  zombie worker or stale socket reappearing after a partition cannot
  ack RPCs from a previous incarnation.  Per-RPC deadlines
  (`rpc_deadline_s`) fail calls whose reply never arrives (dropped
  frame, silent partition) without waiting for the detector.

Wire format (TCP): `!IIQ` header — magic, control length, payload
length — followed by a pickled control tuple `(epoch, kind, rid, val)`
and an out-of-band payload section of concatenated raw bytes.  Bulk
values never ride the pickle: request descriptors `("o", off, nbytes)`
point into the frame's payload section, mirroring the arena descriptors
`("a", pos, nbytes)` of the shm path ("i" = inline bytes, "n" = None).
Frames are pickled between mutually-trusting processes of ONE host
deployment — do not expose the listener beyond a trusted network.

Deterministic network chaos: `TcpTransport` fires four `FaultPlan`
sites on every outbound frame, keyed `op:<op>:s<shard>` for data and
`hb:s<shard>` for heartbeats —

    site            action       effect
    --------------  -----------  ----------------------------------
    net.delay       "delay"      sleeps the point's latency_s before
                                 the frame is written
    net.partition   "partition"  blackholes BOTH directions for
                                 `HeartbeatConfig.partition_s` (the
                                 triggering frame is lost; reconnect
                                 attempts fail until the heal)
    net.drop        "drop"       the frame is silently dropped
    net.dup         "dup"        the frame is sent twice (the worker
                                 dedupes by monotonic rid)

Schedules that run alongside heartbeats must use `match` filters (e.g.
``match="op:put:"``): an unmatched fire() consumes no hit index, so the
nondeterministic ping stream cannot shift the data-op schedule.
"""
from __future__ import annotations

import logging
import pickle
import socket
import struct
import threading
import time
from dataclasses import dataclass
from typing import Callable, List, Optional, Tuple

from .faults import RetryPolicy
from .ipc import ShmArena, pack_payload
from .locks import make_lock
from .payload import as_u8

__all__ = [
    "ShardWorkerDied", "HeartbeatConfig", "ShardTransport",
    "LocalTransport", "TcpTransport", "FrameError",
    "CONNECTED", "SUSPECT", "DOWN", "RECONNECTING",
    "send_frame", "recv_frame",
]

_LOG = logging.getLogger("repro.transport")

# transport states (snapshot_metadata()["health"]["transport"]["state"])
CONNECTED = "CONNECTED"
SUSPECT = "SUSPECT"
DOWN = "DOWN"
RECONNECTING = "RECONNECTING"


class ShardWorkerDied(ConnectionError):
    """A shard's worker is unreachable with RPCs outstanding (or a new
    RPC was issued against a dead/partitioned worker): process death,
    pipe EOF, socket reset, heartbeat timeout, or per-RPC deadline —
    every transport-level failure maps here, on every frontend.  The
    shard's durable state (spill journal, COS root) is intact;
    `restart_shard` (or a transport reconnect) rebuilds the path.
    Carries the failure context: `shard_id`, the transport `epoch` at
    failure time, and the `op` that failed (None when not op-bound)."""

    def __init__(self, msg: str = "", *, shard_id: Optional[int] = None,
                 epoch: Optional[int] = None,
                 op: Optional[str] = None) -> None:
        super().__init__(msg)
        self.shard_id = shard_id
        self.epoch = epoch
        self.op = op

    def __reduce__(self):
        return (self.__class__, (str(self),),
                {"shard_id": self.shard_id, "epoch": self.epoch,
                 "op": self.op})


@dataclass(frozen=True)
class HeartbeatConfig:
    """Failure-detector + reconnect knobs for `TcpTransport`.

    Defaults are deliberately lazy (10s to DOWN): a busy single-core
    box can starve the worker's reply thread for whole seconds, and a
    false DOWN costs a reconnect epoch.  Tests and the chaos soak run
    much hotter (50ms pings, sub-second death)."""
    interval_s: float = 0.5          # ping period
    suspect_after_s: float = 2.0     # no pong for this long -> SUSPECT
    dead_after_s: float = 10.0       # no pong for this long -> DOWN
    connect_timeout_s: float = 10.0  # bound on connect()+hello/welcome
    rpc_deadline_s: Optional[float] = None   # per-RPC reply deadline
    reconnect: bool = True
    reconnect_max_attempts: int = 8
    reconnect_backoff_base_s: float = 0.05
    reconnect_backoff_cap_s: float = 1.0
    partition_s: float = 1.0         # injected net.partition duration


# ---------------------------------------------------------------------------
# TCP framing
# ---------------------------------------------------------------------------

MAGIC = 0x49535452                   # "ISTR"
_HDR = struct.Struct("!IIQ")         # magic, ctrl_len, payload_len


class FrameError(ConnectionError):
    """The TCP stream closed or desynchronized mid-frame."""


def send_frame(sock: socket.socket, ctrl: tuple,
               bufs: Tuple[bytes, ...] = ()) -> None:
    """One frame: header + pickled control tuple + payload section."""
    cb = pickle.dumps(ctrl, protocol=pickle.HIGHEST_PROTOCOL)
    pl = b"".join(bufs)
    sock.sendall(_HDR.pack(MAGIC, len(cb), len(pl)) + cb + pl)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    bufs: List[bytes] = []
    while n:
        b = sock.recv(min(n, 1 << 20))
        if not b:
            raise FrameError("connection closed mid-frame")
        bufs.append(b)
        n -= len(b)
    return b"".join(bufs)


def recv_frame(sock: socket.socket) -> Tuple[tuple, bytes]:
    """Returns (control tuple, payload bytes)."""
    magic, cl, pl = _HDR.unpack(_recv_exact(sock, _HDR.size))
    if magic != MAGIC:
        raise FrameError(f"bad frame magic {magic:#x}")
    ctrl = pickle.loads(_recv_exact(sock, cl))
    payload = _recv_exact(sock, pl) if pl else b""
    return ctrl, payload


def _close_sock(s: Optional[socket.socket]) -> None:
    if s is None:
        return
    try:
        s.shutdown(socket.SHUT_RDWR)
    except OSError:
        pass
    try:
        s.close()
    except OSError:
        pass


def _reap_process(proc, deadline: Optional[float]) -> None:
    """Escalating join -> terminate -> kill, bounded by `deadline`."""
    if proc is None:
        return
    try:
        if proc.is_alive():
            budget = 10.0 if deadline is None \
                else max(0.5, deadline - time.monotonic())
            proc.join(timeout=budget)
            if proc.is_alive():
                proc.terminate()
                proc.join(timeout=5.0)
            if proc.is_alive():                       # pragma: no cover
                proc.kill()
                proc.join(timeout=5.0)
    except (ValueError, OSError):
        pass                         # never started / already reaped
    try:
        proc.close()
    except (ValueError, AttributeError):
        pass


# ---------------------------------------------------------------------------
# the interface
# ---------------------------------------------------------------------------

class ShardTransport:
    """Control/data plane to ONE shard worker.

    Lifecycle: `start(on_message=..., on_down=..., ...)` boots the
    worker (or connects to one) and returns its pid; `reap(deadline)`
    tears everything down.  Data plane: `pack(value)` stages one bulk
    payload and returns its descriptor (call under the proxy's order
    lock; `send` flushes the staging), `send((op, rid, payload))`
    transmits one RPC, `reply_view`/`ack_reply` service arena-backed
    reply descriptors.  Callbacks: `on_message((kind, rid, val))` for
    every reply, `on_down(exc)` when the worker becomes unreachable,
    `on_reconnect(epoch)` after a successful re-handshake, `on_tick()`
    every detector interval (the proxy expires RPC deadlines there)."""

    kind = "abstract"

    shard_id: int
    epoch: int = 1
    state: str = DOWN
    pid: Optional[int] = None

    def start(self, *, on_message: Callable, on_down: Callable,
              on_reconnect: Optional[Callable] = None,
              on_tick: Optional[Callable] = None) -> Optional[int]:
        raise NotImplementedError

    def send(self, msg: tuple) -> None:
        raise NotImplementedError

    def pack(self, value):
        raise NotImplementedError

    def discard_staged(self) -> None:
        """Drop payloads staged by `pack` when the RPC failed pre-send
        (keeps the out-of-band offsets of the NEXT frame correct)."""

    def reply_view(self, pos: int, n: int):
        raise NotImplementedError(f"{self.kind} has no reply arena")

    def ack_reply(self, watermark: int) -> None:
        """Acknowledge consumption of arena-backed reply bytes."""

    def default_rpc_deadline(self) -> Optional[float]:
        return None

    def suppress_reconnect(self) -> None:
        """Stop trying to resurrect the connection (expected death)."""

    def join(self, timeout: float) -> None:
        """Wait for an owned worker process to exit."""

    def health(self) -> dict:
        raise NotImplementedError

    def reap(self, deadline: Optional[float] = None) -> None:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# LocalTransport: pipe control plane + ShmArena data plane (PR-7 path)
# ---------------------------------------------------------------------------

class LocalTransport(ShardTransport):
    """Pipe + shared-memory rings to a `host._worker_main` process on
    this machine.  Failure detection is the process sentinel; there is
    no reconnect (epoch stays 1) — a dead worker is rebuilt by
    `restart_shard`, which replays the shard's journal."""

    kind = "shm"

    def __init__(self, *, ctx, shard_id: int, spec: dict,
                 arena_bytes: int, boot_timeout_s: float) -> None:
        self.shard_id = shard_id
        self.epoch = 1
        self.state = DOWN
        self.pid = None
        self._ctx = ctx
        self._spec = spec
        self._arena_bytes = int(arena_bytes)
        self._boot_timeout_s = float(boot_timeout_s)
        self._send_lock = make_lock("transport.LocalTransport._send_lock")
        self._req: Optional[ShmArena] = None
        self._resp: Optional[ShmArena] = None
        self._conn = None
        self._proc = None
        self._closing = False
        self._on_message: Optional[Callable] = None
        self._on_down: Optional[Callable] = None

    def start(self, *, on_message, on_down, on_reconnect=None,
              on_tick=None) -> Optional[int]:
        from . import host              # lazy: host imports this module
        self._on_message = on_message
        self._on_down = on_down
        self._req = ShmArena.create(self._arena_bytes,
                                    tag=f"req{self.shard_id}")
        self._resp = ShmArena.create(self._arena_bytes,
                                     tag=f"resp{self.shard_id}")
        parent_conn, child_conn = self._ctx.Pipe()
        self._conn = parent_conn
        spec = dict(self._spec, req_name=self._req.name,
                    resp_name=self._resp.name,
                    arena_bytes=self._arena_bytes, conn=child_conn)
        self._proc = self._ctx.Process(
            target=host._worker_main, args=(spec,), daemon=True,
            name=f"infinistore-shard-{self.shard_id}")
        self._proc.start()
        child_conn.close()
        if not parent_conn.poll(self._boot_timeout_s):
            raise ShardWorkerDied(
                f"shard {self.shard_id} worker failed to boot within "
                f"{self._boot_timeout_s}s", shard_id=self.shard_id,
                epoch=self.epoch, op="boot")
        try:
            kind, _rid, val = parent_conn.recv()
        except (EOFError, OSError) as e:
            raise ShardWorkerDied(
                f"shard {self.shard_id} worker died during boot (spawn "
                "re-imports __main__: guard scripts with "
                "if __name__ == '__main__')", shard_id=self.shard_id,
                epoch=self.epoch, op="boot") from e
        if kind == "err":
            raise val if isinstance(val, BaseException) \
                else ShardWorkerDied(str(val), shard_id=self.shard_id,
                                     epoch=self.epoch, op="boot")
        self.pid = val
        self.state = CONNECTED
        threading.Thread(target=self._read_loop, daemon=True,
                         name=f"shard-host-rx-{self.shard_id}").start()
        return self.pid

    # -- reader thread -----------------------------------------------------

    def _read_loop(self) -> None:
        from multiprocessing import connection as mpc
        conn, sentinel = self._conn, self._proc.sentinel
        while True:
            try:
                ready = mpc.wait([conn, sentinel])
            except OSError:
                break
            if conn in ready:
                try:
                    msg = conn.recv()
                except (EOFError, OSError):
                    break
                self._deliver(msg)
            elif sentinel in ready:
                # the process died: drain replies already buffered,
                # then fail what's left
                try:
                    while conn.poll(0):
                        self._deliver(conn.recv())
                except (EOFError, OSError):
                    pass
                break
        self._mark_dead()

    def _deliver(self, msg) -> None:
        kind, _rid, val = msg
        if kind == "rel":                # request-ring watermark ack
            self._req.release_to(val)
            return
        self._on_message(msg)

    def _mark_dead(self) -> None:
        self.state = DOWN
        exc = ShardWorkerDied(
            f"shard {self.shard_id} worker (pid {self.pid}) died",
            shard_id=self.shard_id, epoch=self.epoch)
        if self._req is not None:
            self._req.fail(exc)
        if self._resp is not None:
            self._resp.fail(exc)
        if self._on_down is not None:
            self._on_down(exc)

    # -- data plane ----------------------------------------------------------

    def send(self, msg: tuple) -> None:
        with self._send_lock:
            try:
                # lint: allow(blocking-under-lock): _send_lock's critical section IS the pipe write
                self._conn.send(msg)
            except (OSError, ValueError, BrokenPipeError) as e:
                raise ShardWorkerDied(
                    f"shard {self.shard_id} worker pipe broken",
                    shard_id=self.shard_id, epoch=self.epoch,
                    op=msg[0]) from e

    def pack(self, value):
        return pack_payload(self._req, value)

    def reply_view(self, pos: int, n: int):
        return self._resp.view(pos, n)

    def ack_reply(self, watermark: int) -> None:
        with self._send_lock:
            try:
                # lint: allow(blocking-under-lock): release watermark shares the serialized pipe write
                self._conn.send(("release", 0, watermark))
            except (OSError, ValueError, BrokenPipeError):
                pass

    # -- lifecycle -----------------------------------------------------------

    def join(self, timeout: float) -> None:
        if self._proc is not None:
            self._proc.join(timeout=timeout)

    def health(self) -> dict:
        return {"kind": self.kind, "state": self.state,
                "epoch": self.epoch, "last_heartbeat_age_s": None,
                "reconnects": 0, "pid": self.pid, "addr": None}

    def reap(self, deadline: Optional[float] = None) -> None:
        self._closing = True
        # tell the worker to exit BEFORE closing the pipe: recv-EOF
        # delivery is not reliable on this transport, so a healthy
        # worker leaves on the explicit "bye" and the join below
        # returns immediately instead of burning the budget
        if self._conn is not None:
            with self._send_lock:
                try:
                    # lint: allow(blocking-under-lock): final 'bye' shares the serialized pipe write
                    self._conn.send(("bye", 0, None))
                except (OSError, ValueError, BrokenPipeError):
                    pass             # worker already gone
            try:
                self._conn.close()
            except OSError:
                pass
        _reap_process(self._proc, deadline)
        self.state = DOWN
        exc = ShardWorkerDied(
            f"shard {self.shard_id} worker reaped",
            shard_id=self.shard_id, epoch=self.epoch)
        for arena in (self._req, self._resp):
            if arena is not None:
                arena.fail(exc)
                arena.close()        # owner: close + unlink


# ---------------------------------------------------------------------------
# TcpTransport: framed sockets + heartbeat detector + epoch fencing
# ---------------------------------------------------------------------------

class TcpTransport(ShardTransport):
    """Framed RPCs over a loopback/LAN socket to a `netshard` worker
    (module docstring).  `spec` spawns a worker through `ctx`; `addr`
    instead attaches to one that is already listening (tests, off-box
    deployment)."""

    kind = "tcp"

    def __init__(self, *, shard_id: int, ctx=None,
                 spec: Optional[dict] = None,
                 addr: Optional[Tuple[str, int]] = None,
                 hb: Optional[HeartbeatConfig] = None,
                 boot_timeout_s: float = 120.0,
                 faults=None, seed: int = 0) -> None:
        if spec is None and addr is None:
            raise ValueError("TcpTransport needs a worker spec or addr")
        self.shard_id = shard_id
        self.epoch = 0                   # first connect makes it 1
        self.state = DOWN
        self.pid = None
        self.hb = hb or HeartbeatConfig()
        self.reconnects = 0
        self.stale_frames_dropped = 0
        self._ctx = ctx
        self._spec = spec
        self._addr = addr
        self._boot_timeout_s = float(boot_timeout_s)
        self._faults = faults
        # parent-side ObsPlane (attached by _ShardProxy): heartbeat-age
        # samples + SUSPECT/DOWN/reconnect flight events. None = free.
        self.obs = None
        self._backoff = RetryPolicy(
            max_attempts=self.hb.reconnect_max_attempts,
            backoff_base_s=self.hb.reconnect_backoff_base_s,
            backoff_cap_s=self.hb.reconnect_backoff_cap_s, seed=seed)
        self._lock = make_lock("transport.TcpTransport._lock")    # sock/epoch/state/last_pong
        self._send_lock = make_lock("transport.TcpTransport._send_lock")
        self._conn_lock = make_lock("transport.TcpTransport._conn_lock")   # one (re)connect at a time
        self._sock: Optional[socket.socket] = None
        self._last_pong: Optional[float] = None
        self._partition_until = 0.0
        self._out_bufs: List[bytes] = []
        self._out_len = 0
        self._pings = 0
        self._suppress = False
        self._closing = False
        self._boot = None
        self._proc = None
        self._hb_stop = threading.Event()
        self._on_message: Optional[Callable] = None
        self._on_down: Optional[Callable] = None
        self._on_reconnect: Optional[Callable] = None
        self._on_tick: Optional[Callable] = None

    # -- boot ----------------------------------------------------------------

    def start(self, *, on_message, on_down, on_reconnect=None,
              on_tick=None) -> Optional[int]:
        self._on_message = on_message
        self._on_down = on_down
        self._on_reconnect = on_reconnect
        self._on_tick = on_tick
        if self._addr is None:
            from . import netshard      # lazy: netshard imports host
            parent_conn, child_conn = self._ctx.Pipe()
            self._boot = parent_conn
            spec = dict(self._spec, conn=child_conn)
            self._proc = self._ctx.Process(
                target=netshard._net_worker_main, args=(spec,),
                daemon=True,
                name=f"infinistore-netshard-{self.shard_id}")
            self._proc.start()
            child_conn.close()
            if not parent_conn.poll(self._boot_timeout_s):
                raise ShardWorkerDied(
                    f"shard {self.shard_id} net worker failed to boot "
                    f"within {self._boot_timeout_s}s",
                    shard_id=self.shard_id, epoch=0, op="boot")
            try:
                kind, _rid, val = parent_conn.recv()
            except (EOFError, OSError) as e:
                raise ShardWorkerDied(
                    f"shard {self.shard_id} net worker died during "
                    "boot", shard_id=self.shard_id, epoch=0,
                    op="boot") from e
            if kind == "err":
                raise val if isinstance(val, BaseException) \
                    else ShardWorkerDied(str(val),
                                         shard_id=self.shard_id,
                                         epoch=0, op="boot")
            self.pid, port = val
            self._addr = ("127.0.0.1", port)
        self._connect(self.hb.connect_timeout_s)
        threading.Thread(target=self._hb_loop, daemon=True,
                         name=f"shard-hb-{self.shard_id}").start()
        return self.pid

    # -- connection management ----------------------------------------------

    def _connect(self, timeout: float) -> None:
        """One bounded connect + hello/welcome handshake at epoch+1.
        Every path through here is covered by `timeout` (socket-level),
        so `close()`/`restart_shard` against a half-connected worker
        cannot hang past their own deadline."""
        with self._conn_lock:
            if time.monotonic() < self._partition_until:
                raise ShardWorkerDied(
                    f"shard {self.shard_id} is partitioned",
                    shard_id=self.shard_id, epoch=self.epoch,
                    op="connect")
            ep = self.epoch + 1
            try:
                # lint: allow(blocking-under-lock): _conn_lock serializes connect+handshake; bounded by connect timeout
                s = socket.create_connection(self._addr, timeout=timeout)
            except OSError as e:
                raise ShardWorkerDied(
                    f"shard {self.shard_id} connect to {self._addr} "
                    f"failed: {e}", shard_id=self.shard_id, epoch=ep,
                    op="connect") from e
            try:
                s.settimeout(timeout)
                s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                # lint: allow(blocking-under-lock): handshake frame under _conn_lock; socket timeout bounds it
                send_frame(s, (ep, "hello", 0, None))
                # lint: allow(blocking-under-lock): handshake reply under _conn_lock; socket timeout bounds it
                ctrl, _ = recv_frame(s)
                _fep, kind, _rid, val = ctrl
                if kind != "welcome":
                    raise FrameError(f"handshake rejected: {kind!r}")
                s.settimeout(None)
            except (OSError, FrameError, pickle.PickleError) as e:
                _close_sock(s)
                raise ShardWorkerDied(
                    f"shard {self.shard_id} handshake at epoch {ep} "
                    f"failed: {e}", shard_id=self.shard_id, epoch=ep,
                    op="connect") from e
            if self.pid is None:
                self.pid = val
            with self._lock:
                old, self._sock = self._sock, s
                self.epoch = ep
                self._last_pong = time.monotonic()
                self.state = CONNECTED
            _close_sock(old)
            threading.Thread(target=self._read_loop, args=(s, ep),
                             daemon=True,
                             name=f"shard-rx-{self.shard_id}").start()

    def _read_loop(self, sock: socket.socket, ep: int) -> None:
        while True:
            try:
                ctrl, payload = recv_frame(sock)
            except (OSError, FrameError, pickle.PickleError,
                    EOFError):
                break
            with self._lock:
                current = sock is self._sock
                cur_epoch = self.epoch
            if not current:
                break                    # superseded by a newer epoch
            if time.monotonic() < self._partition_until:
                continue                 # blackhole inbound too
            fep, kind, rid, val = ctrl
            if fep != cur_epoch:
                self.stale_frames_dropped += 1
                continue
            if kind == "pong":
                with self._lock:
                    self._last_pong = time.monotonic()
                continue
            if kind == "val":
                val = _resolve_frame_descs(val, payload)
            self._on_message((kind, rid, val))
        with self._lock:
            current = sock is self._sock
        if current and not self._closing:
            self._declare_down("connection lost")

    def _declare_down(self, why: str) -> None:
        with self._lock:
            if self.state in (DOWN, RECONNECTING):
                return
            self.state = DOWN
            sock, self._sock = self._sock, None
        _close_sock(sock)
        obs = self.obs
        if obs is not None:
            obs.event("transport.down", shard=self.shard_id,
                      epoch=self.epoch, why=why)
        exc = ShardWorkerDied(
            f"shard {self.shard_id} worker unreachable at epoch "
            f"{self.epoch}: {why}", shard_id=self.shard_id,
            epoch=self.epoch)
        if self._on_down is not None:
            self._on_down(exc)
        if self.hb.reconnect and not self._suppress \
                and not self._closing:
            with self._lock:
                self.state = RECONNECTING
            threading.Thread(target=self._reconnect_loop, daemon=True,
                             name=f"shard-reconn-{self.shard_id}"
                             ).start()

    def _reconnect_loop(self) -> None:
        for attempt in range(1, self.hb.reconnect_max_attempts + 1):
            if self._closing or self._suppress:
                break
            time.sleep(self._backoff.delay(attempt))
            if self._closing or self._suppress:
                break
            try:
                self._connect(self.hb.connect_timeout_s)
            except ShardWorkerDied:
                continue
            self.reconnects += 1
            _LOG.info("shard %d reconnected at epoch %d (attempt %d)",
                      self.shard_id, self.epoch, attempt)
            obs = self.obs
            if obs is not None:
                obs.event("transport.reconnect", shard=self.shard_id,
                          epoch=self.epoch, attempt=attempt)
            if self._on_reconnect is not None:
                self._on_reconnect(self.epoch)
            return
        with self._lock:
            if self.state == RECONNECTING:
                self.state = DOWN    # permanent until restart_shard

    # -- heartbeat loop ------------------------------------------------------

    def _hb_loop(self) -> None:
        hb = self.hb
        while not self._hb_stop.wait(hb.interval_s):
            if self._closing:
                break
            if self._on_tick is not None:
                self._on_tick()      # proxy expires RPC deadlines
            with self._lock:
                state = self.state
                last = self._last_pong
            if state in (DOWN, RECONNECTING):
                continue             # the reconnect loop owns recovery
            self._pings += 1
            try:
                self._transmit("ping", self._pings, None, (),
                               f"hb:s{self.shard_id}")
            except ShardWorkerDied:
                pass                 # the reader declares the down
            age = time.monotonic() - (last or 0.0)
            obs = self.obs
            if obs is not None and last is not None:
                obs.record("transport.heartbeat_age_us", age * 1e6)
            if age > hb.dead_after_s:
                self._declare_down(f"heartbeat timeout ({age:.2f}s "
                                   f"since last pong)")
            elif age > hb.suspect_after_s:
                with self._lock:
                    became_suspect = self.state == CONNECTED
                    if became_suspect:
                        self.state = SUSPECT
                if became_suspect and obs is not None:
                    obs.event("transport.suspect", shard=self.shard_id,
                              epoch=self.epoch, age_s=round(age, 3))
            else:
                with self._lock:
                    if self.state == SUSPECT:
                        self.state = CONNECTED

    # -- data plane ----------------------------------------------------------

    def pack(self, value):
        u8 = as_u8(value)
        raw = u8.tobytes()
        off = self._out_len
        self._out_bufs.append(raw)
        self._out_len += len(raw)
        return ("o", off, len(raw))

    def discard_staged(self) -> None:
        self._out_bufs = []
        self._out_len = 0

    def send(self, msg: tuple) -> None:
        op, rid, payload = msg
        bufs, self._out_bufs, self._out_len = self._out_bufs, [], 0
        self._transmit(op, rid, payload, tuple(bufs),
                       f"op:{op}:s{self.shard_id}")

    def _transmit(self, kind: str, rid: int, val, bufs, key: str) -> None:
        if time.monotonic() < self._partition_until:
            return                   # blackholed: the frame is lost
        f = self._faults
        dup = False
        if f is not None:
            f.fire("net.delay", key)             # latency inside fire()
            if f.fire("net.partition", key) == "partition":
                self._partition_until = \
                    time.monotonic() + self.hb.partition_s
                return               # the triggering frame is lost too
            if f.fire("net.drop", key) == "drop":
                return
            dup = f.fire("net.dup", key) == "dup"
        with self._lock:
            sock, ep = self._sock, self.epoch
        if sock is None:
            raise ShardWorkerDied(
                f"shard {self.shard_id} transport is down",
                shard_id=self.shard_id, epoch=ep, op=kind)
        ctrl = (ep, kind, rid, val)
        try:
            with self._send_lock:
                # lint: allow(blocking-under-lock): _send_lock's critical section IS the frame write
                send_frame(sock, ctrl, bufs)
                if dup:
                    # lint: allow(blocking-under-lock): fault-injected dup frame shares the serialized write
                    send_frame(sock, ctrl, bufs)
        except OSError as e:
            raise ShardWorkerDied(
                f"shard {self.shard_id} socket send failed ({kind}): "
                f"{e}", shard_id=self.shard_id, epoch=ep,
                op=kind) from e

    def default_rpc_deadline(self) -> Optional[float]:
        return self.hb.rpc_deadline_s

    # -- test / chaos hooks --------------------------------------------------

    def _force_partition(self, duration_s: float) -> None:
        """Blackhole both directions for `duration_s` (tests)."""
        self._partition_until = time.monotonic() + duration_s

    # -- lifecycle -----------------------------------------------------------

    def suppress_reconnect(self) -> None:
        self._suppress = True

    def join(self, timeout: float) -> None:
        if self._proc is not None:
            self._proc.join(timeout=timeout)

    def health(self) -> dict:
        with self._lock:
            age = None if self._last_pong is None \
                else max(0.0, time.monotonic() - self._last_pong)
            return {"kind": self.kind, "state": self.state,
                    "epoch": self.epoch,
                    "last_heartbeat_age_s": age,
                    "reconnects": self.reconnects,
                    "stale_frames_dropped": self.stale_frames_dropped,
                    "pid": self.pid, "addr": self._addr}

    def reap(self, deadline: Optional[float] = None) -> None:
        self._closing = True
        self._suppress = True
        self._hb_stop.set()
        if self._boot is not None:
            try:
                self._boot.send(("bye", 0, None))
            except (OSError, ValueError, BrokenPipeError):
                pass
            try:
                self._boot.close()
            except OSError:
                pass
        with self._lock:
            sock, self._sock = self._sock, None
            self.state = DOWN
        _close_sock(sock)
        _reap_process(self._proc, deadline)


def _resolve_frame_descs(val, payload: bytes):
    """Materialize out-of-band reply descriptors `("o", off, n)` against
    the frame's payload section, yielding the inline form the proxy's
    desc handlers already speak.  `val` is one descriptor or a
    {key: descriptor} map (get_many); everything else passes through."""
    def one(d):
        if isinstance(d, tuple) and d and d[0] == "o":
            _, off, n = d
            return ("i", payload[off:off + n])
        return d
    if isinstance(val, dict):
        return {k: one(d) for k, d in val.items()}
    return one(val)
