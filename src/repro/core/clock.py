"""Logical/wall clock shared by the storage components.

Tests and trace replays drive a logical clock deterministically; the
serving engine can run it off wall time. All InfiniStore components
(GC window, COS visibility lag, cost model, warmup scheduling) read the
same clock so behaviour is reproducible.
"""
from __future__ import annotations

import threading
import time


class Clock:
    def __init__(self, *, wall: bool = False):
        self._wall = wall
        self._t = 0.0
        self._lock = threading.Lock()

    def now(self) -> float:
        if self._wall:
            return time.monotonic()
        with self._lock:
            return self._t

    def advance(self, dt: float) -> float:
        if self._wall:
            raise RuntimeError("cannot advance a wall clock")
        with self._lock:
            self._t += dt
            return self._t

    @property
    def is_wall(self) -> bool:
        return self._wall
