"""Named lock factory with an opt-in runtime lock-order witness.

Every lock in the concurrency-bearing core modules is created through
`make_lock` / `make_rlock` with a stable dotted name
(``module.Class.attr``).  In normal operation the factory returns a
plain ``threading.Lock`` / ``threading.RLock`` — zero wrapper, zero
per-acquisition overhead.  When a witness is installed (programmatic
`install_witness`, or ``ISTORE_LOCK_WITNESS=1`` in the environment at
first lock creation) each factory call instead returns a thin proxy
that reports acquisitions and releases to the witness, which checks the
observed acquisition order against the statically derived lock
hierarchy (`repro.devtools.lockgraph`) and records any inversion.

The names passed to the factory are the SAME node names the static
analyzer derives (`python -m repro.devtools.lint src/repro
--emit-hierarchy ...`), which is what lets the runtime witness and the
static model cross-validate: `repro.devtools.lint` checks the
literal matches the defining ``module.Class.attr`` site, so the two
views cannot drift.

Witness installation only affects locks created AFTER the install —
install one before constructing the stores under test (the conformance
suite and ``benchmarks/fault_soak.py`` do exactly that).
"""
from __future__ import annotations

import os
import threading
from typing import Optional

__all__ = ["make_lock", "make_rlock", "install_witness", "current_witness"]

_witness = None
_env_checked = False


def install_witness(witness) -> None:
    """Install (or with None, remove) the process-global lock witness.

    `witness` must provide ``on_acquire(name)`` / ``on_release(name)``
    — normally a `repro.devtools.witness.LockWitness`.
    """
    global _witness, _env_checked
    _witness = witness
    _env_checked = True          # explicit install overrides the env path


def current_witness():
    return _witness


def _active_witness():
    global _env_checked, _witness
    if not _env_checked:
        _env_checked = True
        if _witness is None and os.environ.get("ISTORE_LOCK_WITNESS"):
            # Lazy import: devtools is pure-stdlib AST analysis; core
            # never pays for it unless the witness is switched on.
            from repro.devtools.witness import LockWitness
            _witness = LockWitness.with_static_order()
    return _witness


class _WitnessedLock:
    """Transparent proxy reporting acquire/release to the witness.

    Unknown attributes delegate to the inner lock so
    ``threading.Condition`` works over both flavors: an RLock exposes
    ``_release_save``/``_acquire_restore``/``_is_owned`` (delegated,
    bypassing the witness for the wait-window release — the thread
    still logically holds the lock), while a plain Lock raises
    AttributeError and Condition falls back to ``acquire``/``release``
    through this proxy.
    """

    __slots__ = ("_inner", "name", "_w")

    def __init__(self, inner, name: str, witness):
        self._inner = inner
        self.name = name
        self._w = witness

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        got = self._inner.acquire(blocking, timeout)
        if got:
            self._w.on_acquire(self.name)
        return got

    def release(self) -> None:
        self._inner.release()
        self._w.on_release(self.name)

    def locked(self) -> bool:
        return self._inner.locked()

    def __enter__(self) -> "_WitnessedLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> bool:
        self.release()
        return False

    def __getattr__(self, item):
        return getattr(self._inner, item)

    def __repr__(self) -> str:
        return f"<witnessed {self._inner!r} name={self.name!r}>"


def make_lock(name: str):
    """A ``threading.Lock`` (or witnessed proxy) named for the witness."""
    w = _active_witness()
    inner = threading.Lock()
    return inner if w is None else _WitnessedLock(inner, name, w)


def make_rlock(name: str):
    """A ``threading.RLock`` (or witnessed proxy) named for the witness."""
    w = _active_witness()
    inner = threading.RLock()
    return inner if w is None else _WitnessedLock(inner, name, w)
